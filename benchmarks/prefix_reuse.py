"""Prefix-cache benchmark: shared-system-prompt and multi-turn traces,
cache-enabled vs cache-disabled, at exact token parity.

Measures the one number that matters — **prefill compute** (padded token
positions run through the prefill program, a machine-independent FLOP proxy:
every padded position costs the same per-layer work) — plus wall time and
hit rates for context, then drives a 2-replica fleet over a shared-prefix
trace to show router prefix affinity and per-replica hit rates end-to-end.

ASSERTS (the paper's lean-invocation claim, made falsifiable):
  * >= 2x prefill-compute reduction on the shared-prefix trace,
  * byte-identical token streams with the cache on vs off,
  * nonzero router prefix-affinity routes and per-replica hits in the fleet.

Writes machine-readable results to ``BENCH_prefix.json`` (``--out``).

    PYTHONPATH=src python benchmarks/prefix_reuse.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro import configs
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine


def _shared_prefix_stream(vocab: int, *, requests: int, prefix_len: int,
                          tail_lo: int, tail_hi: int, max_new: int,
                          seed: int = 0):
    """The canonical serving workload: one system prompt, many user tails."""
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, vocab, (prefix_len,), dtype=np.int32)
    out = []
    for i in range(requests):
        tail = rng.integers(0, vocab, (int(rng.integers(tail_lo, tail_hi + 1)),),
                            dtype=np.int32)
        out.append((np.concatenate([sys_prompt, tail]), max_new))
    return out


def _multi_turn_stream(vocab: int, *, sessions: int, turns: int,
                       turn_len: int, max_new: int, seed: int = 1):
    """Conversations: each turn's prompt extends the previous turn's."""
    rng = np.random.default_rng(seed)
    out = []
    for s in range(sessions):
        hist = rng.integers(0, vocab, (turn_len,), dtype=np.int32)
        out.append((hist, max_new))
        for _ in range(turns - 1):
            hist = np.concatenate(
                [hist, rng.integers(0, vocab, (turn_len,), dtype=np.int32)])
            out.append((hist, max_new))
    return out


def bench_engine(cfg, params, stream, *, cache_bytes, slots, max_len,
                 buckets) -> dict:
    engine = ServingEngine(cfg, params, slots=slots, max_len=max_len,
                           prompt_buckets=buckets,
                           prefix_cache_bytes=cache_bytes)
    engine.warmup()
    warm = dict(engine.stats)
    t0 = time.perf_counter()
    for i, (prompt, max_new) in enumerate(stream):
        engine.submit(Request(request_id=i, prompt=prompt,
                              max_new_tokens=max_new))
        engine.run_to_completion()  # arrival order preserved (multi-turn)
    wall = time.perf_counter() - t0
    res = {k: engine.results[k].tokens for k in sorted(engine.results)}
    hits, misses = engine.stats["prefix_hits"], engine.stats["prefix_misses"]
    return {
        "mode": "cached" if cache_bytes else "uncached",
        "wall_s": round(wall, 4),
        "tokens": sum(len(t) for t in res.values()),
        "prefill_tokens": engine.stats["prefill_tokens"] - warm["prefill_tokens"],
        "prefill_calls": engine.stats["prefill_calls"] - warm["prefill_calls"],
        "prefix_hits": hits,
        "prefix_misses": misses,
        "hit_rate": round(hits / max(hits + misses, 1), 4),
        "prefix_hit_tokens": engine.stats["prefix_hit_tokens"],
        "cache": engine.prefix_cache.report() if engine.prefix_cache else None,
        "results": res,
    }


def bench_scenario(name, cfg, params, stream, *, slots, max_len, buckets,
                   cache_bytes) -> dict:
    off = bench_engine(cfg, params, stream, cache_bytes=None, slots=slots,
                       max_len=max_len, buckets=buckets)
    on = bench_engine(cfg, params, stream, cache_bytes=cache_bytes,
                      slots=slots, max_len=max_len, buckets=buckets)
    assert on["results"] == off["results"], (
        f"{name}: token parity broken — the cache changed served tokens")
    reduction = off["prefill_tokens"] / max(on["prefill_tokens"], 1)
    row = {
        "scenario": name,
        "requests": len(stream),
        "prefill_tokens_uncached": off["prefill_tokens"],
        "prefill_tokens_cached": on["prefill_tokens"],
        "prefill_reduction": round(reduction, 3),
        "wall_s_uncached": off["wall_s"],
        "wall_s_cached": on["wall_s"],
        "hit_rate": on["hit_rate"],
        "prefix_hit_tokens": on["prefix_hit_tokens"],
        "evictions": on["cache"]["evictions"],
        "token_parity": True,
    }
    print(f"  {name:<14} prefill tokens {off['prefill_tokens']:>6} -> "
          f"{on['prefill_tokens']:>6}  ({reduction:.2f}x less compute)  "
          f"hit rate {on['hit_rate']:.0%}  wall {off['wall_s']:.2f}s -> "
          f"{on['wall_s']:.2f}s")
    return row


def bench_fleet(cfg, params, *, smoke: bool, seed: int = 0) -> dict:
    """Shared-prefix trace through the elastic fleet: the router's prefix
    affinity steers prompt families to the replica holding their prefix."""
    from repro import fleet as fl

    trace = fl.steady_trace(seed=seed, duration_s=8.0 if smoke else 16.0,
                            rate=2.0, prompt_median=6, prompt_lo=3,
                            prompt_hi=10, max_new_lo=3, max_new_hi=6,
                            new_session_p=0.5)
    reqs = fl.materialize(trace, vocab_size=cfg.vocab_size, seed=seed + 1,
                          shared_prefix_len=10, multi_turn=True,
                          max_prompt_len=40)
    fleet_cfg = fl.FleetConfig(min_replicas=2, max_replicas=2, slots=2,
                               max_len=64, prompt_buckets=(8, 16, 32, 48),
                               tick_s=0.1, prefix_cache_mb=16.0)
    fm = fl.FleetManager.build(cfg, params, chips=2, fleet=fleet_cfg)
    report = fm.run_trace(reqs)
    pc = report.prefix_cache
    per_replica = {r["id"]: r["prefix"] for r in report.replicas
                   if r["prefix"] is not None}
    print(f"  fleet          {report.served}/{report.requests} served | "
          f"prefix-affinity routes {pc['prefix_affinity_routes']} | "
          f"hit rate {pc['hit_rate']:.0%} "
          f"({pc['hit_tokens']} tokens restored across "
          f"{len(per_replica)} replicas)")
    assert report.served == report.requests
    assert pc["prefix_affinity_routes"] > 0, (
        "router never used prefix affinity on a shared-prefix trace")
    assert pc["hits"] > 0 and any(
        p["hits"] > 0 for p in per_replica.values()), (
        "no per-replica prefix-cache hits on a shared-prefix trace")
    return {
        "requests": report.requests,
        "prefix_affinity_routes": pc["prefix_affinity_routes"],
        "session_affinity_routes": pc["session_affinity_routes"],
        "hit_rate": pc["hit_rate"],
        "hit_tokens": pc["hit_tokens"],
        "per_replica": per_replica,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer requests, same assertions)")
    ap.add_argument("--out", default="BENCH_prefix.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = args.arch + ("" if args.arch.endswith("-smoke") else "-smoke")
    cfg = configs.get_config(arch)
    params = transformer.init_model(jax.random.key(args.seed), cfg)
    n = 8 if args.smoke else 24
    geometry = dict(slots=4, max_len=128, buckets=(16, 32, 64, 128),
                    cache_bytes=64 << 20)

    print(f"\narch={arch} requests={n} (shared-prefix) "
          f"geometry slots=4 max_len=128")
    shared = bench_scenario(
        "shared-prefix", cfg, params,
        _shared_prefix_stream(cfg.vocab_size, requests=n, prefix_len=48,
                              tail_lo=4, tail_hi=12, max_new=6,
                              seed=args.seed),
        **geometry)
    multi = bench_scenario(
        "multi-turn", cfg, params,
        _multi_turn_stream(cfg.vocab_size, sessions=2 if args.smoke else 4,
                           turns=4, turn_len=10, max_new=4,
                           seed=args.seed + 1),
        **geometry)
    fleet = bench_fleet(cfg, params, smoke=args.smoke, seed=args.seed)

    # the headline claim, asserted: prefix reuse at least halves prefill
    # compute on the canonical shared-system-prompt workload
    assert shared["prefill_reduction"] >= 2.0, (
        f"expected >= 2x prefill-compute reduction, got "
        f"{shared['prefill_reduction']}x")
    assert multi["prefill_reduction"] >= 2.0, (
        f"multi-turn reduction {multi['prefill_reduction']}x < 2x")

    payload = {
        "benchmark": "prefix_reuse",
        "arch": arch,
        "requests": n,
        "prefill_reduction": shared["prefill_reduction"],
        "scenarios": {"shared_prefix": shared, "multi_turn": multi},
        "fleet": fleet,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"\nheadline: {shared['prefill_reduction']:.2f}x prefill-compute "
          f"reduction at exact token parity")
    print(f"wrote {args.out}")
    print("prefix_reuse OK")


if __name__ == "__main__":
    main()
