"""Serving data-plane benchmark: fused single-program step vs legacy host loop.

Measures, for the same synthetic request stream on one model:

  * tok/s (end-to-end, including admission)
  * host<->device syncs per decode step — the fused data plane performs
    EXACTLY 1 blocking sync per step (a single packed "tokens|active|done"
    fetch); the legacy loop pays ~2 per active slot (one device_get per
    sampled token + one length sync) plus per-slot sample dispatches.
  * prefill program calls — batched admission runs one program per prompt
    bucket instead of one per request.

Writes machine-readable results to ``BENCH_serving.json`` (``--out``) so the
perf trajectory is tracked across PRs.

    PYTHONPATH=src python benchmarks/serving_throughput.py \
        [--arch qwen2-0.5b] [--requests 16] [--max-new 16]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro import configs
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampling import SamplingConfig


def _request_stream(cfg, requests: int, max_new: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(requests):
        plen = int(rng.integers(6, 30))
        if cfg.frontend == "audio":
            prompt = rng.integers(0, cfg.vocab_size,
                                  (cfg.num_codebooks, plen), dtype=np.int32)
        else:
            prompt = rng.integers(0, cfg.vocab_size, (plen,), dtype=np.int32)
        out.append(Request(request_id=i, prompt=prompt, max_new_tokens=max_new,
                           sampling=SamplingConfig()))
    return out


def bench_mode(cfg, params, reqs, *, fused: bool, slots: int, max_len: int,
               sync_every: int = 1) -> dict:
    engine = ServingEngine(cfg, params, slots=slots, max_len=max_len,
                           prompt_buckets=(16, 32, 64), fused=fused,
                           sync_every=sync_every)
    engine.warmup()  # steady-state measurement: all programs compiled
    warm_stats = dict(engine.stats)

    for r in reqs:
        engine.submit(r)
    t0 = time.perf_counter()
    results = engine.run_to_completion()
    wall = time.perf_counter() - t0

    tokens = sum(len(r.tokens) for r in results.values())
    decode_steps = engine.stats["decode_steps"] - warm_stats["decode_steps"]
    decode_syncs = engine.stats["host_syncs_decode"] - warm_stats["host_syncs_decode"]
    prefill_calls = engine.stats["prefill_calls"] - warm_stats["prefill_calls"]
    return {
        "mode": ("fused" if fused else "legacy")
                + (f"(sync_every={sync_every})" if sync_every > 1 else ""),
        "tokens": tokens,
        "wall_s": wall,
        "tok_s": tokens / max(wall, 1e-9),
        "decode_steps": decode_steps,
        "decode_syncs": decode_syncs,
        "syncs_per_step": decode_syncs / max(decode_steps, 1),
        "prefill_calls": prefill_calls,
        "results": {rid: r.tokens for rid, r in results.items()},
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--sync-every", type=int, default=4,
                    help="extra fused run with k-step sync batching")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()

    arch = args.arch + ("" if args.arch.endswith("-smoke") else "-smoke")
    cfg = configs.get_config(arch)
    params = transformer.init_model(jax.random.key(0), cfg)
    reqs = _request_stream(cfg, args.requests, args.max_new)

    rows = [
        bench_mode(cfg, params, reqs, fused=False, slots=args.slots,
                   max_len=args.max_len),
        bench_mode(cfg, params, reqs, fused=True, slots=args.slots,
                   max_len=args.max_len),
    ]
    if args.sync_every > 1:
        rows.append(bench_mode(cfg, params, reqs, fused=True, slots=args.slots,
                               max_len=args.max_len, sync_every=args.sync_every))

    print(f"\narch={arch} requests={args.requests} max_new={args.max_new} "
          f"slots={args.slots}")
    hdr = (f"{'mode':<20} {'tok/s':>8} {'wall_s':>7} {'steps':>6} "
           f"{'syncs':>6} {'syncs/step':>10} {'prefill_calls':>13}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['mode']:<20} {r['tok_s']:>8.1f} {r['wall_s']:>7.2f} "
              f"{r['decode_steps']:>6} {r['decode_syncs']:>6} "
              f"{r['syncs_per_step']:>10.2f} {r['prefill_calls']:>13}")

    legacy, fused = rows[0], rows[1]
    speedup = fused["tok_s"] / max(legacy["tok_s"], 1e-9)
    print(f"\nfused speedup: {speedup:.2f}x tok/s | syncs/step "
          f"{legacy['syncs_per_step']:.2f} -> {fused['syncs_per_step']:.2f}")
    # greedy decode: the refactor must not change a single served token
    assert fused["results"] == legacy["results"], "token parity broken"
    assert fused["syncs_per_step"] == 1.0, (
        f"fused data plane must sync exactly once per decode step, "
        f"got {fused['syncs_per_step']}")
    assert fused["tok_s"] > legacy["tok_s"], "fused engine should be faster"

    payload = {
        "benchmark": "serving_throughput",
        "arch": arch,
        "requests": args.requests,
        "max_new": args.max_new,
        "slots": args.slots,
        "fused_speedup": round(speedup, 3),
        "modes": [{k: v for k, v in r.items() if k != "results"}
                  for r in rows],
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")
    print("serving_throughput OK")


if __name__ == "__main__":
    main()
