"""Fleet elasticity benchmark: SLO-driven autoscaling vs static allocation.

Runs the SAME seeded bursty trace through three allocation policies on the
same cluster (serving replicas coexisting with preemptible BATCH training
jobs), entirely in virtual time, and compares:

  * **autoscaled**    — min..max replicas, queue/p95-driven scale-up (with
                        BATCH preemption + FTManager checkpoint-requeue when
                        the cluster is full), idle-driven scale-to-min.
  * **static-minimal** — the scale-to-min footprint held for the whole run:
                        cheapest chips, worst burst latency.
  * **static-peak**   — the burst footprint held for the whole run: best
                        latency, most chip-seconds (and starved batch jobs).

The paper's claim under test: an elastic lease-based fleet beats static-min
on p99 latency while consuming fewer chip-seconds than static-peak.
Deterministic given --seed; writes machine-readable results to
``BENCH_fleet.json`` so the trajectory is tracked across PRs.

    PYTHONPATH=src python benchmarks/fleet_scaling.py [--smoke] [--seed 0]
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro import configs
from repro.fleet import (SLO, FleetConfig, FleetManager, bursty_trace,
                         materialize)
from repro.models import transformer


def scenario_table(smoke: bool) -> dict:
    """Cluster + trace geometry. Smoke = the CI variant: 2 replicas max,
    one batch job, a short burst — still must exhibit scale-up, scale-down,
    and at least one preemption."""
    if smoke:
        return dict(
            chips=2, min_replicas=1, max_replicas=2,
            batch_jobs=[(1, 20)],
            trace=dict(duration_s=16.0, base_rate=0.3, burst_rate=6.0,
                       bursts=((3.0, 9.0),), prompt_median=8, prompt_lo=4,
                       prompt_hi=16, max_new_lo=4, max_new_hi=6),
        )
    return dict(
        chips=4, min_replicas=1, max_replicas=4,
        batch_jobs=[(1, 30), (1, 30)],
        trace=dict(duration_s=24.0, base_rate=0.3, burst_rate=8.0,
                   bursts=((4.0, 12.0),), prompt_median=8, prompt_lo=4,
                   prompt_hi=16, max_new_lo=4, max_new_hi=8),
    )


def run_policy(name: str, cfg, params, reqs, *, chips, min_replicas,
               max_replicas, batch_jobs, seed: int) -> dict:
    fleet_cfg = FleetConfig(
        min_replicas=min_replicas, max_replicas=max_replicas,
        slots=2, max_len=64, prompt_buckets=(8, 16), tick_s=0.1,
        warm_boot_s=0.5, cold_boot_s=1.5, settle_s=30.0)
    slo = SLO(p95_target_s=1.5, queue_high_per_slot=1.0, up_cooldown_s=1.0,
              down_cooldown_s=2.0, idle_drain_s=3.0)
    fm = FleetManager.build(cfg, params, chips=chips, fleet=fleet_cfg,
                            slo=slo, batch_jobs=batch_jobs)
    # every policy is accounted over the SAME virtual window (trace duration
    # + a fixed tail), so chip-second totals are directly comparable
    horizon = max(r.arrival_s for r in reqs) + 12.0
    t0 = time.perf_counter()
    report = fm.run_trace(reqs, until_s=horizon)
    wall = time.perf_counter() - t0
    assert report.served == report.requests, (
        f"{name}: {report.served}/{report.requests} served")
    assert report.reconciled, f"{name}: per-tenant ledger does not reconcile"
    out = report.to_dict()
    out["policy"] = name
    out["real_wall_s"] = round(wall, 2)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI variant: tiny trace, 2 replicas max")
    ap.add_argument("--out", default="BENCH_fleet.json")
    args = ap.parse_args()

    arch = args.arch + ("" if args.arch.endswith("-smoke") else "-smoke")
    cfg = configs.get_config(arch)
    params = transformer.init_model(jax.random.key(args.seed), cfg)
    spec = scenario_table(args.smoke)
    trace = bursty_trace(seed=args.seed, **spec["trace"])
    reqs = materialize(trace, vocab_size=cfg.vocab_size, seed=args.seed + 1)
    print(f"arch={arch} trace={len(reqs)} requests "
          f"(burst {spec['trace']['burst_rate']}/s) chips={spec['chips']} "
          f"batch_jobs={len(spec['batch_jobs'])}")

    mx = spec["max_replicas"]
    rows = [
        run_policy("autoscaled", cfg, params, reqs, chips=spec["chips"],
                   min_replicas=spec["min_replicas"], max_replicas=mx,
                   batch_jobs=spec["batch_jobs"], seed=args.seed),
        run_policy("static-minimal", cfg, params, reqs, chips=spec["chips"],
                   min_replicas=spec["min_replicas"],
                   max_replicas=spec["min_replicas"],
                   batch_jobs=spec["batch_jobs"], seed=args.seed),
        run_policy("static-peak", cfg, params, reqs, chips=spec["chips"],
                   min_replicas=mx, max_replicas=mx,
                   batch_jobs=spec["batch_jobs"], seed=args.seed),
    ]

    hdr = (f"{'policy':<15} {'p50_s':>7} {'p99_s':>7} {'tok/s':>7} "
           f"{'chip_s':>7} {'ups':>4} {'downs':>6} {'preempt':>8}")
    print("\n" + hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['policy']:<15} {r['latency_p50_s']:>7.2f} "
              f"{r['latency_p99_s']:>7.2f} {r['tokens_per_s']:>7.1f} "
              f"{r['serving_chip_s']:>7.1f} {r['scale_ups']:>4} "
              f"{r['scale_downs']:>6} {r['preemptions']:>8}")

    auto, smin, speak = rows
    # ---- the paper's elasticity claim, asserted ----
    assert auto["latency_p99_s"] < smin["latency_p99_s"], (
        f"autoscaled p99 {auto['latency_p99_s']:.2f}s must beat static-min "
        f"{smin['latency_p99_s']:.2f}s under the bursty trace")
    assert auto["serving_chip_s"] < speak["serving_chip_s"], (
        f"autoscaled {auto['serving_chip_s']:.1f} chip-s must undercut "
        f"static-peak {speak['serving_chip_s']:.1f}")
    assert auto["preemptions"] >= 1 and auto["batch"]["checkpoints"] >= 1, (
        "scale-up must preempt (and checkpoint) at least one BATCH job")
    assert auto["batch"]["resumes"] >= 1, (
        "a preempted BATCH job must requeue and resume from its checkpoint")
    assert auto["scale_ups"] >= 1 and auto["lease_releases"] >= 1, (
        "autoscaled run must both scale up and release a lease (scale-to-min)")
    print(f"\nautoscaled: p99 {auto['latency_p99_s']:.2f}s "
          f"(static-min {smin['latency_p99_s']:.2f}s, "
          f"{smin['latency_p99_s'] / max(auto['latency_p99_s'], 1e-9):.1f}x worse) | "
          f"chip-s {auto['serving_chip_s']:.1f} "
          f"(static-peak {speak['serving_chip_s']:.1f}, "
          f"{speak['serving_chip_s'] / max(auto['serving_chip_s'], 1e-9):.2f}x more) | "
          f"preemptions {auto['preemptions']} resumes {auto['batch']['resumes']}")

    payload = {
        "benchmark": "fleet_scaling",
        "arch": arch,
        "seed": args.seed,
        "smoke": args.smoke,
        "trace": {**spec["trace"], "bursts": [list(b) for b in spec["trace"]["bursts"]],
                  "requests": len(reqs)},
        "scenarios": {r["policy"]: r for r in rows},
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")
    print("fleet_scaling OK")


if __name__ == "__main__":
    main()
