"""Paged-KV benchmark: the slots x memory frontier of the block-managed
engine vs the contiguous slot engine (the PR-1..5 baseline).

Same request stream, same model, greedy decoding, both engines warm:

  * peak concurrent requests per byte of KV memory — the headline. The slot
    engine must provision ``slots x max_len`` cache strips to hold ``slots``
    requests; the paged engine holds the same concurrency in a pool sized by
    TOKENS ACTUALLY HELD (pages_for(prompt+decode) per request), so short
    requests against a long-context provisioning stop paying for max_len.
  * decode throughput (tok/s end-to-end) — the cost side: gather/scatter
    through block tables must stay within 10% of the contiguous layout.
  * token parity — greedy streams must be BYTE-IDENTICAL between the two
    engines: paging is a memory-management change, never a behavior change
    (asserted, request by request).

``--smoke`` is the CI variant: a 16-slot engine over 24 requests that must
sustain MORE THAN 8 requests in flight simultaneously at token parity,
without gating on wall-clock. The full run drives 128 concurrent requests
through ONE replica and asserts the >=2x concurrency-per-KV-byte headline
plus the <=10% decode-throughput bound.

Writes machine-readable results to ``BENCH_paged.json`` (``--out``), gated
by ``benchmarks/validate_bench.py`` (the concurrency-per-byte ratio and
decode tok/s ratio are hard <=20%-regression gates; absolute tok/s is
advisory, as everywhere).

    PYTHONPATH=src python benchmarks/paged_kv.py [--arch qwen2-0.5b]
        [--concurrency 128] [--requests 144] [--max-new 10] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro import configs
from repro.models import transformer
from repro.serving.block_manager import pages_for
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampling import SamplingConfig

PAGE = 8


def _request_stream(cfg, requests: int, max_new: int, seed: int = 0,
                    shared_prefix: int = 6):
    """Short prompts (some sharing a system prefix) against a long-context
    engine — the workload class where paging wins: the slot engine pays for
    max_len per request, the paged engine for actual tokens."""
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, cfg.vocab_size, shared_prefix,
                              dtype=np.int32)
    out = []
    for i in range(requests):
        plen = int(rng.integers(4, 18))
        prompt = rng.integers(0, cfg.vocab_size, (plen,), dtype=np.int32)
        if i % 2 == 0:
            prompt = np.concatenate([sys_prompt, prompt])
        out.append(Request(request_id=i, prompt=prompt,
                           max_new_tokens=max_new,
                           sampling=SamplingConfig()))
    return out


def _serve_tracked(engine, reqs):
    """run_to_completion with peak-concurrency tracking."""
    for r in reqs:
        engine.submit(r)
    peak = 0
    t0 = time.perf_counter()
    while True:
        active = engine.step()
        peak = max(peak, active)
        if active == 0 and not engine.queue:
            break
    wall = time.perf_counter() - t0
    return engine.results, peak, wall


def bench_mode(cfg, params, reqs, *, slots: int, max_len: int,
               page_size: int | None, kv_pages: int | None,
               repeats: int = 3) -> dict:
    """Serve the stream ``repeats`` times on fresh warm engines and keep the
    fastest trial (token streams are identical across trials — asserted)."""
    best = None
    for _ in range(max(repeats, 1)):
        engine = ServingEngine(cfg, params, slots=slots, max_len=max_len,
                               prompt_buckets=(16, 32, max_len),
                               page_size=page_size, kv_pages=kv_pages,
                               prefix_cache_bytes=None)
        engine.warmup()
        results, peak, wall = _serve_tracked(engine, reqs)
        tokens = sum(len(r.tokens) for r in results.values())
        if page_size is not None:
            # pool bytes actually provisioned (null page excluded)
            kv_bytes = (engine.kv_pages - 1) * engine.page_bytes
            token_bytes = engine.page_bytes // page_size
        else:
            # contiguous strips: slots x max_len tokens, same per-token cost
            probe = transformer.init_paged_states(
                cfg, 2, PAGE, jax.numpy.dtype(cfg.activ_dtype))
            token_bytes = sum(
                int(np.prod(l.shape)) // 2 * jax.numpy.dtype(l.dtype).itemsize
                for l in jax.tree.leaves(probe)) // PAGE
            kv_bytes = slots * max_len * token_bytes
        lat = engine.latency_summary()
        row = {
            "mode": ("slot-engine" if page_size is None
                     else f"paged(page={page_size})"),
            "slots": slots,
            "kv_bytes": int(kv_bytes),
            "kv_tokens_capacity": int(kv_bytes // token_bytes),
            "peak_concurrent": peak,
            "tokens": tokens,
            "wall_s": wall,
            "tok_s": tokens / max(wall, 1e-9),
            "decode_steps": engine.stats["decode_steps"],
            "ttft_p50_s": lat["ttft_p50_s"],
            "tpot_p50_s": lat["tpot_p50_s"],
            "preemptions": engine.stats["preemptions"],
            "results": {rid: r.tokens for rid, r in results.items()},
        }
        if page_size is not None:
            row["paged"] = engine.paged_summary()
        if best is not None:
            assert row["results"] == best["results"], (
                "greedy token streams differ across trials")
        if best is None or row["tok_s"] > best["tok_s"]:
            best = row
    return best


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--concurrency", type=int, default=128,
                    help="engine slots = target in-flight requests")
    ap.add_argument("--requests", type=int, default=144)
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--max-len", type=int, default=128,
                    help="provisioned context per request (the slot engine "
                         "pays for all of it; the paged engine only for "
                         "pages actually written)")
    ap.add_argument("--repeats", type=int, default=3,
                    help="trials per mode; the fastest is kept")
    ap.add_argument("--smoke", action="store_true",
                    help="CI variant: 16 slots, 24 requests, asserts >8 "
                         "peak concurrency + parity (no wall-clock gate)")
    ap.add_argument("--out", default="BENCH_paged.json")
    args = ap.parse_args()

    if args.smoke:
        args.concurrency, args.requests, args.max_new = 16, 24, 8
        args.max_len = min(args.max_len, 64)
        args.repeats = 1

    arch = args.arch + ("" if args.arch.endswith("-smoke") else "-smoke")
    cfg = configs.get_config(arch)
    params = transformer.init_model(jax.random.key(0), cfg)
    reqs = _request_stream(cfg, args.requests, args.max_new)

    # provision the pool for the worst-case resident set: the `slots`
    # hungriest requests fully grown, plus growth headroom — zero
    # preemptions, so the throughput comparison isolates the data-plane cost
    need = sorted((pages_for(int(np.asarray(r.prompt).shape[-1])
                             + r.max_new_tokens, PAGE) for r in reqs),
                  reverse=True)
    kv_pages = sum(need[:args.concurrency]) + args.concurrency // 8 + 1

    base = bench_mode(cfg, params, reqs, slots=args.concurrency,
                      max_len=args.max_len, page_size=None, kv_pages=None,
                      repeats=args.repeats)
    paged = bench_mode(cfg, params, reqs, slots=args.concurrency,
                       max_len=args.max_len, page_size=PAGE,
                       kv_pages=kv_pages, repeats=args.repeats)

    parity = paged["results"] == base["results"]
    tok_s_ratio = paged["tok_s"] / max(base["tok_s"], 1e-9)
    # requests-in-flight each engine sustains per byte of provisioned KV
    conc_per_byte_ratio = (
        (paged["peak_concurrent"] / paged["kv_bytes"])
        / max(base["peak_concurrent"] / base["kv_bytes"], 1e-12))

    print(f"\narch={arch} concurrency={args.concurrency} "
          f"requests={args.requests} max_new={args.max_new} "
          f"max_len={args.max_len} page={PAGE}")
    hdr = (f"{'mode':<16} {'peak':>5} {'KV MiB':>8} {'cap tok':>8} "
           f"{'tok/s':>8} {'steps':>6} {'preempt':>7}")
    print(hdr)
    print("-" * len(hdr))
    for r in (base, paged):
        print(f"{r['mode']:<16} {r['peak_concurrent']:>5} "
              f"{r['kv_bytes'] / (1 << 20):>8.2f} "
              f"{r['kv_tokens_capacity']:>8} {r['tok_s']:>8.1f} "
              f"{r['decode_steps']:>6} {r['preemptions']:>7}")
    print(f"\nconcurrency per KV byte: {conc_per_byte_ratio:.2f}x | "
          f"decode throughput ratio: {tok_s_ratio:.2f}x | "
          f"token parity: {parity}")

    # paging is a memory-management change, never a behavior change
    assert parity, "paged engine changed a greedy token stream"
    if args.smoke:
        assert paged["peak_concurrent"] > 8, (
            f"paged smoke sustained only {paged['peak_concurrent']} "
            f"concurrent requests (need > 8)")
    else:
        assert paged["peak_concurrent"] >= args.concurrency, (
            f"paged engine never reached {args.concurrency} concurrent "
            f"requests (peak {paged['peak_concurrent']})")
        assert conc_per_byte_ratio >= 2.0, (
            f"concurrency-per-KV-byte {conc_per_byte_ratio:.2f}x < 2x "
            f"headline")
        assert tok_s_ratio >= 0.9, (
            f"paged decode throughput {tok_s_ratio:.2f}x of contiguous "
            f"(> 10% regression)")

    payload = {
        "benchmark": "paged_kv",
        "arch": arch,
        "concurrency": args.concurrency,
        "requests": args.requests,
        "max_new": args.max_new,
        "max_len": args.max_len,
        "page_size": PAGE,
        "kv_pages": kv_pages,
        "concurrency_per_kv_byte": round(conc_per_byte_ratio, 3),
        "kv_bytes_reduction": round(base["kv_bytes"] / paged["kv_bytes"], 3),
        "decode_tok_s_ratio": round(tok_s_ratio, 3),
        "token_parity": parity,
        "modes": [{k: v for k, v in r.items() if k != "results"}
                  for r in (base, paged)],
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")
    print("paged_kv OK")


if __name__ == "__main__":
    main()
