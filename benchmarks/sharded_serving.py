"""Sharded serving benchmark: tensor/expert-parallel replicas vs one chip.

Three claims, all asserted here and re-gated by ``validate_bench.py`` on the
committed ``BENCH_sharding.json`` (docs/sharding.md):

  * **capacity** — a config whose per-replica footprint (params + KV pool)
    exceeds one chip's modeled HBM *fits* at TP=2: per-chip bytes halve
    along the model axis, and the fleet's width-vs-count policy records
    that it was FORCED to widen ("widened past 1x1 ...").
  * **parity** — greedy token streams from a (1,2)-mesh replica are
    byte-identical to the single-device engine, on both the fused-decode
    and the paged+chunked-prefill data planes: sharding is a capacity/
    latency tool, never a behavior change.
  * **efficiency** — per-chip-second throughput at TP=2 stays within 20%
    of the 1-chip engine. Modeled at the profile roofline from the ledger's
    billed FLOPs (the compiled artifact's post-SPMD cost analysis): forced
    host devices share one physical CPU, so wall clock cannot measure
    scaling, but billed-FLOPs-per-chip CAN — if sharding replicated the
    compute instead of splitting it, per-chip FLOPs would not drop and the
    ratio would collapse toward 1/width.

Needs >= 2 devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
        python benchmarks/sharded_serving.py --smoke --out BENCH_sharding.json
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro import configs, fleet as fl
from repro.core import recompile, scheduler
from repro.core.invocation import InvocationService
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampling import SamplingConfig
from repro.serving.service import serving_container

ARCH = "deepseek-v3-671b-smoke"
GEOM = dict(slots=2, max_len=64, prompt_buckets=(16, 64))
MESH = (1, 2)
SERVE_KINDS = ("serve_prefill", "serve_decode", "serve_spec_verify")


def _requests(cfg, n: int, max_new: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [Request(request_id=i,
                    prompt=rng.integers(0, cfg.vocab_size, (7,),
                                        dtype=np.int32),
                    max_new_tokens=max_new, sampling=SamplingConfig())
            for i in range(n)]


# ---------------------------------------------------------------------------
# capacity: the KV pool that does not fit one chip fits at TP=2
# ---------------------------------------------------------------------------
def capacity(cfg, params, *, requests: int) -> dict:
    fleet_cfg = fl.FleetConfig(min_replicas=1, max_replicas=2,
                               slots=GEOM["slots"], max_len=GEOM["max_len"],
                               prompt_buckets=(8, 16), tick_s=0.1,
                               warm_boot_s=0.2, cold_boot_s=0.5,
                               prefix_cache_mb=0.0,
                               mesh_options=((1, 1), MESH))
    b1 = fl.replica_bytes_per_chip(cfg, fleet_cfg, (1, 1))
    b2 = fl.replica_bytes_per_chip(cfg, fleet_cfg, MESH)
    # model a chip whose HBM sits between the two footprints: one chip
    # cannot hold the replica, two model-parallel shards can
    hbm = (b1 + b2) // 2
    assert b2 <= hbm < b1, f"footprints degenerate: {b1} vs {b2}"
    profile = recompile.host_mesh_profile(MESH, hbm_bytes=hbm)
    fm = fl.FleetManager.build(cfg, params, chips=4, fleet=fleet_cfg,
                               profile=profile)
    wd = fm.width_decision
    assert wd["chips_per_replica"] == 2, f"policy chose {wd}"
    assert "widened past" in wd["reason"], wd["reason"]
    trace = fl.steady_trace(seed=0, duration_s=6.0, prompt_median=6,
                            prompt_lo=4, prompt_hi=8,
                            max_new_lo=4, max_new_hi=6)
    reqs = fl.materialize(trace, vocab_size=cfg.vocab_size, seed=1,
                          max_prompt_len=16)[:requests]
    report = fm.run_trace(reqs)
    assert report.served == report.requests and report.reconciled
    assert all(r["chips"] == 2 for r in report.replicas)
    return {
        "bytes_per_chip_1x1": b1,
        "bytes_per_chip_tp2": b2,
        "hbm_bytes_modeled": hbm,
        "fits_1chip": b1 <= hbm,
        "fits_tp2": b2 <= hbm,
        "width_reason": wd["reason"],
        "fleet_served": report.served,
        "replica_chips": 2,
    }


# ---------------------------------------------------------------------------
# parity: sharded greedy streams byte-identical to single-device
# ---------------------------------------------------------------------------
def _stream(cfg, params, mesh, reqs, **kw) -> dict:
    eng = ServingEngine(cfg, params, mesh=mesh, **GEOM, **kw)
    eng.warmup()
    for r in reqs:
        eng.submit(r)
    return {rid: list(map(int, r.tokens))
            for rid, r in eng.run_to_completion().items()}


def parity(cfg, params, *, requests: int, max_new: int) -> dict:
    mesh = jax.make_mesh(MESH, ("data", "model"))
    paths = {"decode": {},
             "prefill_chunk": dict(page_size=16, kv_pages=9,
                                   prefill_chunk_tokens=16)}
    out = {}
    for name, kw in paths.items():
        ref = _stream(cfg, params, None, _requests(cfg, requests, max_new),
                      **kw)
        got = _stream(cfg, params, mesh, _requests(cfg, requests, max_new),
                      **kw)
        assert got == ref, f"{name}: sharded stream diverged"
        out[name] = True
    return out


# ---------------------------------------------------------------------------
# efficiency: modeled per-chip-second throughput within 20% of one chip
# ---------------------------------------------------------------------------
def throughput_mode(cfg, params, profile, mesh_shape, reqs) -> dict:
    cont = serving_container(cfg, params, mesh_shape=mesh_shape, **GEOM)
    service = InvocationService(scheduler.Cluster(chips=profile.chips))
    with service.acquire_serving("bench", cont, profile) as ex:
        ex.warmup()
        for r in reqs:
            ex.submit(r)
        ex.run()
        tokens = service.meter.served_tokens("bench")
        flop_s = sum(b.flop_s for b in service.meter.bills
                     if b.kind in SERVE_KINDS)
    chip_s = flop_s / profile.peak_flops  # roofline-modeled chip-seconds
    return {
        "mesh": "x".join(map(str, mesh_shape or (1,))),
        "chips": profile.chips,
        "tokens": tokens,
        "billed_flops": flop_s,
        "modeled_chip_s": chip_s,
        "tok_per_chip_s": tokens / max(chip_s, 1e-12),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--smoke", action="store_true",
                    help="CI variant: fewer requests, same assertions")
    ap.add_argument("--out", default="BENCH_sharding.json")
    args = ap.parse_args()
    if jax.device_count() < int(np.prod(MESH)):
        raise SystemExit(
            f"needs {int(np.prod(MESH))} devices; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count=8")
    n = 2 if args.smoke else args.requests
    max_new = 4 if args.smoke else args.max_new

    cfg = configs.get_config(ARCH)
    params = transformer.init_model(jax.random.key(0), cfg)

    cap = capacity(cfg, params, requests=max(n, 2))
    gib = 1 / (1 << 30)
    print(f"capacity: 1x1 needs {cap['bytes_per_chip_1x1'] * gib:.4f} "
          f"GiB/chip > modeled HBM {cap['hbm_bytes_modeled'] * gib:.4f} GiB; "
          f"TP=2 needs {cap['bytes_per_chip_tp2'] * gib:.4f} GiB/chip — "
          f"fits, fleet served {cap['fleet_served']} requests")
    print(f"  width policy: {cap['width_reason']}")

    par = parity(cfg, params, requests=n, max_new=max_new)
    print(f"parity: greedy streams byte-identical on {list(par)} "
          f"(mesh {'x'.join(map(str, MESH))} vs single device)")

    reqs = _requests(cfg, n, max_new)
    base = throughput_mode(cfg, params, recompile.PORTABLE_CPU, None, reqs)
    shard = throughput_mode(cfg, params, recompile.host_mesh_profile(MESH),
                            MESH, reqs)
    ratio = shard["tok_per_chip_s"] / base["tok_per_chip_s"]
    print(f"throughput (roofline-modeled from billed FLOPs): "
          f"1-chip {base['tok_per_chip_s']:.0f} tok/chip-s, TP=2 "
          f"{shard['tok_per_chip_s']:.0f} tok/chip-s — ratio {ratio:.2f}")
    assert shard["tokens"] == base["tokens"]
    assert ratio >= 0.8, (
        f"TP=2 per-chip throughput ratio {ratio:.2f} < 0.8: sharding is "
        f"duplicating compute instead of splitting it")

    payload = {
        "benchmark": "sharded_serving",
        "arch": ARCH,
        "mesh": list(MESH),
        "smoke": args.smoke,
        "capacity": cap,
        "token_parity": all(par.values()),
        "parity_paths": sorted(par),
        "throughput": {
            "modes": [base, shard],
            "per_chip_throughput_ratio": round(ratio, 4),
        },
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")
    print("sharded_serving OK")


if __name__ == "__main__":
    main()
