"""Speculative decoding benchmark: fused draft-verify data plane vs the
plain fused decode loop (the PR-1 baseline).

Same request stream, same model, greedy decoding, both engines warm:

  * tok/s end-to-end (admission + decode) — the headline; the stream is
    decode-dominated, so this is decode throughput to first order.
  * TPOT (per-output-token decode latency, p50 over requests) — the latency
    face of the same coin (satellite telemetry).
  * acceptance rate + tokens per slot-step — WHY it is faster: one target
    forward emits up to k+1 tokens when the proposer's drafts survive
    lossless rejection sampling.
  * token parity — greedy streams must be BYTE-IDENTICAL: speculative
    decoding is an acceleration, never a behavior change (asserted).

The NGram (prompt-lookup) proposer drafts from the request's own emitted
history, so the benchmark asserts on the same workload class it targets:
continuations with internal repetition. ``--smoke`` is the CI variant — a
2-slot engine that asserts a NONZERO acceptance rate and token parity
without gating on wall-clock.

Writes machine-readable results to ``BENCH_spec.json`` (``--out``), gated by
``benchmarks/validate_bench.py`` (speedup and acceptance-rate ratios are
hard <=20%-regression gates; absolute tok/s is advisory, as everywhere).

    PYTHONPATH=src python benchmarks/speculative.py [--arch qwen2-0.5b]
        [--requests 16] [--max-new 32] [--k 4] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro import configs
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampling import SamplingConfig
from repro.serving.speculative import SpecConfig


def _request_stream(cfg, requests: int, max_new: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(requests):
        plen = int(rng.integers(6, 30))
        prompt = rng.integers(0, cfg.vocab_size, (plen,), dtype=np.int32)
        out.append(Request(request_id=i, prompt=prompt, max_new_tokens=max_new,
                           sampling=SamplingConfig()))
    return out


def bench_mode(cfg, params, reqs, *, spec: SpecConfig | None, slots: int,
               max_len: int, repeats: int = 3) -> dict:
    """Serve the stream ``repeats`` times on fresh warm engines and keep the
    fastest trial — wall-clock on a shared CI runner is noisy, and the
    best-of-N trial is the least-contended measurement of the same
    deterministic work (token streams are identical across trials, which is
    also asserted)."""
    best = None
    for _ in range(max(repeats, 1)):
        engine = ServingEngine(cfg, params, slots=slots, max_len=max_len,
                               prompt_buckets=(16, 32, 64), spec=spec)
        engine.warmup()
        warm = dict(engine.stats)

        for r in reqs:
            engine.submit(r)
        t0 = time.perf_counter()
        results = engine.run_to_completion()
        wall = time.perf_counter() - t0

        tokens = sum(len(r.tokens) for r in results.values())
        lat = engine.latency_summary()
        row = {
            "mode": "baseline-fused" if spec is None
                    else f"spec({spec.proposer} k={spec.k})",
            "tokens": tokens,
            "wall_s": wall,
            "tok_s": tokens / max(wall, 1e-9),
            "decode_steps": engine.stats["decode_steps"] - warm["decode_steps"],
            "tpot_p50_s": lat["tpot_p50_s"],
            "tpot_p95_s": lat["tpot_p95_s"],
            "ttft_p50_s": lat["ttft_p50_s"],
            "results": {rid: r.tokens for rid, r in results.items()},
        }
        if spec is not None:
            row.update(engine.spec_summary())
        if best is not None:
            assert row["results"] == best["results"], (
                "greedy token streams differ across trials")
        if best is None or row["tok_s"] > best["tok_s"]:
            best = row
    return best


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=64)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--repeats", type=int, default=3,
                    help="trials per mode; the fastest is kept")
    ap.add_argument("--smoke", action="store_true",
                    help="CI variant: 2 slots, tiny stream, asserts nonzero "
                         "acceptance + parity (no wall-clock gate)")
    ap.add_argument("--out", default="BENCH_spec.json")
    args = ap.parse_args()

    if args.smoke:
        args.requests, args.max_new, args.slots = 6, 12, 2
        args.max_len = min(args.max_len, 64)
        args.repeats = 1

    arch = args.arch + ("" if args.arch.endswith("-smoke") else "-smoke")
    cfg = configs.get_config(arch)
    params = transformer.init_model(jax.random.key(0), cfg)
    reqs = _request_stream(cfg, args.requests, args.max_new)
    spec = SpecConfig(k=args.k, proposer="ngram")

    base = bench_mode(cfg, params, reqs, spec=None, slots=args.slots,
                      max_len=args.max_len, repeats=args.repeats)
    fast = bench_mode(cfg, params, reqs, spec=spec, slots=args.slots,
                      max_len=args.max_len, repeats=args.repeats)

    speedup = fast["tok_s"] / max(base["tok_s"], 1e-9)
    step_ratio = base["decode_steps"] / max(fast["decode_steps"], 1)
    print(f"\narch={arch} requests={args.requests} max_new={args.max_new} "
          f"slots={args.slots} k={args.k}")
    hdr = (f"{'mode':<18} {'tok/s':>8} {'wall_s':>7} {'steps':>6} "
           f"{'tpot_p50':>9} {'accept':>7}")
    print(hdr)
    print("-" * len(hdr))
    for r in (base, fast):
        acc = f"{r['acceptance_rate']:.0%}" if "acceptance_rate" in r else "--"
        print(f"{r['mode']:<18} {r['tok_s']:>8.1f} {r['wall_s']:>7.2f} "
              f"{r['decode_steps']:>6} {r['tpot_p50_s'] * 1e3:>8.2f}m {acc:>7}")
    print(f"\nspeculative speedup: {speedup:.2f}x tok/s | "
          f"{step_ratio:.2f}x fewer target steps | acceptance "
          f"{fast['acceptance_rate']:.0%}")

    # lossless contract: greedy streams byte-identical, always asserted
    assert fast["results"] == base["results"], (
        "speculative decoding changed a greedy token stream")
    assert fast["acceptance_rate"] > 0, "proposer never had a draft accepted"
    if not args.smoke:
        assert speedup >= 1.5, (
            f"speculative decode speedup {speedup:.2f}x < 1.5x headline")

    payload = {
        "benchmark": "speculative",
        "arch": arch,
        "requests": args.requests,
        "max_new": args.max_new,
        "slots": args.slots,
        "k": args.k,
        "proposer": "ngram",
        "speedup": round(speedup, 3),
        "step_reduction": round(step_ratio, 3),
        "acceptance_rate": fast["acceptance_rate"],
        "tokens_per_slot_step": fast["tokens_per_slot_step"],
        "token_parity": fast["results"] == base["results"],
        "modes": [{k: v for k, v in r.items() if k != "results"}
                  for r in (base, fast)],
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")
    print("speculative OK")


if __name__ == "__main__":
    main()
