"""Disaggregated prefill/decode fleet benchmark: phase-specialized pools
with KV page handoff vs a monolithic fleet, at matched replica footprint.

Runs the SAME seeded bursty trace (long prompts inside the burst — the
prefill-heavy regime the split targets) through two topologies over the
same virtual-time window:

  * **monolithic** — every replica runs both phases; chunked prefill uses
    a small chunk cap to protect co-resident decode TPOT, which is exactly
    what throttles prompt admission under the burst.
  * **disagg**     — a prefill pool (full-width chunks, wide admission
    batches: no co-resident decode to protect) computes prompts and ships
    KV pages over the :class:`~repro.fleet.disagg.KVHandoff` plane to a
    decode pool, each pool autoscaled against its own SLO (TTFT vs TPOT).

The paper's converged-infrastructure claim under test: specializing
execution per phase (while keeping one lease/container abstraction) cuts
burst TTFT p99 by >= 1.3x at <= 1.05x the chip-seconds, with greedy token
streams byte-identical to the monolithic fleet. Deterministic given
--seed; writes ``BENCH_disagg.json`` for the CI regression gate.

    PYTHONPATH=src python benchmarks/disagg.py [--smoke] [--seed 0]
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro import configs
from repro.fleet import (SLO, DisaggConfig, DisaggFleetManager, FleetConfig,
                         FleetManager, bursty_trace, materialize)
from repro.models import transformer

TTFT_RATIO_FLOOR = 1.3   # disagg burst TTFT p99 must beat mono by this
CHIP_RATIO_CEIL = 1.05   # ...without spending more than 5% extra chip-s


def scenario_table(smoke: bool) -> dict:
    """Trace + fleet geometry. Both topologies get the same max footprint
    (mono 2..4 replicas vs disagg 1+1..2+2). Smoke = the CI variant: a
    shorter burst, same shape — still must hand off and show the pools
    scaling independently."""
    trace = dict(duration_s=16.0, base_rate=0.4, burst_rate=8.0,
                 bursts=((3.0, 11.0),), prompt_median=8, prompt_lo=4,
                 prompt_hi=32, max_new_lo=6, max_new_hi=10,
                 burst_prompt_median=28)
    if smoke:
        trace.update(duration_s=10.0, bursts=((2.0, 8.0),))
    return dict(
        chips=8, mono_min=2, mono_max=4,
        disagg=DisaggConfig(
            prefill_min=1, prefill_max=2, decode_min=1, decode_max=2,
            # prefill engines admit wide: there is no decode latency to
            # protect, so the batch dimension is free admission throughput
            prefill_slots=4,
            decode_slo=SLO(p95_target_s=0.3, queue_high_per_slot=3.0)),
        trace=trace)


def _fleet_cfg(min_replicas: int, max_replicas: int) -> FleetConfig:
    # prefill_chunk_tokens=8 is the monolithic fleet's TPOT-protective
    # chunk cap — the disagg prefill pool overrides it to full width
    return FleetConfig(
        min_replicas=min_replicas, max_replicas=max_replicas, slots=2,
        max_len=48, prompt_buckets=(8, 16, 32), tick_s=0.05, page_size=8,
        prefix_cache_mb=1.0, warm_boot_s=0.4, cold_boot_s=0.8,
        prefill_chunk_tokens=8)


def run_topology(name: str, cfg, params, reqs, spec, *, horizon: float) -> tuple:
    if name == "monolithic":
        fm = FleetManager.build(cfg, params, chips=spec["chips"],
                                fleet=_fleet_cfg(spec["mono_min"],
                                                 spec["mono_max"]))
    else:
        d = spec["disagg"]
        fm = DisaggFleetManager.build(
            cfg, params, chips=spec["chips"],
            fleet=_fleet_cfg(d.prefill_min + d.decode_min,
                             d.prefill_max + d.decode_max),
            disagg=d)
    t0 = time.perf_counter()
    report = fm.run_trace(reqs, until_s=horizon)
    wall = time.perf_counter() - t0
    assert report.served == report.requests, (
        f"{name}: {report.served}/{report.requests} served")
    assert report.reconciled, f"{name}: per-tenant ledger does not reconcile"
    row = report.to_dict()
    row["topology"] = name
    row["real_wall_s"] = round(wall, 2)
    return fm, row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI variant: short burst, handoff + per-pool "
                         "scaling asserted, no ratio gates")
    ap.add_argument("--out", default="BENCH_disagg.json")
    args = ap.parse_args()

    arch = args.arch + ("" if args.arch.endswith("-smoke") else "-smoke")
    cfg = configs.get_config(arch)
    params = transformer.init_model(jax.random.key(args.seed), cfg)
    spec = scenario_table(args.smoke)
    trace = bursty_trace(seed=args.seed, **spec["trace"])
    reqs = materialize(trace, vocab_size=cfg.vocab_size, seed=args.seed + 1,
                       max_prompt_len=32)
    # both topologies are accounted over the SAME virtual window, so
    # chip-second totals are directly comparable
    horizon = max(r.arrival_s for r in reqs) + 12.0
    print(f"arch={arch} trace={len(reqs)} requests "
          f"(burst {spec['trace']['burst_rate']}/s, "
          f"burst prompts ~{spec['trace']['burst_prompt_median']} tok) "
          f"chips={spec['chips']} horizon={horizon:.1f}s")

    mono_fm, mono = run_topology("monolithic", cfg, params, reqs, spec,
                                 horizon=horizon)
    d_fm, dis = run_topology("disagg", cfg, params, reqs, spec,
                             horizon=horizon)

    hdr = (f"{'topology':<12} {'ttft_p50':>9} {'ttft_p99':>9} {'p99_s':>7} "
           f"{'chip_s':>7} {'handoffs':>9} {'fallbacks':>10}")
    print("\n" + hdr)
    print("-" * len(hdr))
    for r in (mono, dis):
        h = r["disagg"].get("handoff", {})
        print(f"{r['topology']:<12} {r['ttft_virtual_p50_s']:>9.3f} "
              f"{r['ttft_virtual_p99_s']:>9.3f} {r['latency_p99_s']:>7.2f} "
              f"{r['serving_chip_s']:>7.1f} {h.get('installed', 0):>9} "
              f"{r['disagg'].get('fallback_submits', 0):>10}")

    # ---- byte parity: the split must not change a single token ----
    sm, sd = mono_fm.token_streams(), d_fm.token_streams()
    assert set(sm) == set(sd) == {r.request_id for r in reqs}
    mismatched = [rid for rid in sm if sm[rid] != sd[rid]]
    assert not mismatched, f"{len(mismatched)} streams diverged: " \
                           f"{sorted(mismatched)[:5]}"
    parity = True

    handoff = dis["disagg"]["handoff"]
    pools = dis["disagg"]["pools"]
    assert handoff["installed"] >= 1, "disagg run never handed off KV pages"
    assert handoff["sha_rejected"] == 0, "unexpected sha rejects"
    # per-pool autoscaling independence: at least one pool reacted to the
    # burst while the other held its own floor — one global cooldown/window
    # could not produce this
    scale_ups = {p: pools[p]["scale_ups"] for p in ("prefill", "decode")}
    assert sum(scale_ups.values()) >= 1, "neither pool ever scaled up"
    assert any(pools[p]["live"] == pools[p]["min"]
               for p in ("prefill", "decode")), \
        "no pool settled back to its own floor"

    ttft_ratio = (mono["ttft_virtual_p99_s"]
                  / max(dis["ttft_virtual_p99_s"], 1e-9))
    chip_ratio = dis["serving_chip_s"] / max(mono["serving_chip_s"], 1e-9)
    print(f"\ndisagg: TTFT p99 {dis['ttft_virtual_p99_s']:.3f}s vs mono "
          f"{mono['ttft_virtual_p99_s']:.3f}s ({ttft_ratio:.2f}x better) | "
          f"chip-s {dis['serving_chip_s']:.1f} vs {mono['serving_chip_s']:.1f} "
          f"({chip_ratio:.2f}x) | {handoff['installed']} handoffs "
          f"({handoff['bytes'] / 1e6:.1f} MB) | pool scale-ups {scale_ups}")

    if not args.smoke:
        # ---- the headline claim, asserted ----
        assert ttft_ratio >= TTFT_RATIO_FLOOR, (
            f"disagg TTFT p99 must be >= {TTFT_RATIO_FLOOR}x better under "
            f"the prefill-heavy burst (got {ttft_ratio:.2f}x)")
        assert chip_ratio <= CHIP_RATIO_CEIL, (
            f"disagg chip-seconds must stay within {CHIP_RATIO_CEIL}x of "
            f"monolithic (got {chip_ratio:.2f}x)")

    payload = {
        "benchmark": "disagg",
        "arch": arch,
        "seed": args.seed,
        "smoke": args.smoke,
        "trace": {**spec["trace"],
                  "bursts": [list(b) for b in spec["trace"]["bursts"]],
                  "requests": len(reqs), "horizon_s": horizon},
        "headline": {
            "ttft_p99_ratio": round(ttft_ratio, 4),
            "chip_seconds_ratio": round(chip_ratio, 4),
            "token_parity": parity,
            "handoffs_installed": handoff["installed"],
        },
        "scenarios": {r["topology"]: r for r in (mono, dis)},
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")
    print("disagg OK")


if __name__ == "__main__":
    main()
