"""Schema-validate committed BENCH_*.json files and gate headline-metric
regressions against a freshly generated candidate set.

Two modes (both pure stdlib — no jsonschema dependency in the image):

  schema check (always):
      every committed BENCH_*.json must parse and carry its benchmark's
      required fields with sane types/ranges — a half-written or
      hand-mangled benchmark artifact fails CI at the door.

  regression gate (``--candidate DIR``):
      compares the candidate run's headline metrics against the committed
      baselines and FAILS when one regresses beyond its threshold, printing
      the comparison table either way. Tracked headlines:

        * serving tok/s (fused)     — advisory only (wall clock on a CI
                                      runner vs a baseline from different
                                      hardware never gates)
        * serving fused speedup     — same-machine ratio, 20%
        * fleet p99 latency         — virtual-time (deterministic), 20%
        * prefix prefill reduction  — token-count ratio (deterministic), 20%
        * spec tok/s                — advisory (wall clock, as above)
        * spec decode speedup       — same-machine ratio, 20%
        * spec acceptance rate      — deterministic token-count ratio, 20%
        * paged concurrency/KV byte — deterministic byte-accounting ratio, 20%
        * paged decode tok/s ratio  — same-machine ratio, 20%
        * paged tok/s               — advisory (wall clock, as above)
        * boot IR-vs-cold speedup   — same-machine ratio, 20%
        * cold/IR boot seconds      — advisory (wall clock, as above)
        * disagg TTFT p99 ratio     — virtual-time ratio (deterministic), 20%
        * disagg chip-seconds ratio — virtual-time ratio (deterministic), 20%
        * sharded per-chip ratio    — billed-FLOPs ratio (deterministic), 20%

    PYTHONPATH=src python benchmarks/validate_bench.py [--candidate DIR]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys


def _get(d: dict, path: str):
    cur = d
    for part in path.split("."):
        if isinstance(cur, list):
            cur = cur[int(part)]
        elif isinstance(cur, dict):
            if part not in cur:
                raise KeyError(path)
            cur = cur[part]
        else:
            raise KeyError(path)
    return cur


# benchmark name -> [(field path, type, predicate description, predicate)]
_SCHEMAS = {
    "BENCH_serving.json": [
        ("benchmark", str, "== serving_throughput",
         lambda v: v == "serving_throughput"),
        ("arch", str, "non-empty", bool),
        ("fused_speedup", (int, float), "> 1", lambda v: v > 1),
        ("modes", list, ">= 2 modes", lambda v: len(v) >= 2),
        ("modes.0.tok_s", (int, float), "> 0", lambda v: v > 0),
        ("modes.1.tok_s", (int, float), "> 0", lambda v: v > 0),
        ("modes.1.syncs_per_step", (int, float), "== 1 (fused contract)",
         lambda v: v == 1.0),
    ],
    "BENCH_fleet.json": [
        ("benchmark", str, "== fleet_scaling",
         lambda v: v == "fleet_scaling"),
        ("scenarios.autoscaled.latency_p99_s", (int, float), "> 0",
         lambda v: v > 0),
        ("scenarios.autoscaled.reconciled", bool, "ledger reconciles",
         lambda v: v is True),
        ("scenarios.autoscaled.served", int, "> 0", lambda v: v > 0),
        ("scenarios.autoscaled.scale_ups", int, ">= 1", lambda v: v >= 1),
    ],
    "BENCH_prefix.json": [
        ("benchmark", str, "== prefix_reuse", lambda v: v == "prefix_reuse"),
        ("prefill_reduction", (int, float), ">= 2 (headline claim)",
         lambda v: v >= 2.0),
        ("scenarios.shared_prefix.token_parity", bool, "parity holds",
         lambda v: v is True),
        ("scenarios.multi_turn.token_parity", bool, "parity holds",
         lambda v: v is True),
        ("fleet.prefix_affinity_routes", int, "> 0", lambda v: v > 0),
        ("fleet.hit_rate", (int, float), "> 0", lambda v: v > 0),
    ],
    "BENCH_spec.json": [
        ("benchmark", str, "== speculative", lambda v: v == "speculative"),
        ("speedup", (int, float), ">= 1.5 (headline claim)",
         lambda v: v >= 1.5),
        ("acceptance_rate", (int, float), "in (0, 1]",
         lambda v: 0 < v <= 1),
        ("token_parity", bool, "greedy streams byte-identical",
         lambda v: v is True),
        ("step_reduction", (int, float), "> 1", lambda v: v > 1),
        ("modes", list, ">= 2 modes", lambda v: len(v) >= 2),
        ("modes.1.tpot_p50_s", (int, float), ">= 0", lambda v: v >= 0),
    ],
    "BENCH_paged.json": [
        ("benchmark", str, "== paged_kv", lambda v: v == "paged_kv"),
        ("concurrency", int, ">= 128 (headline claim)",
         lambda v: v >= 128),
        ("concurrency_per_kv_byte", (int, float), ">= 2 (headline claim)",
         lambda v: v >= 2.0),
        ("decode_tok_s_ratio", (int, float), ">= 0.9 (<=10% regression)",
         lambda v: v >= 0.9),
        ("token_parity", bool, "greedy streams byte-identical",
         lambda v: v is True),
        ("modes", list, ">= 2 modes", lambda v: len(v) >= 2),
        ("modes.1.peak_concurrent", int, ">= 128 in flight",
         lambda v: v >= 128),
        ("modes.1.preemptions", int, "== 0 (pool provisioned)",
         lambda v: v == 0),
    ],
    "BENCH_disagg.json": [
        ("benchmark", str, "== disagg", lambda v: v == "disagg"),
        ("headline.ttft_p99_ratio", (int, float), ">= 1.3 (headline claim)",
         lambda v: v >= 1.3),
        ("headline.chip_seconds_ratio", (int, float),
         "<= 1.05 (headline claim)", lambda v: v <= 1.05),
        ("headline.token_parity", bool, "greedy streams byte-identical",
         lambda v: v is True),
        ("headline.handoffs_installed", int, ">= 1 (pages actually moved)",
         lambda v: v >= 1),
        ("scenarios.disagg.disagg.handoff.sha_rejected", int,
         "== 0 (no corrupt transfers at rest)", lambda v: v == 0),
        ("scenarios.disagg.served", int, "> 0", lambda v: v > 0),
        ("scenarios.disagg.reconciled", bool, "ledger reconciles",
         lambda v: v is True),
    ],
    "BENCH_sharding.json": [
        ("benchmark", str, "== sharded_serving",
         lambda v: v == "sharded_serving"),
        ("capacity.fits_1chip", bool,
         "False (replica exceeds one chip's modeled HBM)",
         lambda v: v is False),
        ("capacity.fits_tp2", bool, "TP=2 per-chip footprint fits",
         lambda v: v is True),
        ("capacity.replica_chips", int, "== 2 (multi-chip lease)",
         lambda v: v == 2),
        ("capacity.fleet_served", int, "> 0", lambda v: v > 0),
        ("token_parity", bool, "greedy streams byte-identical",
         lambda v: v is True),
        ("throughput.per_chip_throughput_ratio", (int, float),
         ">= 0.8 (<= 20% per-chip overhead at TP=2)", lambda v: v >= 0.8),
        ("throughput.modes", list, ">= 2 modes", lambda v: len(v) >= 2),
    ],
    "BENCH_boot.json": [
        ("benchmark", str, "== boot_latency", lambda v: v == "boot_latency"),
        ("arch", str, "non-empty", bool),
        ("ir_speedup", (int, float), ">= 3 (headline claim)",
         lambda v: v >= 3.0),
        ("cold_boot_s", (int, float), "> 0", lambda v: v > 0),
        ("ir_boot_s", (int, float), "> 0", lambda v: v > 0),
        ("token_parity", bool, "greedy streams byte-identical",
         lambda v: v is True),
        ("modes", list, ">= 2 modes", lambda v: len(v) >= 2),
        ("modes.0.warmup_compiles", int, "> 0 (cold rung compiled)",
         lambda v: v > 0),
    ],
}

# (label, file, json path, direction, allowed fractional regression)
# tol=None -> advisory only: absolute tok/s compares a CI runner's wall
# clock against a baseline generated on different hardware, so it is shown
# in the table but never gates; the serving gate is the same-machine
# fused-vs-legacy speedup RATIO, and fleet p99 / prefix reduction are
# virtual-time / token-count metrics (deterministic across machines).
_HEADLINES = [
    ("serving tok/s (fused)", "BENCH_serving.json", "modes.1.tok_s",
     "higher", None),
    ("serving fused speedup", "BENCH_serving.json", "fused_speedup",
     "higher", 0.20),
    ("fleet p99 latency (virtual s)", "BENCH_fleet.json",
     "scenarios.autoscaled.latency_p99_s", "lower", 0.20),
    ("prefix prefill reduction", "BENCH_prefix.json", "prefill_reduction",
     "higher", 0.20),
    ("spec tok/s", "BENCH_spec.json", "modes.1.tok_s", "higher", None),
    ("spec decode speedup", "BENCH_spec.json", "speedup", "higher", 0.20),
    ("spec acceptance rate", "BENCH_spec.json", "acceptance_rate",
     "higher", 0.20),
    ("paged concurrency per KV byte", "BENCH_paged.json",
     "concurrency_per_kv_byte", "higher", 0.20),
    ("paged decode tok/s ratio", "BENCH_paged.json", "decode_tok_s_ratio",
     "higher", 0.20),
    ("paged tok/s", "BENCH_paged.json", "modes.1.tok_s", "higher", None),
    ("boot IR-vs-cold speedup", "BENCH_boot.json", "ir_speedup",
     "higher", 0.20),
    ("cold boot (s)", "BENCH_boot.json", "cold_boot_s", "lower", None),
    ("IR boot (s)", "BENCH_boot.json", "ir_boot_s", "lower", None),
    ("disagg TTFT p99 ratio", "BENCH_disagg.json",
     "headline.ttft_p99_ratio", "higher", 0.20),
    ("disagg chip-seconds ratio", "BENCH_disagg.json",
     "headline.chip_seconds_ratio", "lower", 0.20),
    ("sharded per-chip throughput ratio", "BENCH_sharding.json",
     "throughput.per_chip_throughput_ratio", "higher", 0.20),
]


def validate_schema(root: pathlib.Path) -> list[str]:
    errors = []
    for fname, rules in _SCHEMAS.items():
        path = root / fname
        if not path.exists():
            errors.append(f"{fname}: missing")
            continue
        try:
            data = json.loads(path.read_text())
        except json.JSONDecodeError as e:
            errors.append(f"{fname}: invalid JSON ({e})")
            continue
        for field, typ, desc, pred in rules:
            try:
                val = _get(data, field)
            except (KeyError, IndexError):
                errors.append(f"{fname}: missing field {field!r}")
                continue
            if not isinstance(val, typ):
                errors.append(
                    f"{fname}: {field} has type {type(val).__name__}, "
                    f"expected {typ}")
            elif not pred(val):
                errors.append(f"{fname}: {field}={val!r} violates '{desc}'")
    return errors


def compare(baseline_root: pathlib.Path, candidate_root: pathlib.Path) -> list[str]:
    failures = []
    w = max(len(h[0]) for h in _HEADLINES)
    print(f"\n{'headline metric':<{w}}  {'baseline':>10}  {'candidate':>10} "
          f"{'delta':>8}  {'allowed':>8}  verdict")
    print("-" * (w + 52))
    for label, fname, field, direction, tol in _HEADLINES:
        base = _get(json.loads((baseline_root / fname).read_text()), field)
        cand = _get(json.loads((candidate_root / fname).read_text()), field)
        if direction == "higher":
            regression = (base - cand) / base if base else 0.0
        else:
            regression = (cand - base) / base if base else 0.0
        bad = tol is not None and regression > tol
        verdict = "REGRESSED" if bad else ("info" if tol is None else "ok")
        allowed = "     -- " if tol is None else f"{tol:>7.0%}"
        print(f"{label:<{w}}  {base:>10.3f}  {cand:>10.3f} "
              f"{-regression:>+7.1%}  {allowed}  {verdict}")
        if bad:
            failures.append(
                f"{label}: {base:.3f} -> {cand:.3f} "
                f"({regression:.1%} worse, allowed {tol:.0%})")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=".",
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--candidate", default=None,
                    help="directory with freshly generated BENCH_*.json to "
                         "gate against the baseline")
    args = ap.parse_args()

    baseline = pathlib.Path(args.baseline)
    errors = validate_schema(baseline)
    for e in errors:
        print(f"schema: {e}", file=sys.stderr)
    if args.candidate:
        cand = pathlib.Path(args.candidate)
        errors += [f"candidate {e}" for e in validate_schema(cand)]
        if not errors:
            errors += compare(baseline, cand)
    if errors:
        print(f"\nvalidate_bench: {len(errors)} failure(s)", file=sys.stderr)
        raise SystemExit(1)
    print("\nvalidate_bench OK"
          + ("" if args.candidate else " (schema only)"))


if __name__ == "__main__":
    main()
