"""Benchmark entry point: one bench per paper claim + the roofline report.

    PYTHONPATH=src python -m benchmarks.run [--skip-roofline]

Prints ``name,value,unit,detail`` CSV rows per claim bench, then the
roofline tables derived from results/dryrun (if the dry-run has been run).
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--only", default=None,
                    help="substring filter on bench names")
    args = ap.parse_args()

    from benchmarks import paper_claims

    failures = 0
    print("name,value,unit,detail")
    for bench in paper_claims.ALL:
        if args.only and args.only not in bench.__name__:
            continue
        try:
            for name, value, unit, detail in bench():
                print(f"{name},{value:.6g},{unit},{detail}")
        except Exception as e:  # a failing bench must not hide the others
            failures += 1
            print(f"{bench.__name__},ERROR,,{type(e).__name__}: {e}",
                  file=sys.stdout)
            traceback.print_exc(file=sys.stderr)

    if not args.skip_roofline:
        from benchmarks import roofline

        roofline.main()

    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
