"""Per-claim benchmarks — one per XaaS paper table/claim (deliverable (d)).

Each bench returns rows of (name, value, unit, detail); run.py prints CSV.
All numbers are REAL measurements on this host (the roofline, which models
TPU, lives in roofline.py).
"""
from __future__ import annotations

import time
import timeit

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hooks, invocation, recompile, scheduler
from repro.core.accounting import Meter
from repro.core.container import XContainer


def _mm_container(n=128):
    def fn(a, b):
        return hooks.call("matmul", a, b)

    def make_args(mesh):
        sds = jax.ShapeDtypeStruct((n, n), jnp.float32)
        return (sds, sds), {}, {}

    return XContainer(name=f"mm{n}", entrypoints={"mm": (fn, make_args)})


# ---------------------------------------------------------------------------
# Claim: hooked libraries add "close-to-zero overheads" vs bare metal
# ---------------------------------------------------------------------------
def bench_hook_overhead():
    x = jnp.ones((256, 256))
    direct = jax.jit(lambda a, b: jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype))
    binding = hooks.bind(None)

    def hooked_fn(a, b):
        with hooks.use(binding):
            return hooks.call("matmul", a, b)

    hooked = jax.jit(hooked_fn)
    direct(x, x).block_until_ready()
    hooked(x, x).block_until_ready()
    n = 300
    t_direct = timeit.timeit(lambda: direct(x, x).block_until_ready(), number=n) / n
    t_hooked = timeit.timeit(lambda: hooked(x, x).block_until_ready(), number=n) / n
    # the hook call is resolved at TRACE time: compiled programs are
    # structurally identical (op sequence modulo naming/metadata)
    def _structure(compiled):
        import re

        ops = []
        for line in compiled.as_text().splitlines():
            ls = line.strip()
            if "=" in ls and ls.startswith(("%", "ROOT")):
                rhs = ls.split("=", 1)[1]
                rhs = re.sub(r"metadata=\{[^}]*\}", "", rhs)
                rhs = re.sub(r"%[\w.\-]+", "%x", rhs)
                ops.append(rhs.strip().rstrip(","))
        return ops

    same_hlo = (_structure(direct.lower(x, x).compile())
                == _structure(hooked.lower(x, x).compile()))
    return [
        ("hook_overhead.direct_us", t_direct * 1e6, "us", "bare jit matmul"),
        ("hook_overhead.hooked_us", t_hooked * 1e6, "us", "via hooks.call"),
        ("hook_overhead.delta_pct", 100 * (t_hooked - t_direct) / t_direct,
         "%", "claim: ~0 (hook resolves at trace time)"),
        ("hook_overhead.identical_hlo", float(same_hlo), "bool",
         "compiled programs structurally identical"),
    ]


# ---------------------------------------------------------------------------
# Claim: deployment recompilation — warm deploys in "seconds, not minutes"
# ---------------------------------------------------------------------------
def bench_recompile_cache():
    comp = recompile.DeploymentCompiler()
    cont_fn = lambda a: jnp.tanh(a @ a) @ a
    x = jnp.zeros((512, 512))
    t0 = time.perf_counter()
    comp.deploy(cont_fn, "c", recompile.PORTABLE_CPU, args=(x,))
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    art = comp.deploy(cont_fn, "c", recompile.PORTABLE_CPU, args=(x,))
    warm = time.perf_counter() - t0
    assert art.cache_hit
    return [
        ("recompile.cold_deploy_s", cold, "s", "trace+lower+XLA compile"),
        ("recompile.warm_deploy_s", warm, "s", "cache hit (the paper's "
         "container-reuse warm start)"),
        ("recompile.speedup", cold / max(warm, 1e-9), "x", ""),
    ]


# ---------------------------------------------------------------------------
# Claim: FaaS-grade invocation with fine-grained billing, low control-plane
# overhead (REST off the data path)
# ---------------------------------------------------------------------------
def bench_invocation_overhead():
    cluster = scheduler.Cluster(chips=16)
    svc = invocation.InvocationService(cluster, Meter(),
                                       measure_wall_time=True)
    cont = _mm_container(256)
    lease = svc.acquire("t", cont, recompile.PORTABLE_CPU)
    art = lease.deployment.artifact("mm")
    x = jnp.ones((256, 256))
    art(x, x)  # warm
    n = 200
    t_bare = timeit.timeit(lambda: art(x, x), number=n) / n
    t_inv = timeit.timeit(lambda: svc.invoke(lease, "mm", x, x), number=n) / n
    svc.release(lease)
    return [
        ("invocation.bare_call_us", t_bare * 1e6, "us", "compiled artifact"),
        ("invocation.metered_us", t_inv * 1e6, "us",
         "through lease + ledger (control plane)"),
        ("invocation.overhead_us", (t_inv - t_bare) * 1e6, "us",
         "claim: fine-grained metering at ~us cost"),
    ]


# ---------------------------------------------------------------------------
# Claim: fine-grained accounting is accurate (billed == analyzed)
# ---------------------------------------------------------------------------
def bench_accounting_accuracy():
    comp = recompile.DeploymentCompiler()
    n = 384
    x = jnp.zeros((n, n))
    art = comp.deploy(lambda a, b: a @ b, "mm", recompile.PORTABLE_CPU,
                      args=(x, x))
    meter = Meter()
    bill = meter.record(tenant="t", kind="mm", steps=7, chips=1, wall_s=0.1,
                        artifact=art)
    analytic = 2.0 * n**3
    return [
        ("accounting.billed_flops", bill.flops, "flop", "from artifact"),
        ("accounting.analytic_flops", analytic, "flop", "2*n^3"),
        ("accounting.rel_err", abs(bill.flops - analytic) / analytic, "",
         "claim: billing == compiled truth"),
    ]


# ---------------------------------------------------------------------------
# Claim: EASY backfill raises utilization without starving the head job
# ---------------------------------------------------------------------------
def bench_scheduler_backfill():
    def workload(c: scheduler.Cluster):
        rng = np.random.default_rng(0)
        for i in range(200):
            c.submit(tenant=f"t{i % 7}",
                     chips=int(rng.integers(1, 129)),
                     runtime_s=float(rng.uniform(1, 50)),
                     klass=scheduler.JobClass.BATCH,
                     at=float(rng.uniform(0, 200)))
        c.run()
        return c.utilization(), c.mean_wait()

    u_bf, w_bf = workload(scheduler.Cluster(chips=256, backfill=True))
    u_no, w_no = workload(scheduler.Cluster(chips=256, backfill=False))
    return [
        ("scheduler.util_backfill", u_bf, "frac", "EASY backfill"),
        ("scheduler.util_fcfs", u_no, "frac", "strict FCFS"),
        ("scheduler.util_gain_pct", 100 * (u_bf - u_no) / max(u_no, 1e-9),
         "%", "claim: backfill raises utilization"),
        ("scheduler.wait_backfill_s", w_bf, "s", ""),
        ("scheduler.wait_fcfs_s", w_no, "s", ""),
    ]


# ---------------------------------------------------------------------------
# Claim: performance-portable containers — portable vs system-optimized
# implementations of one accelerated API produce the same numerics with
# different performance profiles
# ---------------------------------------------------------------------------
def bench_kernel_tiers():
    from repro.kernels import ops, ref

    key = jax.random.key(0)
    q = jax.random.normal(key, (1, 2048, 4, 64), jnp.float32)
    k = jax.random.normal(key, (1, 2048, 1, 64), jnp.float32)
    v = jax.random.normal(key, (1, 2048, 1, 64), jnp.float32)
    f_ref = jax.jit(lambda q, k, v: ref.attention(q, k, v, causal=True))
    f_blk = jax.jit(lambda q, k, v: ops.blocked_attention(
        q, k, v, causal=True, block_q=256, block_k=512))
    a = f_ref(q, k, v).block_until_ready()
    b = f_blk(q, k, v).block_until_ready()
    err = float(jnp.max(jnp.abs(a - b)))
    n = 10
    t_ref = timeit.timeit(lambda: f_ref(q, k, v).block_until_ready(), number=n) / n
    t_blk = timeit.timeit(lambda: f_blk(q, k, v).block_until_ready(), number=n) / n
    return [
        ("kernels.attention_portable_ms", t_ref * 1e3, "ms",
         "O(S^2) oracle (this host)"),
        ("kernels.attention_blocked_ms", t_blk * 1e3, "ms",
         "memory-bounded tier (this host)"),
        ("kernels.tier_max_abs_err", err, "", "ABI contract: same numerics"),
    ]


ALL = [
    bench_hook_overhead,
    bench_recompile_cache,
    bench_invocation_overhead,
    bench_accounting_accuracy,
    bench_scheduler_backfill,
    bench_kernel_tiers,
]
