"""Roofline analysis (assignment deliverable (g)): read the dry-run JSON
records and derive the three-term roofline per (arch x shape x mesh).

    compute    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = ICI_bytes_per_chip / (links x link_bw)  [+ DCN term]

Hardware constants are assignment-fixed (TPU v5e): 197 TFLOP/s bf16,
819 GB/s HBM, 4 links x 50 GB/s ICI, 25 GB/s DCN per chip-pair row.
HLO terms come from the loop-aware walker (launch/hlo_cost.py) recorded by
launch/dryrun.py; MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference) with
N = active non-embedding params.
"""
from __future__ import annotations

import json
import pathlib

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9 * 4  # 4 links/chip participating
DCN_BW = 25e9

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_cells(mesh: str = "single", tag: str = "") -> list[dict]:
    d = RESULTS / mesh
    if not d.exists():
        return []
    out = []
    for p in sorted(d.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("tag", "") != tag:
            continue
        out.append(rec)
    return out


def roofline_row(rec: dict) -> dict | None:
    """Three terms (seconds), dominant bottleneck, usefulness ratio."""
    if rec.get("status") != "ok":
        return {
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "status": rec.get("status"), "reason": rec.get("reason",
                                                           rec.get("error")),
        }
    hc = rec["hlo_cost"]
    chips = rec["chips"]
    t_comp = hc["flops"] / PEAK_FLOPS
    # memory term from the fusion-optimistic byte count (hbm_min): the
    # CPU-lowered HLO leaves elementwise ops unfused that TPU fuses, so the
    # raw walker bytes overstate traffic 10-50x; both are recorded.
    t_mem = hc.get("hbm_min", hc["hbm_bytes"]) / HBM_BW
    t_mem_ub = hc["hbm_bytes"] / HBM_BW
    # collective bytes in the walker are whole-program; per-chip wire bytes
    # for ring collectives ~= payload_per_chip, and the walker already sees
    # the per-chip partitioned module -> use directly
    t_ici = hc["collective_bytes_ici"] / ICI_BW
    t_dcn = hc["collective_bytes_dcn"] / DCN_BW
    t_coll = t_ici + t_dcn
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    useful = rec["model_flops"] / max(hc["flops"] * chips, 1e-30)
    # roofline fraction: useful model FLOP/s achieved vs fleet peak,
    # at the overlap-optimistic step time
    mfu = rec["model_flops"] / max(step_s * chips * PEAK_FLOPS, 1e-30)
    mem = rec.get("memory") or {}
    hbm_gb = (mem.get("argument_size_in_bytes", 0)
              + mem.get("temp_size_in_bytes", 0)) / 2**30
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "status": "ok",
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_memory_ub_s": t_mem_ub,
        "t_ici_s": t_ici, "t_dcn_s": t_dcn,
        "dominant": dominant, "step_s": step_s,
        "useful_ratio": useful, "mfu": mfu,
        "hbm_gb_per_chip": hbm_gb,
        "fits_16gb": hbm_gb <= 16.0,
        "compile_s": rec.get("compile_s"),
    }


def table(mesh: str = "single", tag: str = "") -> list[dict]:
    return [r for r in (roofline_row(rec) for rec in load_cells(mesh, tag))
            if r is not None]


def markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | ici s | dcn s | "
           "dominant | useful | MFU | HBM GiB | fits |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in rows:
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                f"{r['status']}: {str(r.get('reason'))[:60]} | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} | "
            f"{r['t_memory_s']:.3f} | {r['t_ici_s']:.3f} | "
            f"{r['t_dcn_s']:.3f} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['mfu']:.3f} | "
            f"{r['hbm_gb_per_chip']:.1f} | "
            f"{'yes' if r['fits_16gb'] else 'NO'} |")
    return "\n".join(lines)


def main() -> None:
    for mesh in ("single", "multi"):
        rows = table(mesh)
        if not rows:
            print(f"[roofline] no dry-run records for mesh={mesh}")
            continue
        print(f"\n== Roofline ({mesh}-pod) ==")
        print(markdown(rows))
        ok = [r for r in rows if r["status"] == "ok"]
        if ok:
            worst = min(ok, key=lambda r: r["mfu"])
            print(f"\nworst MFU: {worst['arch']} x {worst['shape']} "
                  f"({worst['mfu']:.4f})")
            coll = max(ok, key=lambda r: r["t_ici_s"] + r["t_dcn_s"])
            print(f"most collective-bound: {coll['arch']} x {coll['shape']}")


if __name__ == "__main__":
    main()
