"""Boot-latency benchmark: the IR-boot ladder vs cold trace+compile.

A serving replica boots through a three-rung ladder (docs/ir-containers.md):

  * **cold** — trace + XLA-compile every data-plane program, then persist
    the serialized executables into the container's ``ArtifactStore``.
  * **warm** — an in-process engine with the same bundle key reuses the
    already-compiled program cache (the intra-process rung).
  * **IR**   — a FRESH process (simulated with ``clear_program_caches()``)
    deserializes the persisted executables and installs them: zero traces,
    zero compiles, sub-second boot.

The headline is the IR-vs-cold wall-clock ratio, and the contract is the
same as every other acceleration in this repo: byte-identical greedy token
streams across all three rungs — an IR boot is a faster way to reach the
SAME executable, never a behavior change. Both are asserted here
(``ir_speedup >= 3x`` hard; parity always) and re-gated by
``benchmarks/validate_bench.py`` on the committed ``BENCH_boot.json``.

``--smoke`` is the CI variant: boots the same ``serving_container`` twice
through the real control plane (``InvocationService.acquire_serving``) with
a program-cache clear in between, and asserts the second boot lands on the
IR rung with zero warmup compiles.

    PYTHONPATH=src python benchmarks/boot_latency.py [--repeats 2]
    PYTHONPATH=src python benchmarks/boot_latency.py --smoke --out /tmp/b.json
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time

import jax
import numpy as np

from repro import configs
from repro.checkpoint.store import ArtifactStore
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine, clear_program_caches
from repro.serving.sampling import SamplingConfig

ARCH = "qwen2-0.5b-smoke"
GEOM = dict(slots=2, max_len=32, prompt_buckets=(8,))


def _requests(cfg, n: int, max_new: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [Request(request_id=i,
                    prompt=rng.integers(0, cfg.vocab_size, (6,),
                                        dtype=np.int32),
                    max_new_tokens=max_new, sampling=SamplingConfig())
            for i in range(n)]


def _boot_and_serve(cfg, params, store, reqs, *, expect: str) -> dict:
    """One rung: construct + warmup an engine (timed), assert the ladder
    landed where expected, then serve the stream for the parity check."""
    t0 = time.perf_counter()
    engine = ServingEngine(cfg, params, artifact_store=store, **GEOM)
    man = engine.warmup()
    boot_s = time.perf_counter() - t0
    boot = man["boot"]
    assert boot["path"] == expect, (
        f"expected {expect}-boot, got {boot['path']} "
        f"(fallthrough: {boot['fallthrough']})")
    if expect in ("warm", "ir"):
        assert boot["warmup_compiles"] == 0, (
            f"{expect}-boot re-traced {boot['warmup_compiles']} program(s)")
    for r in reqs:
        engine.submit(r)
    results = engine.run_to_completion()
    return {
        "mode": expect,
        "boot_s": boot_s,
        "warmup_compiles": boot["warmup_compiles"],
        "programs_installed": boot["programs"]["installed"],
        "bundle_key": boot["bundle_key"],
        "results": {rid: r.tokens for rid, r in results.items()},
    }


def bench(cfg, params, reqs, repeats: int) -> list[dict]:
    """Cold -> warm -> IR, ``repeats`` times (fresh store per trial so the
    cold rung stays cold); keeps the fastest trial per rung. Token streams
    are asserted identical across every rung of every trial."""
    best: dict[str, dict] = {}
    golden = None
    for _ in range(max(repeats, 1)):
        with tempfile.TemporaryDirectory() as d:
            store = ArtifactStore(d)
            clear_program_caches()
            rows = [_boot_and_serve(cfg, params, store, reqs, expect="cold")]
            rows.append(_boot_and_serve(cfg, params, store, reqs,
                                        expect="warm"))
            clear_program_caches()
            rows.append(_boot_and_serve(cfg, params, store, reqs,
                                        expect="ir"))
        for row in rows:
            if golden is None:
                golden = row["results"]
            assert row["results"] == golden, (
                f"{row['mode']}-boot changed a greedy token stream")
            cur = best.get(row["mode"])
            if cur is None or row["boot_s"] < cur["boot_s"]:
                best[row["mode"]] = row
    return [best["cold"], best["warm"], best["ir"]]


def smoke(cfg, params) -> dict:
    """CI boot-path smoke: deploy + boot the same container twice through
    the control plane; the second boot (fresh program caches, same store)
    must land on the IR rung."""
    from repro.core import recompile, scheduler
    from repro.core.invocation import InvocationService
    from repro.serving.service import serving_container

    reqs = _requests(cfg, 2, 4)
    profile = recompile.PORTABLE_CPU
    boots = []
    with tempfile.TemporaryDirectory() as d:
        store = ArtifactStore(d)
        golden = None
        for i in range(2):
            clear_program_caches()
            cont = serving_container(cfg, params, artifact_store=store,
                                     **GEOM)
            cluster = scheduler.Cluster(chips=profile.chips)
            service = InvocationService(cluster)
            t0 = time.perf_counter()
            with service.acquire_serving("boot-smoke", cont,
                                         profile) as executor:
                man = executor.warmup()
                boot_s = time.perf_counter() - t0
                for r in reqs:
                    executor.submit(r)
                results = {rid: r.tokens
                           for rid, r in executor.run().items()}
            boot = man["boot"]
            if golden is None:
                golden = results
            assert results == golden, "reboot changed a greedy token stream"
            boots.append({"mode": boot["path"], "boot_s": boot_s,
                          "warmup_compiles": boot["warmup_compiles"],
                          "programs_installed": boot["programs"]["installed"],
                          "bundle_key": boot["bundle_key"],
                          "results": results})
    assert boots[0]["mode"] == "cold", (
        f"first boot should be cold, got {boots[0]['mode']}")
    assert boots[1]["mode"] == "ir", (
        f"second boot should be ir, got {boots[1]['mode']}")
    assert boots[1]["warmup_compiles"] == 0, "IR boot re-traced programs"
    return {"boots": boots}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=5)
    ap.add_argument("--repeats", type=int, default=2,
                    help="cold/warm/IR trials; fastest per rung is kept")
    ap.add_argument("--smoke", action="store_true",
                    help="CI variant: boot the same container twice via the "
                         "control plane, assert the second boot is IR")
    ap.add_argument("--out", default="BENCH_boot.json")
    args = ap.parse_args()

    cfg = configs.get_config(ARCH)
    params = transformer.init_model(jax.random.key(0), cfg)

    if args.smoke:
        sm = smoke(cfg, params)
        modes = sm["boots"]
    else:
        reqs = _requests(cfg, args.requests, args.max_new)
        modes = bench(cfg, params, reqs, args.repeats)

    by = {m["mode"]: m for m in modes}
    cold_s = by["cold"]["boot_s"]
    ir_s = by["ir"]["boot_s"]
    ir_speedup = cold_s / max(ir_s, 1e-9)

    hdr = f"{'mode':<6} {'boot_s':>8} {'compiles':>9} {'installed':>10}"
    print(f"\narch={ARCH} slots={GEOM['slots']} max_len={GEOM['max_len']}")
    print(hdr)
    print("-" * len(hdr))
    for m in modes:
        print(f"{m['mode']:<6} {m['boot_s']:>8.3f} "
              f"{m['warmup_compiles']:>9} {m['programs_installed']:>10}")
    print(f"\nIR-boot speedup vs cold: {ir_speedup:.1f}x "
          f"({cold_s:.2f}s -> {ir_s:.2f}s), byte-identical greedy streams")

    # the acceptance gate: IR-boot must beat cold trace+compile by >= 3x
    assert ir_speedup >= 3.0, (
        f"IR-boot speedup {ir_speedup:.1f}x < 3x gate "
        f"(cold {cold_s:.2f}s, ir {ir_s:.2f}s)")

    payload = {
        "benchmark": "boot_latency",
        "arch": ARCH,
        "slots": GEOM["slots"],
        "max_len": GEOM["max_len"],
        "smoke": args.smoke,
        "ir_speedup": round(ir_speedup, 3),
        "cold_boot_s": round(cold_s, 4),
        "warm_boot_s": round(by["warm"]["boot_s"], 4) if "warm" in by else None,
        "ir_boot_s": round(ir_s, 4),
        "token_parity": True,  # asserted above on every rung
        "modes": [{k: v for k, v in m.items() if k != "results"}
                  for m in modes],
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")
    print("boot_latency OK")


if __name__ == "__main__":
    main()
