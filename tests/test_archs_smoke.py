"""Per-arch smoke tests (assignment deliverable (f)): reduced same-family
configs run one forward + one train step on CPU; output shapes + no NaNs.
Serving consistency: prefill+decode matches the full forward (dropless MoE
capacity for exactness — capacity dropping is group-dependent by design)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs import base as cfgbase
from repro.models import frontends, transformer
from repro.training import train_step as ts

ARCHS = list(configs.ARCH_IDS)


def _inputs(cfg, key, b=2, s=16):
    if cfg.frontend == "audio":
        tokens = jax.random.randint(key, (b, cfg.num_codebooks, s), 0, cfg.vocab_size)
    else:
        tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    kw = {}
    if cfg.frontend == "vlm":
        kw["patch_embeds"] = jax.random.normal(
            key, (b, cfg.num_image_tokens, frontends.VIS_DIM), jnp.float32)
    return tokens, kw


def _dropless(cfg):
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_finite(arch):
    cfg = configs.get_config(arch + "-smoke")
    key = jax.random.key(0)
    params = transformer.init_model(key, cfg)
    tokens, kw = _inputs(cfg, key)
    logits, aux = transformer.forward(params, cfg, tokens, **kw)
    b, s = 2, 16
    s_total = s + (cfg.num_image_tokens if cfg.frontend == "vlm" else 0)
    if cfg.frontend == "audio":
        assert logits.shape == (b, cfg.num_codebooks, s, cfg.vocab_size)
    else:
        assert logits.shape == (b, s_total, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_loss_decreases(arch):
    cfg = configs.get_config(arch + "-smoke")
    tcfg = ts.TrainConfig(microbatches=2)
    state = ts.init_train_state(jax.random.key(0), cfg, tcfg)
    step = jax.jit(ts.make_train_step(cfg, tcfg))
    key = jax.random.key(3)
    tokens, kw = _inputs(cfg, key, b=4)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=-1), **kw}
    losses = []
    for _ in range(4):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert all(jnp.isfinite(jnp.asarray(losses)))
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = _dropless(configs.get_config(arch + "-smoke"))
    key = jax.random.key(1)
    params = transformer.init_model(key, cfg)
    b, s, max_len = 2, 12, 48
    tokens, kw = _inputs(cfg, key, b=b, s=s)

    logits_pf, states, lengths = transformer.prefill(params, cfg, tokens,
                                                     max_len, **kw)
    logits_full, _ = transformer.forward(params, cfg, tokens, **kw)
    last = logits_full[:, :, -1] if cfg.frontend == "audio" else logits_full[:, -1]
    assert float(jnp.max(jnp.abs(logits_pf - last))) < 1e-3

    # greedy-decode two tokens, checking each against the full forward
    cur = tokens
    for _ in range(2):
        nxt = jnp.argmax(logits_pf, -1).astype(jnp.int32)
        lengths = lengths + 1
        logits_pf, states = transformer.decode_step(params, cfg, nxt, states,
                                                    lengths)
        cur = jnp.concatenate(
            [cur, nxt[..., None]], axis=-1)
        full, _ = transformer.forward(params, cfg, cur, **kw)
        last = full[:, :, -1] if cfg.frontend == "audio" else full[:, -1]
        assert float(jnp.max(jnp.abs(logits_pf - last))) < 5e-3


@pytest.mark.parametrize("arch", ARCHS)
def test_assigned_config_exact(arch):
    """The full config matches the assignment table exactly."""
    spec = {
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
    }[arch]
    cfg = configs.get_config(arch)
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == spec, f"{arch}: {got} != {spec}"
    # layer layout consistency
    assert len(cfg.layer_specs()) == cfg.num_layers
    # MoE details per the assignment
    if arch == "moonshot-v1-16b-a3b":
        assert cfg.moe.num_experts == 64 and cfg.moe.top_k == 6
    if arch == "deepseek-v3-671b":
        assert cfg.moe.num_experts == 256 and cfg.moe.top_k == 8
        assert cfg.mla is not None


def test_shape_applicability_covers_40_cells():
    cells = [(a, s) for a in ARCHS for s in cfgbase.SHAPES]
    assert len(cells) == 40
    runnable = [
        (a, s) for a, s in cells
        if cfgbase.shape_applicable(configs.get_config(a), cfgbase.SHAPES[s])[0]
    ]
    skipped = set(cells) - set(runnable)
    # long_500k runs only for the two sub-quadratic archs
    assert skipped == {
        (a, "long_500k") for a in ARCHS
        if a not in ("xlstm-1.3b", "recurrentgemma-9b")
    }
