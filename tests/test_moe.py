"""MoE routing properties (hypothesis) + dispatch/combine correctness vs a
dense per-token reference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models import moe


def _cfg(e=8, k=2, cap_f=1.25, shared=0, bias=False):
    base = configs.get_config("moonshot-v1-16b-a3b-smoke")
    return dataclasses.replace(
        base,
        moe=dataclasses.replace(
            base.moe, num_experts=e, top_k=k, capacity_factor=cap_f,
            num_shared_experts=shared, d_shared=32 if shared else 0,
            bias_routing=bias))


@given(
    s=st.integers(4, 32),
    e=st.sampled_from([4, 8]),
    k=st.integers(1, 3),
    seed=st.integers(0, 2**30),
)
@settings(max_examples=25, deadline=None)
def test_routing_properties(s, e, k, seed):
    """Capacity respected; gates normalized; kept slots unique per bucket."""
    k = min(k, e)
    cfg = _cfg(e=e, k=k)
    key = jax.random.key(seed)
    p = moe.init(key, cfg)
    x = jax.random.normal(jax.random.key(seed + 1), (2, s, cfg.d_model)) * 0.5

    gates, ids, probs = moe.router(p, cfg, x)
    # gates are a normalized distribution over the top-k
    np.testing.assert_allclose(np.asarray(jnp.sum(gates, -1)), 1.0, atol=1e-5)
    assert bool(jnp.all(ids >= 0)) and bool(jnp.all(ids < e))

    cap = moe.capacity(cfg, s)
    flat_ids = ids.reshape(2, s * k)
    dest, order, keep = jax.vmap(
        lambda f: moe._route_group(f, e, cap))(flat_ids)
    nslots = e * cap
    # kept slots land strictly inside buckets; each bucket slot used once
    d = np.asarray(dest)
    kept = np.asarray(keep)
    assert (d[kept] < nslots).all()
    assert (d[~kept] == nslots).all()
    for b in range(2):
        used = d[b][kept[b]]
        assert len(np.unique(used)) == len(used)
    # per-expert kept count never exceeds capacity
    for b in range(2):
        for ex in range(e):
            in_bucket = ((d[b] >= ex * cap) & (d[b] < (ex + 1) * cap)).sum()
            assert in_bucket <= cap


def test_moe_matches_dense_reference_dropless():
    cfg = _cfg(e=8, k=2, cap_f=64.0, shared=1, bias=True)
    key = jax.random.key(0)
    p = moe.init(key, cfg)
    x = jax.random.normal(jax.random.key(1), (2, 12, cfg.d_model)) * 0.3
    out, metrics = moe.apply(p, cfg, x)
    assert float(metrics["moe_dropped_frac"]) == 0.0

    gates, ids, _ = moe.router(p, cfg, x)
    ref = jnp.zeros_like(x)
    for b in range(2):
        for s in range(12):
            acc = jnp.zeros((cfg.d_model,))
            for j in range(cfg.moe.top_k):
                e_idx = int(ids[b, s, j])
                h = x[b, s]
                g = h @ p["experts"]["w_gate"][e_idx]
                u = h @ p["experts"]["w_up"][e_idx]
                acc += float(gates[b, s, j]) * (
                    (jax.nn.silu(g) * u) @ p["experts"]["w_down"][e_idx])
            ref = ref.at[b, s].set(acc)
    sh = p["shared"]
    g = x @ sh["w_gate"]["w"]
    u = x @ sh["w_up"]["w"]
    ref = ref + (jax.nn.silu(g) * u) @ sh["w_down"]["w"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_dropping_zeroes_not_corrupts():
    """With capacity 0 margin, dropped tokens contribute zero (not garbage)."""
    cfg = _cfg(e=4, k=2, cap_f=0.25)
    p = moe.init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 16, cfg.d_model))
    out, metrics = moe.apply(p, cfg, x)
    assert float(metrics["moe_dropped_frac"]) > 0
    assert bool(jnp.isfinite(out).all())


def test_router_bias_update_direction():
    """Aux-free balancing nudges under-loaded experts up."""
    bias = jnp.zeros((4,))
    load = jnp.asarray([0.7, 0.1, 0.1, 0.1])
    new = moe.update_router_bias(bias, load, rate=0.1)
    assert float(new[0]) < 0  # overloaded expert pushed down
    assert all(float(new[i]) > 0 for i in (1, 2, 3))


def test_moe_grads_flow_to_all_parts():
    cfg = _cfg(e=4, k=2, shared=1)
    p = moe.init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model)) * 0.3
    g = jax.grad(lambda pp: jnp.sum(moe.apply(pp, cfg, x)[0] ** 2))(p)
    for path, leaf in jax.tree_util.tree_leaves_with_path(g):
        if "router" in str(path) and "bias" in str(path):
            continue  # bias routes through top_k: no gradient by design
        assert bool(jnp.isfinite(leaf).all())
    assert float(jnp.sum(jnp.abs(g["experts"]["w_gate"]))) > 0
    assert float(jnp.sum(jnp.abs(g["shared"]["w_up"]["w"]))) > 0
