"""Sharding rules and the sharded serving data plane.

Deterministic half of the sharding suite (the guarded_spec hypothesis
properties live in test_sharding_props.py): param/state rule totality,
recipe rule composition, and — on a forced multi-device host platform
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``, the dedicated CI
step) — sharded-vs-unsharded greedy stream parity across every serving
path plus fleet metering over multi-chip replicas. On a single-device run
those tests skip and the portability-floor tests still execute.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.distributed import sharding as shd
from repro.launch import recipes as rec
from repro.models import transformer

NDEV = jax.device_count()
needs_2dev = pytest.mark.skipif(
    NDEV < 2, reason="needs >=2 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
needs_8dev = pytest.mark.skipif(
    NDEV < 8, reason="needs 8 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _mesh(shape=(2, 4), axes=("data", "model")):
    # multiple *logical* devices are not needed: guarded_spec only reads
    # mesh.shape, so a 1-device abstract mesh works
    devs = np.array(jax.devices() * int(np.prod(shape)))[: int(np.prod(shape))]
    return jax.sharding.Mesh(devs.reshape(shape), axes)


MESH = _mesh()


def test_guarded_spec_tuple_degrade():
    """batch=256 on a ("pod","data","model") product that doesn't divide
    degrades to the longest dividing prefix."""
    mesh = _mesh((2, 4, 2), ("pod", "data", "model"))
    rules = dict(shd.RULES_2D, batch=("pod", "data", "model"))
    with shd.use_rules(rules, mesh):
        spec = shd.guarded_spec((8, 16), ("batch", None))
    assert tuple(spec)[0] == ("pod", "data")  # 8 % (2*4*2) != 0 -> drop model


@pytest.mark.parametrize("arch", list(configs.ARCH_IDS))
def test_param_rules_total(arch):
    """Every parameter of every arch matches a PARAM_RULES entry and gets a
    valid spec on the production-shaped mesh."""
    cfg = configs.get_config(arch + "-smoke")
    params = jax.eval_shape(
        lambda: transformer.init_model(jax.random.key(0), cfg))
    axes = shd.logical_param_axes(params)  # raises if any param unmatched
    with shd.use_rules(dict(shd.RULES_2D), MESH):
        specs = shd.param_pspecs(params)
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for p, s in zip(flat_p, flat_s):
        assert len(tuple(s)) <= p.ndim


def test_recipe_rules_no_axis_conflicts():
    """Recipe-composed rules never produce duplicate-axis specs (the
    moonshot ZeRO-1 regression: moments spec with 'data' twice)."""
    from repro.training import train_step as ts

    mesh = _mesh((4, 4), ("data", "model"))
    for arch in ("moonshot-v1-16b-a3b", "deepseek-v3-671b", "command-r-plus-104b"):
        recipe = rec.recipe_for(arch, "train_4k")
        rules = rec.rules_for(recipe, multi_pod=False, serving=False)
        cfg = configs.get_config(arch + "-smoke")
        tcfg = rec.train_config_for(cfg, recipe, mesh=mesh, multi_pod=False)
        state = jax.eval_shape(
            lambda: ts.init_train_state(jax.random.key(0), cfg, tcfg))
        with shd.use_rules(rules, mesh):
            specs = ts.train_state_pspecs(state, mesh, tcfg)
        for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
            flat = []
            for e in tuple(s):
                if e is None:
                    continue
                flat.extend(e if isinstance(e, tuple) else (e,))
            assert len(flat) == len(set(flat)), f"duplicate axes in {s}"


def test_constraint_noop_without_rules():
    x = jnp.ones((4, 4))
    y = shd.constraint(x, "batch", "embed")
    assert y is x  # the portability floor: plain CPU execution untouched


def test_state_rules_cover_all_archs():
    for arch in configs.ARCH_IDS:
        cfg = configs.get_config(arch + "-smoke")
        states = jax.eval_shape(
            lambda: transformer.init_states(cfg, 2, 16, jnp.float32))
        with shd.use_rules(dict(shd.RULES_2D), MESH):
            shd.state_pspecs(states)  # must not raise


# ---------------------------------------------------------------------------
# Sharded serving data plane: stream parity on a real multi-device mesh.
# ---------------------------------------------------------------------------

def _stream(cfg, params, mesh, *, max_new=10, **engine_kw):
    """Greedy token stream for one request through a fresh engine."""
    from repro.serving.engine import Request, ServingEngine
    from repro.serving.sampling import SamplingConfig

    eng = ServingEngine(cfg, params, slots=2, max_len=64,
                        prompt_buckets=(16, 64), mesh=mesh, **engine_kw)
    eng.warmup()
    lead = (cfg.num_codebooks,) if cfg.frontend == "audio" else ()
    prompt = np.arange(int(np.prod(lead + (7,))),
                       dtype=np.int32).reshape(lead + (7,)) % cfg.vocab_size
    eng.submit(Request(request_id=1, prompt=prompt, max_new_tokens=max_new,
                       sampling=SamplingConfig(temperature=0.0)))
    return [int(t) for t in eng.run_to_completion()[1].tokens]


def _engine_kw(path):
    if path == "prefill_chunk":
        return dict(page_size=16, kv_pages=9, prefill_chunk_tokens=16)
    if path == "spec_verify":
        from repro.serving.speculative import SpecConfig
        return dict(spec=SpecConfig(k=2, proposer="ngram"))
    return {}


# one attention arch (GQA) and one MLA+MoE arch: the MoE one routes its FFN
# through kernels/moe_gmm with experts sharded on the "model" axis
PARITY_ARCHS = ("qwen2-0.5b", "deepseek-v3-671b")


@needs_2dev
@pytest.mark.parametrize("path", ["decode", "prefill_chunk", "spec_verify"])
@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_sharded_stream_parity(arch, path):
    """Greedy streams are identical with and without a (1,2) tensor/expert
    parallel mesh, for the fused-decode, paged+chunked-prefill, and
    speculative-verify data planes."""
    cfg = configs.get_config(arch + "-smoke")
    params = transformer.init_model(jax.random.key(0), cfg)
    kw = _engine_kw(path)
    ref = _stream(cfg, params, None, **kw)
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    got = _stream(cfg, params, mesh, **kw)
    assert got == ref, f"{arch}/{path}: sharded stream diverged"


@needs_8dev
def test_sharded_stream_wide_mesh_completes():
    """A (1,4) model-parallel mesh serves a full greedy stream. Exact parity
    with the unsharded stream is only guaranteed at TP=2: wider meshes
    change the float reduction order of collectives, which can flip argmax
    on the near-uniform logits of a random-init smoke model."""
    cfg = configs.get_config("deepseek-v3-671b-smoke")
    params = transformer.init_model(jax.random.key(0), cfg)
    got = _stream(cfg, params, jax.make_mesh((1, 4), ("data", "model")),
                  max_new=6)
    assert len(got) == 6
    assert all(0 <= t < cfg.vocab_size for t in got)


@needs_2dev
def test_data_axis_mesh_rejected():
    """Data parallelism inside one engine is rejected with a clear error —
    replicas scale out, they don't shard the batch."""
    from repro.serving.engine import ServingEngine

    cfg = configs.get_config("qwen2-0.5b-smoke")
    params = transformer.init_model(jax.random.key(0), cfg)
    with pytest.raises(ValueError, match="data axis"):
        ServingEngine(cfg, params, slots=2, max_len=32,
                      mesh=jax.make_mesh((2, 1), ("data", "model")))


@needs_2dev
def test_expert_weights_and_kv_pool_sharded():
    """The MoE expert stacks and the paged KV pool are *actually* split
    across the model axis — per-device shards are smaller than the global
    array and span every mesh device."""
    from repro.serving.engine import ServingEngine

    cfg = configs.get_config("deepseek-v3-671b-smoke")
    params = transformer.init_model(jax.random.key(0), cfg)
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    eng = ServingEngine(cfg, params, slots=2, max_len=64,
                        prompt_buckets=(16, 64), mesh=mesh,
                        page_size=16, kv_pages=9)
    hits = []

    def check(path, leaf):
        name = jax.tree_util.keystr(path)
        if "experts" in name and "w_up" in name:
            shard = leaf.sharding.shard_shape(leaf.shape)
            assert shard != leaf.shape, f"{name} not sharded: {leaf.shape}"
            assert len(leaf.devices()) == 2
            hits.append(name)
        return leaf

    jax.tree_util.tree_map_with_path(check, eng.params)
    assert hits, "no expert w_up leaves found"
    # paged KV pool: every state leaf lives on the mesh, model-dim leaves
    # (kv heads / latent) shard when divisible
    for leaf in jax.tree.leaves(eng.states):
        assert len(leaf.devices()) in (1, 2) and leaf.committed


@needs_2dev
def test_fleet_two_chip_replica_meters_all_chips():
    """A fleet of (1,2)-mesh replicas leases 2 chips per replica and every
    serving bill meters device-seconds across BOTH chips."""
    from repro import fleet as fl

    cfg = configs.get_config("qwen2-0.5b-smoke")
    params = transformer.init_model(jax.random.key(0), cfg)
    fleet_cfg = fl.FleetConfig(min_replicas=1, max_replicas=2, slots=2,
                               max_len=32, prompt_buckets=(8, 16),
                               tick_s=0.1, warm_boot_s=0.2, cold_boot_s=0.5,
                               prefix_cache_mb=0.0, mesh_shape=(1, 2))
    fm = fl.FleetManager.build(cfg, params, chips=4, fleet=fleet_cfg)
    trace = fl.steady_trace(seed=0, duration_s=6.0, prompt_median=6,
                            prompt_lo=4, prompt_hi=8,
                            max_new_lo=4, max_new_hi=6)
    reqs = fl.materialize(trace, vocab_size=cfg.vocab_size, seed=1,
                          max_prompt_len=16)
    report = fm.run_trace(reqs)
    assert report.served == report.requests
    assert report.reconciled
    for r in report.replicas:
        assert r["chips"] == 2
        assert r["mesh"] == {"shape": [1, 2], "axes": ["data", "model"]}
    decode = [b for b in fm.service.meter.bills if b.kind == "serve_decode"]
    assert decode, "no decode bills recorded"
    for b in decode:
        assert b.chips == 2
        assert b.device_s == pytest.approx(b.wall_s * 2)
