"""Sharding-rule properties: guarded_spec (hypothesis), param-rule totality,
recipe rule composition."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.distributed import sharding as shd
from repro.launch import recipes as rec
from repro.models import transformer


def _mesh(shape=(2, 4), axes=("data", "model")):
    # multiple *logical* devices are not needed: guarded_spec only reads
    # mesh.shape, so a 1-device abstract mesh works
    import numpy as np_

    devs = np_.array(jax.devices() * int(np_.prod(shape)))[: int(np_.prod(shape))]
    return jax.sharding.Mesh(devs.reshape(shape), axes)


MESH = _mesh()


@given(
    dims=st.lists(st.integers(1, 64), min_size=1, max_size=4),
    names=st.lists(
        st.sampled_from(["batch", "heads", "ff", "embed", None]),
        min_size=1, max_size=4),
)
@settings(max_examples=200, deadline=None)
def test_guarded_spec_properties(dims, names):
    """Invariants: never uses a mesh axis twice; every kept axis divides its
    dim; length <= ndim."""
    n = min(len(dims), len(names))
    dims, names = tuple(dims[:n]), tuple(names[:n])
    with shd.use_rules(dict(shd.RULES_2D), MESH):
        spec = shd.guarded_spec(dims, names)
    used = []
    sizes = dict(zip(MESH.axis_names, MESH.devices.shape))
    for dim, entry in zip(dims, tuple(spec)):
        if entry is None:
            continue
        es = entry if isinstance(entry, tuple) else (entry,)
        for a in es:
            assert a not in used, f"axis {a} used twice in {spec}"
            used.append(a)
        total = int(np.prod([sizes[a] for a in es]))
        assert dim % total == 0, f"{dim} % {total} != 0 in {spec}"


def test_guarded_spec_tuple_degrade():
    """batch=256 on a ("pod","data","model") product that doesn't divide
    degrades to the longest dividing prefix."""
    mesh = _mesh((2, 4, 2), ("pod", "data", "model"))
    rules = dict(shd.RULES_2D, batch=("pod", "data", "model"))
    with shd.use_rules(rules, mesh):
        spec = shd.guarded_spec((8, 16), ("batch", None))
    assert tuple(spec)[0] == ("pod", "data")  # 8 % (2*4*2) != 0 -> drop model


@pytest.mark.parametrize("arch", list(configs.ARCH_IDS))
def test_param_rules_total(arch):
    """Every parameter of every arch matches a PARAM_RULES entry and gets a
    valid spec on the production-shaped mesh."""
    cfg = configs.get_config(arch + "-smoke")
    params = jax.eval_shape(
        lambda: transformer.init_model(jax.random.key(0), cfg))
    axes = shd.logical_param_axes(params)  # raises if any param unmatched
    with shd.use_rules(dict(shd.RULES_2D), MESH):
        specs = shd.param_pspecs(params)
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for p, s in zip(flat_p, flat_s):
        assert len(tuple(s)) <= p.ndim


def test_recipe_rules_no_axis_conflicts():
    """Recipe-composed rules never produce duplicate-axis specs (the
    moonshot ZeRO-1 regression: moments spec with 'data' twice)."""
    from repro.training import train_step as ts

    mesh = _mesh((4, 4), ("data", "model"))
    for arch in ("moonshot-v1-16b-a3b", "deepseek-v3-671b", "command-r-plus-104b"):
        recipe = rec.recipe_for(arch, "train_4k")
        rules = rec.rules_for(recipe, multi_pod=False, serving=False)
        cfg = configs.get_config(arch + "-smoke")
        tcfg = rec.train_config_for(cfg, recipe, mesh=mesh, multi_pod=False)
        state = jax.eval_shape(
            lambda: ts.init_train_state(jax.random.key(0), cfg, tcfg))
        with shd.use_rules(rules, mesh):
            specs = ts.train_state_pspecs(state, mesh, tcfg)
        for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
            flat = []
            for e in tuple(s):
                if e is None:
                    continue
                flat.extend(e if isinstance(e, tuple) else (e,))
            assert len(flat) == len(set(flat)), f"duplicate axes in {s}"


def test_constraint_noop_without_rules():
    x = jnp.ones((4, 4))
    y = shd.constraint(x, "batch", "embed")
    assert y is x  # the portability floor: plain CPU execution untouched


def test_state_rules_cover_all_archs():
    for arch in configs.ARCH_IDS:
        cfg = configs.get_config(arch + "-smoke")
        states = jax.eval_shape(
            lambda: transformer.init_states(cfg, 2, 16, jnp.float32))
        with shd.use_rules(dict(shd.RULES_2D), MESH):
            shd.state_pspecs(states)  # must not raise
