"""Scheduler properties: EASY backfill, class priority, elasticity,
conservation invariants (hypothesis-driven random workloads)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.scheduler import Cluster, JobClass, JobState


def test_fcfs_and_finish():
    c = Cluster(chips=100)
    j1 = c.submit(tenant="a", chips=60, runtime_s=10)
    j2 = c.submit(tenant="b", chips=60, runtime_s=10)
    c.run()
    assert j1.state == JobState.DONE and j2.state == JobState.DONE
    assert j1.start_s == 0.0
    assert j2.start_s == 10.0  # had to wait for j1's chips


def test_easy_backfill_small_job_jumps_queue():
    c = Cluster(chips=100)
    c.submit(tenant="a", chips=80, runtime_s=100)      # runs now
    big = c.submit(tenant="b", chips=100, runtime_s=10)  # blocked (head)
    small = c.submit(tenant="c", chips=20, runtime_s=50)  # fits + ends before
    c.run(until=1.0)
    assert small.state == JobState.RUNNING  # backfilled into the 20 free
    assert big.state == JobState.PENDING
    c.run()
    assert big.state == JobState.DONE


def test_backfill_never_delays_reservation():
    c = Cluster(chips=100)
    c.submit(tenant="a", chips=80, runtime_s=100)
    big = c.submit(tenant="b", chips=100, runtime_s=10)
    # would fit now but runs PAST the reservation at t=100 -> must NOT start
    late = c.submit(tenant="c", chips=20, runtime_s=500)
    c.run(until=1.0)
    assert late.state == JobState.PENDING
    c.run()
    assert big.start_s == pytest.approx(100.0)


def test_interactive_priority():
    c = Cluster(chips=10)
    c.submit(tenant="x", chips=10, runtime_s=10)  # occupies everything
    b = c.submit(tenant="x", chips=10, runtime_s=10, klass=JobClass.BATCH)
    i = c.submit(tenant="x", chips=10, runtime_s=1, klass=JobClass.INTERACTIVE)
    c.run()
    assert i.start_s < b.start_s  # interactive served first despite later submit


def test_service_runs_forever_until_cancelled():
    c = Cluster(chips=10)
    s = c.submit(tenant="svc", chips=4, runtime_s=1.0, klass=JobClass.SERVICE)
    c.run(until=1000.0)
    assert s.state == JobState.RUNNING  # ignores runtime_s
    c.cancel(s.job_id)
    c.run()
    assert s.state == JobState.CANCELLED


def test_elastic_shrink_then_grow():
    c = Cluster(chips=10)
    a = c.submit(tenant="a", chips=6, runtime_s=5)
    e = c.submit(tenant="b", chips=8, runtime_s=100, min_chips=2)
    c.run(until=0.0)
    assert e.state == JobState.RUNNING and e.granted_chips == 4  # shrunk start
    c.run(until=6.0)
    assert e.granted_chips == 8  # grew when a finished


def test_failure_event_releases_chips():
    c = Cluster(chips=8)
    j = c.submit(tenant="a", chips=8, runtime_s=100)
    c.run(until=1.0)
    seen = []
    c.listeners.append(lambda kind, job: seen.append((kind, job.job_id)))
    c.fail(j.job_id, at=2.0)
    c.run(until=3.0)
    assert j.state == JobState.FAILED
    assert c.free_chips == 8
    assert ("fail", j.job_id) in seen


@given(
    jobs=st.lists(
        st.tuples(
            st.integers(1, 64),            # chips
            st.floats(0.5, 50.0),          # runtime
            st.sampled_from(list(JobClass)),
            st.floats(0.0, 20.0),          # submit time
        ),
        min_size=1, max_size=30,
    )
)
@settings(max_examples=50, deadline=None)
def test_invariants_random_workloads(jobs):
    c = Cluster(chips=64)
    for chips, rt, klass, at in jobs:
        c.submit(tenant="t", chips=chips, runtime_s=rt, klass=klass, at=at)
    steps = 0
    while c.events_pending() and steps < 2000:
        c.step()
        c.check_invariants()
        steps += 1
    # run-forever services may legitimately pin the cluster; cancel them,
    # then everything remaining must complete
    for j in list(c.jobs.values()):
        if j.klass == JobClass.SERVICE and j.state in (JobState.RUNNING,
                                                       JobState.PENDING):
            c.cancel(j.job_id)
    while c.events_pending() and steps < 4000:
        c.step()
        c.check_invariants()
        steps += 1
    for j in c.jobs.values():
        if j.klass != JobClass.SERVICE:
            assert j.state == JobState.DONE, (j.state, j.chips)
    # utilization is a valid fraction
    assert 0.0 <= c.utilization() <= 1.0 + 1e-9


def test_no_backfill_mode_is_strict_fcfs():
    c = Cluster(chips=100, backfill=False)
    c.submit(tenant="a", chips=80, runtime_s=100)
    c.submit(tenant="b", chips=100, runtime_s=10)
    small = c.submit(tenant="c", chips=10, runtime_s=1)
    c.run(until=1.0)
    assert small.state == JobState.PENDING  # no jumping without backfill

