"""Hypothesis property tests for guarded_spec.

Split from test_sharding.py so the deterministic sharding tests (rule
totality, sharded-vs-unsharded stream parity) still collect in environments
without hypothesis — conftest auto-ignores *_props.py modules there.
"""
import jax
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.distributed import sharding as shd


def _mesh(shape=(2, 4), axes=("data", "model")):
    # multiple *logical* devices are not needed: guarded_spec only reads
    # mesh.shape, so a 1-device abstract mesh works
    devs = np.array(jax.devices() * int(np.prod(shape)))[: int(np.prod(shape))]
    return jax.sharding.Mesh(devs.reshape(shape), axes)


MESH = _mesh()


@given(
    dims=st.lists(st.integers(1, 64), min_size=1, max_size=4),
    names=st.lists(
        st.sampled_from(["batch", "heads", "ff", "embed", None]),
        min_size=1, max_size=4),
)
@settings(max_examples=200, deadline=None)
def test_guarded_spec_properties(dims, names):
    """Invariants: never uses a mesh axis twice; every kept axis divides its
    dim; length <= ndim."""
    n = min(len(dims), len(names))
    dims, names = tuple(dims[:n]), tuple(names[:n])
    with shd.use_rules(dict(shd.RULES_2D), MESH):
        spec = shd.guarded_spec(dims, names)
    used = []
    sizes = dict(zip(MESH.axis_names, MESH.devices.shape))
    for dim, entry in zip(dims, tuple(spec)):
        if entry is None:
            continue
        es = entry if isinstance(entry, tuple) else (entry,)
        for a in es:
            assert a not in used, f"axis {a} used twice in {spec}"
            used.append(a)
        total = int(np.prod([sizes[a] for a in es]))
        assert dim % total == 0, f"{dim} % {total} != 0 in {spec}"
