"""Property tests for the paged-KV block manager and the page-aliasing
prefix cache: refcount balance (everything allocated is freed exactly once
at retire), no double-free, CoW isolation after divergence, allocator
determinism under random admit/fork/write/retire interleavings, and
cache-hold accounting (bytes == distinct held pages x page_bytes).

Module requires `hypothesis` (skip-guarded in conftest.py like the other
property suites). The model under test here is pure host-side control plane
— no jax arrays — so examples are cheap and the state space is searched
hard."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.serving.block_manager import (BlockManager, PagedPrefixCache,
                                         pages_for)

PAGE = 4
POOL = 33  # pages incl. the reserved null page


@st.composite
def _trace(draw):
    """A random interleaving of request lifecycle events over a shared
    page pool: admit (alloc), fork (alias another live request's pages),
    write (CoW any shared page in range), retire (decref)."""
    n_events = draw(st.integers(5, 40))
    events = []
    for _ in range(n_events):
        events.append(draw(st.sampled_from(["admit", "fork", "write",
                                            "retire"])))
    lengths = draw(st.lists(st.integers(1, 24), min_size=n_events,
                            max_size=n_events))
    picks = draw(st.lists(st.integers(0, 10 ** 6), min_size=n_events,
                          max_size=n_events))
    return list(zip(events, lengths, picks))


def _run_trace(trace):
    """Replay a lifecycle trace against a BlockManager, mirroring expected
    refcounts in plain dicts. Returns (bm, log of allocated page ids)."""
    bm = BlockManager(POOL, PAGE)
    live: dict[int, list[int]] = {}  # request -> its page list
    next_id = 0
    alloc_log: list[int] = []
    for op, length, pick in trace:
        if op == "admit":
            need = pages_for(length, PAGE)
            if not bm.can_alloc(need):
                continue
            pages = bm.alloc(need)
            alloc_log.extend(pages)
            live[next_id] = pages
            next_id += 1
        elif op == "fork" and live:
            donor = sorted(live)[pick % len(live)]
            pages = list(live[donor])
            bm.incref(pages)
            live[next_id] = pages
            next_id += 1
        elif op == "write" and live:
            rid = sorted(live)[pick % len(live)]
            pages = live[rid]
            j = pick % max(len(pages), 1) if pages else 0
            if pages and bm.ref[pages[j]] > 1 and bm.can_alloc(1):
                new = bm.cow(pages[j])
                alloc_log.append(new)
                pages[j] = new
        elif op == "retire" and live:
            rid = sorted(live)[pick % len(live)]
            bm.decref(live.pop(rid))
    return bm, live, alloc_log


@settings(max_examples=200, deadline=None)
@given(_trace())
def test_refcount_balance_at_retire(trace):
    """After every live request retires, the pool is whole again: zero refs,
    every page back on the free list, allocs == frees."""
    bm, live, _ = _run_trace(trace)
    for rid in sorted(live):
        bm.decref(live.pop(rid))
    assert bm.in_use == 0
    assert bm.free_pages == POOL - 1
    assert (bm.ref == 0).all()
    assert bm.stats["allocs"] == bm.stats["frees"]


@settings(max_examples=200, deadline=None)
@given(_trace())
def test_ref_matches_alias_count(trace):
    """At any stop point, each page's refcount equals the number of live
    request block-tables referencing it, and in-use/free partition the
    pool exactly."""
    bm, live, _ = _run_trace(trace)
    expect = np.zeros(POOL, np.int32)
    for pages in live.values():
        for p in pages:
            expect[p] += 1
    assert (bm.ref == expect).all()
    assert bm.in_use == int((expect > 0).sum())
    assert bm.in_use + bm.free_pages == POOL - 1


@settings(max_examples=100, deadline=None)
@given(_trace())
def test_allocator_determinism(trace):
    """The same interleaving replayed twice hands out the identical page-id
    sequence — the LIFO free list has no hidden nondeterminism, which is
    what makes preemption-replay byte-reproducible."""
    _, _, log_a = _run_trace(trace)
    _, _, log_b = _run_trace(trace)
    assert log_a == log_b


@settings(max_examples=100, deadline=None)
@given(_trace())
def test_cow_isolation(trace):
    """A CoW'd page is private: refcount 1, distinct id from the donor, and
    the donor's refcount dropped by exactly the caller's share."""
    bm, live, _ = _run_trace(trace)
    shared = [int(p) for p in np.flatnonzero(bm.ref > 1) if p > 0]
    for pid in shared:
        before = int(bm.ref[pid])
        if not bm.can_alloc(1):
            break
        new = bm.cow(pid)
        assert new != pid
        assert bm.ref[new] == 1
        assert bm.ref[pid] == before - 1


def test_double_free_asserts():
    bm = BlockManager(8, PAGE)
    (p,) = bm.alloc(1)
    bm.decref([p])
    try:
        bm.decref([p])
    except AssertionError:
        return
    raise AssertionError("double free was not caught")


# ----------------------------------------------------------------------
# paged prefix cache: hold accounting + reclaim under random use
# ----------------------------------------------------------------------
@st.composite
def _cache_trace(draw):
    vocab = 16
    n_prefixes = draw(st.integers(1, 3))
    prefixes = [draw(st.lists(st.integers(0, vocab - 1), min_size=2,
                              max_size=12)) for _ in range(n_prefixes)]
    ops = []
    for _ in range(draw(st.integers(3, 15))):
        base = draw(st.sampled_from(prefixes))
        cut = draw(st.integers(1, len(base)))
        tail = draw(st.lists(st.integers(0, vocab - 1), min_size=1,
                             max_size=6))
        ops.append((draw(st.sampled_from(["insert", "reclaim"])),
                    base[:cut] + tail, draw(st.integers(1, 8))))
    return ops


@settings(max_examples=150, deadline=None)
@given(_cache_trace())
def test_cache_hold_accounting(ops):
    """Across random insert/match/split/reclaim interleavings the cache's
    byte accounting equals distinct held pages x page_bytes, its holds
    agree with the block manager's refcounts, and dropping the cache
    returns the pool to whole."""
    bm = BlockManager(POOL, PAGE)
    cache = PagedPrefixCache(bm, capacity_bytes=12 * PAGE * 16,
                             page_bytes=16)
    for op, prompt_list, n in ops:
        prompt = np.asarray(prompt_list, np.int32)
        if op == "insert":
            need = pages_for(len(prompt_list), PAGE)
            if not bm.can_alloc(need):
                continue
            pages = bm.alloc(need)  # stand-in for a request's prefill pages
            cache.insert(prompt, pages)
            bm.decref(pages)        # the "request" retires; cache holds live on
        else:
            cache.reclaim(n)
        m = cache.match(prompt)
        assert m.usable <= len(prompt_list)
        assert len(m.pages) == pages_for(m.usable, PAGE)
        # every page the match hands out is genuinely referenced
        for p in m.pages:
            assert bm.ref[p] > 0
    assert cache.bytes == len(cache._holds) * 16
    for p, holds in cache._holds.items():
        assert bm.ref[p] >= holds > 0
    # cache is the only page owner left: reclaiming everything empties the pool
    cache.reclaim(POOL - 1)
    assert bm.in_use == 0
    assert (bm.ref == 0).all()


# ----------------------------------------------------------------------
# cross-replica KV handoff (disaggregated fleet): export pins on the
# source pool, install allocs on the destination pool, release decrefs —
# the two pools must balance independently under any interleaving.
# ----------------------------------------------------------------------

@st.composite
def _handoff_trace(draw):
    """Random interleaving of the disagg handoff lifecycle across TWO block
    managers: admit (src prefill alloc), export (ticket pin + src slot
    retire), install (dst alloc + ticket release), drop (sha-reject: ticket
    release, nothing installed), retire (dst decode slot retire)."""
    n_events = draw(st.integers(5, 50))
    events = [draw(st.sampled_from(["admit", "export", "install", "drop",
                                    "retire"])) for _ in range(n_events)]
    lengths = draw(st.lists(st.integers(1, 24), min_size=n_events,
                            max_size=n_events))
    picks = draw(st.lists(st.integers(0, 10 ** 6), min_size=n_events,
                          max_size=n_events))
    return list(zip(events, lengths, picks))


def _run_handoff_trace(trace):
    """Replay the protocol the engines + KVHandoff plane implement:

      src.alloc -> src.export_pages (pin) -> src slot decref (retire)
        -> [in flight] -> dst.install_pages (fresh alloc)
        -> src.decref(ticket pages)   # release on install OR drop

    Returns (src, dst, in_flight, src_live, dst_live)."""
    src, dst = BlockManager(POOL, PAGE), BlockManager(POOL, PAGE)
    src_live: dict[int, list[int]] = {}   # prefill slots on the source
    dst_live: dict[int, list[int]] = {}   # decode slots on the destination
    in_flight: list[list[int]] = []       # ticket-pinned source page lists
    next_id = 0
    for op, length, pick in trace:
        if op == "admit":
            need = pages_for(length, PAGE)
            if not src.can_alloc(need):
                continue
            src_live[next_id] = src.alloc(need)
            next_id += 1
        elif op == "export" and src_live:
            rid = sorted(src_live)[pick % len(src_live)]
            pages = src_live.pop(rid)
            src.export_pages(pages)   # the ticket's own reference
            src.decref(pages)         # the slot's reference: prefill is done
            in_flight.append(pages)
        elif op == "install" and in_flight:
            pages = in_flight[pick % len(in_flight)]
            if not dst.can_alloc(len(pages)):
                continue              # KVHandoff requeues; pin stays live
            in_flight.remove(pages)
            dst_live[next_id] = dst.install_pages(len(pages))
            next_id += 1
            src.decref(pages)         # install confirmed: release the ticket
        elif op == "drop" and in_flight:
            pages = in_flight.pop(pick % len(in_flight))
            src.decref(pages)         # sha reject: release, install nothing
        elif op == "retire" and dst_live:
            rid = sorted(dst_live)[pick % len(dst_live)]
            dst.decref(dst_live.pop(rid))
    return src, dst, in_flight, src_live, dst_live


@settings(max_examples=200, deadline=None)
@given(_handoff_trace())
def test_handoff_pools_balance_at_retire(trace):
    """Drain tickets and retire everything: BOTH pools return to whole —
    no page leaked by an export whose install never happened, no double
    free from release-after-install."""
    src, dst, in_flight, src_live, dst_live = _run_handoff_trace(trace)
    for pages in in_flight:
        src.decref(pages)
    for rid in sorted(src_live):
        src.decref(src_live.pop(rid))
    for rid in sorted(dst_live):
        dst.decref(dst_live.pop(rid))
    for bm in (src, dst):
        assert bm.in_use == 0
        assert bm.free_pages == POOL - 1
        assert (bm.ref == 0).all()
        assert bm.stats["allocs"] == bm.stats["frees"]
    assert src.stats["exports"] >= dst.stats["installs"]


@settings(max_examples=200, deadline=None)
@given(_handoff_trace())
def test_handoff_ticket_pins_keep_pages_resident(trace):
    """At any stop point every in-flight ticket's pages are still referenced
    on the source (the pin outlives the retired prefill slot), and the
    destination's refcounts exactly mirror its live decode slots."""
    src, dst, in_flight, src_live, dst_live = _run_handoff_trace(trace)
    for pages in in_flight:
        for p in pages:
            assert src.ref[p] > 0, "ticket pin lost before release"
    expect = np.zeros(POOL, np.int32)
    for pages in dst_live.values():
        for p in pages:
            expect[p] += 1
    assert (dst.ref == expect).all()
    assert dst.in_use + dst.free_pages == POOL - 1
