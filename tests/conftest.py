"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the real single
CPU device (the dry-run forces its own 512 stand-in devices in-process)."""
import pathlib

import jax
import jax.numpy as jnp
import pytest

# Skip-guard: property-based test modules need `hypothesis` (declared in
# requirements-dev.txt / pyproject's [test] extra). When it isn't installed,
# exclude those modules from collection so the rest of the suite still runs
# everywhere, instead of erroring the whole collection.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import re

    _imports_hypothesis = re.compile(
        r"^\s*(?:import hypothesis|from hypothesis)", re.MULTILINE)
    collect_ignore = [
        p.name for p in pathlib.Path(__file__).parent.glob("test_*.py")
        if _imports_hypothesis.search(p.read_text())
    ]


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)


def tiny_mesh():
    """A (1, 1) data x model mesh on the single real device — exercises the
    full sharded code path (rules, constraints, NamedShardings) without
    fake devices."""
    import numpy as np
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    return jax.sharding.Mesh(devs, ("data", "model"))


@pytest.fixture()
def mesh11():
    return tiny_mesh()
