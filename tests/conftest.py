"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the real single
CPU device (the dry-run forces its own 512 stand-in devices in-process)."""
import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)


def tiny_mesh():
    """A (1, 1) data x model mesh on the single real device — exercises the
    full sharded code path (rules, constraints, NamedShardings) without
    fake devices."""
    import numpy as np
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    return jax.sharding.Mesh(devs, ("data", "model"))


@pytest.fixture()
def mesh11():
    return tiny_mesh()
