"""Per-kernel allclose sweeps vs the kernels/ref.py pure-jnp oracles
(assignment deliverable (c)): Pallas kernels in interpret mode, xla-blocked
implementations, shape x dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import decode_attention as dec
from repro.kernels import flash_attention as fa
from repro.kernels import ops, ref

KEY = jax.random.key(42)


def _qkv(b, sq, skv, hq, hkv, d, dtype, dv=None):
    ks = jax.random.split(jax.random.fold_in(KEY, sq * 131 + skv * 7 + hq), 3)
    q = jax.random.normal(ks[0], (b, sq, hq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, skv, hkv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, skv, hkv, dv or d), jnp.float32).astype(dtype)
    return q, k, v


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# flash attention (Pallas, interpret mode) vs oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,sq,skv,hq,hkv,d,causal,window,softcap",
    [
        (2, 128, 128, 4, 4, 32, True, None, None),
        (1, 128, 128, 4, 2, 64, True, None, None),   # GQA
        (1, 128, 128, 4, 1, 32, True, None, None),   # MQA
        (1, 128, 256, 2, 1, 32, True, None, None),   # suffix-aligned
        (1, 128, 128, 2, 1, 32, True, 64, None),     # sliding window
        (1, 128, 128, 2, 2, 32, True, None, 30.0),   # logit softcap
        (1, 100, 100, 2, 1, 32, True, None, None),   # non-multiple of block
        (1, 128, 128, 2, 2, 32, False, None, None),  # non-causal
    ],
)
def test_flash_attention_vs_ref(b, sq, skv, hq, hkv, d, causal, window,
                                softcap, dtype):
    q, k, v = _qkv(b, sq, skv, hq, hkv, d, dtype)
    want = ref.attention(q, k, v, causal=causal, window=window,
                         logit_softcap=softcap)
    got = fa.flash_attention(q, k, v, causal=causal, window=window,
                             logit_softcap=softcap, block_q=64, block_k=64,
                             interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


# ---------------------------------------------------------------------------
# decode attention (Pallas, interpret) vs oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,hq,hkv,d,window",
    [
        (2, 256, 4, 4, 32, None),
        (2, 256, 8, 2, 64, None),   # GQA group 4
        (1, 256, 4, 1, 32, None),   # MQA
        (2, 256, 4, 1, 32, 64),     # sliding window
        (2, 100, 2, 1, 32, None),   # ragged cache length
    ],
)
def test_decode_attention_vs_ref(b, s, hq, hkv, d, window, dtype):
    q3, k, v = _qkv(b, 1, s, hq, hkv, d, dtype)
    q = q3[:, 0]
    lengths = jnp.asarray([s // 2, s][:b], jnp.int32)
    want = ref.decode_attention(q, k, v, lengths=lengths, window=window)
    got = dec.decode_attention(q, k, v, lengths=lengths, window=window,
                               block_k=64, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


# ---------------------------------------------------------------------------
# blocked (xla) attention vs oracle — including the MLA dv != dq case
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "sq,skv,hq,hkv,d,dv,causal,window,softcap",
    [
        (2048 + 64, 2048 + 64, 2, 1, 32, None, True, None, None),
        (2080, 4160, 3, 1, 16, None, True, None, 20.0),
        (2080, 2080, 2, 1, 32, 24, True, None, None),  # dv != dq (MLA)
        (2080, 2080, 2, 2, 32, None, True, 256, None),
    ],
)
def test_blocked_attention_vs_ref(sq, skv, hq, hkv, d, dv, causal, window,
                                  softcap):
    q, k, v = _qkv(1, sq, skv, hq, hkv, d, jnp.float32, dv=dv)
    want = ref.attention(q, k, v, causal=causal, window=window,
                         logit_softcap=softcap)
    got = ops.blocked_attention(q, k, v, causal=causal, window=window,
                                logit_softcap=softcap, block_q=256,
                                block_k=512)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


def test_blocked_attention_grads_match_ref():
    q, k, v = _qkv(1, 2080, 2080, 2, 1, 16, jnp.float32)

    def loss(fn):
        return lambda q, k, v: jnp.sum(fn(q, k, v, causal=True) ** 2)

    g_ref = jax.grad(loss(ref.attention), argnums=(0, 1, 2))(q, k, v)
    g_blk = jax.grad(loss(ops.blocked_attention), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_blk):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


# ---------------------------------------------------------------------------
# chunkwise mLSTM vs quadratic oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("s,h,dh,chunk", [
    (64, 2, 16, 16), (128, 4, 32, 32), (100, 2, 16, 32), (256, 2, 16, 256),
])
def test_mlstm_chunkwise_vs_ref(s, h, dh, chunk):
    ks = jax.random.split(jax.random.fold_in(KEY, s * 31 + chunk), 5)
    q = jax.random.normal(ks[0], (2, s, h, dh))
    k = jax.random.normal(ks[1], (2, s, h, dh))
    v = jax.random.normal(ks[2], (2, s, h, dh))
    ig = jax.random.normal(ks[3], (2, s, h))
    fg = jax.random.normal(ks[4], (2, s, h)) + 2.0
    want = ref.mlstm(q, k, v, ig, fg)
    got = ops.mlstm_chunkwise(q, k, v, ig, fg, chunk=chunk)
    rel = float(jnp.max(jnp.abs(got - want))) / float(jnp.max(jnp.abs(want)))
    assert rel < 5e-4


# ---------------------------------------------------------------------------
# linear recurrence oracle properties
# ---------------------------------------------------------------------------
def test_linear_recurrence_matches_loop():
    ks = jax.random.split(KEY, 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (2, 37, 5)))
    x = jax.random.normal(ks[1], (2, 37, 5))
    h0 = jax.random.normal(ks[2], (2, 5))
    got = ref.linear_recurrence(a, x, h0=h0)
    h = h0
    for t in range(37):
        h = a[:, t] * h + x[:, t]
        np.testing.assert_allclose(np.asarray(got[:, t]), np.asarray(h),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# hook-level dispatch: binding pallas vs portable gives same numerics
# ---------------------------------------------------------------------------
def test_hook_binding_consistency():
    from repro.core import hooks

    q, k, v = _qkv(1, 128, 128, 2, 1, 32, jnp.float32)
    portable = hooks.bind(None)
    blocked = hooks.bind(None, overrides={"attention": "xla-blocked"})
    with hooks.use(portable):
        a = hooks.call("attention", q, k, v, causal=True)
    with hooks.use(blocked):
        b = hooks.call("attention", q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_hook_dispatch_through_interpret_tier():
    """On the CPU-CI profile the hand-tiled Pallas kernels serve traffic via
    the pallas-interpret tier (probed at bind time), not the portable ref."""
    from repro.core import hooks, recompile

    binding = hooks.bind(recompile.CPU_INTERPRET, probe=True)
    assert binding.providers()["decode_attention"] == "pallas-interpret"
    q3, k, v = _qkv(2, 1, 64, 4, 2, 16, jnp.float32)
    q = q3[:, 0]
    lengths = jnp.asarray([32, 64], jnp.int32)
    want = ref.decode_attention(q, k, v, lengths=lengths)
    with hooks.use(binding):
        got = hooks.call("decode_attention", q, k, v, lengths=lengths)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# rmsnorm (Pallas, interpret) vs oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape,d", [((2, 17), 64), ((3, 128), 256),
                                     ((1, 7), 100)])
def test_rmsnorm_pallas_vs_ref(shape, d, dtype):
    from repro.kernels import rmsnorm as rms

    ks = jax.random.split(jax.random.fold_in(KEY, d), 2)
    x = jax.random.normal(ks[0], (*shape, d), jnp.float32).astype(dtype)
    w = (jax.random.normal(ks[1], (d,), jnp.float32) * 0.1).astype(dtype)
    want = ref.rmsnorm(x, w)
    got = rms.rmsnorm(x, w, block_rows=8, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


# ---------------------------------------------------------------------------
# MoE grouped matmul (Pallas, interpret) vs oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("e,c,d,f", [(4, 64, 96, 128), (2, 100, 64, 100),
                                     (8, 16, 32, 48)])
def test_moe_gmm_pallas_vs_ref(e, c, d, f, dtype):
    from repro.kernels import moe_gmm

    ks = jax.random.split(jax.random.fold_in(KEY, e * c + f), 4)
    x = (jax.random.normal(ks[0], (e, c, d), jnp.float32) * 0.3).astype(dtype)
    wg = (jax.random.normal(ks[1], (e, d, f), jnp.float32) * 0.1).astype(dtype)
    wu = (jax.random.normal(ks[2], (e, d, f), jnp.float32) * 0.1).astype(dtype)
    wd = (jax.random.normal(ks[3], (e, f, d), jnp.float32) * 0.1).astype(dtype)
    want = ref.moe_mlp(x, wg, wu, wd)
    got = moe_gmm.moe_mlp(x, wg, wu, wd, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype] * 4, rtol=TOL[dtype] * 4)
