"""Property test: a prefix-cache-enabled engine serves byte-identical token
streams to a cache-disabled one across random prompt-sharing patterns,
evictions mid-stream, and slot recycling.

Module requires `hypothesis` (skip-guarded in conftest.py like the other
property suites). Greedy decoding keeps both engines deterministic, so any
stream difference is a real prefix-restore defect, not sampling noise.
"""
import functools

import jax
import numpy as np
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine

MAX_LEN = 48


@functools.lru_cache(maxsize=1)
def _model():
    cfg = configs.get_config("qwen2-0.5b-smoke")
    params = transformer.init_model(jax.random.key(0), cfg)
    return cfg, params


@st.composite
def _workload(draw):
    """A request stream over a small pool of shared prefixes: some prompts
    extend a pool prefix (radix hits at varying depths), some are fresh
    (misses), lengths and budgets vary so slots recycle at different times."""
    vocab = 256
    n_prefixes = draw(st.integers(1, 3))
    prefixes = [
        draw(st.lists(st.integers(0, vocab - 1), min_size=4, max_size=16))
        for _ in range(n_prefixes)
    ]
    n_reqs = draw(st.integers(3, 9))
    reqs = []
    for _ in range(n_reqs):
        if draw(st.booleans()):
            base = draw(st.sampled_from(prefixes))
            # share the whole prefix or only part of it (mid-edge matches)
            cut = draw(st.integers(1, len(base)))
            base = base[:cut]
        else:
            base = []
        tail = draw(st.lists(st.integers(0, vocab - 1),
                             min_size=1, max_size=8))
        prompt = (base + tail)[: MAX_LEN - 8]
        reqs.append((np.asarray(prompt, np.int32),
                     draw(st.integers(1, 6))))
    budget = draw(st.sampled_from([12_000, 60_000, 64 << 20]))
    return reqs, budget


def _serve(reqs, cache_bytes):
    cfg, params = _model()
    eng = ServingEngine(cfg, params, slots=2, max_len=MAX_LEN,
                        prompt_buckets=(8, 16, 32),
                        prefix_cache_bytes=cache_bytes)
    for i, (p, m) in enumerate(reqs):
        eng.submit(Request(request_id=i, prompt=p, max_new_tokens=m))
    res = eng.run_to_completion()
    return {k: res[k].tokens for k in sorted(res)}, eng


@settings(max_examples=15, deadline=None)
@given(_workload())
def test_cache_enabled_streams_byte_identical(workload):
    reqs, budget = workload
    base, _ = _serve(reqs, None)
    out, eng = _serve(reqs, budget)
    assert out == base
    # bookkeeping invariants hold no matter the pattern
    assert all(p is None for p in eng._slot_pins)
    for node in eng.prefix_cache._iter_nodes():
        assert node.ref == 0
    assert eng.prefix_cache.bytes >= 0
