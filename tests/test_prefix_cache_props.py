"""Property tests: (1) a prefix-cache-enabled engine serves byte-identical
token streams to a cache-disabled one across random prompt-sharing patterns,
evictions mid-stream, and slot recycling; (2) a SPECULATIVE engine (either
proposer kind, any K, with or without the prefix cache and its mid-stream
evictions) serves byte-identical greedy streams to the plain fused engine.

Module requires `hypothesis` (skip-guarded in conftest.py like the other
property suites). Greedy decoding keeps both engines deterministic, so any
stream difference is a real prefix-restore / rejection-sampling / rollback
defect, not sampling noise.
"""
import functools

import jax
import numpy as np
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine
from repro.serving.speculative import DraftModelProposer, SpecConfig

MAX_LEN = 48


@functools.lru_cache(maxsize=1)
def _model():
    cfg = configs.get_config("qwen2-0.5b-smoke")
    params = transformer.init_model(jax.random.key(0), cfg)
    return cfg, params


@st.composite
def _workload(draw):
    """A request stream over a small pool of shared prefixes: some prompts
    extend a pool prefix (radix hits at varying depths), some are fresh
    (misses), lengths and budgets vary so slots recycle at different times."""
    vocab = 256
    n_prefixes = draw(st.integers(1, 3))
    prefixes = [
        draw(st.lists(st.integers(0, vocab - 1), min_size=4, max_size=16))
        for _ in range(n_prefixes)
    ]
    n_reqs = draw(st.integers(3, 9))
    reqs = []
    for _ in range(n_reqs):
        if draw(st.booleans()):
            base = draw(st.sampled_from(prefixes))
            # share the whole prefix or only part of it (mid-edge matches)
            cut = draw(st.integers(1, len(base)))
            base = base[:cut]
        else:
            base = []
        tail = draw(st.lists(st.integers(0, vocab - 1),
                             min_size=1, max_size=8))
        prompt = (base + tail)[: MAX_LEN - 8]
        reqs.append((np.asarray(prompt, np.int32),
                     draw(st.integers(1, 6))))
    budget = draw(st.sampled_from([12_000, 60_000, 64 << 20]))
    return reqs, budget


def _serve(reqs, cache_bytes, spec=None, proposer=None):
    cfg, params = _model()
    eng = ServingEngine(cfg, params, slots=2, max_len=MAX_LEN,
                        prompt_buckets=(8, 16, 32),
                        prefix_cache_bytes=cache_bytes,
                        spec=spec, proposer=proposer)
    for i, (p, m) in enumerate(reqs):
        eng.submit(Request(request_id=i, prompt=p, max_new_tokens=m))
    res = eng.run_to_completion()
    return {k: res[k].tokens for k in sorted(res)}, eng


@functools.lru_cache(maxsize=8)
def _draft_proposer(k):
    cfg, params = _model()
    return DraftModelProposer(cfg, params, k)


@settings(max_examples=15, deadline=None)
@given(_workload())
def test_cache_enabled_streams_byte_identical(workload):
    reqs, budget = workload
    base, _ = _serve(reqs, None)
    out, eng = _serve(reqs, budget)
    assert out == base
    # bookkeeping invariants hold no matter the pattern
    assert all(p is None for p in eng._slot_pins)
    for node in eng.prefix_cache._iter_nodes():
        assert node.ref == 0
    assert eng.prefix_cache.bytes >= 0


@settings(max_examples=10, deadline=None)
@given(_workload(), st.sampled_from(["ngram", "draft"]), st.integers(1, 4),
       st.booleans())
def test_speculative_streams_byte_identical(workload, kind, k, with_cache):
    """Speculative-on/off greedy parity across random prompt-sharing
    patterns, both proposer kinds, K in {1..4}, and (when with_cache) the
    prefix cache under the 12KB eviction-pressure budgets — drafts are
    verified on top of restored prefixes and mid-stream evictions."""
    reqs, budget = workload
    base, _ = _serve(reqs, None)
    spec = SpecConfig(k=k, proposer=kind, draft_arch="qwen2-0.5b-smoke")
    proposer = _draft_proposer(k) if kind == "draft" else None
    out, eng = _serve(reqs, budget if with_cache else None, spec=spec,
                      proposer=proposer)
    assert out == base
    assert all(h is None for h in eng._hist)  # mirrors drained with slots
    if with_cache:
        assert all(p is None for p in eng._slot_pins)
