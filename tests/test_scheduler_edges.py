"""Scheduler edge paths: cancel/fail against non-running jobs, the
preemption lifecycle (graceful checkpoint window, runtime credit, stale
finish invalidation, class-priority requeue), and elastic/backfill
interactions. Split from test_scheduler.py so these run even where
`hypothesis` is unavailable."""
import pytest

from repro.core.scheduler import Cluster, JobClass, JobState


def test_cancel_while_pending_removes_from_queue():
    c = Cluster(chips=10)
    c.submit(tenant="a", chips=10, runtime_s=50)
    waiting = c.submit(tenant="b", chips=10, runtime_s=10)
    c.run(until=1.0)
    assert waiting.state == JobState.PENDING
    c.cancel(waiting.job_id)
    c.run(until=2.0)
    assert waiting.state == JobState.CANCELLED
    assert waiting.job_id not in c.pending
    assert waiting.start_s is None and waiting.granted_chips == 0
    c.check_invariants()


def test_fail_while_pending_is_noop():
    c = Cluster(chips=10)
    c.submit(tenant="a", chips=10, runtime_s=5)
    waiting = c.submit(tenant="b", chips=10, runtime_s=1)
    c.fail(waiting.job_id, at=1.0)  # crash report for a job not yet placed
    c.run()
    assert waiting.state == JobState.DONE  # ran normally once chips freed
    c.check_invariants()


def test_preempt_releases_chips_and_requeues():
    c = Cluster(chips=8)
    j = c.submit(tenant="t", chips=8, runtime_s=100, klass=JobClass.BATCH)
    c.run(until=10.0)
    assert j.state == JobState.RUNNING
    seen = []
    # listener fires inside the graceful window: chips still granted
    c.listeners.append(lambda kind, job: seen.append((kind, job.granted_chips)))
    c.preempt(j.job_id)
    c.run(until=10.0)
    assert ("preempt", 8) in seen
    assert j.preemptions == 1
    # requeued with elapsed runtime credited, then restarted (chips free)
    assert j.state == JobState.RUNNING and j.start_s == 10.0
    assert j.runtime_s == pytest.approx(90.0)
    c.run()
    assert j.state == JobState.DONE
    assert j.end_s == pytest.approx(100.0)
    c.check_invariants()


def test_preempt_service_is_noop():
    c = Cluster(chips=4)
    s = c.submit(tenant="svc", chips=4, runtime_s=1.0, klass=JobClass.SERVICE)
    c.run(until=1.0)
    c.preempt(s.job_id)
    c.run(until=2.0)
    assert s.state == JobState.RUNNING and s.preemptions == 0


def test_stale_finish_does_not_kill_restarted_incarnation():
    c = Cluster(chips=4)
    batch = c.submit(tenant="b", chips=4, runtime_s=10, klass=JobClass.BATCH)
    c.run(until=2.0)
    # an interactive job arrives first, then the preemption: the requeued
    # batch job waits behind it past its ORIGINAL finish time (t=10)
    hog = c.submit(tenant="i", chips=4, runtime_s=8, at=4.0,
                   klass=JobClass.INTERACTIVE)
    c.preempt(batch.job_id, at=4.0)
    c.run(until=5.0)
    assert batch.state == JobState.PENDING and hog.state == JobState.RUNNING
    c.run(until=11.0)  # past the stale finish event at t=10
    assert batch.state != JobState.DONE  # stale finish ignored
    c.run()
    # restarted at t=12 with 6s credit remaining -> done at 18
    assert batch.state == JobState.DONE
    assert batch.end_s == pytest.approx(18.0)
    assert batch.preemptions == 1
    c.check_invariants()


def test_preempt_yields_chips_to_higher_priority_class():
    c = Cluster(chips=4)
    batch = c.submit(tenant="b", chips=4, runtime_s=100, klass=JobClass.BATCH)
    c.run(until=1.0)
    svc = c.submit(tenant="s", chips=4, runtime_s=1.0, klass=JobClass.SERVICE)
    c.run(until=1.0)
    assert svc.state == JobState.PENDING  # cluster full
    c.preempt(batch.job_id)
    c.run(until=1.0)
    # SERVICE outranks the requeued BATCH job in the pending queue
    assert svc.state == JobState.RUNNING
    assert batch.state == JobState.PENDING
    c.cancel(svc.job_id, at=5.0)
    c.run(until=6.0)
    assert batch.state == JobState.RUNNING  # resumed once the lease released
    c.check_invariants()


def test_preempted_job_outranks_elastic_grow_then_grow_on_cancel():
    c = Cluster(chips=10)
    rigid = c.submit(tenant="a", chips=6, runtime_s=100, klass=JobClass.BATCH)
    elastic = c.submit(tenant="b", chips=8, runtime_s=50, min_chips=2,
                       klass=JobClass.BATCH)
    c.run(until=0.0)
    assert elastic.granted_chips == 4  # shrunk start
    c.preempt(rigid.job_id, at=5.0)
    c.run(until=5.0)
    # the requeued job is the queue head: it restarts with its full
    # allocation rather than losing chips to the elastic grow pass
    assert rigid.state == JobState.RUNNING and rigid.granted_chips == 6
    assert rigid.preemptions == 1
    assert elastic.granted_chips == 4
    c.cancel(rigid.job_id, at=6.0)
    c.run(until=6.0)
    assert elastic.granted_chips == 8  # grew once the chips truly freed
    c.check_invariants()


def test_backfill_preserves_head_reservation_with_mixed_classes():
    c = Cluster(chips=100)
    c.submit(tenant="a", chips=80, runtime_s=100)
    head = c.submit(tenant="b", chips=100, runtime_s=10)
    fits = c.submit(tenant="c", chips=20, runtime_s=50)   # ends before t=100
    late = c.submit(tenant="d", chips=20, runtime_s=500)  # would delay head
    c.run(until=1.0)
    assert fits.state == JobState.RUNNING
    assert late.state == JobState.PENDING
    c.run(until=150.0)
    assert head.start_s == pytest.approx(100.0)  # reservation honored
    c.check_invariants()
