"""Disaggregated prefill/decode fleet: KV handoff plane, phase-aware
routing, per-pool autoscaler state isolation, sha-reject recompute, and the
headline byte-parity suite (monolithic vs disagg vs disagg-with-fallback)
across plain / speculative / prefix-cache-hit serving."""
import functools

import jax
import numpy as np
import pytest

from repro import configs
from repro.fleet import (SLO, Autoscaler, DisaggConfig, DisaggFleetManager,
                         FleetConfig, FleetManager, Router, bursty_trace,
                         materialize)
from repro.models import transformer


@functools.lru_cache(maxsize=1)
def _model():
    cfg = configs.get_config("qwen2-0.5b-smoke")
    params = transformer.init_model(jax.random.key(0), cfg)
    return cfg, params


def _requests(seed=0, shared_prefix=0, n_target=12):
    cfg, _ = _model()
    trace = bursty_trace(
        seed=seed, duration_s=10.0, base_rate=0.4, burst_rate=3.0,
        bursts=((2.0, 6.0),), prompt_median=8, prompt_lo=4, prompt_hi=24,
        max_new_lo=3, max_new_hi=7, burst_prompt_median=16)[:n_target]
    return materialize(trace, vocab_size=cfg.vocab_size, seed=seed + 1,
                       shared_prefix_len=shared_prefix, max_prompt_len=32)


def _fleet_cfg(spec_k=0, min_replicas=2, max_replicas=2):
    return FleetConfig(
        min_replicas=min_replicas, max_replicas=max_replicas, slots=2,
        max_len=48, prompt_buckets=(8, 16, 32), tick_s=0.05, page_size=8,
        prefix_cache_mb=1.0, spec_k=spec_k)


def _run_mono(reqs, spec_k=0):
    cfg, params = _model()
    fm = FleetManager.build(cfg, params, chips=8, fleet=_fleet_cfg(spec_k))
    rep = fm.run_trace(reqs)
    return fm, rep


def _run_disagg(reqs, spec_k=0, disagg=None):
    cfg, params = _model()
    fm = DisaggFleetManager.build(
        cfg, params, chips=8, fleet=_fleet_cfg(spec_k),
        disagg=disagg or DisaggConfig(prefill_min=1, prefill_max=1,
                                      decode_min=1, decode_max=1))
    rep = fm.run_trace(reqs)
    return fm, rep


# ----------------------------------------------------------------------
# KVHandoff link model (pure virtual time, no engines)
# ----------------------------------------------------------------------

class _Pkt:
    def __init__(self, nbytes):
        self.nbytes = nbytes


def test_handoff_link_serializes_transfers():
    from repro.fleet.disagg import KVHandoff
    h = KVHandoff(bandwidth_bytes_per_s=1000.0, latency_s=0.5)
    t1 = h.submit(0.0, _Pkt(1000), src=None)   # 1s xfer + 0.5 latency
    t2 = h.submit(0.0, _Pkt(2000), src=None)   # queues behind t1
    assert t1.ready_s == pytest.approx(1.5)
    assert t2.ready_s == pytest.approx(1.5 + 2.5)
    assert h.backlog == 2
    assert h.take_ready(1.0) == []
    assert h.take_ready(2.0) == [t1]
    assert h.backlog == 1
    assert h.take_ready(10.0) == [t2]
    assert h.backlog == 0
    # an uninstallable ticket requeues and comes back next take
    h.requeue([t2])
    assert h.backlog == 1 and h.stats["retries"] == 1
    assert h.take_ready(10.0) == [t2]
    assert h.stats["submitted"] == 2 and h.stats["bytes"] == 3000


# ----------------------------------------------------------------------
# per-pool autoscaler state (satellite bugfix regression)
# ----------------------------------------------------------------------

def test_autoscaler_cooldowns_are_per_pool():
    """A scale-up in one pool must NOT consume the other pool's up-cooldown
    (the global-state bug this PR fixes)."""
    a = Autoscaler(SLO(queue_high_per_slot=1.0, up_cooldown_s=5.0), 1, 8)
    kw = dict(serving=1, booting=0, queued=9, busy_slots=2, total_slots=2)
    assert a.decide(0.0, pool="prefill", **kw) == "up"
    # same instant, other pool under the same pressure: must still fire
    assert a.decide(0.0, pool="decode", **kw) == "up"
    # each pool's OWN cooldown still suppresses its next scale-up
    assert a.decide(1.0, pool="prefill", **kw) is None
    assert a.decide(1.0, pool="decode", **kw) is None
    assert a.decide(6.0, pool="prefill", **kw) == "up"


def test_autoscaler_latency_windows_are_per_pool():
    """TTFT samples recorded into the prefill pool must not trip the decode
    pool's p95 trigger (and vice versa)."""
    slo = SLO(p95_target_s=1.0, queue_high_per_slot=100.0,
              min_window_samples=2, window_s=60.0)
    a = Autoscaler(slo, 1, 8)
    for t in (0.1, 0.2, 0.3, 0.4):
        a.record_completion(t, 5.0, pool="prefill")  # badly violating
        a.record_completion(t, 0.01, pool="decode")  # comfortably inside
    kw = dict(serving=1, booting=0, queued=0, busy_slots=2, total_slots=2)
    assert a.decide(1.0, pool="prefill", slo=slo, **kw) == "up"
    assert a.decide(1.0, pool="decode", slo=slo, **kw) is None
    assert a.p95(1.0, pool="decode", slo=slo) == pytest.approx(0.01)


def test_autoscaler_default_pool_unchanged():
    """Single-pool callers (no pool kwarg) keep the exact legacy behavior."""
    a = Autoscaler(SLO(queue_high_per_slot=1.0, up_cooldown_s=1.0), 1, 4)
    assert a.decide(0.0, serving=1, booting=0, queued=5, busy_slots=2,
                    total_slots=2) == "up"
    assert a.decide(0.5, serving=1, booting=1, queued=9, busy_slots=2,
                    total_slots=4) is None  # cooldown
    assert a.decide(1.5, serving=1, booting=1, queued=9, busy_slots=2,
                    total_slots=4) == "up"


def test_autoscaler_per_pool_min_max_overrides():
    a = Autoscaler(SLO(queue_high_per_slot=1.0), 1, 10)
    # pool capped at max_replicas=2: no up even under pressure
    assert a.decide(0.0, serving=2, booting=0, queued=50, busy_slots=4,
                    total_slots=4, pool="prefill", max_replicas=2) is None
    # pool floor min_replicas=2: no down at the floor
    slo = SLO(idle_drain_s=0.0, down_cooldown_s=0.0)
    assert a.decide(1.0, serving=2, booting=0, queued=0, busy_slots=0,
                    total_slots=4, pool="decode", slo=slo,
                    min_replicas=2) is None
    assert a.decide(2.0, serving=3, booting=0, queued=0, busy_slots=0,
                    total_slots=6, pool="decode", slo=slo,
                    min_replicas=2) == "down"


# ----------------------------------------------------------------------
# handoff routing layer
# ----------------------------------------------------------------------

class _FakeBM:
    def __init__(self, free):
        self.free_pages = free


class _FakeEngine:
    def __init__(self, free):
        self.block_manager = _FakeBM(free)


class _FakeDecodeReplica:
    def __init__(self, rid, free=10, accepting=True, cached=0):
        self.replica_id = rid
        self.engine = _FakeEngine(free)
        self.accepting = accepting
        self._cached = cached

    def cached_prefix_len(self, prompt):
        return self._cached


def test_route_handoff_prefers_session_then_prefix_then_free_pages():
    r = Router()
    prompt = np.zeros(8, np.int32)
    reps = [_FakeDecodeReplica(0, free=2), _FakeDecodeReplica(1, free=9)]
    # no pin, no prefix: most free pages wins
    first = r.route_handoff("s1", prompt, reps)
    assert first.replica_id == 1
    assert r.stats["handoff_free_pages"] == 1
    # the install pinned the session: same session comes back
    again = r.route_handoff("s1", prompt, reps)
    assert again.replica_id == 1 and r.stats["handoff_session_hits"] == 1
    # a fresh session with a prefix-advertising replica prefers it
    reps[0]._cached = 6
    assert r.route_handoff("s2", prompt, reps).replica_id == 0
    assert r.stats["handoff_prefix_hits"] == 1
    # nothing accepting -> None (caller colocates)
    assert r.route_handoff("s3", prompt,
                           [_FakeDecodeReplica(0, accepting=False)]) is None


# ----------------------------------------------------------------------
# byte-parity suite: (mono, disagg, disagg-with-fallback) x
#                    (plain, spec, prefix-hit)
# ----------------------------------------------------------------------

def _assert_parity(mono_fm, d_fm, reqs):
    sm, sd = mono_fm.token_streams(), d_fm.token_streams()
    assert set(sm) == set(sd) == {r.request_id for r in reqs}
    for rid in sm:
        assert sm[rid] == sd[rid], f"request {rid} diverged"


@pytest.mark.parametrize("spec_k,shared_prefix",
                         [(0, 0), (2, 0), (0, 12)],
                         ids=["plain", "spec", "prefix-hit"])
def test_disagg_byte_parity(spec_k, shared_prefix):
    reqs = _requests(seed=3, shared_prefix=shared_prefix)
    mono_fm, mono_rep = _run_mono(reqs, spec_k=spec_k)
    d_fm, d_rep = _run_disagg(reqs, spec_k=spec_k)
    assert mono_rep.served == d_rep.served == len(reqs)
    assert d_rep.disagg["handoff"]["installed"] >= 1
    assert d_rep.disagg["handoff"]["sha_rejected"] == 0
    assert d_rep.reconciled and mono_rep.reconciled
    _assert_parity(mono_fm, d_fm, reqs)
    # data-plane balance: every export was installed (or rejected) and no
    # packet is still staged on a prefill engine
    exported = sum(r.engine.stats["handoffs_out"] for r in d_fm.replicas)
    h = d_rep.disagg["handoff"]
    assert exported == h["installed"] + h["sha_rejected"]
    for r in d_fm.replicas:
        assert not r.engine.handoff_out, \
            f"replica {r.replica_id} still holds staged packets"
    # phase metering split: prefill FLOPs landed on prefill-pool leases too
    assert d_rep.phase_metering["prefill_tokens"] > 0
    if spec_k:
        assert d_rep.phase_metering["spec_positions"] > 0
    else:
        assert d_rep.phase_metering["decode_steps"] > 0


@pytest.mark.parametrize("spec_k,shared_prefix",
                         [(0, 0), (2, 0), (0, 12)],
                         ids=["plain", "spec", "prefix-hit"])
def test_disagg_backlog_fallback_byte_parity(spec_k, shared_prefix):
    """A starved handoff link (tiny bandwidth, watermark 0) forces submit-
    time colocation on the decode pool — streams must still be identical."""
    reqs = _requests(seed=5, shared_prefix=shared_prefix)
    mono_fm, mono_rep = _run_mono(reqs, spec_k=spec_k)
    d_fm, d_rep = _run_disagg(
        reqs, spec_k=spec_k,
        disagg=DisaggConfig(prefill_min=1, prefill_max=1, decode_min=1,
                            decode_max=1, handoff_backlog_watermark=0,
                            handoff_bandwidth_bytes_per_s=2e5,
                            handoff_latency_s=0.1))
    assert mono_rep.served == d_rep.served == len(reqs)
    assert d_rep.disagg["fallback_submits"] >= 1, \
        "starved link never triggered colocation fallback"
    _assert_parity(mono_fm, d_fm, reqs)


def test_disagg_sha_reject_recomputes_monolithically():
    """A corrupted transfer is detected destination-side (page shas), the
    ticket dropped, the source pin released, and the request recomputed on
    the decode pool — still byte-identical to the monolithic fleet."""
    reqs = _requests(seed=7)
    cfg, params = _model()
    d_fm = DisaggFleetManager.build(
        cfg, params, chips=8, fleet=_fleet_cfg(),
        disagg=DisaggConfig(prefill_min=1, prefill_max=1,
                            decode_min=1, decode_max=1))
    orig, hit = d_fm.handoff.submit, []

    def corrupting_submit(now, pkt, src):
        if not hit:
            hit.append(True)
            leaf = np.array(pkt.payload[0])        # device_get is read-only
            leaf.view(np.uint8).reshape(-1)[0] ^= 0xFF  # flip a bit in page 0
            pkt.payload[0] = leaf
        return orig(now, pkt, src)

    d_fm.handoff.submit = corrupting_submit
    d_rep = d_fm.run_trace(reqs)
    assert d_rep.served == len(reqs)
    assert d_rep.disagg["handoff"]["sha_rejected"] == 1
    assert d_rep.disagg["handoff"]["recomputed"] == 1
    mono_fm, _ = _run_mono(reqs)
    _assert_parity(mono_fm, d_fm, reqs)
    for r in d_fm.replicas:
        assert not r.engine.handoff_out


# ----------------------------------------------------------------------
# persist-on-scale-to-min (satellite: IR-boot follow-on)
# ----------------------------------------------------------------------

def test_fleet_persists_programs_on_drain(tmp_path):
    from repro.checkpoint.store import ArtifactStore
    from repro.core import aot
    if not aot.AOT_AVAILABLE:
        pytest.skip("jax AOT serialization unavailable")
    cfg, params = _model()
    store = ArtifactStore(str(tmp_path / "artifacts"))
    fleet = FleetConfig(
        min_replicas=1, max_replicas=2, slots=2, max_len=48,
        prompt_buckets=(8, 16, 32), tick_s=0.05, page_size=8,
        prefix_cache_mb=1.0, artifact_store=store,
        settle_s=30.0)
    fm = FleetManager.build(cfg, params, chips=8, fleet=fleet,
                            slo=SLO(queue_high_per_slot=0.5,
                                    up_cooldown_s=0.2, down_cooldown_s=0.5,
                                    idle_drain_s=0.5))
    reqs = _requests(seed=11)
    rep = fm.run_trace(reqs)
    assert rep.served == len(reqs)
    assert rep.scale_downs >= 1, "fleet never scaled back to min"
    persists = [m for _, m in fm.timeline if m.startswith("persist:")]
    assert persists, "scale-to-min drain did not persist programs"
    assert store.keys(), "persist wrote nothing to the artifact store"
    key = store.keys()[0]
    meta = store.meta(key)
    assert meta and meta.get("programs"), "persisted bundle lists no programs"
