"""The loop-aware HLO cost walker (the roofline instrument) validated against
XLA's own cost_analysis on loop-free programs and hand-computed scan costs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_cost


def _compiled(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_matmul_matches_xla_cost_analysis():
    x = jnp.zeros((256, 256))
    c = _compiled(lambda a, b: a @ b, x, x)
    rep = hlo_cost.analyze(c.as_text())
    xla = hlo_cost.xla_cost_analysis(c)
    assert rep.flops == pytest.approx(float(xla["flops"]), rel=0.01)
    assert rep.flops == pytest.approx(2 * 256**3, rel=0.01)


def test_scan_multiplies_by_trip_count():
    """THE reason this module exists: XLA cost_analysis counts a while body
    once; the walker multiplies by known_trip_count."""
    x = jnp.zeros((128, 128))
    ws = jnp.zeros((12, 128, 128))

    def scanned(a, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), a, ws)[0]

    c = _compiled(scanned, x, ws)
    rep = hlo_cost.analyze(c.as_text())
    xla = hlo_cost.xla_cost_analysis(c)
    one = 2 * 128**3
    assert float(xla["flops"]) == pytest.approx(one, rel=0.05)  # undercount
    assert rep.flops == pytest.approx(12 * one, rel=0.05)  # corrected
    assert rep.unknown_trip_counts == 0


def test_nested_scan_multiplies_both_levels():
    x = jnp.zeros((64, 64))
    ws = jnp.zeros((3, 4, 64, 64))

    def inner(c, w_stack):
        return jax.lax.scan(lambda cc, w: (cc @ w, None), c, w_stack)[0]

    def outer(a, ws):
        return jax.lax.scan(lambda c, w: (inner(c, w), None), a, ws)[0]

    rep = hlo_cost.analyze(_compiled(outer, x, ws).as_text())
    assert rep.flops == pytest.approx(12 * 2 * 64**3, rel=0.05)


def test_dot_flops_with_batch_dims():
    a = jnp.zeros((8, 32, 64))
    b = jnp.zeros((8, 64, 16))
    rep = hlo_cost.analyze(_compiled(jnp.matmul, a, b).as_text())
    assert rep.flops == pytest.approx(2 * 8 * 32 * 64 * 16, rel=0.05)


def test_gather_bytes_not_full_table():
    table = jnp.zeros((100_000, 64))
    idx = jnp.zeros((16,), jnp.int32)
    rep = hlo_cost.analyze(_compiled(lambda t, i: t[i], table, idx).as_text())
    # an embedding lookup reads O(output), not the 25 MB table
    assert rep.hbm_bytes < table.size * 4 / 10


def test_parse_computations_roundtrip():
    x = jnp.zeros((32, 32))
    text = _compiled(lambda a: jnp.tanh(a @ a), x).as_text()
    comps = hlo_cost.parse_computations(text)
    assert "__entry__" in comps
    ops = {i.opcode for il in comps.values() for i in il}
    assert "dot" in ops or "fusion" in ops


def test_iota_replica_groups_parser():
    groups = hlo_cost._parse_groups("[4,2]<=[8]")
    assert groups == [[0, 1], [2, 3], [4, 5], [6, 7]]
    groups_t = hlo_cost._parse_groups("[2,4]<=[4,2]T(1,0)")
    # arange(8).reshape(4,2).T.reshape(2,4)
    assert groups_t == [[0, 2, 4, 6], [1, 3, 5, 7]]
    explicit = hlo_cost._parse_groups("{{0,1},{2,3}}")
    assert explicit == [[0, 1], [2, 3]]
