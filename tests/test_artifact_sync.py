"""ArtifactStore.sync_from: cross-host distribution of the compiled-program
corpus (manifest-diff, sha-verified, skip-corrupt, atomic per key)."""
import glob
import os

from repro.checkpoint.store import ArtifactStore


def _seed(store, key, payload=b"x" * 64, meta=None):
    store.put(key, {"prog.bin": payload, "aux.bin": payload[::-1]},
              meta=meta or {"programs": ["prog"]})


def test_sync_copies_everything_into_empty_store(tmp_path):
    src = ArtifactStore(str(tmp_path / "src"))
    dst = ArtifactStore(str(tmp_path / "dst"))
    _seed(src, "bundle-a", b"alpha" * 10)
    _seed(src, "bundle-b", b"beta" * 10, meta={"programs": ["p", "q"]})
    out = dst.sync_from(src)
    assert out["copied"] == 2 and out["skipped"] == 0 and out["corrupt"] == 0
    assert sorted(out["keys"]) == sorted(dst.keys()) == sorted(src.keys())
    got = dst.get("bundle-b")
    assert got is not None
    blobs, meta = got
    assert blobs["prog.bin"] == b"beta" * 10
    assert meta == {"programs": ["p", "q"]}


def test_sync_accepts_a_bare_directory_path(tmp_path):
    src = ArtifactStore(str(tmp_path / "src"))
    _seed(src, "bundle-a")
    dst = ArtifactStore(str(tmp_path / "dst"))
    out = dst.sync_from(str(tmp_path / "src"))
    assert out["copied"] == 1
    assert dst.contains("bundle-a")


def test_sync_skips_existing_keys_unless_overwrite(tmp_path):
    src = ArtifactStore(str(tmp_path / "src"))
    dst = ArtifactStore(str(tmp_path / "dst"))
    _seed(src, "bundle-a", b"new-version")
    _seed(src, "bundle-b", b"fresh")
    _seed(dst, "bundle-a", b"local-version")
    out = dst.sync_from(src)
    assert out["copied"] == 1 and out["skipped"] == 1
    assert out["keys"] == ["bundle-b"]
    # the local artifact was NOT clobbered
    assert dst.get("bundle-a")[0]["prog.bin"] == b"local-version"
    out2 = dst.sync_from(src, overwrite=True)
    assert out2["copied"] == 2 and out2["skipped"] == 0
    assert dst.get("bundle-a")[0]["prog.bin"] == b"new-version"


def test_sync_skips_corrupt_source_artifacts(tmp_path):
    src = ArtifactStore(str(tmp_path / "src"))
    dst = ArtifactStore(str(tmp_path / "dst"))
    _seed(src, "bundle-good", b"fine")
    _seed(src, "bundle-bad", b"doomed")
    # tamper one blob of the bad bundle on disk: sha check must catch it
    (victim,) = [p for p in glob.glob(str(tmp_path / "src" / "*" / "blobs" /
                                          "prog*.bin"))
                 if "bad" in p]
    with open(victim, "wb") as f:
        f.write(b"garbage")
    misses_before = src.stats["corrupt"]
    out = dst.sync_from(src)
    assert out["copied"] == 1 and out["corrupt"] == 1
    assert out["keys"] == ["bundle-good"]
    assert dst.contains("bundle-good") and not dst.contains("bundle-bad")
    # the rejection was recorded on the SOURCE store, get-style
    assert src.stats["corrupt"] == misses_before + 1


def test_sync_truncated_blob_is_corrupt_not_fatal(tmp_path):
    src = ArtifactStore(str(tmp_path / "src"))
    dst = ArtifactStore(str(tmp_path / "dst"))
    _seed(src, "bundle-a", b"z" * 128)
    (blob,) = glob.glob(str(tmp_path / "src" / "*" / "blobs" / "prog*.bin"))
    with open(blob, "rb") as f:
        data = f.read()
    with open(blob, "wb") as f:
        f.write(data[: len(data) // 2])
    out = dst.sync_from(src)
    assert out == {"copied": 0, "skipped": 0, "corrupt": 1, "keys": []}
    assert dst.keys() == []


def test_sync_lands_atomically_committed(tmp_path):
    """Synced artifacts go through put(): COMMIT present, no temp debris."""
    src = ArtifactStore(str(tmp_path / "src"))
    dst = ArtifactStore(str(tmp_path / "dst"))
    _seed(src, "bundle-a")
    dst.sync_from(src)
    (d,) = [p for p in os.listdir(dst.root) if not p.startswith(".")]
    assert os.path.exists(os.path.join(dst.root, d, "COMMIT"))
    assert not [p for p in os.listdir(dst.root) if p.startswith(".tmp_")]
