"""Property tests for the persistent AOT artifact store and the bundle-key
function: round-trip byte determinism over arbitrary blob sets, bundle-key
injectivity under random field perturbations, crash/corruption safety (a
mangled artifact is a MISS, never an exception — the boot ladder depends on
``get()`` never raising), and put/get consistency under concurrent writers.

Module requires `hypothesis` (skip-guarded in conftest.py like the other
property suites). The store is pure host-side stdlib — no jax arrays — so
examples are cheap; each example builds its own store in a fresh temp dir
(no function-scoped pytest fixtures inside ``@given``, per hypothesis'
health check)."""
import json
import os
import tempfile
import threading

from hypothesis import given, settings, strategies as st

from repro.checkpoint.store import ArtifactStore
from repro.core import aot

# blob names exercise the sanitizer: path-hostile characters must land as
# flat files under blobs/ and round-trip by their ORIGINAL name
_names = st.text(
    st.characters(codec="ascii", exclude_characters="\x00"),
    min_size=1, max_size=24)
_blobs = st.dictionaries(_names, st.binary(min_size=0, max_size=256),
                         min_size=1, max_size=8)

# bundle-key fields: the kinds of values the engine actually keys on
# (strings, ints, None, tuples-of-pairs like a tier fingerprint)
_field_vals = st.one_of(
    st.none(), st.booleans(), st.integers(-8, 8),
    st.text(max_size=8),
    st.tuples(st.text(max_size=4), st.text(max_size=4)))
_fields = st.fixed_dictionaries(
    {"family": st.sampled_from(["serving:a", "serving:b"]),
     "slots": st.integers(1, 4), "max_len": st.integers(8, 64),
     "tiers": _field_vals, "spec": _field_vals})


@given(_blobs, st.dictionaries(st.text(max_size=8), st.integers(),
                               max_size=4))
@settings(max_examples=40, deadline=None)
def test_roundtrip_byte_identity(blobs, meta):
    with tempfile.TemporaryDirectory() as d:
        store = ArtifactStore(d)
        store.put("k", blobs, meta=meta)
        got = store.get("k")
        assert got is not None
        out, out_meta = got
        assert out == blobs
        assert out_meta == meta
        # a second put of the same key atomically replaces, never corrupts
        store.put("k", blobs, meta=meta)
        assert store.get("k") == (blobs, meta)


@given(_fields, _fields)
@settings(max_examples=60, deadline=None)
def test_bundle_key_injective_over_fields(a, b):
    ka, kb = aot.bundle_key(a), aot.bundle_key(b)
    assert (ka == kb) == (a == b)
    assert ka.startswith("aot-")
    # deterministic: same fields, same key, every time
    assert ka == aot.bundle_key(dict(a))


@given(_blobs, st.data())
@settings(max_examples=40, deadline=None)
def test_corruption_is_a_miss_never_an_exception(blobs, data):
    """Truncate / overwrite / delete any committed file: get() must return
    None with a reason in ``last_error``, and the store must stay usable."""
    with tempfile.TemporaryDirectory() as d:
        store = ArtifactStore(d)
        store.put("k", blobs, meta={"n": len(blobs)})
        # find the artifact dir on disk without relying on private helpers
        art_dir = next(p for p in (os.path.join(d, e) for e in os.listdir(d))
                       if os.path.isdir(p))
        files = sorted(
            os.path.join(dp, f)
            for dp, _, fs in os.walk(art_dir) for f in fs)
        victim = files[data.draw(st.integers(0, len(files) - 1))]
        action = data.draw(st.sampled_from(["truncate", "garbage", "delete"]))
        if action == "truncate":
            with open(victim, "rb") as f:
                raw = f.read()
            with open(victim, "wb") as f:
                f.write(raw[: len(raw) // 2])
        elif action == "garbage":
            with open(victim, "wb") as f:
                f.write(b"\xde\xad\xbe\xef")
        else:
            os.remove(victim)

        got = store.get("k")
        if got is not None:
            # only legal survival: the mangled file did not participate in
            # the manifest's integrity domain AND bytes still verify
            out, _ = got
            assert out == blobs
        else:
            assert store.last_error
            assert store.stats["misses"] + store.stats["corrupt"] >= 1
        # the store is still writable and consistent after the damage
        store.put("k2", blobs, meta={})
        assert store.get("k2") == (blobs, {})


@given(st.lists(_blobs, min_size=2, max_size=4))
@settings(max_examples=15, deadline=None)
def test_concurrent_put_get_consistency(blob_sets):
    """N writers hammer the SAME key while readers poll: every successful
    read must be one of the complete bundles, never an interleaving."""
    with tempfile.TemporaryDirectory() as d:
        store = ArtifactStore(d)
        valid = [frozenset((k, v) for k, v in b.items()) for b in blob_sets]
        errors: list[str] = []

        def writer(b):
            for _ in range(3):
                store.put("k", b, meta={})

        def reader():
            for _ in range(10):
                got = store.get("k")
                if got is None:
                    continue
                seen = frozenset((k, v) for k, v in got[0].items())
                if seen not in valid:
                    errors.append(f"torn read: {sorted(got[0])}")

        threads = [threading.Thread(target=writer, args=(b,))
                   for b in blob_sets]
        threads += [threading.Thread(target=reader) for _ in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors, errors[0]
        got = store.get("k")
        assert got is not None
        assert frozenset((k, v) for k, v in got[0].items()) in valid


@given(_blobs)
@settings(max_examples=20, deadline=None)
def test_manifest_records_every_blob(blobs):
    """The on-disk MANIFEST.json is the integrity domain: one entry per
    blob with its byte length and sha256 (what the corrupt-boot test in
    test_ir_boot.py relies on)."""
    with tempfile.TemporaryDirectory() as d:
        store = ArtifactStore(d)
        store.put("k", blobs, meta={})
        art_dir = next(p for p in (os.path.join(d, e) for e in os.listdir(d))
                       if os.path.isdir(p))
        man = json.load(open(os.path.join(art_dir, "MANIFEST.json")))
        entries = man["blobs"]
        names = {e["name"] for e in entries}
        assert names == set(blobs)
        for e in entries:
            assert e["bytes"] == len(blobs[e["name"]])
            assert len(e["sha256"]) == 64
