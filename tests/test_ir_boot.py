"""IR-boot container tests: the persistent-AOT boot ladder.

Covers the three-rung ladder (cold trace+compile -> warm in-process cache
-> IR deserialize-and-install) at byte-identical greedy streams across
plain / speculative / paged engines, stale-artifact invalidation (jax
version drift, kernel-tier drift), corrupt-artifact fallthrough, the
warmup() manifest contract (full manifest + boot record even on a pure
cache hit, zero re-traces on warm/IR rungs), and entrypoint-level IR
restore through the deployment compiler.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint.store import ArtifactStore
from repro.core import aot, container as xc, hooks, recompile, scheduler
from repro.core.invocation import InvocationService
from repro.models import transformer
from repro.serving.engine import (Request, ServingEngine,
                                  clear_program_caches)
from repro.serving.sampling import SamplingConfig
from repro.serving.service import serving_container
from repro.serving.speculative import SpecConfig

GEOM = dict(slots=2, max_len=32, prompt_buckets=(8,))

pytestmark = pytest.mark.skipif(
    not aot.AOT_AVAILABLE, reason="jax AOT serialization unavailable")


@functools.lru_cache(maxsize=2)
def _model(arch="qwen2-0.5b-smoke"):
    cfg = configs.get_config(arch)
    params = transformer.init_model(jax.random.key(0), cfg)
    return cfg, params


def _reqs(cfg, n=3, max_new=5, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(request_id=i,
                    prompt=rng.integers(0, cfg.vocab_size, (6,),
                                        dtype=np.int32),
                    max_new_tokens=max_new, sampling=SamplingConfig())
            for i in range(n)]


def _serve(engine, cfg):
    for r in _reqs(cfg):
        engine.submit(r)
    res = engine.run_to_completion()
    return {rid: r.tokens for rid, r in res.items()}


# ---------------------------------------------------------------------------
# the ladder: cold -> warm -> IR at byte parity (satellite 1)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("variant", ["plain", "spec", "paged"])
def test_boot_ladder_byte_parity(variant, tmp_path):
    cfg, params = _model()
    kw = dict(GEOM)
    if variant == "spec":
        kw["spec"] = SpecConfig(k=2, proposer="ngram")
    elif variant == "paged":
        kw["page_size"] = 8
    store = ArtifactStore(tmp_path / "store")

    clear_program_caches()
    e1 = ServingEngine(cfg, params, artifact_store=store, **kw)
    assert e1.boot_path_preview() == "cold"
    m1 = e1.warmup()
    b1 = m1["boot"]
    assert b1["path"] == "cold"
    assert b1["warmup_compiles"] > 0
    assert b1.get("persisted", 0) > 0          # cold rung persisted the IR
    assert store.contains(e1._bundle_key)
    toks1 = _serve(e1, cfg)

    # warm: same process, program bundle already compiled
    e2 = ServingEngine(cfg, params, artifact_store=store, **kw)
    assert e2.boot_path_preview() == "warm"
    b2 = e2.warmup()["boot"]
    assert b2["path"] == "warm"
    assert b2["warmup_compiles"] == 0
    assert _serve(e2, cfg) == toks1

    # IR: fresh "process" (cleared program caches), store hit
    clear_program_caches()
    e3 = ServingEngine(cfg, params, artifact_store=store, **kw)
    assert e3.boot_path_preview() == "ir"
    b3 = e3.warmup()["boot"]
    assert b3["path"] == "ir"
    assert b3["warmup_compiles"] == 0          # never re-traces installed IR
    assert b3["programs"]["installed"] > 0
    assert b3["bundle_key"] == b1["bundle_key"]
    assert _serve(e3, cfg) == toks1


# ---------------------------------------------------------------------------
# stale-artifact invalidation (satellite 3)
# ---------------------------------------------------------------------------
def test_stale_jax_version_falls_through_to_cold(tmp_path, monkeypatch):
    cfg, params = _model()
    store = ArtifactStore(tmp_path / "store")
    clear_program_caches()
    ServingEngine(cfg, params, artifact_store=store, **GEOM).warmup()
    assert store.keys()

    clear_program_caches()
    real = aot.runtime_fingerprint()
    monkeypatch.setattr(aot, "runtime_fingerprint",
                        lambda: dict(real, jax="999.0.0", jaxlib="999.0.0"))
    e = ServingEngine(cfg, params, artifact_store=store, **GEOM)
    assert e.boot_path_preview() == "cold"     # key includes the version
    m = e.warmup()
    assert m["boot"]["path"] == "cold"
    reasons = " ".join(m["boot"]["fallthrough"])
    assert "stale artifact" in reasons and "jax" in reasons


def test_stale_tier_binding_falls_through_to_cold(tmp_path):
    cfg, params = _model()
    store = ArtifactStore(tmp_path / "store")
    clear_program_caches()
    # persist under the unbound (portable) tier fingerprint ...
    ServingEngine(cfg, params, artifact_store=store, **GEOM).warmup()

    # ... then "re-deploy" with an explicit hook binding: different tier
    # fingerprint -> different bundle key -> loader rejects and re-traces
    clear_program_caches()
    binding = hooks.bind(recompile.PORTABLE_CPU)
    e = ServingEngine(cfg, params, artifact_store=store, binding=binding,
                      **GEOM)
    assert e.boot_path_preview() == "cold"
    m = e.warmup()
    assert m["boot"]["path"] == "cold"
    reasons = " ".join(m["boot"]["fallthrough"])
    assert "stale artifact" in reasons and "tiers" in reasons


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >=2 devices (XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8)")
def test_stale_mesh_falls_through_to_cold(tmp_path):
    """Executables persisted by a single-device engine must never IR-boot a
    sharded replica: the mesh geometry is in the bundle key, and the boot
    manifest explains the miss with the mesh diff first."""
    cfg, params = _model()
    store = ArtifactStore(tmp_path / "store")
    clear_program_caches()
    ServingEngine(cfg, params, artifact_store=store, **GEOM).warmup()
    assert store.keys()

    clear_program_caches()
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    e = ServingEngine(cfg, params, artifact_store=store, mesh=mesh, **GEOM)
    assert e.boot_path_preview() == "cold"
    m = e.warmup()
    assert m["boot"]["path"] == "cold"
    reasons = " ".join(m["boot"]["fallthrough"])
    assert "stale artifact" in reasons and "mesh" in reasons


def test_corrupt_artifact_falls_through_without_raising(tmp_path):
    cfg, params = _model()
    store = ArtifactStore(tmp_path / "store")
    clear_program_caches()
    e1 = ServingEngine(cfg, params, artifact_store=store, **GEOM)
    e1.warmup()
    toks1 = _serve(e1, cfg)

    # truncate one committed blob on disk: the sha256 check must reject the
    # whole bundle and the ladder must land on cold, not raise
    blobdir = tmp_path / "store" / e1._bundle_key / "blobs"
    victim = sorted(blobdir.iterdir())[0]
    victim.write_bytes(victim.read_bytes()[: max(1, victim.stat().st_size // 2)])

    clear_program_caches()
    e2 = ServingEngine(cfg, params, artifact_store=store, **GEOM)
    m = e2.warmup()
    assert m["boot"]["path"] == "cold"
    assert store.stats["corrupt"] >= 1
    assert any(r.startswith("ir:") for r in m["boot"]["fallthrough"])
    # the cold rung re-persisted a good bundle, and parity still holds
    assert _serve(e2, cfg) == toks1
    assert store.get(e2._bundle_key) is not None


# ---------------------------------------------------------------------------
# warmup() manifest contract (satellite 4: fix + pin)
# ---------------------------------------------------------------------------
def test_warmup_returns_full_manifest_even_on_pure_cache_hit(tmp_path):
    cfg, params = _model()
    store = ArtifactStore(tmp_path / "store")
    cont = serving_container(cfg, params, artifact_store=store, **GEOM)
    profile = recompile.PORTABLE_CPU
    service = InvocationService(scheduler.Cluster(chips=profile.chips))
    clear_program_caches()
    with service.acquire_serving("boot-pin", cont, profile) as ex:
        m1 = ex.warmup()
        assert m1["boot"]["path"] == "cold"
        # second warmup: EVERY program is a cache hit — still the full
        # manifest (apis + boot), zero re-traces
        m2 = ex.engine.warmup()
        assert m2["apis"] and m2["container"] == cont.name
        assert m2["boot"]["path"] == "warm"
        assert m2["boot"]["warmup_compiles"] == 0
        assert m2["boot"]["bundle_key"] == m1["boot"]["bundle_key"]


def test_ir_boot_installs_without_retracing(tmp_path):
    cfg, params = _model()
    store = ArtifactStore(tmp_path / "store")
    clear_program_caches()
    ServingEngine(cfg, params, artifact_store=store, **GEOM).warmup()

    clear_program_caches()
    e = ServingEngine(cfg, params, artifact_store=store, **GEOM)
    m = e.warmup()
    reg = e._aot_registry()
    assert m["boot"]["path"] == "ir"
    assert m["boot"]["warmup_compiles"] == 0
    counts = reg.counts()
    assert counts["installed"] > 0
    assert counts["exe_hits"] > 0              # warmup dispatched to them
    assert counts["fallbacks"] == 0            # none were discarded


# ---------------------------------------------------------------------------
# entrypoint-level IR restore through the deployment compiler
# ---------------------------------------------------------------------------
def test_entrypoint_ir_boot_across_compilers(tmp_path):
    def fn(a, b):
        return a @ b

    def make_args(mesh):
        sds = jax.ShapeDtypeStruct((16, 16), jnp.float32)
        return (sds, sds), {}, {}

    store = ArtifactStore(tmp_path / "store")
    cont = xc.XContainer(name="ir-demo", entrypoints={"mm": (fn, make_args)},
                         artifact_store=store)
    profile = recompile.PORTABLE_CPU
    x = jnp.ones((16, 16), jnp.float32)

    comp1 = recompile.DeploymentCompiler()
    dep1 = cont.deploy(profile, compiler=comp1)
    assert dep1.artifact("mm").boot == "cold"
    out1 = np.asarray(dep1("mm", x, x))

    # fresh compiler = fresh process: the executable comes back from the
    # container's store, not from a re-trace
    comp2 = recompile.DeploymentCompiler()
    dep2 = cont.deploy(profile, compiler=comp2)
    art2 = dep2.artifact("mm")
    assert art2.boot == "ir"
    assert comp2.stats.get("ir_boots", 0) == 1
    assert dep2.manifest()["entrypoint_boot"]["mm"]["boot"] == "ir"
    np.testing.assert_array_equal(np.asarray(dep2("mm", x, x)), out1)

    # third deploy on the SAME compiler: in-process warm hit, not IR
    dep3 = cont.deploy(profile, compiler=comp2)
    assert dep3.artifact("mm").boot == "warm"
