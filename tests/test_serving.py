"""Serving engine: continuous batching completes all requests; greedy decode
matches the step-by-step model; slot recycling; audio path; fused vs legacy
data-plane parity; batched admission; per-slot sampling divergence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampling import (SamplingConfig, SamplingParams, sample,
                                    sample_batched)


def _engine(arch="qwen2-0.5b", dropless=True, **kw):
    cfg = configs.get_config(arch + "-smoke")
    if dropless and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    params = transformer.init_model(jax.random.key(0), cfg)
    kw = {"slots": 4, "max_len": 128, "prompt_buckets": (16, 32), **kw}
    return cfg, params, ServingEngine(cfg, params, **kw)


def test_all_requests_complete_more_requests_than_slots():
    cfg, params, eng = _engine()
    rng = np.random.default_rng(0)
    n = 10  # > slots
    for i in range(n):
        plen = int(rng.integers(4, 16))
        eng.submit(Request(request_id=i,
                           prompt=rng.integers(0, cfg.vocab_size, (plen,)),
                           max_new_tokens=int(rng.integers(2, 8))))
    results = eng.run_to_completion()
    assert sorted(results) == list(range(n))
    assert eng.stats["retired"] == n
    assert eng.stats["prefills"] == n
    for i, r in results.items():
        assert 2 <= len(r.tokens) <= 8


def test_greedy_engine_matches_manual_decode():
    """Engine greedy output == hand-rolled prefill+decode_step loop."""
    cfg, params, eng = _engine()
    prompt = np.arange(10, dtype=np.int32) % cfg.vocab_size
    eng.submit(Request(request_id=0, prompt=prompt, max_new_tokens=5))
    result = eng.run_to_completion()[0]

    # manual greedy reference: exact-length prefill at absolute positions
    # [0, L) — the engine's right-aligned layout makes its bucket padding
    # transparent (pads sit causally after the prompt and are never written
    # into the caches)
    logits, states, lengths = transformer.prefill(
        params, cfg, jnp.asarray(prompt)[None], 128)
    toks = [int(jnp.argmax(logits[0]))]
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(4):
        lengths = lengths + 1
        logits, states = transformer.decode_step(params, cfg, cur, states, lengths)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(int(cur[0]))
    assert result.tokens == toks


def test_eos_stops_generation():
    cfg, params, eng = _engine()
    # find the actual first greedy token, then use it as "eos"
    prompt = np.arange(6, dtype=np.int32)
    eng.submit(Request(request_id=0, prompt=prompt, max_new_tokens=50))
    first = eng.run_to_completion()[0].tokens[1]
    cfg2, params2, eng2 = _engine()
    eng2.submit(Request(request_id=1, prompt=prompt, max_new_tokens=50,
                        eos_id=int(first)))
    r = eng2.run_to_completion()[1]
    assert len(r.tokens) < 50
    assert r.tokens[-1] == first


def test_audio_engine_multicodebook():
    cfg, params, eng = _engine("musicgen-medium")
    rng = np.random.default_rng(1)
    eng.submit(Request(
        request_id=0,
        prompt=rng.integers(0, cfg.vocab_size, (cfg.num_codebooks, 8)),
        max_new_tokens=3))
    r = eng.run_to_completion()[0]
    assert len(r.tokens) == 3
    assert all(len(t) == cfg.num_codebooks for t in r.tokens)


def test_sampling_modes():
    key = jax.random.key(0)
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]])
    assert int(sample(key, logits, SamplingConfig())[0]) == 1  # greedy
    # top-k=1 == greedy regardless of temperature
    assert int(sample(key, logits,
                      SamplingConfig(temperature=2.0, top_k=1))[0]) == 1
    # temperature sampling stays in-vocab
    s = sample(key, jnp.zeros((64, 16)), SamplingConfig(temperature=1.0))
    assert s.shape == (64,) and bool((s >= 0).all()) and bool((s < 16).all())


def test_sample_batched_per_row_configs():
    """One vectorized call handles greedy, top-k, and full-dist rows at once,
    and jits cleanly."""
    key = jax.random.key(3)
    logits = jax.random.normal(key, (4, 32))
    sp = SamplingParams.from_configs([
        SamplingConfig(),                          # greedy
        SamplingConfig(temperature=2.0, top_k=1),  # degenerate top-k == greedy
        SamplingConfig(temperature=0.9, top_k=5),
        SamplingConfig(temperature=1.3),
    ])
    out = jax.jit(sample_batched)(key, logits, sp)
    assert out.shape == (4,)
    assert int(out[0]) == int(jnp.argmax(logits[0]))
    assert int(out[1]) == int(jnp.argmax(logits[1]))
    assert bool((out >= 0).all()) and bool((out < 32).all())
    # audio-shaped logits broadcast the per-slot params over codebooks
    out_a = jax.jit(sample_batched)(key, jax.random.normal(key, (4, 3, 32)), sp)
    assert out_a.shape == (4, 3)


def test_fused_matches_legacy_host_loop():
    """The fused single-program data plane serves byte-identical greedy
    tokens to the legacy per-slot host loop, across mixed prompt buckets and
    slot recycling (more requests than slots)."""
    cfg = configs.get_config("qwen2-0.5b-smoke")
    params = transformer.init_model(jax.random.key(0), cfg)
    rng = np.random.default_rng(7)
    reqs = [(i, rng.integers(0, cfg.vocab_size, (int(rng.integers(4, 30)),),
                             dtype=np.int32), int(rng.integers(2, 7)))
            for i in range(7)]

    def serve(fused, sync_every=1):
        eng = ServingEngine(cfg, params, slots=3, max_len=64,
                            prompt_buckets=(8, 16, 32), fused=fused,
                            sync_every=sync_every)
        for i, p, m in reqs:
            eng.submit(Request(request_id=i, prompt=p, max_new_tokens=m))
        res = eng.run_to_completion()
        return {k: res[k].tokens for k in sorted(res)}, eng.stats

    fused_toks, fused_stats = serve(True)
    legacy_toks, legacy_stats = serve(False)
    assert fused_toks == legacy_toks
    # exactly one blocking sync per decode step on the fused path
    assert fused_stats["host_syncs_decode"] == fused_stats["decode_steps"]
    assert legacy_stats["host_syncs_decode"] > 2 * legacy_stats["decode_steps"]
    # batched admission: fewer prefill program calls than requests
    assert fused_stats["prefill_calls"] < fused_stats["prefills"] == 7
    # k-step sync batching serves the same tokens with ~k-fold fewer syncs
    batched_toks, batched_stats = serve(True, sync_every=4)
    assert batched_toks == fused_toks
    assert batched_stats["host_syncs_decode"] < fused_stats["host_syncs_decode"]


def test_per_slot_sampling_divergence():
    """Slots with diverging sampling configs coexist in one fused batch."""
    cfg, params, eng = _engine()
    prompt = np.arange(9, dtype=np.int32) % cfg.vocab_size
    cfgs = [SamplingConfig(),
            SamplingConfig(temperature=2.0, top_k=1),  # == greedy
            SamplingConfig(temperature=0.9, top_k=5),
            SamplingConfig(temperature=1.3)]
    for i, sc in enumerate(cfgs):
        eng.submit(Request(request_id=i, prompt=prompt, max_new_tokens=6,
                           sampling=sc))
    res = eng.run_to_completion()
    assert sorted(res) == [0, 1, 2, 3]
    for r in res.values():
        assert len(r.tokens) == 6
        assert all(0 <= t < cfg.vocab_size for t in r.tokens)
    # greedy and degenerate top-k=1 rows decode identically
    assert res[0].tokens == res[1].tokens


def test_slot_recycling_after_eos_retirement():
    """An EOS-retired slot is recycled for a queued request, which then
    completes normally."""
    cfg, params, eng = _engine()
    prompt = np.arange(6, dtype=np.int32)
    eng.submit(Request(request_id=0, prompt=prompt, max_new_tokens=50))
    first_decode_tok = eng.run_to_completion()[0].tokens[1]

    cfg2, params2, eng2 = _engine(slots=1)
    eng2.submit(Request(request_id=1, prompt=prompt, max_new_tokens=50,
                        eos_id=int(first_decode_tok)))
    eng2.submit(Request(request_id=2, prompt=prompt, max_new_tokens=3))
    res = eng2.run_to_completion()
    assert sorted(res) == [1, 2]
    assert res[1].tokens[-1] == first_decode_tok and len(res[1].tokens) < 50
    assert len(res[2].tokens) == 3  # served on the recycled slot
    assert eng2.stats["retired"] == 2


def test_overlong_prompt_lands_in_max_len_bucket():
    """A prompt longer than the largest configured bucket but <= max_len pads
    into the implicit max_len bucket instead of crashing on a negative pad.
    Decode room is governed by the REAL prompt length (right-aligned layout),
    so a 100-token prompt in a 128-entry cache still gets its 4 tokens."""
    cfg, params, eng = _engine()  # buckets (16, 32), max_len 128
    assert eng.prompt_buckets[-1] == 128
    prompt = np.arange(100, dtype=np.int32) % cfg.vocab_size
    eng.submit(Request(request_id=0, prompt=prompt, max_new_tokens=4))
    res = eng.run_to_completion()
    assert len(res[0].tokens) == 4  # room = max_len - 100 + 1 = 29 >= 4
    # a prompt that fills the whole cache leaves no decode room: it completes
    # with its prefill token and a logged truncation warning
    eng.submit(Request(request_id=1,
                       prompt=np.arange(128, dtype=np.int32) % cfg.vocab_size,
                       max_new_tokens=4))
    res = eng.run_to_completion()
    assert len(res[1].tokens) == 1
    # beyond max_len is rejected up front
    with pytest.raises(ValueError):
        eng.submit(Request(request_id=2,
                           prompt=np.zeros(300, np.int32), max_new_tokens=1))


def test_max_new_tokens_one_yields_exactly_one_token():
    """A 1-token request is served straight from the prefill logits and never
    occupies a decode slot (the seed emitted 2 tokens here)."""
    cfg, params, eng = _engine()
    eng.submit(Request(request_id=0, prompt=np.arange(8, dtype=np.int32),
                       max_new_tokens=1))
    eng.submit(Request(request_id=1, prompt=np.arange(8, dtype=np.int32),
                       max_new_tokens=3))
    res = eng.run_to_completion()
    assert len(res[0].tokens) == 1
    assert len(res[1].tokens) == 3
    # the 1-token request's first token matches the longer request's first
    assert res[0].tokens[0] == res[1].tokens[0]


def test_admission_refills_slots_when_requests_retire_at_admission():
    """Requests retired AT admission (max_new_tokens=1) must not consume the
    admission budget: the engine refills from the queue within the same
    _admit call, so slots are saturated instead of idling a full step."""
    cfg, params, eng = _engine(slots=2)
    for i in range(2):  # these retire straight from the prefill logits
        eng.submit(Request(request_id=i, prompt=np.arange(4, dtype=np.int32),
                           max_new_tokens=1))
    for i in range(2, 4):  # these need decode slots
        eng.submit(Request(request_id=i, prompt=np.arange(6, dtype=np.int32),
                           max_new_tokens=4))
    eng.step()
    # both 1-token requests done AND both slots occupied by the decoders
    assert sorted(eng.results) == [0, 1]
    assert sum(r is not None for r in eng.active) == 2, (
        "slots left idle while the queue was non-empty")
    res = eng.run_to_completion()
    assert sorted(res) == [0, 1, 2, 3]
    assert [len(res[i].tokens) for i in range(4)] == [1, 1, 4, 4]


def test_duplicate_request_id_rejected():
    cfg, params, eng = _engine()
    eng.submit(Request(request_id=0, prompt=np.arange(4, dtype=np.int32),
                       max_new_tokens=2))
    with pytest.raises(ValueError):
        eng.submit(Request(request_id=0, prompt=np.arange(4, dtype=np.int32),
                           max_new_tokens=2))


def test_sync_window_flushes_early_when_batch_drains():
    """With a large sync window, the engine must not burn decode steps past
    the point where every in-flight request has provably finished."""
    cfg, params, eng = _engine(slots=2, sync_every=16)
    eng.submit(Request(request_id=0, prompt=np.arange(6, dtype=np.int32),
                       max_new_tokens=4))
    res = eng.run_to_completion()
    assert len(res[0].tokens) == 4
    # 1 prefill token + 3 decode steps; the 16-step window must not inflate it
    assert eng.stats["decode_steps"] == 3


def test_run_to_completion_reports_unserved_on_truncation():
    cfg, params, eng = _engine()
    for i in range(6):  # 6 requests, 4 slots, way too few steps
        eng.submit(Request(request_id=i,
                           prompt=np.arange(8, dtype=np.int32),
                           max_new_tokens=40))
    res = eng.run_to_completion(max_steps=3)
    assert eng.stats["unserved"] == 6 - len(res) > 0

    # a completed run reports zero unserved
    cfg2, params2, eng2 = _engine()
    eng2.submit(Request(request_id=0, prompt=np.arange(8, dtype=np.int32),
                        max_new_tokens=3))
    eng2.run_to_completion()
    assert eng2.stats["unserved"] == 0


def test_audio_eos_parity_fused_vs_legacy():
    """Audio EOS convention: generation stops when CODEBOOK 0 of a sampled
    frame equals eos_id. The fused path evaluates this on device
    (toks[:, 0] == eos inside the jitted step); the legacy host loop checks
    t[0] on the host — this test pins the two to byte-identical streams
    (including eos_id=0, sync-window batching, and slot recycling) so a
    divergence in either side's EOS handling can never land silently."""
    cfg = configs.get_config("musicgen-medium-smoke")
    params = transformer.init_model(jax.random.key(1), cfg)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, (cfg.num_codebooks, 4 + i),
                            dtype=np.int32) for i in range(5)]

    def serve(fused, eos_list, sync_every=1):
        eng = ServingEngine(cfg, params, slots=2, max_len=32,
                            prompt_buckets=(8, 16), fused=fused,
                            sync_every=sync_every)
        for i, p in enumerate(prompts):
            eng.submit(Request(request_id=i, prompt=p, max_new_tokens=12,
                               eos_id=eos_list[i]))
        res = eng.run_to_completion()
        return {k: res[k].tokens for k in sorted(res)}

    # harvest real mid-stream codebook-0 values to use as per-request eos ids
    base = serve(True, [None] * 5)
    eos = [int(base[i][min(2, len(base[i]) - 1)][0]) for i in range(5)]
    eos[-1] = 0  # the zero token must behave like any other eos value
    for sync_every in (1, 4):
        fused = serve(True, eos, sync_every=sync_every)
        legacy = serve(False, eos)
        assert fused == legacy
    # at least one request actually stopped on EOS (not just max_new)
    stopped = [i for i in range(5) if len(fused[i]) < 12]
    assert stopped, "no request hit its EOS token — test vacuous"
    for i in stopped:
        assert fused[i][-1][0] == eos[i] or len(fused[i]) == 12


def test_audio_batched_admission_and_recycling():
    """Multi-codebook frontend through the fused path: batched audio
    admission plus slot recycling."""
    cfg, params, eng = _engine("musicgen-medium", slots=2)
    rng = np.random.default_rng(2)
    for i in range(3):  # > slots
        eng.submit(Request(
            request_id=i,
            prompt=rng.integers(0, cfg.vocab_size, (cfg.num_codebooks, 4 + i)),
            max_new_tokens=2))
    res = eng.run_to_completion()
    assert sorted(res) == [0, 1, 2]
    for r in res.values():
        assert len(r.tokens) == 2
        assert all(len(t) == cfg.num_codebooks for t in r.tokens)
