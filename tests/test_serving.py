"""Serving engine: continuous batching completes all requests; greedy decode
matches the step-by-step model; slot recycling; audio path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampling import SamplingConfig, sample


def _engine(arch="qwen2-0.5b", dropless=True, **kw):
    cfg = configs.get_config(arch + "-smoke")
    if dropless and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    params = transformer.init_model(jax.random.key(0), cfg)
    return cfg, params, ServingEngine(cfg, params, slots=4, max_len=128,
                                      prompt_buckets=(16, 32), **kw)


def test_all_requests_complete_more_requests_than_slots():
    cfg, params, eng = _engine()
    rng = np.random.default_rng(0)
    n = 10  # > slots
    for i in range(n):
        plen = int(rng.integers(4, 16))
        eng.submit(Request(request_id=i,
                           prompt=rng.integers(0, cfg.vocab_size, (plen,)),
                           max_new_tokens=int(rng.integers(2, 8))))
    results = eng.run_to_completion()
    assert sorted(results) == list(range(n))
    assert eng.stats["retired"] == n
    assert eng.stats["prefills"] == n
    for i, r in results.items():
        assert 2 <= len(r.tokens) <= 8


def test_greedy_engine_matches_manual_decode():
    """Engine greedy output == hand-rolled prefill+decode_step loop."""
    cfg, params, eng = _engine()
    prompt = np.arange(10, dtype=np.int32) % cfg.vocab_size
    eng.submit(Request(request_id=0, prompt=prompt, max_new_tokens=5))
    result = eng.run_to_completion()[0]

    # manual greedy reference with the left-padded bucket the engine used
    bucket = 16
    padded = jnp.pad(jnp.asarray(prompt), (bucket - len(prompt), 0))[None]
    logits, states, lengths = transformer.prefill(params, cfg, padded, 128)
    toks = [int(jnp.argmax(logits[0]))]
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(4):
        lengths = lengths + 1
        logits, states = transformer.decode_step(params, cfg, cur, states, lengths)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(int(cur[0]))
    assert result.tokens == toks


def test_eos_stops_generation():
    cfg, params, eng = _engine()
    # find the actual first greedy token, then use it as "eos"
    prompt = np.arange(6, dtype=np.int32)
    eng.submit(Request(request_id=0, prompt=prompt, max_new_tokens=50))
    first = eng.run_to_completion()[0].tokens[1]
    cfg2, params2, eng2 = _engine()
    eng2.submit(Request(request_id=1, prompt=prompt, max_new_tokens=50,
                        eos_id=int(first)))
    r = eng2.run_to_completion()[1]
    assert len(r.tokens) < 50
    assert r.tokens[-1] == first


def test_audio_engine_multicodebook():
    cfg, params, eng = _engine("musicgen-medium")
    rng = np.random.default_rng(1)
    eng.submit(Request(
        request_id=0,
        prompt=rng.integers(0, cfg.vocab_size, (cfg.num_codebooks, 8)),
        max_new_tokens=3))
    r = eng.run_to_completion()[0]
    assert len(r.tokens) == 3
    assert all(len(t) == cfg.num_codebooks for t in r.tokens)


def test_sampling_modes():
    key = jax.random.key(0)
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]])
    assert int(sample(key, logits, SamplingConfig())[0]) == 1  # greedy
    # top-k=1 == greedy regardless of temperature
    assert int(sample(key, logits,
                      SamplingConfig(temperature=2.0, top_k=1))[0]) == 1
    # temperature sampling stays in-vocab
    s = sample(key, jnp.zeros((64, 16)), SamplingConfig(temperature=1.0))
    assert s.shape == (64,) and bool((s >= 0).all()) and bool((s < 16).all())
