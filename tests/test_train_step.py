"""Train-step semantics: microbatch-count invariance, both accumulation
forms, int8 error-feedback compression (hypothesis), gradient flow."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.training import train_step as ts


def _batch(cfg, key, b=8, s=16):
    tok = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return {"tokens": tok, "labels": jnp.roll(tok, -1, axis=-1)}


def test_microbatch_invariance_f32():
    """M=1 and M=4 produce (near-)identical updates with f32 accumulation."""
    cfg = configs.get_config("qwen2-0.5b-smoke")
    key = jax.random.key(0)
    batch = _batch(cfg, jax.random.key(1))
    outs = {}
    for m in (1, 4):
        tcfg = ts.TrainConfig(microbatches=m, accum_dtype="float32")
        state = ts.init_train_state(key, cfg, tcfg)
        step = jax.jit(ts.make_train_step(cfg, tcfg))
        new_state, metrics = step(state, batch)
        outs[m] = (new_state["params"], float(metrics["loss"]))
    # loss means match; params updates match closely
    assert abs(outs[1][1] - outs[4][1]) < 2e-2
    flat1 = jax.tree.leaves(outs[1][0])
    flat4 = jax.tree.leaves(outs[4][0])
    for a, b in zip(flat1, flat4):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-2)


def test_cotangent_accumulation_matches_explicit():
    """The scan-inside-grad accumulation (accum_dtype=bfloat16) matches the
    explicit f32 accumulator within bf16 tolerance."""
    cfg = configs.get_config("qwen2-0.5b-smoke")
    key = jax.random.key(0)
    batch = _batch(cfg, jax.random.key(1))
    outs = {}
    for dt in ("float32", "bfloat16"):
        tcfg = ts.TrainConfig(microbatches=4, accum_dtype=dt)
        state = ts.init_train_state(key, cfg, tcfg)
        step = jax.jit(ts.make_train_step(cfg, tcfg))
        new_state, metrics = step(state, batch)
        outs[dt] = (new_state["params"], float(metrics["loss"]))
    assert abs(outs["float32"][1] - outs["bfloat16"][1]) < 5e-2
    for a, b in zip(jax.tree.leaves(outs["float32"][0]),
                    jax.tree.leaves(outs["bfloat16"][0])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=0.1)


@given(st.lists(st.floats(-100.0, 100.0), min_size=1, max_size=64))
@settings(max_examples=100, deadline=None)
def test_int8_compression_bounded_error(vals):
    """|dequant(quant(x)) - x| <= scale/2 elementwise (hypothesis)."""
    x = jnp.asarray(vals, jnp.float32)
    q, scale = ts.compress_int8(x)
    back = ts.decompress_int8(q, scale)
    assert q.dtype == jnp.int8
    err = np.max(np.abs(np.asarray(back) - np.asarray(x)))
    assert err <= float(scale) * 0.5 + 1e-6


def test_int8_error_feedback_converges():
    """With error feedback, repeated compression of a constant gradient has
    O(1/steps) mean bias (the residual carries what quantization dropped)."""
    g = jnp.asarray([0.001, 0.5, -0.3, 1.0], jnp.float32)
    ef = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    steps = 50
    for _ in range(steps):
        q, scale = ts.compress_int8(g + ef)
        back = ts.decompress_int8(q, scale)
        ef = (g + ef) - back
        acc = acc + back
    np.testing.assert_allclose(np.asarray(acc / steps), np.asarray(g),
                               atol=2e-3)


def test_vlm_loss_aligns_labels():
    """VLM logits cover [image|text]; CE must use only the text tail."""
    cfg = configs.get_config("llava-next-34b-smoke")
    from repro.models import frontends

    key = jax.random.key(0)
    b, s = 2, 8
    tok = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {
        "tokens": tok,
        "labels": jnp.roll(tok, -1, -1),
        "patch_embeds": jax.random.normal(
            key, (b, cfg.num_image_tokens, frontends.VIS_DIM), jnp.float32),
    }
    tcfg = ts.TrainConfig()
    state = ts.init_train_state(key, cfg, tcfg)
    loss, metrics = ts.loss_fn(state["params"], cfg, batch)
    assert jnp.isfinite(loss)


def test_masked_labels_ignored():
    cfg = configs.get_config("qwen2-0.5b-smoke")
    logits = jnp.zeros((2, 4, cfg.vocab_size))
    labels = jnp.asarray([[1, 2, -100, -100], [3, -100, -100, -100]])
    ce = ts.cross_entropy(logits, labels)
    # uniform logits -> CE = log(V) over the 3 valid positions only
    np.testing.assert_allclose(float(ce), float(jnp.log(cfg.vocab_size)),
                               rtol=1e-5)
