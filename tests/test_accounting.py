"""Accounting invariants (the paper's fine-grained billing claim): ledger
conservation, artifact-derived metering, utilization rebate monotonicity."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.accounting import Bill, Meter, PriceSheet


@given(
    entries=st.lists(
        st.tuples(
            st.sampled_from(["alice", "bob", "carol"]),
            st.integers(1, 1000),     # steps
            st.integers(1, 512),      # chips
            st.floats(1e-3, 1e4),     # wall_s
            st.floats(0, 1e15),       # flops
        ),
        min_size=1, max_size=50,
    )
)
@settings(max_examples=50, deadline=None)
def test_ledger_conservation(entries):
    m = Meter()
    for tenant, steps, chips, wall, flops in entries:
        m.record(tenant=tenant, kind="train_step", steps=steps, chips=chips,
                 wall_s=wall, flops=flops)
    m.check_invariants()
    assert math.isclose(m.total_usd(), sum(m.by_tenant().values()),
                        rel_tol=1e-9)
    # per-tenant totals sum to the whole
    per = sum(m.total_device_s(t) for t in ("alice", "bob", "carol"))
    assert math.isclose(per, m.total_device_s(), rel_tol=1e-9)


def test_rebate_monotone_in_mfu():
    p = PriceSheet()
    c_low = p.charge(3600.0, mfu=0.1)
    c_high = p.charge(3600.0, mfu=0.9)
    assert c_high < c_low  # better utilization -> cheaper (XaaS incentive)
    assert p.charge(3600.0, mfu=0.0) == pytest.approx(p.chip_hour_usd)


def test_bill_flop_seconds():
    b = Bill(tenant="t", job_id="j", kind="k", steps=10, chips=4,
             wall_s=2.0, flops=1e12, bytes_hbm=0, bytes_collective=0, usd=1.0)
    assert b.device_s == 8.0
    assert b.flop_s == 1e12 * 4 * 10


def test_metering_from_artifact_matches_analysis():
    """Billed FLOPs == the compiled artifact's analyzed FLOPs (the
    auditability invariant)."""
    import jax.numpy as jnp

    from repro.core import recompile

    comp = recompile.DeploymentCompiler()
    x = jnp.zeros((128, 128))
    art = comp.deploy(lambda a: a @ a, "sq", recompile.PORTABLE_CPU,
                      args=(x,))
    m = Meter()
    bill = m.record(tenant="t", kind="sq", steps=3, chips=1, wall_s=0.5,
                    artifact=art)
    assert bill.flops == art.flops
    assert bill.flops == pytest.approx(2 * 128**3, rel=0.1)
