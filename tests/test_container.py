"""XaaS core integration: hooks registry semantics, performance-portable
container deploy, deployment-recompilation cache, invocation + metering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import container as xc
from repro.core import hooks, invocation, recompile, scheduler
from repro.core.accounting import Meter


# ---------------------------------------------------------------------------
# hooks
# ---------------------------------------------------------------------------
def test_hook_registry_and_priorities():
    apis = hooks.list_apis()
    for required in ("attention", "decode_attention", "matmul", "rmsnorm",
                     "moe_mlp", "linear_recurrence", "mlstm"):
        assert required in apis
    # pallas-tpu outranks xla-blocked on a TPU profile
    impls = hooks.available_impls("attention", recompile.TPU_V5E_POD)
    assert impls[0] == "portable" or "pallas-tpu" in impls
    binding = hooks.bind(recompile.TPU_V5E_POD)
    assert binding.providers()["attention"] == "pallas-tpu"
    # the portable floor: no profile -> reference everywhere
    floor = hooks.bind(None)
    assert floor.providers()["attention"] == "portable"
    # CPU profile gets no TPU kernels
    cpu = hooks.bind(recompile.PORTABLE_CPU)
    assert cpu.providers()["attention"] == "portable"


def test_hook_override_and_unknown_rejected():
    b = hooks.bind(None, overrides={"attention": "xla-blocked"})
    assert b.providers()["attention"] == "xla-blocked"
    with pytest.raises(hooks.HookError):
        hooks.bind(None, overrides={"attention": "no-such-provider"})
    with pytest.raises(hooks.HookError):
        hooks.bind(None, overrides={"no_such_api": "portable"})


def test_hook_scoping_nested():
    b1 = hooks.bind(None)
    b2 = hooks.bind(None, overrides={"attention": "xla-blocked"})
    with hooks.use(b1):
        assert hooks.current_binding() is b1
        with hooks.use(b2):
            assert hooks.current_binding() is b2
        assert hooks.current_binding() is b1
    assert hooks.current_binding() is None


# ---------------------------------------------------------------------------
# deployment recompilation (ship IR, specialize at target)
# ---------------------------------------------------------------------------
def test_recompile_cache_cold_vs_warm():
    fn = lambda a: a @ a
    x = jnp.zeros((64, 64))
    comp = recompile.DeploymentCompiler()
    b1 = comp.deploy(fn, "m", recompile.PORTABLE_CPU, args=(x,))
    b2 = comp.deploy(fn, "m", recompile.PORTABLE_CPU, args=(x,))
    assert not b1.cache_hit and b2.cache_hit
    assert comp.stats == {"ir_hits": 1, "ir_misses": 1,
                          "exe_hits": 1, "exe_misses": 1}
    # different arg shape -> new IR (a different "container image")
    y = jnp.zeros((32, 32))
    comp.deploy(fn, "m", recompile.PORTABLE_CPU, args=(y,))
    assert comp.stats["ir_misses"] == 2


def test_collective_parser_on_sharded_program():
    text = """
  %ag = bf16[256,1024]{1,0} all-gather(%x), replica_groups={{0,1}}, dimensions={0}
  %ar = f32[64]{0} all-reduce(%y), replica_groups=[1,2]<=[2], to_apply=%sum
"""
    out = recompile.collective_bytes(text)
    assert out["all-gather"] == 256 * 1024 * 2
    assert out["all-reduce"] == 64 * 4
    assert out["total"] == 256 * 1024 * 2 + 64 * 4


# ---------------------------------------------------------------------------
# XContainer end-to-end on the portable profile
# ---------------------------------------------------------------------------
def _matmul_container():
    def fn(a, b):
        return hooks.call("matmul", a, b)

    def make_args(mesh):
        sds = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        return (sds, sds), {}, {}

    return xc.XContainer(name="blas-demo", entrypoints={"mm": (fn, make_args)})


def test_container_deploy_and_run():
    cont = _matmul_container()
    dep = cont.deploy(recompile.PORTABLE_CPU)
    art = dep.artifact("mm")
    assert art.flops == pytest.approx(2 * 64**3, rel=0.05)
    x = jnp.ones((64, 64))
    out = dep("mm", x, x)
    np.testing.assert_allclose(np.asarray(out), 64.0)


def test_invocation_lease_lifecycle_and_metering():
    cluster = scheduler.Cluster(chips=8)
    svc = invocation.InvocationService(cluster, Meter())
    cont = _matmul_container()
    prof = recompile.PORTABLE_CPU
    lease = svc.acquire("alice", cont, prof)
    assert lease.active and lease.chips == 1
    x = jnp.ones((64, 64))
    svc.invoke(lease, "mm", x, x, steps=3)
    assert svc.meter.total_usd("alice") > 0
    assert svc.meter.bills[0].flops == lease.deployment.artifact("mm").flops
    svc.release(lease)
    assert not lease.active
    # warm re-acquire skips compilation
    lease2 = svc.acquire("alice", cont, prof)
    assert svc.stats["warm_acquires"] == 1
    svc.release(lease2)
