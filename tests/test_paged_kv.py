"""Paged KV data plane: byte-exact greedy parity with the contiguous slot
engine (plain, prefix-cached, speculative, and under pool pressure with
preemptions), watermark out-of-order admission (the head-of-line starvation
fix), chunked prefill, page accounting, and telemetry surfaces.

Greedy decoding keeps both engines deterministic, so any stream difference is
a real gather/scatter, block-table, CoW, or preemption-recompute defect."""
import functools

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import transformer
from repro.serving.block_manager import pages_for
from repro.serving.engine import Request, ServingEngine
from repro.serving.speculative import SpecConfig

MAX_LEN = 48
PAGE = 8
SLOTS = 2


@functools.lru_cache(maxsize=1)
def _model():
    cfg = configs.get_config("qwen2-0.5b-smoke")
    params = transformer.init_model(jax.random.key(0), cfg)
    return cfg, params


def _requests(seed=3, n=9, shared=7):
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, 256, shared).tolist()
    reqs = []
    for i in range(n):
        body = rng.integers(0, 256, int(rng.integers(1, 14))).tolist()
        prompt = (sys_prompt + body) if i % 2 == 0 else body
        reqs.append((np.asarray(prompt, np.int32), 2 + i % 6))
    return reqs


def _engine(**kw):
    cfg, params = _model()
    kw.setdefault("slots", SLOTS)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("prompt_buckets", (8, 16, 32))
    return ServingEngine(cfg, params, **kw)


def _serve(reqs, **kw):
    eng = _engine(**kw)
    for i, (p, m) in enumerate(reqs):
        eng.submit(Request(request_id=i, prompt=p, max_new_tokens=m))
    res = eng.run_to_completion()
    return {k: res[k].tokens for k in sorted(res)}, eng


@functools.lru_cache(maxsize=1)
def _baseline():
    return _serve(_requests())[0]


# ----------------------------------------------------------------------
# token parity with the slot engine
# ----------------------------------------------------------------------
def test_paged_parity_full_pool():
    out, eng = _serve(_requests(), page_size=PAGE,
                      prefix_cache_bytes=8 << 20)
    assert out == _baseline()
    assert eng.stats["chunk_prefill_calls"] > 0
    # drained engine: only the prefix cache may still hold pages
    bm = eng.block_manager
    assert bm.in_use == len(eng.prefix_cache._holds)
    assert all(not p for p in eng._pages)
    assert (eng._bt_host == 0).all()


def test_paged_parity_no_cache_all_pages_freed():
    out, eng = _serve(_requests(), page_size=PAGE)
    assert out == _baseline()
    bm = eng.block_manager
    assert bm.in_use == 0
    assert bm.free_pages == bm.num_pages - 1
    assert bm.stats["allocs"] == bm.stats["frees"]


def _overflow_requests():
    """Two long generations whose combined page demand (6 + 5 pages) must
    overflow a 6-page pool mid-decode: serving them on ``kv_pages=7``
    deterministically forces preemption-by-recompute."""
    rng = np.random.default_rng(11)
    return [(rng.integers(0, 256, 4, dtype=np.int32), 40),   # -> len 44
            (rng.integers(0, 256, 4, dtype=np.int32), 30)]   # -> len 34


def test_paged_parity_tight_pool_preempts():
    """An under-provisioned pool must preempt-by-recompute (discard the
    victim's generated tokens, requeue, replay) yet still serve every
    request the identical greedy stream."""
    base, _ = _serve(_overflow_requests())
    out, eng = _serve(_overflow_requests(), page_size=PAGE, kv_pages=7)
    assert out == base
    assert eng.stats["preemptions"] > 0
    assert eng.block_manager.stats["peak_in_use"] <= 6


def test_paged_parity_speculative():
    spec = SpecConfig(k=3, proposer="ngram")
    base, _ = _serve(_requests(), spec=spec)
    out, eng = _serve(_requests(), spec=spec, page_size=PAGE,
                      prefix_cache_bytes=8 << 20)
    assert out == base == _baseline()  # greedy spec is lossless too
    lbase, _ = _serve(_overflow_requests(), spec=spec)
    tight, et = _serve(_overflow_requests(), spec=spec, page_size=PAGE,
                       kv_pages=7)
    assert tight == lbase
    assert et.stats["preemptions"] > 0


def test_paged_parity_chunked_prefill():
    """A tiny chunk budget splits every prompt across many interleaved
    prefill steps without changing a single output token."""
    out, eng = _serve(_requests(), page_size=PAGE, prefill_chunk_tokens=8)
    assert out == _baseline()
    # 9 prompts, several > 8 tokens: strictly more chunk calls than prompts
    assert eng.stats["chunk_prefill_calls"] > 9 / SLOTS


# ----------------------------------------------------------------------
# watermark admission: out-of-order under pressure (starvation regression)
# ----------------------------------------------------------------------
def test_admission_skips_blocked_head_admits_smaller():
    """A page-hungry request at the queue head must not starve smaller
    requests behind it: while the pool cannot host the big one, later small
    requests admit out of order; the big one runs once pages free up."""
    eng = _engine(page_size=PAGE, kv_pages=8, slots=2)  # 7 usable pages
    rng = np.random.default_rng(0)
    # long-runner: holds pages for many steps
    eng.submit(Request(request_id=0,
                       prompt=rng.integers(0, 256, 12, dtype=np.int32),
                       max_new_tokens=24))
    # big head request: needs 6 pages -> can't fit while 0 is running
    eng.submit(Request(request_id=1,
                       prompt=rng.integers(0, 256, 42, dtype=np.int32),
                       max_new_tokens=2))
    # small request behind it: 1 page
    eng.submit(Request(request_id=2,
                       prompt=rng.integers(0, 256, 4, dtype=np.int32),
                       max_new_tokens=2))
    small_done_at = big_done_at = None
    for step in range(400):
        eng.step()
        if small_done_at is None and 2 in eng.results:
            small_done_at = step
        if big_done_at is None and 1 in eng.results:
            big_done_at = step
        if len(eng.results) == 3:
            break
    assert len(eng.results) == 3, "requests starved"
    assert eng.stats["admit_skips"] > 0
    assert small_done_at < big_done_at, (
        "small request should overtake the blocked big one")


def test_idle_engine_admits_below_watermark():
    """A sole tenant must admit even when the watermark would forbid it —
    the watermark only arbitrates between concurrent tenants."""
    eng = _engine(page_size=PAGE, kv_pages=7, slots=2,
                  kv_watermark=0.3)  # 6 usable pages, watermark 2
    prompt = np.arange(42, dtype=np.int32) % 251  # needs all 6 pages
    eng.submit(Request(request_id=0, prompt=prompt, max_new_tokens=3))
    res = eng.run_to_completion()
    assert len(res[0].tokens) == 3


# ----------------------------------------------------------------------
# page sharing / accounting
# ----------------------------------------------------------------------
def test_prefix_reuse_aliases_pages_not_copies():
    """Two requests over the same cached prompt share full pages by
    refcount; the second admission restores the prefix without prefilling
    it again (prefill token accounting shows only the suffix)."""
    cfg, params = _model()
    prompt = (np.arange(2 * PAGE + 3) % 251).astype(np.int32)
    eng = _engine(page_size=PAGE, prefix_cache_bytes=8 << 20)
    eng.submit(Request(request_id=0, prompt=prompt, max_new_tokens=2))
    eng.run_to_completion()
    tokens_before = eng.stats["prefill_tokens"]
    eng.submit(Request(request_id=1, prompt=prompt, max_new_tokens=2))
    res = eng.run_to_completion()
    assert res[1].tokens == res[0].tokens  # greedy determinism
    assert eng.stats["prefix_hits"] == 1
    # only the last token (plus padding) re-prefilled, not the whole prompt
    assert eng.stats["prefill_tokens"] - tokens_before < prompt.size
    assert eng.stats["prefix_hit_tokens"] >= 2 * PAGE


def test_paged_geometry_validation():
    cfg, params = _model()
    with pytest.raises(ValueError, match="multiple"):
        _engine(page_size=7)  # 48 % 7 != 0
    with pytest.raises(ValueError, match="cannot hold"):
        _engine(page_size=PAGE, kv_pages=4)
    with pytest.raises(ValueError, match="fused"):
        _engine(page_size=PAGE, fused=False)
    rec = configs.get_config("recurrentgemma-9b-smoke")
    rparams = transformer.init_model(jax.random.key(0), rec)
    with pytest.raises(NotImplementedError, match="attention-family"):
        ServingEngine(rec, rparams, slots=2, max_len=MAX_LEN,
                      page_size=PAGE)


# ----------------------------------------------------------------------
# telemetry surfaces
# ----------------------------------------------------------------------
def test_paged_summary_and_manifest():
    cfg, params = _model()
    eng = ServingEngine(cfg, params, slots=SLOTS, max_len=MAX_LEN,
                        prompt_buckets=(8, 16, 32), page_size=PAGE,
                        prefix_cache_bytes=8 << 20,
                        manifest={"apis": {}})
    assert eng.manifest["paged_kv"]["page_size"] == PAGE
    assert eng.manifest["paged_kv"]["kv_pages"] == eng.kv_pages
    assert eng.manifest["paged_kv"]["page_bytes"] == eng.page_bytes
    for i, (p, m) in enumerate(_requests(n=4)):
        eng.submit(Request(request_id=i, prompt=p, max_new_tokens=m))
    eng.step()  # mid-flight: active requests hold pages
    s = eng.paged_summary()
    assert s["pages_in_use"] >= sum(len(p) for p in eng._pages) > 0
    assert 0.0 <= s["fragmentation"] <= 1.0
    assert s["blocks_per_request_max"] >= s["blocks_per_request_mean"] > 0
    assert s["active_requests"] == sum(r is not None for r in eng.active)
    assert "prefix" in s
    eng.run_to_completion()
    # the slot engine reports no paged section
    base = _engine()
    assert base.paged_summary() is None


def test_fleet_report_carries_paged_kv_telemetry():
    """A paged fleet surfaces page-pool occupancy in the FleetReport (the
    fleet-wide aggregate and the per-replica breakdown) and still serves
    and reconciles every request."""
    from repro import fleet as fl
    cfg, params = _model()
    fleet_cfg = fl.FleetConfig(
        min_replicas=1, max_replicas=1, slots=2, max_len=32,
        prompt_buckets=(8, 16), tick_s=0.1, settle_s=10.0,
        page_size=8, kv_pages=9, prefix_cache_mb=1.0)
    trace = fl.steady_trace(seed=0, duration_s=4.0, prompt_median=8,
                            prompt_lo=4, prompt_hi=12, max_new_lo=2,
                            max_new_hi=4)
    reqs = fl.materialize(trace, vocab_size=cfg.vocab_size, seed=1)
    fm = fl.FleetManager.build(cfg, params, chips=2, fleet=fleet_cfg)
    report = fm.run_trace(reqs)
    assert report.served == report.requests
    assert report.reconciled
    assert report.paged_kv["enabled"]
    assert report.paged_kv["pages_total"] == 8
    assert report.paged_kv["peak_in_use"] > 0
    per_replica = [r["paged"] for r in report.replicas if r["paged"]]
    assert per_replica and all(p["page_size"] == 8 for p in per_replica)
    fm.shutdown()


def test_pages_for():
    assert pages_for(0, 8) == 0
    assert pages_for(1, 8) == 1
    assert pages_for(8, 8) == 1
    assert pages_for(9, 8) == 2
    assert pages_for(48, 8) == 6
