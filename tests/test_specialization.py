"""Backend specialization: deploy-time probes, tier fallback, and the
container specialization manifest (docs/kernel-portability.md).

The contract under test: a tier that cannot actually compile/run on the
target must be *rejected at bind time* and dispatch must fall back to the
next priority, with the rejection recorded in the manifest — never an
exception escaping from inside a deployed program.
"""
import dataclasses
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import container as xc
from repro.core import hooks, recompile
from repro.kernels import compat, ops, ref


@dataclasses.dataclass(frozen=True)
class FakeProfile:
    """Minimal stand-in for a SystemProfile with a controllable library set."""

    name: str
    chip: str
    providers: tuple

    def supports(self, provider):
        return provider in self.providers


_uid = itertools.count()


def _fresh_api():
    """Register a throwaway accelerated API with a hi/lo tier pair where the
    hi tier's probe fails. Returns (api_name, probe_call_counts)."""
    name = f"_spec_probe_op_{next(_uid)}"
    hooks.register_api(name, "(x) -> x", lambda x: x * 0 + 1.0)
    calls = {"hi": 0, "lo": 0}

    def hi_probe(profile):
        calls["hi"] += 1
        raise AttributeError("module has no attribute 'CompilerParams'")

    def lo_probe(profile):
        calls["lo"] += 1

    hooks.register_impl(
        name, "tier-hi", lambda x: x * 0 + 2.0,
        supports=lambda p: p.supports("tier-hi"), priority=20, probe=hi_probe)
    hooks.register_impl(
        name, "tier-lo", lambda x: x * 0 + 3.0,
        supports=lambda p: p.supports("tier-lo"), priority=10, probe=lo_probe)
    return name, calls


def test_probe_failure_falls_back_to_next_tier():
    api, calls = _fresh_api()
    prof = FakeProfile(f"fake-{api}", f"chip-{api}", ("tier-hi", "tier-lo"))
    binding = hooks.bind(prof, probe=True)
    assert binding.providers()[api] == "tier-lo"
    choice = binding.choices[api]
    assert choice.probed
    assert choice.rejected[0][0] == "tier-hi"
    assert "CompilerParams" in choice.rejected[0][1]
    # the bound fn is really the lo tier
    with hooks.use(binding):
        np.testing.assert_allclose(
            np.asarray(hooks.call(api, jnp.zeros(2))), 3.0)


def test_all_probes_failing_reaches_portable_floor():
    api, _ = _fresh_api()
    # profile only offers the (broken) hi tier -> reference must serve
    prof = FakeProfile(f"fake-{api}", f"chip-{api}", ("tier-hi",))
    binding = hooks.bind(prof, probe=True)
    assert binding.providers()[api] == "portable"
    assert binding.choices[api].rejected == (
        ("tier-hi", "AttributeError: module has no attribute "
         "'CompilerParams'"),)
    with hooks.use(binding):
        np.testing.assert_allclose(
            np.asarray(hooks.call(api, jnp.zeros(2))), 1.0)


def test_probe_results_cached_per_chip():
    api, calls = _fresh_api()
    prof = FakeProfile(f"fake-{api}", f"chip-{api}", ("tier-hi", "tier-lo"))
    hooks.bind(prof, probe=True)
    hooks.bind(prof, probe=True)  # warm re-bind: no re-probe
    assert calls == {"hi": 1, "lo": 1}
    # a different chip kind re-probes (different local toolchain assumption)
    other = FakeProfile(f"fake2-{api}", f"chip2-{api}", ("tier-hi", "tier-lo"))
    hooks.bind(other, probe=True)
    assert calls == {"hi": 2, "lo": 2}


def test_reregister_invalidates_stale_probe_verdict():
    api, _ = _fresh_api()
    prof = FakeProfile(f"fake-{api}", f"chip-{api}", ("tier-hi", "tier-lo"))
    assert hooks.bind(prof, probe=True).providers()[api] == "tier-lo"
    # ship a fixed implementation under the same provider tag: the cached
    # failure verdict for the old one must not keep rejecting it
    hooks.register_impl(
        api, "tier-hi", lambda x: x * 0 + 4.0,
        supports=lambda p: p.supports("tier-hi"), priority=20,
        probe=lambda profile: None)
    assert hooks.bind(prof, probe=True).providers()[api] == "tier-hi"


def test_unprobed_bind_keeps_legacy_selection():
    api, calls = _fresh_api()
    prof = FakeProfile(f"fake-{api}", f"chip-{api}", ("tier-hi", "tier-lo"))
    binding = hooks.bind(prof)  # probe=False: priority wins, nothing runs
    assert binding.providers()[api] == "tier-hi"
    assert calls == {"hi": 0, "lo": 0}


def test_pinned_override_is_not_probed():
    api, calls = _fresh_api()
    prof = FakeProfile(f"fake-{api}", f"chip-{api}", ("tier-hi", "tier-lo"))
    binding = hooks.bind(prof, overrides={api: "tier-hi"}, probe=True)
    assert binding.providers()[api] == "tier-hi"
    assert calls["hi"] == 0  # a pin is an operator's explicit order


# ---------------------------------------------------------------------------
# The real tiers on the CPU-CI profile
# ---------------------------------------------------------------------------
def test_cpu_interpret_profile_binds_pallas_interpret():
    binding = hooks.bind(recompile.CPU_INTERPRET, probe=True)
    prov = binding.providers()
    for api in ("attention", "decode_attention", "rmsnorm", "moe_mlp"):
        assert prov[api] == "pallas-interpret", (api, prov[api])
    assert prov["mlstm"] == "xla-blocked"
    man = binding.manifest()
    assert man["apis"]["attention"]["probed"]


def test_interpret_tier_numerics_match_ref():
    k1, k2, k3 = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(k1, (1, 64, 2, 16))
    k = jax.random.normal(k2, (1, 64, 1, 16))
    v = jax.random.normal(k3, (1, 64, 1, 16))
    binding = hooks.bind(recompile.CPU_INTERPRET, probe=True)
    with hooks.use(binding):
        got = hooks.call("attention", q, k, v, causal=True)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_container_deploy_records_manifest():
    def fn(q, k, v):
        return hooks.call("attention", q, k, v, causal=True)

    def make_args(mesh):
        q = jax.ShapeDtypeStruct((1, 16, 2, 8), jnp.float32)
        kv = jax.ShapeDtypeStruct((1, 16, 1, 8), jnp.float32)
        return (q, kv, kv), {}, {}

    cont = xc.XContainer(name="spec-demo", entrypoints={"attn": (fn, make_args)})
    dep = cont.deploy(recompile.CPU_INTERPRET)
    man = dep.manifest()
    assert man["profile"] == "cpu-pallas-interpret"
    assert man["apis"]["attention"]["provider"] == "pallas-interpret"
    # deploy() mirrors the manifest into the container's meta, keyed by
    # profile, so a shipped recipe carries the record of every specialization
    stored = cont.meta["specialization"]["cpu-pallas-interpret"]
    assert stored["apis"] == man["apis"]
    # and the portable floor stays portable
    dep_cpu = cont.deploy(recompile.PORTABLE_CPU)
    assert dep_cpu.manifest()["apis"]["attention"]["provider"] == "portable"


# ---------------------------------------------------------------------------
# cost_analysis normalization (the version shim's other half)
# ---------------------------------------------------------------------------
def test_normalize_cost_analysis_formats():
    assert compat.normalize_cost_analysis(None) == {}
    assert compat.normalize_cost_analysis([]) == {}
    assert compat.normalize_cost_analysis({"flops": 1.0}) == {"flops": 1.0}
    assert compat.normalize_cost_analysis([{"flops": 2.0}]) == {"flops": 2.0}
    assert compat.normalize_cost_analysis(
        [("flops", 3.0), ("bytes", 4.0)]) == {"flops": 3.0, "bytes": 4.0}
    with pytest.raises(TypeError):
        compat.normalize_cost_analysis(["seven-key-dict-keys-iterated"])


def test_compiled_artifact_cost_analysis_normalized():
    x = jnp.zeros((32, 32))
    comp = recompile.DeploymentCompiler()
    art = comp.deploy(lambda a: a @ a, "norm-demo", recompile.PORTABLE_CPU,
                      args=(x,))
    cost = art.cost_analysis()
    assert isinstance(cost, dict)
    assert art.flops == pytest.approx(2 * 32**3, rel=0.05)
