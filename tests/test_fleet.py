"""Elastic serving fleet: router affinity, SLO autoscaler policy, trace
determinism, batch checkpoint-preempt-resume, per-tenant metering across
replicas, shared engine program cache, and the end-to-end control plane."""
import functools

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import recompile, scheduler
from repro.core.invocation import InvocationService
from repro.fleet import (SLO, Autoscaler, BatchWorkload, FleetConfig,
                         FleetManager, FleetRequest, ReplicaState, Router,
                         bursty_trace, diurnal_trace, materialize)
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine
from repro.serving.service import serving_container


@functools.lru_cache(maxsize=1)
def _model():
    cfg = configs.get_config("qwen2-0.5b-smoke")
    params = transformer.init_model(jax.random.key(0), cfg)
    return cfg, params


# ----------------------------------------------------------------------
# router
# ----------------------------------------------------------------------

class FakeReplica:
    def __init__(self, rid, load=0, accepting=True, hot=()):
        self.replica_id = rid
        self.load = load
        self.accepting = accepting
        self.hot_buckets = set(hot)

    def outstanding_tokens(self):
        return self.load

    def bucket_for(self, plen):
        return 16 if plen <= 16 else 64


def _req(session="s0", plen=8, rid=0):
    return FleetRequest(request_id=rid, tenant="t", session=session,
                        prompt=np.zeros(plen, np.int32), max_new_tokens=4,
                        arrival_s=0.0)


def test_router_least_loaded_deterministic_ties():
    r = Router(session_affinity=False, bucket_affinity=False)
    reps = [FakeReplica(0, load=10), FakeReplica(1, load=2), FakeReplica(2, load=2)]
    assert r.route(_req(), reps).replica_id == 1  # least load, lowest id wins tie


def test_router_skips_non_accepting():
    r = Router()
    reps = [FakeReplica(0, load=0, accepting=False), FakeReplica(1, load=50)]
    assert r.route(_req(), reps).replica_id == 1
    with pytest.raises(RuntimeError):
        r.route(_req(), [FakeReplica(0, accepting=False)])


def test_router_session_affinity_sticks_until_overloaded():
    r = Router(slack_tokens=4, overload_factor=2.0)
    reps = [FakeReplica(0, load=0), FakeReplica(1, load=0)]
    first = r.route(_req(session="alice"), reps)
    # returning session sticks even when the other replica is now emptier
    # (load 3 is within overload_factor * floor + slack = 4)
    reps[first.replica_id].load = 3
    again = r.route(_req(session="alice", rid=1), reps)
    assert again.replica_id == first.replica_id
    assert r.stats["session_hits"] == 1
    # ... but not when the pinned replica is overloaded vs the fleet floor
    reps[first.replica_id].load = 100
    spilled = r.route(_req(session="alice", rid=2), reps)
    assert spilled.replica_id != first.replica_id


def test_router_bucket_affinity_prefers_hot_replica():
    r = Router(session_affinity=False)
    cold = FakeReplica(0, load=0)
    hot = FakeReplica(1, load=2, hot=(16,))
    assert r.route(_req(plen=8), [cold, hot]).replica_id == 1
    assert r.stats["bucket_hits"] == 1
    # a long prompt (bucket 64) has no hot replica -> least loaded
    assert r.route(_req(plen=40, rid=1), [cold, hot]).replica_id == 0


def test_router_no_session_pins_recorded_when_affinity_disabled():
    """Regression: route() used to record a pin on EVERY call even with
    session_affinity=False, so _sessions grew by one entry per session
    forever on a long fleet run."""
    r = Router(session_affinity=False)
    reps = [FakeReplica(0), FakeReplica(1, load=1)]
    for i in range(50):
        r.route(_req(session=f"s{i}", rid=i), reps)
    assert len(r._sessions) == 0


def test_router_session_pin_map_is_lru_bounded():
    r = Router(max_sessions=4)
    reps = [FakeReplica(0), FakeReplica(1, load=1)]
    for i in range(10):
        r.route(_req(session=f"s{i}", rid=i), reps)
    assert len(r._sessions) == 4
    assert r.stats["sessions_evicted"] == 6
    # the survivors are the most recent sessions, oldest evicted first
    assert list(r._sessions) == ["s6", "s7", "s8", "s9"]
    # an evicted session simply re-routes (no stale pin, no error)
    r.route(_req(session="s0", rid=100), reps)
    assert "s0" in r._sessions
    # forget_session drops a pin explicitly (drop-on-retire hook)
    r.forget_session("s0")
    assert "s0" not in r._sessions


class PrefixFakeReplica(FakeReplica):
    def __init__(self, rid, prefix_len=0, **kw):
        super().__init__(rid, **kw)
        self._plen = prefix_len

    def cached_prefix_len(self, prompt):
        return self._plen


def test_router_prefix_affinity_prefers_longest_cached_prefix():
    r = Router(session_affinity=False, bucket_affinity=False)
    cold = PrefixFakeReplica(0, prefix_len=0)
    warm = PrefixFakeReplica(1, prefix_len=8, load=2)
    warmer = PrefixFakeReplica(2, prefix_len=20, load=3)
    assert r.route(_req(), [cold, warm, warmer]).replica_id == 2
    assert r.stats["prefix_hits"] == 1
    # an overloaded replica loses its prefix pull (affinity never hotspots)
    warmer.load = 100
    assert r.route(_req(rid=1), [cold, warm, warmer]).replica_id == 1
    # no cached prefix anywhere -> least loaded
    warm._plen = warmer._plen = 0
    assert r.route(_req(rid=2), [cold, warm, warmer]).replica_id == 0
    assert r.stats["least_loaded"] == 1


def test_router_prefix_affinity_ranks_below_session_affinity():
    r = Router()
    pinned = PrefixFakeReplica(0, prefix_len=0)
    prefixy = PrefixFakeReplica(1, prefix_len=0, load=1)
    first = r.route(_req(session="alice"), [pinned, prefixy])
    assert first.replica_id == 0  # least loaded on first contact
    # another replica now advertises a long cached prefix, but the returning
    # session sticks to its pinned replica (conversation state beats prefix)
    prefixy._plen = 30
    again = r.route(_req(session="alice", rid=1), [pinned, prefixy])
    assert again.replica_id == 0
    assert r.stats["session_hits"] == 1
    # a session-less fresh request does follow the prefix signal
    assert r.route(_req(session="bob", rid=2), [pinned, prefixy]).replica_id == 1
    assert r.stats["prefix_hits"] == 1


def test_router_forget_replica_unpins_sessions():
    r = Router()
    reps = [FakeReplica(0), FakeReplica(1, load=1)]
    assert r.route(_req(session="bob"), reps).replica_id == 0
    r.forget_replica(0)
    reps[0].accepting = False
    assert r.route(_req(session="bob", rid=1), reps).replica_id == 1


# ----------------------------------------------------------------------
# autoscaler
# ----------------------------------------------------------------------

def test_autoscaler_scales_up_on_queue_pressure_with_cooldown():
    a = Autoscaler(SLO(queue_high_per_slot=1.0, up_cooldown_s=1.0), 1, 4)
    up = a.decide(0.0, serving=1, booting=0, queued=5, busy_slots=2, total_slots=2)
    assert up == "up"
    # cooldown suppresses an immediate second scale-up
    assert a.decide(0.5, serving=1, booting=1, queued=9, busy_slots=2,
                    total_slots=2) is None
    assert a.decide(1.5, serving=1, booting=1, queued=9, busy_slots=2,
                    total_slots=4) == "up"


def test_autoscaler_respects_max_and_min():
    a = Autoscaler(SLO(idle_drain_s=0.0, down_cooldown_s=0.0), 1, 2)
    assert a.decide(0.0, serving=2, booting=0, queued=100, busy_slots=4,
                    total_slots=4) is None  # at max
    # at min: sustained idle still never drains below min_replicas
    assert a.decide(1.0, serving=1, booting=0, queued=0, busy_slots=0,
                    total_slots=2) is None


def test_autoscaler_scales_up_on_p95_violation():
    a = Autoscaler(SLO(p95_target_s=1.0, queue_high_per_slot=100.0,
                       min_window_samples=4), 1, 4)
    for i in range(4):
        a.record_completion(1.0, 3.0)
    assert a.decide(1.0, serving=1, booting=0, queued=0, busy_slots=2,
                    total_slots=2) == "up"
    # completions age out of the window -> no p95 signal -> no scale-up
    b = Autoscaler(SLO(p95_target_s=1.0, queue_high_per_slot=100.0,
                       window_s=2.0), 1, 4)
    for i in range(4):
        b.record_completion(0.0, 3.0)
    assert b.decide(10.0, serving=1, booting=0, queued=0, busy_slots=2,
                    total_slots=2) is None


def test_autoscaler_drains_only_after_sustained_idle():
    slo = SLO(idle_drain_s=2.0, down_cooldown_s=0.0, low_util=0.25)
    a = Autoscaler(slo, 1, 4)
    assert a.decide(0.0, serving=3, booting=0, queued=0, busy_slots=0,
                    total_slots=6) is None  # idle starts counting
    assert a.decide(1.0, serving=3, booting=0, queued=0, busy_slots=0,
                    total_slots=6) is None  # not sustained yet
    # load returning resets the idle clock
    a.decide(1.5, serving=3, booting=0, queued=4, busy_slots=6, total_slots=6)
    assert a.decide(2.5, serving=3, booting=0, queued=0, busy_slots=0,
                    total_slots=6) is None
    assert a.decide(5.0, serving=3, booting=0, queued=0, busy_slots=0,
                    total_slots=6) == "down"


# ----------------------------------------------------------------------
# traffic
# ----------------------------------------------------------------------

def test_traces_are_deterministic_and_seed_sensitive():
    kw = dict(duration_s=30.0, base_rate=0.5, burst_rate=5.0,
              bursts=((5.0, 10.0),))
    t1, t2 = bursty_trace(seed=7, **kw), bursty_trace(seed=7, **kw)
    assert t1 == t2
    assert bursty_trace(seed=8, **kw) != t1
    d1, d2 = diurnal_trace(seed=3), diurnal_trace(seed=3)
    assert d1 == d2


def test_bursty_trace_is_denser_inside_the_burst():
    tr = bursty_trace(seed=0, duration_s=30.0, base_rate=0.2, burst_rate=8.0,
                      bursts=((10.0, 15.0),))
    inside = sum(1 for r in tr if 10.0 <= r.arrival_s < 15.0)
    outside = len(tr) - inside
    assert inside > outside  # 5s of burst dominates 25s of trickle


def test_trace_fields_respect_bounds_and_mix():
    tr = bursty_trace(seed=1, duration_s=40.0, base_rate=2.0, burst_rate=2.0,
                      bursts=(), prompt_lo=4, prompt_hi=16, max_new_lo=3,
                      max_new_hi=9, tenants={"a": 0.8, "b": 0.2})
    assert tr and all(4 <= r.prompt_len <= 16 for r in tr)
    assert all(3 <= r.max_new_tokens <= 9 for r in tr)
    assert {r.tenant for r in tr} <= {"a", "b"}
    # sessions recur (affinity raw material) and stay within their tenant
    assert any(r1.session == r2.session
               for i, r1 in enumerate(tr) for r2 in tr[i + 1:])
    assert all(r.session.startswith(r.tenant) for r in tr)


def test_materialize_builds_submittable_requests():
    cfg, _ = _model()
    tr = bursty_trace(seed=2, duration_s=10.0, base_rate=1.0, burst_rate=1.0,
                      bursts=(), prompt_lo=4, prompt_hi=16)
    reqs = materialize(tr, vocab_size=cfg.vocab_size, seed=3)
    assert [r.request_id for r in reqs] == list(range(len(reqs)))
    assert all(r.prompt.dtype == np.int32 for r in reqs)
    assert all(r.prompt.shape == (t.prompt_len,) for r, t in zip(reqs, tr))
    # deterministic payloads too
    again = materialize(tr, vocab_size=cfg.vocab_size, seed=3)
    assert all(np.array_equal(a.prompt, b.prompt) for a, b in zip(reqs, again))


# ----------------------------------------------------------------------
# engine program cache (warm replica boots)
# ----------------------------------------------------------------------

def test_engines_share_compiled_program_bundle_per_geometry():
    cfg, params = _model()
    e1 = ServingEngine(cfg, params, slots=2, max_len=64, prompt_buckets=(8, 16))
    e2 = ServingEngine(cfg, params, slots=2, max_len=64, prompt_buckets=(8, 16))
    assert e1._fused_step is e2._fused_step  # same jit program object
    assert e1._prefill_chunk is e2._prefill_chunk
    e3 = ServingEngine(cfg, params, slots=4, max_len=64, prompt_buckets=(8, 16))
    assert e3._fused_step is not e1._fused_step  # geometry changes the key


def test_shared_programs_keep_engine_state_isolated():
    cfg, params = _model()
    e1 = ServingEngine(cfg, params, slots=2, max_len=64, prompt_buckets=(8, 16))
    e2 = ServingEngine(cfg, params, slots=2, max_len=64, prompt_buckets=(8, 16))
    rng = np.random.default_rng(0)
    for i in range(2):
        e1.submit(Request(request_id=i,
                          prompt=rng.integers(0, cfg.vocab_size, (6,), dtype=np.int32),
                          max_new_tokens=3))
    r1 = e1.run_to_completion()
    assert sorted(r1) == [0, 1]
    assert e2.results == {} and not any(e2.active)  # untouched by e1's traffic


# ----------------------------------------------------------------------
# batch workload: checkpoint through FTManager on preempt, resume on restart
# ----------------------------------------------------------------------

def _drive(cluster, bw, until, dt=0.5):
    t = cluster.now
    while t < until:
        t += dt
        bw.tick(t, dt)
        cluster.advance_to(t)


def test_batch_preempt_checkpoints_and_resumes():
    cluster = scheduler.Cluster(chips=1)
    bw = BatchWorkload(cluster, step_s=1.0, ckpt_every=2)
    job = bw.submit(chips=1, total_steps=10)
    cluster.run(until=0.0)
    _drive(cluster, bw, 5.0)
    entry = bw.jobs[job.job_id]
    assert entry.progress == pytest.approx(5.0)
    assert entry.ckpt_step >= 2  # periodic cadence ran
    cluster.preempt(job.job_id)
    cluster.run(until=cluster.now)
    # graceful window checkpointed the exact preemption step, then the free
    # chip restarted the job, which resumed from that checkpoint
    assert bw.stats["preemptions"] == 1 and bw.stats["resumes"] == 1
    assert entry.ckpt_step == 5
    assert entry.progress == pytest.approx(5.0)
    assert job.state == scheduler.JobState.RUNNING
    _drive(cluster, bw, 12.0)
    assert job.state == scheduler.JobState.DONE
    assert entry.progress == pytest.approx(10.0)


def test_batch_checkpoints_through_real_store(tmp_path):
    from repro.checkpoint.store import CheckpointStore
    cluster = scheduler.Cluster(chips=1)
    stores = {}

    def factory(job_id):
        stores[job_id] = CheckpointStore(str(tmp_path / f"job-{job_id}"), keep=2)
        return stores[job_id]

    bw = BatchWorkload(cluster, step_s=1.0, ckpt_every=2, store_factory=factory)
    job = bw.submit(chips=1, total_steps=8)
    cluster.run(until=0.0)
    _drive(cluster, bw, 3.0)
    cluster.preempt(job.job_id)
    cluster.run(until=cluster.now)
    store = stores[job.job_id]
    assert store.latest_step() == 3  # preemption checkpoint committed to disk
    like = {"data_step": np.asarray(0)}
    tree, meta = store.restore(like)
    assert int(tree["data_step"]) == 3 and meta["job"] == job.job_id


# ----------------------------------------------------------------------
# per-tenant metering through one lease
# ----------------------------------------------------------------------

def test_executor_attributes_tokens_per_request_tenant():
    cfg, params = _model()
    cont = serving_container(cfg, params, slots=2, max_len=64,
                             prompt_buckets=(8, 16))
    profile = recompile.PORTABLE_CPU
    service = InvocationService(scheduler.Cluster(chips=profile.chips))
    owners = {0: "acme", 1: "globex", 2: "acme"}
    with service.acquire_serving("fleet-op", cont, profile,
                                 tenant_of=owners.__getitem__) as ex:
        rng = np.random.default_rng(0)
        for i in range(3):
            ex.submit(Request(
                request_id=i,
                prompt=rng.integers(0, cfg.vocab_size, (6,), dtype=np.int32),
                max_new_tokens=3))
        results = ex.run()
        tok = {rid: len(r.tokens) for rid, r in results.items()}
        assert service.meter.served_tokens("acme") == tok[0] + tok[2]
        assert service.meter.served_tokens("globex") == tok[1]
        # chips billed to the lease holder, not the request tenants
        assert service.meter.total_steps("serve_decode", "fleet-op") == \
            ex.engine.stats["decode_steps"]
        assert service.meter.total_steps("serve_decode", "acme") == 0
    # context manager released the lease on exit
    assert not ex.lease.active
    assert service.cluster.free_chips == service.cluster.total_chips


# ----------------------------------------------------------------------
# the fleet, end to end
# ----------------------------------------------------------------------

def test_fleet_end_to_end_scales_preempts_and_reconciles():
    cfg, params = _model()
    fleet_cfg = FleetConfig(min_replicas=1, max_replicas=2, slots=2,
                            max_len=64, prompt_buckets=(8, 16), tick_s=0.1,
                            warm_boot_s=0.3, cold_boot_s=0.6, settle_s=20.0)
    slo = SLO(p95_target_s=1.0, queue_high_per_slot=1.0, up_cooldown_s=0.5,
              down_cooldown_s=1.0, idle_drain_s=2.0)
    trace = bursty_trace(seed=0, duration_s=10.0, base_rate=0.3,
                         burst_rate=6.0, bursts=((2.0, 6.0),),
                         prompt_median=8, prompt_lo=4, prompt_hi=16,
                         max_new_lo=4, max_new_hi=6)
    reqs = materialize(trace, vocab_size=cfg.vocab_size, seed=1)
    # 2 chips total: min replica + one batch job -> the second replica can
    # only come from preemption
    fm = FleetManager.build(cfg, params, chips=2, fleet=fleet_cfg, slo=slo,
                            batch_jobs=[(1, 20)])
    report = fm.run_trace(reqs)

    assert report.served == report.requests == len(reqs)
    # elastic scale-ups only: the initial min-footprint boot is not counted
    assert report.scale_ups >= 1
    assert report.preemptions >= 1          # scale-up had to evict the batch job
    assert report.batch["checkpoints"] >= 1
    assert report.batch["resumes"] >= 1     # batch resumed after scale-to-min
    assert report.lease_releases >= 1       # scale-to-min released a lease
    assert report.reconciled                # per-tenant ledger == served tokens
    assert sum(report.metered_by_tenant.values()) == report.tokens
    # warm-deployment cache: only the first replica deploy is cold
    assert fm.service.stats["cold_acquires"] == 1
    assert fm.service.stats["warm_acquires"] >= 1
    # every promoted replica surfaced its specialization manifest
    assert all(r["tiers"] for r in report.replicas if r["state"] != "booting")
    # settled back to the min footprint with the batch job re-running
    assert len([r for r in fm.replicas if r.state == ReplicaState.SERVING]) == 1
    fm.cluster.check_invariants()

    # shutdown releases the last lease; every serving chip returns
    fm.shutdown()
    assert all(r.state == ReplicaState.RELEASED for r in fm.replicas)
    assert not fm.service.active_leases()


def test_fleet_runs_are_deterministic():
    cfg, params = _model()

    def one_run():
        fleet_cfg = FleetConfig(min_replicas=1, max_replicas=2, slots=2,
                                max_len=64, prompt_buckets=(8, 16), tick_s=0.1,
                                warm_boot_s=0.3, cold_boot_s=0.6)
        trace = bursty_trace(seed=5, duration_s=6.0, base_rate=0.5,
                             burst_rate=4.0, bursts=((1.0, 3.0),),
                             prompt_median=8, prompt_lo=4, prompt_hi=16,
                             max_new_lo=3, max_new_hi=5)
        reqs = materialize(trace, vocab_size=cfg.vocab_size, seed=6)
        fm = FleetManager.build(cfg, params, chips=3, fleet=fleet_cfg)
        rep = fm.run_trace(reqs)
        return (rep.served, rep.tokens, rep.latency_p50_s, rep.latency_p99_s,
                rep.scale_ups, rep.lease_releases, rep.serving_chip_s)

    assert one_run() == one_run()
