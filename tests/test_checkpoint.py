"""Checkpoint/restart + elastic restore + data-pipeline determinism (the FT
invariants from DESIGN.md §7)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore
from repro.data import pipeline as datalib


def _tree(key):
    ks = jax.random.split(key, 3)
    return {
        "params": {"w": jax.random.normal(ks[0], (8, 16)),
                   "b": jax.random.normal(ks[1], (16,))},
        "opt": {"step": jnp.int32(7),
                "mu": {"w": jax.random.normal(ks[2], (8, 16)),
                       "b": jnp.zeros((16,))}},
    }


def test_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = _tree(jax.random.key(0))
    store.save(42, tree, meta={"data_step": 42}, blocking=True)
    assert store.steps() == [42]
    got, meta = store.restore(tree)
    assert meta["data_step"] == 42
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_uncommitted_ignored(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = _tree(jax.random.key(0))
    store.save(1, tree, blocking=True)
    # simulate a mid-write crash: step dir without COMMIT
    crashed = os.path.join(str(tmp_path), "step_000000002")
    os.makedirs(os.path.join(crashed, "arrays"))
    assert store.steps() == [1]
    assert store.latest_step() == 1


def test_gc_keeps_latest(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    tree = _tree(jax.random.key(0))
    for s in (1, 2, 3, 4):
        store.save(s, tree, blocking=True)
    assert store.steps() == [3, 4]


def test_elastic_restore_onto_mesh(tmp_path):
    """Restore places leaves with target-mesh shardings (topology change)."""
    from jax.sharding import PartitionSpec as P

    store = CheckpointStore(str(tmp_path))
    tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(4, 8)}
    store.save(5, tree, blocking=True)
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    got, _ = store.restore(tree, mesh=mesh, pspecs={"w": P("data", None)})
    assert isinstance(got["w"].sharding, jax.sharding.NamedSharding)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))


def test_shape_mismatch_rejected(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(1, {"w": jnp.zeros((4, 4))}, blocking=True)
    with pytest.raises(ValueError):
        store.restore({"w": jnp.zeros((8, 8))})


# ---------------------------------------------------------------------------
# data pipeline determinism — the restart-exactness invariant
# ---------------------------------------------------------------------------
def test_data_restart_determinism():
    cfg = datalib.DataConfig(global_batch=8, seq_len=32, vocab_size=100, seed=3)
    src = datalib.SyntheticLM(cfg)
    b1 = src.batch(17)
    b2 = src.batch(17)  # re-materialized after a "restart"
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], src.batch(18)["tokens"])


def test_data_host_sharding_partitions_global_batch():
    cfg = datalib.DataConfig(global_batch=8, seq_len=16, vocab_size=50, seed=0)
    src = datalib.SyntheticLM(cfg)
    full = src.batch(5, host_id=0, num_hosts=1)
    parts = [src.batch(5, host_id=h, num_hosts=4) for h in range(4)]
    for p in parts:
        assert p["tokens"].shape == (2, 16)
    # elastic invariant: the step-5 stream content is host-count independent
    # (host h of 4 sees *a* deterministic slice; same (h, n) -> same data)
    again = src.batch(5, host_id=2, num_hosts=4)
    np.testing.assert_array_equal(parts[2]["tokens"], again["tokens"])


def test_audio_delay_pattern():
    cfg = datalib.DataConfig(global_batch=2, seq_len=16, vocab_size=40,
                             seed=0, num_codebooks=4)
    b = datalib.SyntheticLM(cfg).batch(0)
    assert b["tokens"].shape == (2, 4, 16)
    toks = b["tokens"]
    # codebook j is right-shifted by j: its first j slots are padding zeros
    for j in range(1, 4):
        assert (toks[:, j, :j] == 0).all()


def test_prefetcher_overlaps_and_is_ordered():
    cfg = datalib.DataConfig(global_batch=2, seq_len=8, vocab_size=30, seed=1)
    src = datalib.SyntheticLM(cfg)
    pf = datalib.Prefetcher(src, start_step=3, depth=2)
    try:
        steps = [pf.next()[0] for _ in range(4)]
        assert steps == [3, 4, 5, 6]
        want = src.batch(4)
        got = None
        # re-fetch step 4's content deterministically
        np.testing.assert_array_equal(want["tokens"], src.batch(4)["tokens"])
    finally:
        pf.close()
