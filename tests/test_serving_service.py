"""Serving as a leased XaaS service: SERVICE-class lease boots the engine,
traffic flows through the executor, every served token lands in the tenant's
ledger, warm re-acquire skips deployment."""
import functools

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import recompile, scheduler
from repro.core.invocation import InvocationService
from repro.models import transformer
from repro.serving.engine import Request
from repro.serving.sampling import SamplingConfig
from repro.serving.service import serving_container


@functools.lru_cache(maxsize=1)
def _model():
    cfg = configs.get_config("qwen2-0.5b-smoke")
    params = transformer.init_model(jax.random.key(0), cfg)
    return cfg, params


def _container(**kw):
    cfg, params = _model()
    kw = {"slots": 2, "max_len": 64, "prompt_buckets": (8, 16), **kw}
    return cfg, serving_container(cfg, params, **kw)


def _requests(cfg, n, seed=0, max_new=3):
    rng = np.random.default_rng(seed)
    return [Request(request_id=i,
                    prompt=rng.integers(0, cfg.vocab_size, (6,), dtype=np.int32),
                    max_new_tokens=max_new,
                    sampling=SamplingConfig())
            for i in range(n)]


def test_serving_lease_meters_every_token():
    cfg, cont = _container()
    profile = recompile.PORTABLE_CPU
    service = InvocationService(scheduler.Cluster(chips=profile.chips))
    ex = service.acquire_serving("tenant-a", cont, profile)

    assert ex.lease.job.klass == scheduler.JobClass.SERVICE
    assert ex.lease.job.state == scheduler.JobState.RUNNING
    assert service.stats["cold_acquires"] == 1

    for r in _requests(cfg, 4):
        ex.submit(r)
    results = ex.run()
    assert sorted(results) == [0, 1, 2, 3]
    tokens = sum(len(r.tokens) for r in results.values())

    # the ledger saw every served token, attributed to the tenant
    assert service.meter.served_tokens("tenant-a") == tokens
    assert service.meter.served_tokens("someone-else") == 0
    kinds = {b.kind for b in service.meter.bills}
    assert {"serve_tokens", "serve_decode"} <= kinds
    # decode-step billing pulls FLOPs from the compiled decode artifact
    decode_bills = [b for b in service.meter.bills if b.kind == "serve_decode"]
    assert decode_bills and all(b.flops > 0 for b in decode_bills)
    assert service.meter.total_steps("serve_decode", "tenant-a") == \
        ex.engine.stats["decode_steps"]
    service.meter.check_invariants()

    # a second drain meters only the delta
    for r in _requests(cfg, 2, seed=1):
        ex.submit(Request(request_id=10 + r.request_id, prompt=r.prompt,
                          max_new_tokens=r.max_new_tokens))
    results = ex.run()
    total = sum(len(r.tokens) for r in results.values())
    assert service.meter.served_tokens("tenant-a") == total

    ex.release()
    assert not ex.lease.active
    with pytest.raises(RuntimeError):
        ex.submit(_requests(cfg, 1)[0])
    with pytest.raises(RuntimeError):
        ex.run()


def test_container_name_encodes_geometry():
    """Different slot/cache geometries must not alias each other in the
    warm-deployment cache (it keys on container name + profile)."""
    _, cont_a = _container(slots=2, max_len=64)
    _, cont_b = _container(slots=4, max_len=128)
    assert cont_a.name != cont_b.name


def test_warm_reacquire_reuses_deployment():
    cfg, cont = _container()
    profile = recompile.PORTABLE_CPU
    service = InvocationService(scheduler.Cluster(chips=profile.chips))
    ex1 = service.acquire_serving("tenant-a", cont, profile)
    ex1.release()
    ex2 = service.acquire_serving("tenant-b", cont, profile)
    assert service.stats == {**service.stats, "cold_acquires": 1,
                             "warm_acquires": 1}
    assert ex2.lease.deployment is ex1.lease.deployment
    # fresh engine per lease: no state bleed between tenants
    assert ex2.engine is not ex1.engine
    ex2.release()


def test_two_tenant_ledger_isolation():
    cfg, cont = _container()
    profile = recompile.PORTABLE_CPU
    service = InvocationService(scheduler.Cluster(chips=2 * profile.chips))
    exa = service.acquire_serving("tenant-a", cont, profile)
    exb = service.acquire_serving("tenant-b", cont, profile)

    for r in _requests(cfg, 2, seed=2, max_new=2):
        exa.submit(r)
    for r in _requests(cfg, 3, seed=3, max_new=4):
        exb.submit(r)
    ra, rb = exa.run(), exb.run()

    toks_a = sum(len(r.tokens) for r in ra.values())
    toks_b = sum(len(r.tokens) for r in rb.values())
    assert toks_a == 2 * 2 and toks_b == 3 * 4
    assert service.meter.served_tokens("tenant-a") == toks_a
    assert service.meter.served_tokens("tenant-b") == toks_b
    assert service.meter.served_tokens() == toks_a + toks_b
    by_tenant = service.meter.by_tenant()
    assert set(by_tenant) == {"tenant-a", "tenant-b"}
    exa.release()
    exb.release()


def test_container_without_engine_factory_rejected():
    profile = recompile.PORTABLE_CPU
    service = InvocationService(scheduler.Cluster(chips=profile.chips))
    from repro.core import container as xcontainer
    bare = xcontainer.XContainer(name="not-serving", entrypoints={})
    with pytest.raises(ValueError):
        service.acquire_serving("tenant-a", bare, profile)


def test_warmup_reports_specialization_manifest():
    """warmup() must report exactly which kernel tier serves each accelerated
    API for this deployment (the specialization manifest), and the engine
    must carry the deployment's probed binding."""
    cfg, cont = _container()
    profile = recompile.PORTABLE_CPU
    service = InvocationService(scheduler.Cluster(chips=profile.chips))
    ex = service.acquire_serving("tenant-a", cont, profile)
    man = ex.warmup()
    assert man["container"] == cont.name
    assert man["profile"] == "portable-cpu"
    # the portable floor serves every API on this profile
    assert all(c["provider"] == "portable" for c in man["apis"].values())
    assert ex.engine.binding is ex.lease.deployment.binding
    # deploy() also mirrored the manifest into the shipped recipe's meta
    assert cont.meta["specialization"]["portable-cpu"]["apis"] == man["apis"]
    ex.release()
