"""Radix prefix cache: tree mechanics (match/insert/split/refcount/LRU),
engine-level token parity with reuse enabled, snapshot-boundary semantics for
recurrent archs, eviction under pressure, and fleet-level prefix affinity."""
import functools

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine, _programs_for
from repro.serving.prefix_cache import PrefixCache

MAX_LEN = 64


@functools.lru_cache(maxsize=4)
def _model(arch="qwen2-0.5b"):
    cfg = configs.get_config(arch + "-smoke")
    params = transformer.init_model(jax.random.key(0), cfg)
    return cfg, params


def _cache(arch="qwen2-0.5b", capacity=64 << 20):
    cfg, _ = _model(arch)
    ops = _programs_for(cfg, 2, MAX_LEN, None).state_ops
    return PrefixCache(ops, capacity_bytes=capacity), cfg


def _fake_states(cfg, n=1):
    import jax.numpy as jnp
    return transformer.init_states(cfg, n, MAX_LEN, jnp.dtype(cfg.activ_dtype))


def _engine(arch="qwen2-0.5b", cache_bytes=None, **kw):
    cfg, params = _model(arch)
    kw = {"slots": 3, "max_len": MAX_LEN, "prompt_buckets": (8, 16, 32), **kw}
    return cfg, ServingEngine(cfg, params, prefix_cache_bytes=cache_bytes, **kw)


def _serve(eng, reqs):
    for i, (p, m) in enumerate(reqs):
        eng.submit(Request(request_id=i, prompt=p, max_new_tokens=m))
    res = eng.run_to_completion()
    return {k: res[k].tokens for k in sorted(res)}


def _shared_prefix_reqs(vocab, n=8, plen=20, seed=0, lead=()):
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, vocab, lead + (plen,)).astype(np.int32)
    out = []
    for i in range(n):
        tail = rng.integers(0, vocab, lead + (4 + i % 3,)).astype(np.int32)
        out.append((np.concatenate([sys_prompt, tail], axis=-1), 3 + i % 3))
    return out


# ----------------------------------------------------------------------
# radix tree mechanics (no engine, structure only)
# ----------------------------------------------------------------------

def test_radix_insert_match_and_split():
    cache, cfg = _cache()
    st = _fake_states(cfg)
    a = np.array([1, 2, 3, 4, 5], np.int32)
    cache.insert(a, st, 0)
    assert cache.nodes == 1
    m = cache.match(np.array([1, 2, 3, 9], np.int32))
    assert m.raw_len == 3
    assert m.usable == 3  # pure-KV arch: arbitrary token granularity
    # limit caps usable (engine always prefills the last prompt token)
    assert cache.match(np.array([1, 2, 3, 4, 5], np.int32), limit=4).usable == 4
    # inserting the divergent prompt splits the edge at the fork
    cache.insert(np.array([1, 2, 3, 9], np.int32), st, 0)
    assert cache.stats["splits"] == 1
    assert cache.nodes == 3  # [1,2,3] + [4,5] + [9]
    assert cache.match(a).raw_len == 5
    # exact re-insert adds nothing
    n = cache.nodes
    cache.insert(a, st, 0)
    assert cache.nodes == n


def test_radix_no_match_on_cold_tree_and_foreign_prompt():
    cache, cfg = _cache()
    st = _fake_states(cfg)
    assert cache.match(np.array([7, 8], np.int32)).usable == 0
    cache.insert(np.array([1, 2, 3], np.int32), st, 0)
    assert cache.match(np.array([7, 8], np.int32)).usable == 0


def test_refcount_pins_against_eviction_lru_order():
    cache, cfg = _cache()
    st = _fake_states(cfg)
    n1 = cache.insert(np.array([1, 2, 3, 4], np.int32), st, 0)
    n2 = cache.insert(np.array([9, 8, 7, 6], np.int32), st, 0)
    per_node = n1.nbytes
    cache.acquire(n1)
    cache.capacity_bytes = per_node  # room for exactly one node
    cache.evict_to_budget()
    # n2 is LRU-newer but unpinned; n1 is older but pinned -> n2 evicted
    assert cache.stats["evictions"] == 1
    assert cache.match(np.array([1, 2, 3, 4], np.int32)).raw_len == 4
    assert cache.match(np.array([9, 8, 7, 6], np.int32)).raw_len == 0
    # release unpins; the next budget pass can evict it
    cache.release(n1)
    cache.capacity_bytes = 0
    cache.evict_to_budget()
    assert cache.match(np.array([1, 2, 3, 4], np.int32)).raw_len == 0
    assert cache.bytes == 0 and cache.nodes == 0


def test_interior_nodes_survive_until_children_evicted():
    cache, cfg = _cache()
    st = _fake_states(cfg)
    cache.insert(np.array([1, 2, 3, 4], np.int32), st, 0)
    cache.insert(np.array([1, 2, 3, 9], np.int32), st, 0)  # splits at 3
    assert cache.nodes == 3
    cache.capacity_bytes = 0
    cache.evict_to_budget()  # leaves first, then the exposed interior node
    assert cache.nodes == 0 and cache.bytes == 0


def test_snapshot_boundary_semantics_for_recurrent_arch():
    """Recurrent state can't be sliced mid-edge: a prefix is only usable at
    a snapshot boundary (= the end of a previously inserted prompt)."""
    cache, cfg = _cache("recurrentgemma-9b")
    assert cache.ops.has_snap
    st = _fake_states(cfg)
    full = np.array([1, 2, 3, 4, 5], np.int32)
    cache.insert(full, st, 0)
    # mid-edge raw match, but no snapshot at depth 3 -> unusable
    m = cache.match(np.array([1, 2, 3, 9], np.int32))
    assert m.raw_len == 3 and m.usable == 0
    # exact-boundary extension IS usable (the multi-turn case)
    m2 = cache.match(np.concatenate([full, [7, 7]]).astype(np.int32))
    assert m2.usable == 5 and m2.snap_node is not None
    # a later insert landing exactly on a split point upgrades it with a
    # snapshot, making the shared prefix usable from then on
    cache.insert(np.array([1, 2, 3, 9], np.int32), st, 0)   # split at 3
    assert cache.match(np.array([1, 2, 3, 8], np.int32)).usable == 0
    cache.insert(np.array([1, 2, 3], np.int32), st, 0)      # boundary insert
    assert cache.stats["snapshot_upgrades"] == 1
    assert cache.match(np.array([1, 2, 3, 8], np.int32)).usable == 3


# ----------------------------------------------------------------------
# engine integration: parity, stats, eviction under pressure
# ----------------------------------------------------------------------

def test_engine_shared_prefix_parity_and_savings():
    cfg, e0 = _engine()
    reqs = _shared_prefix_reqs(cfg.vocab_size)
    base = _serve(e0, reqs)
    cfg, e1 = _engine(cache_bytes=64 << 20)
    out = _serve(e1, reqs)
    assert out == base  # token parity is non-negotiable
    assert e1.stats["prefix_hits"] > 0
    assert e1.stats["prefix_hit_tokens"] > 0
    assert e1.stats["prefill_tokens"] < e0.stats["prefill_tokens"]
    assert e1.prefix_cache.nodes > 0


def test_restore_survives_same_batch_split():
    """Regression: a lookup's PrefixMatch can go stale within one _admit
    call — an earlier suffix-bucket group's insert may SPLIT a node on the
    match's path (re-slicing its blocks). restore() must re-walk the tree,
    or the later group silently restores only the post-split segment and
    leaves zeros where the prefix head belongs."""
    cfg, _ = _model()
    rng = np.random.default_rng(11)
    base6 = rng.integers(0, cfg.vocab_size, (6,), dtype=np.int32)
    # same _admit batch, different suffix buckets:
    #   A diverges at depth 3 -> its insert splits the leaf at 3
    #   B extends the full leaf -> its (pre-split) match is now stale
    a = np.concatenate(
        [base6[:3], rng.integers(0, cfg.vocab_size, (12,))]).astype(np.int32)
    b = np.concatenate(
        [base6, rng.integers(0, cfg.vocab_size, (2,))]).astype(np.int32)

    def serve(cache_bytes):
        _, eng = _engine(cache_bytes=cache_bytes, slots=3)
        eng.submit(Request(request_id=0, prompt=base6, max_new_tokens=2))
        eng.run_to_completion()  # seeds the tree with the 6-token leaf
        eng.submit(Request(request_id=1, prompt=a, max_new_tokens=4))
        eng.submit(Request(request_id=2, prompt=b, max_new_tokens=4))
        eng.run_to_completion()
        return {k: r.tokens for k, r in eng.results.items()}, eng

    base, _ = serve(None)
    out, eng = serve(64 << 20)
    assert eng.prefix_cache.stats["splits"] == 1  # the hazard actually fired
    assert eng.stats["prefix_hits"] == 2
    assert out == base


def test_engine_parity_under_eviction_pressure():
    cfg, e0 = _engine()
    reqs = _shared_prefix_reqs(cfg.vocab_size, n=10, seed=3)
    base = _serve(e0, reqs)
    # budget sized to a couple of nodes: constant eviction churn mid-stream
    cfg, e1 = _engine(cache_bytes=40_000)
    out = _serve(e1, reqs)
    assert out == base
    assert e1.prefix_cache.stats["evictions"] > 0


def test_engine_multi_turn_parity_recurrent_arch():
    cfg, _ = _model("recurrentgemma-9b")
    rng = np.random.default_rng(0)
    t1 = rng.integers(0, cfg.vocab_size, (12,)).astype(np.int32)
    t2 = np.concatenate([t1, rng.integers(0, cfg.vocab_size, (6,))]).astype(np.int32)
    t3 = np.concatenate([t2, rng.integers(0, cfg.vocab_size, (5,))]).astype(np.int32)

    def serve(cache_bytes):
        _, eng = _engine("recurrentgemma-9b", cache_bytes=cache_bytes, slots=2)
        for i, p in enumerate([t1, t2, t3]):
            eng.submit(Request(request_id=i, prompt=p, max_new_tokens=3))
            eng.run_to_completion()  # turns arrive sequentially
        return {k: r.tokens for k, r in eng.results.items()}, eng

    base, _ = serve(None)
    out, eng = serve(64 << 20)
    assert out == base
    assert eng.stats["prefix_hits"] == 2  # turns 2 and 3 restore turn n-1
    assert eng.stats["prefix_hit_tokens"] == len(t1) + len(t2)


def test_engine_audio_prefix_parity():
    cfg, _ = _model("musicgen-medium")
    reqs = _shared_prefix_reqs(cfg.vocab_size, n=6, plen=12, seed=1,
                               lead=(cfg.num_codebooks,))
    _, e0 = _engine("musicgen-medium", slots=2)
    base = _serve(e0, reqs)
    _, e1 = _engine("musicgen-medium", cache_bytes=64 << 20, slots=2)
    out = _serve(e1, reqs)
    assert out == base
    assert e1.stats["prefix_hits"] > 0


def test_engine_legacy_path_uses_cache_too():
    cfg, e0 = _engine(fused=False)
    reqs = _shared_prefix_reqs(cfg.vocab_size, n=6)
    base = _serve(e0, reqs)
    cfg, e1 = _engine(cache_bytes=64 << 20, fused=False)
    out = _serve(e1, reqs)
    assert out == base
    assert e1.stats["prefix_hits"] > 0


def test_warmup_precompiles_cache_programs():
    cfg, eng = _engine(cache_bytes=64 << 20, slots=2)
    eng.warmup()
    reqs = _shared_prefix_reqs(cfg.vocab_size, n=4)
    out = _serve(eng, reqs)
    assert sorted(out) == [0, 1, 2, 3]
    assert eng.stats["prefix_hits"] > 0


def test_slot_pins_release_on_retire():
    cfg, eng = _engine(cache_bytes=64 << 20)
    reqs = _shared_prefix_reqs(cfg.vocab_size, n=6)
    _serve(eng, reqs)
    assert all(p is None for p in eng._slot_pins)
    for node in eng.prefix_cache._iter_nodes():
        assert node.ref == 0, "leaked prefix pin after retirement"


def test_max_new_one_request_does_not_leak_pins():
    cfg, eng = _engine(cache_bytes=64 << 20)
    prompt = np.arange(10, dtype=np.int32)
    eng.submit(Request(request_id=0, prompt=prompt, max_new_tokens=3))
    eng.run_to_completion()
    eng.submit(Request(request_id=1, prompt=prompt, max_new_tokens=1))
    eng.run_to_completion()
    for node in eng.prefix_cache._iter_nodes():
        assert node.ref == 0
