"""Fault-tolerance runtime: restart path, elastic shrink, straggler
mitigation, end-to-end FT training with a REAL model + checkpoint store."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint.store import CheckpointStore
from repro.data import pipeline as datalib
from repro.ft.manager import (FailureInjector, FTManager, StragglerPolicy)
from repro.training import train_step as ts


def test_straggler_policy_triggers_after_grace():
    pol = StragglerPolicy(threshold=2.0, grace=2)
    assert pol.observe(1.0) is None  # baseline
    assert pol.observe(1.05) is None
    assert pol.observe(5.0) is None  # first slow
    assert pol.observe(5.0) == "mitigate"  # second consecutive slow
    # run resets after mitigation
    assert pol.observe(5.0) is None


def test_straggler_baseline_not_poisoned():
    pol = StragglerPolicy(threshold=2.0, grace=100)
    pol.observe(1.0)
    for _ in range(50):
        pol.observe(10.0)  # stragglers must not inflate the baseline
    assert pol._baseline == pytest.approx(1.0)


def test_injector_deterministic():
    a = FailureInjector(seed=7, p_node_loss=0.3, straggler_p=0.3)
    b = FailureInjector(seed=7, p_node_loss=0.3, straggler_p=0.3)
    for step in range(20):
        assert a.node_fails(step) == b.node_fails(step)
        assert a.step_time(step) == b.step_time(step)


def test_ft_run_without_faults_completes():
    calls = {"makes": 0, "saves": []}

    def make_step(mesh_size):
        calls["makes"] += 1

        def step(state, i):
            return state + 1, {"loss": float(100 - i)}

        return step, jnp.int32(0), 0

    mgr = FTManager(make_step=make_step,
                    save=lambda s, i: calls["saves"].append(i),
                    injector=FailureInjector(seed=0), ckpt_every=5)
    rep = mgr.run(12, mesh_size=4)
    assert rep.steps_done == 12 and rep.restarts == 0
    assert calls["makes"] == 1
    assert calls["saves"] == [5, 10, 12]


def test_ft_restart_resumes_from_checkpoint_step():
    """On node loss: re-mesh smaller, resume from last saved data step —
    no sample skipped or replayed past the checkpoint."""
    saved = {"step": 0}
    seen_meshes = []

    def make_step(mesh_size):
        seen_meshes.append(mesh_size)

        def step(state, i):
            return state, {}

        return step, None, saved["step"]

    def save(state, i):
        saved["step"] = i

    inj = FailureInjector(seed=1, p_node_loss=0.15)
    mgr = FTManager(make_step=make_step, save=save, injector=inj,
                    ckpt_every=3, min_mesh=2)
    rep = mgr.run(30, mesh_size=8)
    assert rep.steps_done == 30
    assert rep.restarts > 0
    # elastic: mesh shrank but never below min
    assert min(seen_meshes) >= 2
    assert seen_meshes[0] == 8 and len(seen_meshes) == rep.restarts + \
        rep.mitigations + 1


def test_ft_end_to_end_with_real_model(tmp_path):
    """Full stack: real train step + checkpoint store + injected failures;
    the final state must equal a fault-free run's state on the same data
    (determinism through restarts — the paper's checkpoint/restart mode)."""
    cfg = configs.get_config("qwen2-0.5b-smoke")
    tcfg = ts.TrainConfig()
    data = datalib.SyntheticLM(datalib.DataConfig(
        global_batch=4, seq_len=16, vocab_size=cfg.vocab_size, seed=0))
    step_fn = jax.jit(ts.make_train_step(cfg, tcfg))

    def run_with(injector, root):
        store = CheckpointStore(root)
        init = ts.init_train_state(jax.random.key(0), cfg, tcfg)

        def make_step(mesh_size):
            start = 0
            state = init
            if store.latest_step() is not None:
                state, meta = store.restore(init)
                start = int(meta["data_step"])

            def one(state, i):
                b = data.batch(i)
                s2, m = step_fn(state, {"tokens": b["tokens"],
                                        "labels": b["labels"]})
                return s2, {k: float(v) for k, v in m.items()}

            return one, state, start

        mgr = FTManager(
            make_step=make_step,
            save=lambda s, i: store.save(i, s, meta={"data_step": i},
                                         blocking=True),
            injector=injector, ckpt_every=4, min_mesh=1)
        rep = mgr.run(10, mesh_size=4)
        store.wait()
        final, _ = store.restore(init)
        return rep, final

    rep_faulty, state_faulty = run_with(
        FailureInjector(seed=3, p_node_loss=0.12), str(tmp_path / "a"))
    rep_clean, state_clean = run_with(
        FailureInjector(seed=3, p_node_loss=0.0), str(tmp_path / "b"))
    assert rep_faulty.restarts > 0 and rep_clean.restarts == 0
    for a, b in zip(jax.tree.leaves(state_faulty["params"]),
                    jax.tree.leaves(state_clean["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)
