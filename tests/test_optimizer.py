"""Optimizer correctness: AdamW vs a naive reference, Adafactor behavior,
stack-chunked update equivalence, clip-scale equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import optimizer as opt


def _params(key, stacked=False):
    ks = jax.random.split(key, 3)
    p = {
        "w": jax.random.normal(ks[0], (8, 16)) * 0.1,
        "b": jax.random.normal(ks[1], (16,)) * 0.1,
    }
    if stacked:
        p["stack"] = jax.random.normal(ks[2], (4, 8, 16)) * 0.1
    return p


def test_adamw_matches_naive_reference():
    cfg = opt.AdamWConfig(lr_peak=1e-2, warmup_steps=0, decay_steps=100,
                          weight_decay=0.01, grad_clip=1e9)
    key = jax.random.key(0)
    params = _params(key)
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 0.01, params)
    state = opt.init_adamw(params, cfg)
    p2, s2, _ = opt.adamw_update(params, grads, state, cfg)

    # naive reference
    b1, b2, step = cfg.b1, cfg.b2, 1
    lr = float(opt.lr_schedule(cfg, jnp.int32(step)))
    for k in params:
        g = np.asarray(grads[k], np.float64)
        mu = (1 - b1) * g
        nu = (1 - b2) * g * g
        mhat = mu / (1 - b1**step)
        nhat = nu / (1 - b2**step)
        want = (np.asarray(params[k], np.float64)
                - lr * (mhat / (np.sqrt(nhat) + cfg.eps)
                        + cfg.weight_decay * np.asarray(params[k], np.float64)))
        np.testing.assert_allclose(np.asarray(p2[k]), want, atol=1e-5)


def test_stacked_map_update_equivalent():
    """lax.map-chunked update == unchunked update (AdamW has no per-tensor
    reductions, so slicing the stack is exact)."""
    cfg = opt.AdamWConfig(grad_clip=1e9)
    key = jax.random.key(1)
    stacked = {"s": jax.random.normal(key, (4, 8, 16)) * 0.1}
    flat = {"s": stacked["s"].reshape(32, 16)}  # ndim-2: not chunked
    g_st = jax.tree.map(lambda p: p * 0.03, stacked)
    g_fl = {"s": g_st["s"].reshape(32, 16)}
    p2_st, _, _ = opt.adamw_update(stacked, g_st, opt.init_adamw(stacked, cfg), cfg)
    p2_fl, _, _ = opt.adamw_update(flat, g_fl, opt.init_adamw(flat, cfg), cfg)
    np.testing.assert_allclose(
        np.asarray(p2_st["s"]).reshape(32, 16), np.asarray(p2_fl["s"]),
        atol=1e-6)


def test_clip_scale_equals_materialized_clip():
    key = jax.random.key(2)
    grads = {"a": jax.random.normal(key, (32,)) * 10.0}
    clipped, norm1 = opt.clip_by_global_norm(grads, 1.0)
    scale, norm2 = opt.clip_scale(grads, 1.0)
    np.testing.assert_allclose(float(norm1), float(norm2), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(clipped["a"]), np.asarray(grads["a"]) * float(scale),
        rtol=1e-5)
    # clipped norm is at most the max norm
    assert float(opt.global_norm(clipped)) <= 1.0 + 1e-5


def test_global_norm_stacked_matches_flat():
    key = jax.random.key(3)
    x = jax.random.normal(key, (4, 130, 130))  # stacked path (ndim 3)
    n1 = float(opt.global_norm({"x": x}))
    n2 = float(jnp.sqrt(jnp.sum(jnp.square(x))))
    np.testing.assert_allclose(n1, n2, rtol=1e-6)


def test_adafactor_reduces_loss_and_is_factored():
    cfg = opt.AdafactorConfig(lr_peak=0.05, lr_min=0.05, warmup_steps=0,
                              min_factored=8)
    key = jax.random.key(4)
    w = jax.random.normal(key, (16, 16)) * 0.5
    target = jnp.eye(16)
    params = {"w": w}
    state = opt.init_adafactor(params, cfg)
    assert "vr" in state["stats"]["w"] and "vc" in state["stats"]["w"]
    assert state["stats"]["w"]["vr"].shape == (16,)

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    l0 = float(loss(params))
    for _ in range(20):
        g = jax.grad(loss)(params)
        params, state, _ = opt.adafactor_update(params, g, state, cfg)
    assert float(loss(params)) < l0 * 0.7


def test_adafactor_momentum_state():
    cfg = opt.AdafactorConfig(momentum=0.9, min_factored=8)
    params = {"w": jnp.ones((16, 16))}
    state = opt.init_adafactor(params, cfg)
    assert "mu" in state and state["mu"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((16, 16)) * 0.1}
    p2, s2, _ = opt.adafactor_update(params, g, state, cfg)
    assert bool(jnp.any(s2["mu"]["w"] != 0))


def test_adafactor_state_pspecs_mirror_init():
    from jax.sharding import PartitionSpec as P

    cfg = opt.AdafactorConfig(min_factored=8)
    params = {"w": jnp.ones((16, 32)), "b": jnp.ones((32,))}
    state = opt.init_adafactor(params, cfg)
    specs = opt.adafactor_state_pspecs(params, cfg)
    # same tree structure for stats
    s1 = jax.tree.structure(state["stats"])
    s2 = jax.tree.structure(
        jax.tree.map(lambda x: 0, specs["stats"],
                     is_leaf=lambda x: isinstance(x, P)))
    assert s1 == s2


def test_lr_schedule_shape():
    cfg = opt.AdamWConfig(lr_peak=1e-3, lr_min=1e-4, warmup_steps=10,
                          decay_steps=100)
    lrs = [float(opt.lr_schedule(cfg, jnp.int32(s))) for s in range(0, 120, 5)]
    assert lrs[0] == 0.0
    assert max(lrs) <= 1e-3 + 1e-9
    assert abs(lrs[2] - 1e-3) < 1e-9  # peak at warmup end
    assert lrs[-1] >= 1e-4 - 1e-9  # floor
