"""Speculative decoding subsystem tests.

Covers the lossless rejection-sampling rule (distributional + greedy
reduction), the prompt-lookup proposer, engine-level greedy byte-parity
across verify strategies (parallel chunk for attention archs, stepwise
snapshot rollback for recurrent ones), the draft-model proposer's cache
alignment, EOS/budget truncation inside a verified chunk, lease metering of
drafted-but-rejected work, latency telemetry, and the scalar-vs-batched
sampling parity sweep (the top_k clamp bugfix).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import recompile, scheduler
from repro.core.invocation import InvocationService
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampling import (SamplingConfig, SamplingParams,
                                    accept_speculative, sample,
                                    sample_batched, spec_target_probs)
from repro.serving.service import serving_container
from repro.serving.speculative import (DraftModelProposer, NGramProposer,
                                       SpecConfig, has_recurrent_state)


@functools.lru_cache(maxsize=4)
def _model(arch="qwen2-0.5b-smoke"):
    cfg = configs.get_config(arch)
    params = transformer.init_model(jax.random.key(0), cfg)
    return cfg, params


def _stream(cfg, n=6, max_new=10, seed=0, temperature=0.0, eos=None,
            shared_prefix=0):
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size, (shared_prefix,), dtype=np.int32)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(4, 12))
        p = rng.integers(0, cfg.vocab_size, (plen,), dtype=np.int32)
        if shared_prefix and i % 2 == 0:
            p = np.concatenate([shared, p])
        reqs.append(Request(request_id=i, prompt=p,
                            max_new_tokens=int(rng.integers(2, max_new + 1)),
                            sampling=SamplingConfig(temperature=temperature),
                            eos_id=eos))
    return reqs


def _serve(cfg, params, reqs, spec=None, proposer=None, slots=2, max_len=64,
           **kw):
    eng = ServingEngine(cfg, params, slots=slots, max_len=max_len,
                        prompt_buckets=(8, 16, 32), spec=spec,
                        proposer=proposer, **kw)
    for r in reqs:
        eng.submit(r)
    res = eng.run_to_completion()
    assert eng.stats["unserved"] == 0
    return {k: res[k].tokens for k in sorted(res)}, eng


# ---------------------------------------------------------------------------
# The rejection-sampling rule
# ---------------------------------------------------------------------------
def test_accept_residual_identity():
    """The implemented rule is lossless by construction: for ANY proposal q,
    q(t)·min(1, p(t)/q(t)) + P(reject)·residual(t) == p(t), with p the SAME
    modified (temperature/top-k) distribution sample_batched draws from."""
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(3, 2, 16)), jnp.float32)
    params = SamplingParams(jnp.asarray([0.7, 1.3, 1.0]),
                            jnp.asarray([0, 5, 3], jnp.int32))
    p = np.asarray(spec_target_probs(logits, params))[:, 0]  # (3, V)
    q = rng.dirichlet(np.ones(16), size=3)
    accept = np.minimum(1.0, p / np.maximum(q, 1e-30))
    alpha = (q * accept).sum(-1, keepdims=True)
    residual = np.maximum(p - q, 0.0)
    residual /= residual.sum(-1, keepdims=True)
    emitted = q * accept + (1.0 - alpha) * residual
    np.testing.assert_allclose(emitted, p, atol=1e-6)


def test_accept_point_mass_distribution_monte_carlo():
    """Deterministic (point-mass) proposers: the first emitted token is
    still distributed exactly as the target. Monte Carlo over a large
    batch of identical rows with a fixed key — deterministic, not flaky."""
    v, n = 8, 8000
    rng = np.random.default_rng(1)
    row = rng.normal(size=(v,)).astype(np.float32)
    logits = jnp.broadcast_to(jnp.asarray(row), (n, 2, v))
    drafts = jnp.full((n, 1), 3, jnp.int32)
    ndraft = jnp.ones((n,), jnp.int32)
    params = SamplingParams(jnp.ones((n,)), jnp.zeros((n,), jnp.int32))
    out, acc = accept_speculative(jax.random.key(7), logits, drafts, ndraft,
                                  params)
    first = np.asarray(out[np.arange(n), 0])
    # rejected rows emit the resample at position 0; accepted rows emit the
    # draft there — either way out[:, 0] is the first emitted token
    p1 = SamplingParams(params.temperature[:1], params.top_k[:1])
    p = np.asarray(spec_target_probs(logits[:1], p1)[0, 0])
    emp = np.bincount(first, minlength=v) / n
    assert np.abs(emp - p).max() < 4.0 / np.sqrt(n)
    # acceptance of draft 3 should match p(3) (q is a point mass)
    assert abs(np.mean(np.asarray(acc) == 1) - p[3]) < 4.0 / np.sqrt(n)


def test_accept_greedy_reduces_to_prefix_match():
    v = 11
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.normal(size=(2, 4, v)), jnp.float32)
    arg = np.asarray(jnp.argmax(logits, -1))  # (2, 4)
    # row 0: drafts match argmax for 2 positions then diverge
    drafts = np.zeros((2, 3), np.int32)
    drafts[0] = [arg[0, 0], arg[0, 1], (arg[0, 2] + 1) % v]
    drafts[1] = [(arg[1, 0] + 1) % v, arg[1, 1], arg[1, 2]]
    params = SamplingParams(jnp.zeros((2,)), jnp.zeros((2,), jnp.int32))
    out, acc = accept_speculative(
        jax.random.key(0), logits, jnp.asarray(drafts),
        jnp.full((2,), 3, jnp.int32), params)
    out, acc = np.asarray(out), np.asarray(acc)
    assert acc.tolist() == [2, 0]
    # emitted = accepted drafts + argmax at the boundary, zeros after
    assert out[0].tolist() == [arg[0, 0], arg[0, 1], arg[0, 2], 0]
    assert out[1].tolist() == [arg[1, 0], 0, 0, 0]


def test_accept_ndraft_masks_tail():
    v = 5
    logits = jnp.zeros((1, 4, v), jnp.float32)
    drafts = jnp.zeros((1, 3), jnp.int32)  # argmax(0s) == 0 -> all "match"
    params = SamplingParams(jnp.zeros((1,)), jnp.zeros((1,), jnp.int32))
    for nd in range(4):
        _, acc = accept_speculative(jax.random.key(0), logits, drafts,
                                    jnp.asarray([nd], jnp.int32), params)
        assert int(acc[0]) == nd  # never accepts past the real drafts


# ---------------------------------------------------------------------------
# NGram proposer
# ---------------------------------------------------------------------------
def test_ngram_lookup_drafts_continuation():
    prop = NGramProposer(4, ngram_max=3, ngram_min=1)
    h = np.asarray([5, 6, 7, 8, 9, 5, 6, 7], np.int32)
    # suffix [5,6,7] occurred at 0; continuation is [8, 9, 5, 6]
    assert prop.lookup(h, 4).tolist() == [8, 9, 5, 6]
    # no repetition at all -> no draft
    assert prop.lookup(np.arange(8, dtype=np.int32), 4).size == 0
    # prefers an occurrence with a full k continuation over the most recent
    h2 = np.asarray([1, 2, 3, 4, 1, 2, 1, 2], np.int32)
    d = prop.lookup(h2, 3)
    assert d.tolist() == [3, 4, 1]


# ---------------------------------------------------------------------------
# Engine parity: speculative greedy streams are byte-identical
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["qwen2-0.5b-smoke",
                                  "recurrentgemma-9b-smoke"])
@pytest.mark.parametrize("k", [1, 3])
def test_spec_greedy_byte_identical(arch, k):
    cfg, params = _model(arch)
    reqs = _stream(cfg, n=6, max_new=10, shared_prefix=6)
    base, _ = _serve(cfg, params, reqs)
    out, eng = _serve(cfg, params, reqs, spec=SpecConfig(k=k))
    assert out == base
    assert eng.stats["spec_steps"] > 0
    # the verify strategy must match the arch's state structure
    assert has_recurrent_state(cfg) == (arch != "qwen2-0.5b-smoke")


def test_spec_eos_truncates_inside_chunk():
    cfg, params = _model()
    base, _ = _serve(cfg, params, _stream(cfg, n=4, max_new=12))
    # choose an eos that actually appears mid-stream in the baseline
    eos = base[1][len(base[1]) // 2]
    reqs = _stream(cfg, n=4, max_new=12, eos=eos)
    b2, _ = _serve(cfg, params, reqs)
    o2, _ = _serve(cfg, params, reqs, spec=SpecConfig(k=4))
    assert o2 == b2
    assert any(toks[-1] == eos and len(toks) < 12 for toks in b2.values())


def test_spec_with_prefix_cache_byte_identical():
    cfg, params = _model()
    reqs = _stream(cfg, n=8, max_new=8, shared_prefix=10)
    base, _ = _serve(cfg, params, reqs)
    out, eng = _serve(cfg, params, reqs, spec=SpecConfig(k=3),
                      prefix_cache_bytes=1 << 20)
    assert out == base
    assert eng.stats["prefix_hits"] > 0  # both subsystems actually engaged
    assert eng.stats["spec_accepted"] > 0


def test_spec_temperature_rows_serve_and_respect_budget():
    """Stochastic rows are lossless distributionally (proved above); here
    the engine contract: correct token counts, vocab-range tokens, retired
    slots recycled."""
    cfg, params = _model()
    reqs = _stream(cfg, n=6, max_new=8, temperature=0.8)
    out, eng = _serve(cfg, params, reqs, spec=SpecConfig(k=3))
    for r in reqs:
        toks = out[r.request_id]
        assert len(toks) == r.max_new_tokens
        assert all(0 <= t < cfg.vocab_size for t in toks)
    assert eng.stats["spec_steps"] > 0


# ---------------------------------------------------------------------------
# Draft-model proposer
# ---------------------------------------------------------------------------
def test_draft_model_self_draft_accepts_everything():
    """Draft == target (same params): greedy drafts must all be accepted —
    any rejection would mean the draft cache and the target cache disagree
    about the same computation (a rollback/alignment bug)."""
    cfg, params = _model()
    reqs = _stream(cfg, n=5, max_new=9)
    base, _ = _serve(cfg, params, reqs)
    prop = DraftModelProposer(cfg, params, 4)
    out, eng = _serve(cfg, params, reqs,
                      spec=SpecConfig(k=4, proposer="draft",
                                      draft_arch="qwen2-0.5b-smoke"),
                      proposer=prop)
    assert out == base
    sm = eng.spec_summary()
    assert sm["proposer"] == "draft"
    assert sm["acceptance_rate"] == 1.0, sm


def test_draft_model_rejects_recurrent_and_vocab_mismatch():
    cfg, params = _model()
    rcfg, rparams = _model("recurrentgemma-9b-smoke")
    with pytest.raises(NotImplementedError):
        DraftModelProposer(rcfg, rparams, 2)
    prop = DraftModelProposer(cfg, params, 2)

    class _FakeEngine:
        class cfg:
            vocab_size = cfg.vocab_size + 1  # vocab mismatch

    with pytest.raises(AssertionError):
        prop.bind(_FakeEngine())


# ---------------------------------------------------------------------------
# Engine guards + manifest surfacing
# ---------------------------------------------------------------------------
def test_spec_requires_fused_and_text_frontend():
    cfg, params = _model()
    with pytest.raises(ValueError):
        ServingEngine(cfg, params, slots=2, max_len=32, fused=False,
                      spec=SpecConfig(k=2))
    acfg = configs.get_config("musicgen-medium-smoke")
    aparams = transformer.init_model(jax.random.key(0), acfg)
    with pytest.raises(NotImplementedError):
        ServingEngine(acfg, aparams, slots=2, max_len=32,
                      spec=SpecConfig(k=2))


def test_spec_overrides_sync_every():
    cfg, params = _model()
    eng = ServingEngine(cfg, params, slots=2, max_len=32,
                        spec=SpecConfig(k=2), sync_every=4)
    assert eng.sync_every == 1


def test_manifest_gains_speculative_section():
    cfg, params = _model()
    eng = ServingEngine(cfg, params, slots=2, max_len=32,
                        spec=SpecConfig(k=2),
                        manifest={"container": "c", "apis": {}})
    assert eng.manifest["speculative"] == {"proposer": "ngram", "k": 2}


# ---------------------------------------------------------------------------
# Lease metering: drafted-but-rejected FLOPs land on the bill
# ---------------------------------------------------------------------------
def test_executor_bills_spec_verify_positions():
    cfg, params = _model()
    cont = serving_container(cfg, params, slots=2, max_len=64,
                             prompt_buckets=(8, 16, 32),
                             spec=SpecConfig(k=3))
    service = InvocationService(scheduler.Cluster(chips=1))
    with service.acquire_serving("tenant-a", cont,
                                 recompile.PORTABLE_CPU) as ex:
        for r in _stream(cfg, n=4, max_new=8):
            ex.submit(r)
        results = ex.run()
        stats = dict(ex.engine.stats)
    tokens = sum(len(r.tokens) for r in results.values())
    # every emitted token is on the tenant ledger...
    assert service.meter.served_tokens("tenant-a") == tokens
    # ...and the lease was billed per verified POSITION (k+1 per step),
    # which strictly exceeds emitted-token needs whenever a draft was
    # rejected — the tenant pays for the gamble, not just the win
    verify = service.meter.total_steps("serve_spec_verify", "tenant-a")
    assert verify == stats["spec_positions"] > 0
    assert verify >= stats["spec_emitted"]
    assert service.meter.total_steps("serve_decode", "tenant-a") == 0
    service.meter.check_invariants()


# ---------------------------------------------------------------------------
# Latency telemetry (TTFT / TPOT satellite)
# ---------------------------------------------------------------------------
def test_request_latency_telemetry():
    cfg, params = _model()
    _, eng = _serve(cfg, params, _stream(cfg, n=4, max_new=6))
    for res in eng.results.values():
        assert res.ttft_s > 0
        if len(res.tokens) > 1:
            assert res.decode_s > 0
            assert res.tpot_s == pytest.approx(
                res.decode_s / (len(res.tokens) - 1))
    lat = eng.latency_summary()
    assert lat["requests"] == 4
    assert lat["ttft_p95_s"] >= lat["ttft_p50_s"] > 0
    assert lat["tpot_p95_s"] >= lat["tpot_p50_s"] > 0
    assert eng.stats["ttft_sum_s"] > 0


def test_fleet_report_spec_and_latency():
    """Fleet surface: per-replica acceptance telemetry + aggregate, and the
    real-wall-clock TTFT/TPOT percentiles, all through one report."""
    from repro import fleet as fl

    cfg, params = _model()
    trace = fl.steady_trace(seed=3, duration_s=4.0, prompt_median=8,
                            prompt_lo=4, prompt_hi=12, max_new_lo=4,
                            max_new_hi=8)
    reqs = fl.materialize(trace, vocab_size=cfg.vocab_size, seed=4)
    fm = fl.FleetManager.build(
        cfg, params, chips=2,
        fleet=fl.FleetConfig(min_replicas=1, max_replicas=2, slots=2,
                             max_len=64, prompt_buckets=(8, 16, 32),
                             spec_k=2, prefix_cache_mb=1.0))
    report = fm.run_trace(reqs)
    assert report.served == report.requests
    assert report.reconciled
    sp = report.speculative
    assert sp["enabled"] and sp["drafted"] > 0
    assert 0 <= sp["acceptance_rate"] <= 1
    for rep in report.replicas:
        assert rep["spec"] is not None and rep["spec"]["k"] == 2
    assert report.ttft_p95_s >= report.ttft_p50_s > 0
    assert report.tpot_p95_s >= 0


# ---------------------------------------------------------------------------
# Satellite bugfix: scalar sample() top_k clamp parity with sample_batched
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("top_k", [0, 1, 7, 14])  # {0, 1, V, V+7}, V=7
def test_scalar_vs_batched_topk_parity(top_k):
    v = 7
    logits = jnp.asarray(
        np.random.default_rng(5).normal(size=(v,)), jnp.float32)
    cfg = SamplingConfig(temperature=0.8, top_k=top_k)
    params = SamplingParams.from_configs([cfg])
    for seed in range(8):
        key = jax.random.key(seed)
        a = int(sample(key, logits, cfg))
        b = int(sample_batched(key, logits[None], params)[0])
        assert a == b, (top_k, seed)


def test_scalar_topk_overflow_matches_full_distribution():
    """Pre-fix, top_k in (V, 2V) wrapped the negative sort index and
    silently masked the BOTTOM of the distribution; clamped, it must equal
    the full-distribution draw."""
    v = 7
    logits = jnp.asarray(
        np.random.default_rng(6).normal(size=(v,)), jnp.float32)
    for seed in range(8):
        key = jax.random.key(seed)
        full = int(sample(key, logits, SamplingConfig(temperature=1.0)))
        over = int(sample(key, logits,
                          SamplingConfig(temperature=1.0, top_k=v + 3)))
        assert full == over
