"""Training substrate: optimizer (AdamW + ZeRO-1), train-step factory with
microbatched grad accumulation, remat, and DCN gradient compression."""
from repro.training import optimizer, train_step  # noqa: F401
