"""Train-step factory: loss, microbatched grad accumulation, AdamW/ZeRO-1
update, and DCN-aware gradient compression.

The step is a single compiled XLA program (one XaaS invocation quantum):

    batch (B, S) -> [scan over M microbatches: fwd+bwd with remat]
                 -> grad mean -> (optional cross-pod compressed all-reduce)
                 -> clip -> AdamW -> new state

Gradient compression (DESIGN.md §7): on a multi-pod mesh the per-pod batch
gradient is all-reduced across the `pod` (DCN) axis explicitly inside a
``shard_map`` manual region, optionally compressed to int8 with error
feedback. ICI-side reductions stay uncompressed — at 400 GB/s aggregate ICI
the quantize/dequantize would cost more than it saves; DCN at ~25 GB/s is
the 1000-node bottleneck the paper's scale target exposes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models import transformer
from repro.training import optimizer as opt

__all__ = ["TrainConfig", "cross_entropy", "loss_fn", "make_train_step",
           "init_train_state", "compress_int8", "decompress_int8"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: opt.AdamWConfig = dataclasses.field(default_factory=opt.AdamWConfig)
    adafactor: opt.AdafactorConfig = dataclasses.field(
        default_factory=opt.AdafactorConfig)
    optimizer: str = "adamw"  # adamw | adafactor (recipe-selected, DESIGN §4)
    microbatches: int = 1
    # grad-accumulation dtype: f32 default; bf16 for archs whose f32
    # accumulator would not fit (671B: 2.6 GB/chip saved; clip stays f32)
    accum_dtype: str = "float32"
    remat: str | None = "full"  # None | "full" | "dots"
    # cross-pod gradient reduction: "mean" (XLA default) | "bf16" | "int8_ef"
    dcn_compression: str = "mean"
    pod_axis: str = "pod"


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def cross_entropy(logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None):
    """Token-mean CE. logits f32 (..., S, V); labels int (..., S).

    Works for (B,S,V) and audio (B,K,S,V) (labels (B,K,S)). Positions with
    label < 0 are ignored (in addition to `mask`).
    """
    logits = logits.astype(jnp.float32)
    valid = (labels >= 0)
    if mask is not None:
        valid &= mask.astype(bool)
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0] - lse
    n = jnp.maximum(jnp.sum(valid), 1)
    return -jnp.sum(ll * valid) / n


def loss_fn(params, cfg, batch, *, remat="full"):
    """-> (loss, metrics). Contract with data/: batch has `tokens` (inputs),
    `labels` (targets, same trailing shape, -100 = ignore), optional `mask`,
    optional `patch_embeds` (vlm)."""
    logits, aux = transformer.forward(
        params, cfg, batch["tokens"], patch_embeds=batch.get("patch_embeds"),
        remat=remat)
    labels = batch["labels"]
    # vlm: logits cover [image tokens | text]; labels cover text only.
    s_lab = labels.shape[-1]
    if logits.shape[-2] != s_lab:
        logits = logits[..., -s_lab:, :]
    ce = cross_entropy(logits, labels, batch.get("mask"))
    loss = ce + aux
    return loss, {"loss": loss, "ce": ce, "aux_loss": aux}


# ---------------------------------------------------------------------------
# int8 error-feedback compression (cross-pod / DCN only)
# ---------------------------------------------------------------------------
def compress_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization. -> (q int8, scale f32)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _dcn_reduce(grads, ef, mode: str, pod_axis: str):
    """Cross-pod gradient all-reduce inside a manual `pod` region.

    grads enter as the *per-pod mean*; returns the global mean (+ new error
    feedback state for int8_ef).
    """
    npod = jax.lax.axis_size(pod_axis)

    if mode == "bf16":
        out = jax.tree.map(
            lambda g: jax.lax.pmean(g.astype(jnp.bfloat16), pod_axis).astype(g.dtype),
            grads)
        return out, ef

    if mode == "int8_ef":
        def one(g, e):
            gf = g.astype(jnp.float32) + e  # add residual from last step
            q, scale = compress_int8(gf)
            # wire format: int8 payload + f32 scale; sum of dequantized
            g_hat = decompress_int8(q, scale)
            reduced = jax.lax.psum(g_hat, pod_axis) / npod
            new_e = gf - g_hat  # local quantization error, fed back next step
            return reduced.astype(g.dtype), new_e
        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(ef)
        pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return tdef.unflatten([p[0] for p in pairs]), tdef.unflatten([p[1] for p in pairs])

    return jax.tree.map(lambda g: jax.lax.pmean(g, pod_axis), grads), ef


# ---------------------------------------------------------------------------
# Train state
# ---------------------------------------------------------------------------
def init_train_state(key, cfg, tcfg: TrainConfig):
    params = transformer.init_model(key, cfg)
    if tcfg.optimizer == "adafactor":
        opt_state = opt.init_adafactor(params, tcfg.adafactor)
    else:
        opt_state = opt.init_adamw(params, tcfg.adamw)
    state = {"params": params, "opt": opt_state}
    if tcfg.dcn_compression == "int8_ef":
        state["ef"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def train_state_pspecs(state, mesh, tcfg: TrainConfig | None = None, *,
                       data_axes="data"):
    """PartitionSpecs for the full train state (params + sharded opt state)."""
    params = state["params"]
    if tcfg is not None and tcfg.optimizer == "adafactor":
        opt_specs = opt.adafactor_state_pspecs(params, tcfg.adafactor)
    else:
        opt_specs = opt.zero1_state_pspecs(params, mesh, data_axes=data_axes)
    out = {"params": shd.param_pspecs(params), "opt": opt_specs}
    if "ef" in state:
        out["ef"] = shd.param_pspecs(state["ef"])
    return out


# ---------------------------------------------------------------------------
# Step factory
# ---------------------------------------------------------------------------
def make_train_step(cfg, tcfg: TrainConfig, *, multi_pod: bool = False):
    """Returns train_step(state, batch) -> (state, metrics); pure, jit-able.

    Microbatching: batch dim B is split into `tcfg.microbatches` slices that
    run sequentially under lax.scan (grad accumulation in f32), bounding
    activation memory at B/M while keeping one compiled program.
    """
    m = tcfg.microbatches

    def grad_one(params, mb):
        # top-level grad-dtype barrier: f32-accumulating dots hand back f32
        # cotangents for embed/lm_head/prefix params; without this the
        # accumulator tree holds f32 copies of every unscanned param
        def lossp(p):
            p = jax.tree.map(transformer.layers.grad_dtype_barrier, p)
            return loss_fn(p, cfg, mb, remat=tcfg.remat)

        (loss, metrics), grads = jax.value_and_grad(
            lossp, has_aux=True)(params)
        return grads, metrics

    acc_dt = jnp.dtype(tcfg.accum_dtype)

    def accumulate(params, batch):
        if m == 1:
            return grad_one(params, batch)
        split = jax.tree.map(
            lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]), batch)

        def body(acc, mb):
            grads, metrics = grad_one(params, mb)
            acc_g, acc_m = acc
            if acc_dt == jnp.float32:
                add = lambda a, g: a + g.astype(jnp.float32) / m
            else:
                # accumulate natively in acc_dt: an f32 round-trip would
                # materialize f32 copies of every stacked grad tensor
                add = lambda a, g: a + (g / m).astype(acc_dt)
            acc_g = jax.tree.map(add, acc_g, grads)
            acc_m = jax.tree.map(lambda a, x: a + x / m, acc_m, metrics)
            return (acc_g, acc_m), None

        zeros_g = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
        zeros_m = {"loss": 0.0, "ce": 0.0, "aux_loss": 0.0}
        zeros_m = jax.tree.map(jnp.float32, zeros_m)
        (grads, metrics), _ = jax.lax.scan(body, (zeros_g, zeros_m), split)
        grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads, params)
        return grads, metrics

    def train_step(state, batch):
        params = state["params"]
        pod = tcfg.pod_axis

        if multi_pod and tcfg.dcn_compression in ("bf16", "int8_ef"):
            # Manual only over `pod` (data/model stay automatic inside): each
            # pod computes grads on its local batch half, then the cross-pod
            # all-reduce runs on the compressed wire format. This is the one
            # collective that crosses DCN — exactly where compression pays.
            mesh = shd.current_mesh()
            assert mesh is not None, "compressed DCN reduce needs a mesh"
            P = jax.sharding.PartitionSpec
            has_ef = "ef" in state
            assert has_ef or tcfg.dcn_compression != "int8_ef", (
                "int8_ef needs the error-feedback buffer from init_train_state")
            ef = state.get("ef") or jax.tree.map(
                lambda p: jnp.zeros((), jnp.float32), params)

            @functools.partial(
                jax.shard_map, mesh=mesh,
                in_specs=(P(), P(pod), P()),
                out_specs=(P(), P(), P()),
                axis_names={pod}, check_vma=False)
            def pod_grads(params, batch, ef):
                grads, metrics = accumulate(params, batch)
                grads, new_ef = _dcn_reduce(grads, ef, tcfg.dcn_compression, pod)
                metrics = jax.tree.map(lambda x: jax.lax.pmean(x, pod), metrics)
                return grads, metrics, new_ef

            grads, metrics, new_ef = pod_grads(params, batch, ef)
            if has_ef:
                state = dict(state, ef=new_ef)
        else:
            grads, metrics = accumulate(params, batch)

        if tcfg.optimizer == "adafactor":
            new_params, new_opt, opt_metrics = opt.adafactor_update(
                params, grads, state["opt"], tcfg.adafactor)
        else:
            new_params, new_opt, opt_metrics = opt.adamw_update(
                params, grads, state["opt"], tcfg.adamw)
        metrics = dict(metrics, **opt_metrics)
        new_state = dict(state, params=new_params, opt=new_opt)
        return new_state, metrics

    return train_step
