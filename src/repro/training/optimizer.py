"""Optimizers: AdamW with ZeRO-1 sharded states, built from scratch in JAX.

ZeRO-1 on the XaaS mesh: optimizer moments are sharded over the *data* axis
in addition to the parameter's own model-parallel sharding, cutting optimizer
memory by the DP degree. We implement it the pjit-native way — the moment
pytrees get PartitionSpecs that extend the param spec by sharding the largest
replicated dimension over "data"; XLA inserts the reduce-scatter/all-gather
pair around the update. This keeps the update mathematically identical to
replicated AdamW (tests assert bit-equality vs. the naive implementation on
one device).

No optax dependency — the container ships every substrate (assignment rule).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd

__all__ = ["AdamWConfig", "init_adamw", "adamw_update", "zero1_state_pspecs",
           "AdafactorConfig", "init_adafactor", "adafactor_update",
           "adafactor_state_pspecs", "global_norm", "clip_by_global_norm",
           "lr_schedule"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # moment dtype: f32 always (bf16 moments diverge at scale)
    moment_dtype: str = "float32"


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to lr_min (standard LM schedule)."""
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / jnp.maximum(cfg.warmup_steps, 1)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def _stacked(p) -> bool:
    """Scanned-layer parameter stacks (leading dim = layers). Their
    optimizer updates run under lax.map over the stack so update temps are
    one layer's worth — a full 58-layer expert-stack f32 intermediate is
    3.4 GB/chip and backend fusion cannot always be trusted to elide it.
    Tensor-level reductions (Adafactor rms/scale) become per-layer, which
    matches treating each layer as its own logical tensor."""
    return p.ndim >= 3 and p.shape[0] > 1


def _maybe_map_stack(fn, p, *args):
    if _stacked(p):
        return jax.lax.map(lambda t: fn(*t), (p, *args))
    return fn(p, *args)


def init_adamw(params: Any, cfg: AdamWConfig) -> dict:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
    }


def global_norm(tree: Any) -> jax.Array:
    def sumsq(x):
        if _stacked(x):
            # per-layer-slice reduction: a monolithic astype(f32) of a
            # 58-layer grad stack is a 3.4 GB/chip temp if the backend
            # fails to fuse the convert into the reduce
            return jnp.sum(jax.lax.map(
                lambda s: jnp.sum(jnp.square(s.astype(jnp.float32))), x))
        return jnp.sum(jnp.square(x.astype(jnp.float32)))

    leaves = [sumsq(x) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def clip_scale(grads: Any, max_norm: float) -> tuple[jax.Array, jax.Array]:
    """(scale, norm) for global-norm clipping WITHOUT materializing a
    clipped copy of the grads — callers fold `scale` into their update
    chain. A full bf16 grad copy is 5.1 GB/chip at 671B; this is free."""
    norm = global_norm(grads)
    return jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12)), norm


def adamw_update(params: Any, grads: Any, state: dict, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics).

    The clip scale is folded into the moment updates (no clipped-grad
    copy) and the whole per-tensor update is one elementwise chain, so XLA
    fuses it without f32 intermediates in HBM.
    """
    cscale, gnorm = clip_scale(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        gf = g.astype(jnp.float32) * cscale
        mu2 = b1 * mu + (1 - b1) * gf
        nu2 = b2 * nu + (1 - b2) * gf * gf
        mhat = mu2 / bc1
        nhat = nu2 / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), mu2.astype(mu.dtype), nu2.astype(nu.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [_maybe_map_stack(upd, p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, {"step": step, "mu": new_mu, "nu": new_nu}, metrics


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018) — factored second moment, optional bf16
# momentum. The launcher recipe selects it for archs whose full AdamW state
# cannot fit the pod (671B on 256 x 16 GB: params bf16 1.34 TB + f32 m+v
# 5.4 TB > 4 TB HBM — no sharding fixes arithmetic; PaLM-style factored
# stats do). DESIGN.md §Hardware-adaptation records this deviation.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr_peak: float = 1e-2
    lr_min: float = 1e-3
    warmup_steps: int = 100
    decay_steps: int = 10_000
    decay_exponent: float = 0.8  # beta2_t = 1 - step^-0.8
    eps1: float = 1e-30
    eps2: float = 1e-3
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    momentum: float = 0.0  # 0 -> no first moment stored
    momentum_dtype: str = "bfloat16"
    min_factored: int = 128  # factor only if both trailing dims >= this


def _factored(p, cfg: AdafactorConfig) -> bool:
    return p.ndim >= 2 and min(p.shape[-2:]) >= cfg.min_factored


def init_adafactor(params: Any, cfg: AdafactorConfig) -> dict:
    def stats(p):
        if _factored(p, cfg):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    state = {
        "step": jnp.zeros((), jnp.int32),
        "stats": jax.tree.map(stats, params,
                              is_leaf=lambda x: hasattr(x, "shape")),
    }
    if cfg.momentum:
        dt = jnp.dtype(cfg.momentum_dtype)
        state["mu"] = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return state


def adafactor_update(params: Any, grads: Any, state: dict, cfg: AdafactorConfig):
    """One Adafactor step. Returns (new_params, new_state, metrics).

    Memory discipline (671B fits a 16 GB chip because of this): the update
    never materializes a full-tensor f32 intermediate. `u` is expressed as
    the elementwise chain g * rsqrt(vhat) twice — once inside the rms
    reduction (fused into the reduce), once inside the final parameter
    chain (fused into the p_new write). Recompute is ~free; a 58-layer
    expert-stack f32 temp is 3.4 GB/chip.
    """
    cscale, gnorm = clip_scale(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = lr_schedule(  # reuse the warmup+cosine schedule shape
        AdamWConfig(lr_peak=cfg.lr_peak, lr_min=cfg.lr_min,
                    warmup_steps=cfg.warmup_steps, decay_steps=cfg.decay_steps),
        step)
    beta2 = 1.0 - step.astype(jnp.float32) ** -cfg.decay_exponent

    def upd(p, g, st, mu):
        def gf():  # recompute-friendly: never bound to a full f32 temp
            return g.astype(jnp.float32) * cscale

        if "vr" in st:
            g2_row = jnp.mean(jnp.square(gf()), axis=-1) + cfg.eps1
            g2_col = jnp.mean(jnp.square(gf()), axis=-2) + cfg.eps1
            vr = beta2 * st["vr"] + (1 - beta2) * g2_row
            vc = beta2 * st["vc"] + (1 - beta2) * g2_col
            denom = jnp.mean(vr, axis=-1, keepdims=True)
            def rsq():  # broadcast chain, fuses into consumers
                vhat = vr[..., None] * vc[..., None, :] / jnp.maximum(
                    denom[..., None], cfg.eps1)
                return jax.lax.rsqrt(vhat + cfg.eps1)
            new_st = {"vr": vr, "vc": vc}
        else:
            v = beta2 * st["v"] + (1 - beta2) * (jnp.square(gf()) + cfg.eps1)
            def rsq():
                return jax.lax.rsqrt(v + cfg.eps1)
            new_st = {"v": v}
        # rms(u) via a fused reduce (u never materializes)
        rms_u = jnp.sqrt(jnp.mean(jnp.square(gf() * rsq())) + 1e-30)
        uclip = 1.0 / jnp.maximum(1.0, rms_u / cfg.clip_threshold)
        if cfg.momentum:
            u = cfg.momentum * mu.astype(jnp.float32) + (
                1 - cfg.momentum) * (gf() * rsq() * uclip)
            new_mu = u.astype(mu.dtype)
            update = u
        else:
            new_mu = mu
            update = gf() * rsq() * uclip
        scale = jnp.maximum(
            cfg.eps2,
            jnp.sqrt(jnp.mean(jnp.square(p.astype(jnp.float32)))))
        delta = update * scale + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), new_st, new_mu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_st = tdef.flatten_up_to(state["stats"])
    flat_mu = tdef.flatten_up_to(state["mu"]) if cfg.momentum else [None] * len(flat_p)
    def upd_nomu(p, g, st):
        return upd(p, g, st, None)
    out = [
        _maybe_map_stack(upd, p, g, st, m) if m is not None else
        (jax.lax.map(lambda t: upd_nomu(*t), (p, g, st)) if _stacked(p)
         else upd(p, g, st, None))
        for p, g, st, m in zip(flat_p, flat_g, flat_st, flat_mu)
    ]
    new_state = {
        "step": step,
        "stats": tdef.unflatten([o[1] for o in out]),
    }
    if cfg.momentum:
        new_state["mu"] = tdef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return tdef.unflatten([o[0] for o in out]), new_state, metrics


def adafactor_state_pspecs(params: Any, cfg: AdafactorConfig) -> dict:
    """PartitionSpecs mirroring init_adafactor's tree: factored stats drop
    the reduced dim from the param's spec; full stats inherit it."""
    pspecs = shd.param_pspecs(params)

    def stats_spec(p, spec):
        entries = tuple(spec) + (None,) * (p.ndim - len(spec))
        if _factored(p, cfg):
            return {"vr": P(*entries[:-1]), "vc": P(*entries[:-2], entries[-1])}
        return {"v": P(*entries)}

    flat_p, tdef = jax.tree.flatten(params)
    flat_s = tdef.flatten_up_to(pspecs)
    out = {
        "step": P(),
        "stats": tdef.unflatten(
            [stats_spec(p, s) for p, s in zip(flat_p, flat_s)]),
    }
    if cfg.momentum:
        out["mu"] = pspecs
    return out


# ---------------------------------------------------------------------------
# ZeRO-1: moment sharding specs
# ---------------------------------------------------------------------------
def _extend_spec_over_data(spec: P, shape: tuple[int, ...], mesh, data_axes) -> P:
    """Shard the largest axis of `shape` that `spec` leaves replicated over
    the data axis (if divisible) — the moments-only ZeRO-1 partition.
    No-op when the param spec already consumes the data axis (FSDP)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used: set[str] = set()
    for e in entries:
        if e is None:
            continue
        used.update(e if isinstance(e, tuple) else (e,))
    names = data_axes if isinstance(data_axes, tuple) else (data_axes,)
    if used & set(names):
        return P(*entries)
    dp = 1
    for a in names:
        dp *= mesh.shape[a]
    # candidate dims: currently unsharded, divisible by dp; pick the largest
    best, best_dim = -1, -1
    for i, (dim, e) in enumerate(zip(shape, entries)):
        if e is None and dim % dp == 0 and dim > best_dim:
            best, best_dim = i, dim
    if best >= 0:
        entries[best] = data_axes
    return P(*entries)


def zero1_state_pspecs(params: Any, mesh, *, data_axes="data") -> dict:
    """PartitionSpec pytree for the AdamW state under ZeRO-1.

    Each moment inherits its parameter's spec, then additionally shards its
    largest replicated dim over the data axis. `step` is replicated.
    """
    pspecs = shd.param_pspecs(params)
    flat_specs, tdef = jax.tree.flatten(pspecs, is_leaf=lambda x: isinstance(x, P))
    flat_params = tdef.flatten_up_to(params)
    mom = tdef.unflatten([
        _extend_spec_over_data(s, p.shape, mesh, data_axes)
        for s, p in zip(flat_specs, flat_params)
    ])
    return {"step": P(), "mu": mom, "nu": mom}
