"""moonshot-v1-16b-a3b [moe] — kimi/moonlight-style, 64 experts top-6.

48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6
[hf:moonshotai/Moonlight-16B-A3B; hf]

Per the assignment spec all 48 layers are MoE (upstream Moonlight has a
dense layer 0 + 2 shared experts; the assignment's config takes precedence —
noted in DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ArchConfig, LayerSpec, MoEConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    pattern=(LayerSpec("global_attn", "moe"),),
    qkv_bias=False,
    pos="rope",
    rope_theta=50_000.0,
    norm="rmsnorm",
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408),
)
