"""Architecture config registry: the 10 assigned archs by --arch id."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    SHAPES,
    ArchConfig,
    LayerSpec,
    MLAConfig,
    MoEConfig,
    RGLRUConfig,
    ShapeSpec,
    XLSTMConfig,
    shape_applicable,
    smoke_variant,
)

_ARCH_MODULES: dict[str, str] = {
    "llava-next-34b": "llava_next_34b",
    "xlstm-1.3b": "xlstm_1_3b",
    "granite-34b": "granite_34b",
    "qwen2.5-14b": "qwen2_5_14b",
    "qwen2-0.5b": "qwen2_0_5b",
    "command-r-plus-104b": "command_r_plus_104b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "musicgen-medium": "musicgen_medium",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id.endswith("-smoke"):
        return smoke_variant(get_config(arch_id[: -len("-smoke")]))
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.CONFIG


def get_shape(shape_id: str) -> ShapeSpec:
    if shape_id not in SHAPES:
        raise KeyError(f"unknown shape {shape_id!r}; known: {sorted(SHAPES)}")
    return SHAPES[shape_id]
