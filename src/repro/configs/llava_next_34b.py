"""llava-next-34b [vlm] — Yi-34B LM backbone + anyres vision STUB.

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

The anyres vision tower is a stub: `input_specs()` supplies precomputed
patch features (B, 2928, 1024); this config owns the mlp2x_gelu projector
and the backbone. 2928 = 576 (base 24x24) + 4x576 (anyres tiles) + 48 (sep).
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    pattern=(LayerSpec("global_attn", "swiglu"),),
    qkv_bias=False,
    pos="rope",
    rope_theta=5_000_000.0,
    norm="rmsnorm",
    frontend="vlm",
    num_image_tokens=2928,
)
