"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks, xLSTM[7:1].

48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304  [arXiv:2405.04517]

d_ff=0: projection factors live inside the blocks (mLSTM pf=2, sLSTM ffn
pf=4/3*2). Every 8th block is sLSTM. Pure recurrent state => runs long_500k.
"""
from repro.configs.base import ArchConfig, LayerSpec, XLSTMConfig

_PERIOD = tuple([LayerSpec("mlstm", "none")] * 7 + [LayerSpec("slstm", "none")])

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    pattern=_PERIOD,
    pos="none",
    norm="rmsnorm",
    xlstm=XLSTMConfig(),
    subquadratic=True,
)
