"""deepseek-v3-671b [moe] — MLA + 1 shared + 256 routed top-8 experts.

61L d_model=7168 128H d_ff=2048(expert) vocab=129280  [arXiv:2412.19437; hf]

The assignment's "GQA kv=128" is MLA with 128 heads (no KV grouping) —
implemented as true MLA (q_lora 1536, kv_lora 512, nope 128 + rope 64,
v 128). First 3 layers are dense SwiGLU with d_ff=18432. Routing is
aux-loss-free (bias on router logits, nudged outside the gradient). The
MTP-1 head is available via training.mtp (optional, off in the dry-run).
"""
from repro.configs.base import ArchConfig, LayerSpec, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=2048,
    dense_d_ff=18432,
    vocab_size=129280,
    prefix=tuple([LayerSpec("mla", "swiglu")] * 3),
    pattern=(LayerSpec("mla", "moe"),),
    qkv_bias=False,
    pos="rope",
    rope_theta=10_000.0,
    norm="rmsnorm",
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        d_expert=2048,
        num_shared_experts=1,
        d_shared=2048,
        bias_routing=True,
    ),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
)
