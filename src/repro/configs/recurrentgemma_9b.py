"""recurrentgemma-9b [hybrid] — Griffin: RG-LRU + local attention, 1:2.

38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000  [arXiv:2402.19427]

Layout: 2 recurrent blocks then (recurrent, recurrent, local_attn) x 12 —
the Griffin 1:2 cycle (38 = 2 + 3*12; the repeating period starts two blocks
in, which preserves the published ratio). GeGLU FFN, gemma embed scaling,
local window 2048, MQA attention with 256-dim heads. RG-LRU state is O(1)
=> runs long_500k.
"""
from repro.configs.base import ArchConfig, LayerSpec, RGLRUConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    prefix=(LayerSpec("rglru", "geglu"), LayerSpec("rglru", "geglu")),
    pattern=(
        LayerSpec("rglru", "geglu"),
        LayerSpec("rglru", "geglu"),
        LayerSpec("local_attn", "geglu"),
    ),
    qkv_bias=False,
    pos="rope",
    rope_theta=10_000.0,
    local_window=2048,
    norm="rmsnorm",
    embed_scale=True,
    tie_embeddings=True,
    rglru=RGLRUConfig(lru_width=4096),
    subquadratic=True,
)
