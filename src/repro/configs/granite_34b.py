"""granite-34b [dense] — Granite Code 34B, GPT-BigCode lineage, MQA.

88L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152  [arXiv:2405.04324; hf]

Deepest assigned arch (88 layers) — scan-over-layers keeps HLO size flat.
FFN is the non-gated GELU MLP of the GPT-BigCode family: that is what makes
this config 34B (a gated SwiGLU at d_ff=24576 would be 47B).
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    pattern=(LayerSpec("global_attn", "gelu_mlp"),),
    qkv_bias=False,
    pos="rope",
    rope_theta=10_000_000.0,
    norm="rmsnorm",
)
