"""musicgen-medium [audio] — decoder-only over EnCodec tokens.

48L d_model=1536 24H (GQA kv=24) d_ff=6144 vocab=2048  [arXiv:2306.05284; hf]

K=4 EnCodec codebooks: summed input embeddings, 4 parallel LM heads
(vocab 2048 each). EnCodec itself is a STUB; the delay-pattern interleave is
applied in the data pipeline. Sinusoidal positions, LayerNorm, GELU MLP
(audiocraft decoder conventions).
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    pattern=(LayerSpec("global_attn", "gelu_mlp"),),
    qkv_bias=False,
    pos="sinusoidal",
    norm="layernorm",
    frontend="audio",
    num_codebooks=4,
)
