"""qwen2.5-14b [dense] — GQA with QKV bias.

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064  [hf:Qwen/Qwen2.5]
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="qwen2.5-14b",
    family="dense",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=13824,
    vocab_size=152064,
    pattern=(LayerSpec("global_attn", "swiglu"),),
    qkv_bias=True,
    pos="rope",
    rope_theta=1_000_000.0,
    norm="rmsnorm",
)
