"""Architecture config system.

An ArchConfig fully determines the model: layer pattern (mixers + FFNs),
dimensions, positional scheme, and family-specific sub-configs (MoE, MLA,
RG-LRU, xLSTM, frontend stubs). Layer layout = `prefix` (unrolled,
heterogeneous head) followed by `pattern` repeated `scan_repeats` times
(stacked + lax.scan for flat HLO at any depth):

    num_layers == len(prefix) + len(pattern) * scan_repeats
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

MIXERS = ("global_attn", "local_attn", "mla", "rglru", "mlstm", "slstm")
FFNS = ("swiglu", "geglu", "gelu_mlp", "moe", "none")


@dataclass(frozen=True)
class LayerSpec:
    mixer: str
    ffn: str

    def __post_init__(self):
        assert self.mixer in MIXERS, self.mixer
        assert self.ffn in FFNS, self.ffn


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int
    num_shared_experts: int = 0
    d_shared: int = 0  # per shared expert ff dim
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.001
    # DeepSeek-V3 auxiliary-loss-free load balancing (bias on router logits)
    bias_routing: bool = False


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int  # recurrent state width
    conv_width: int = 4
    c: float = 8.0  # Griffin's fixed recurrence exponent scale


@dataclass(frozen=True)
class XLSTMConfig:
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    conv_width: int = 4
    chunk_size: int = 256
    # official xLSTM qkv_proj_blocksize: q/k/v are block-diagonal (near-banded)
    # projections — this is what makes the 1.3B config actually 1.3B.
    qkv_block_size: int = 4


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    dense_d_ff: int = 0  # ff dim of *dense* layers in MoE archs (0 -> d_ff)
    # layer layout
    prefix: tuple[LayerSpec, ...] = ()
    pattern: tuple[LayerSpec, ...] = (LayerSpec("global_attn", "swiglu"),)
    # attention details
    qkv_bias: bool = False
    tie_embeddings: bool = False
    parallel_residual: bool = False  # cohere-style attn || ffn
    pos: str = "rope"  # rope | sinusoidal | none
    rope_theta: float = 10000.0
    local_window: int = 2048
    logit_softcap: float | None = None
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    embed_scale: bool = False  # gemma-style sqrt(d) input scaling
    # family sub-configs
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    rglru: RGLRUConfig | None = None
    xlstm: XLSTMConfig | None = None
    # modality frontend stub: None | "vlm" | "audio"
    frontend: str | None = None
    num_image_tokens: int = 2928  # llava-next anyres: base 576 + 4 tiles + sep
    num_codebooks: int = 1  # musicgen EnCodec codebooks
    # sub-quadratic? (drives long_500k applicability)
    subquadratic: bool = False
    # numerics
    param_dtype: str = "bfloat16"
    activ_dtype: str = "bfloat16"

    def __post_init__(self):
        scanned = self.num_layers - len(self.prefix)
        assert scanned >= 0 and len(self.pattern) > 0
        assert scanned % len(self.pattern) == 0, (
            f"{self.name}: {scanned} scanned layers not divisible by "
            f"pattern period {len(self.pattern)}"
        )
        assert self.num_heads % self.num_kv_heads == 0

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def scan_repeats(self) -> int:
        return (self.num_layers - len(self.prefix)) // len(self.pattern)

    def layer_specs(self) -> list[LayerSpec]:
        return list(self.prefix) + list(self.pattern) * self.scan_repeats

    # ---- parameter counting (for MODEL_FLOPS and accounting) ----
    def param_counts(self) -> dict[str, int]:
        """Analytic parameter counts: total and active-per-token."""
        from repro.models import transformer  # local import to avoid cycle

        return transformer.param_counts(self)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_serving(self) -> bool:
        return self.kind in ("prefill", "decode")


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Is this (arch, shape) cell runnable? Returns (ok, reason_if_not)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, (
            "pure full-attention arch: 524k dense KV cache is out of scope; "
            "long_500k runs only for SSM/hybrid archs (see DESIGN.md §3)"
        )
    return True, ""


def smoke_variant(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests: keeps every structural
    feature (pattern, MoE/MLA/RG-LRU/xLSTM, frontends) at toy width/depth."""
    period = len(cfg.pattern)
    n_prefix = len(cfg.prefix)
    layers = n_prefix + period * min(2, cfg.scan_repeats)
    hd = 16
    kv = min(cfg.num_kv_heads, 2)
    heads = kv * min(4, cfg.num_heads // cfg.num_kv_heads)
    d = 64
    changes: dict = dict(
        name=cfg.name + "-smoke",
        num_layers=layers,
        d_model=d,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=hd,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        local_window=16,
        num_image_tokens=8,
        param_dtype="float32",
        activ_dtype="float32",
    )
    if cfg.dense_d_ff:
        changes["dense_d_ff"] = 128
    if cfg.moe:
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8, top_k=min(cfg.moe.top_k, 2), d_expert=32,
            d_shared=32 if cfg.moe.num_shared_experts else 0)
    if cfg.mla:
        changes["mla"] = MLAConfig(
            q_lora_rank=32, kv_lora_rank=32, qk_nope_head_dim=hd,
            qk_rope_head_dim=8, v_head_dim=hd)
    if cfg.rglru:
        changes["rglru"] = dataclasses.replace(cfg.rglru, lru_width=d)
    if cfg.xlstm:
        changes["xlstm"] = dataclasses.replace(cfg.xlstm, chunk_size=8)
    return dataclasses.replace(cfg, **changes)
