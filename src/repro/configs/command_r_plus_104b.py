"""command-r-plus-104b [dense] — cohere-style parallel attn||FFN, no biases.

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    pattern=(LayerSpec("global_attn", "swiglu"),),
    qkv_bias=False,
    parallel_residual=True,
    pos="rope",
    rope_theta=75_000_000.0,
    norm="layernorm",
)
