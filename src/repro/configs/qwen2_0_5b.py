"""qwen2-0.5b [dense] — GQA, QKV bias, tied embeddings.

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936  [arXiv:2407.10671; hf]
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    pattern=(LayerSpec("global_attn", "swiglu"),),
    qkv_bias=True,
    tie_embeddings=True,
    pos="rope",
    rope_theta=1_000_000.0,
    norm="rmsnorm",
)
