"""Deployment recompilation — the XaaS 'ship IR, specialize at the target'.

The paper's Infrastructure principle rejects binary-only portability ("compile
and test on my laptop, deploy on the largest supercomputer") in favor of
shipping a compiler intermediate representation that is *optimized at the
target architecture* (it names LLVM IR and DaCe SDFGs). JAX implements exactly
this split natively:

    trace (portable)  ->  StableHLO IR  ->  XLA compile (target-specialized)
        .lower()            portable           .compile()

This module packages that split as the XaaS deployment pipeline:

  * ``SystemProfile`` — the provider-published description of one target
    system (chip kind, peak FLOP/s, HBM bytes/bandwidth, ICI links, mesh,
    which accelerated-API providers its "system libraries" support). The
    paper's per-system tuned library set is the ``providers`` field.
  * ``DeploymentCompiler`` — lowers a traced program once (the shipped IR)
    and compiles it per target profile, caching both stages. Cold deploy =
    trace + lower + compile; warm deploy = cache hit (the paper's
    "deployable in seconds rather than minutes" claim is exercised by
    ``benchmarks/recompile_cache.py``).
  * ``CompiledArtifact`` — the deployed unit: compiled executable +
    cost/memory analysis (the single source of truth that both accounting
    and the roofline read from).
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import logging
import time
from typing import Any, Callable, Mapping

import jax

from repro.kernels import compat

logger = logging.getLogger(__name__)

__all__ = [
    "SystemProfile",
    "CompiledArtifact",
    "DeploymentCompiler",
    "TPU_V5E",
    "TPU_V5E_POD",
    "TPU_V5E_2POD",
    "PORTABLE_CPU",
    "CPU_INTERPRET",
    "collective_bytes",
]


# ---------------------------------------------------------------------------
# System profiles (the provider's published hardware + library description)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SystemProfile:
    """One target system, as advertised by its provider."""

    name: str
    chip: str  # "tpu-v5e" | "cpu" | ...
    chips: int
    peak_flops: float  # per-chip, bf16 FLOP/s
    hbm_bytes: float  # per-chip HBM capacity
    hbm_bw: float  # per-chip HBM bandwidth, bytes/s
    ici_bw: float  # per-link ICI bandwidth, bytes/s
    ici_links: int  # links per chip participating in a collective
    dcn_bw: float = 25e9  # per-host cross-pod (DCN) bandwidth, bytes/s
    mesh_shape: tuple[int, ...] = ()
    mesh_axes: tuple[str, ...] = ()
    # accelerated-API providers this system's "library set" supports
    # (consumed by hooks.bind via each impl's `supports` predicate)
    providers: tuple[str, ...] = ()
    # VMEM per chip — bounds Pallas BlockSpec working sets
    vmem_bytes: float = 128 * 2**20

    def supports(self, provider: str) -> bool:
        return provider in self.providers

    @property
    def total_peak_flops(self) -> float:
        return self.peak_flops * self.chips

    def fingerprint(self) -> str:
        return hashlib.sha1(repr(self).encode()).hexdigest()[:12]


# Assignment-fixed hardware constants: TPU v5e.
_V5E = dict(
    chip="tpu-v5e",
    peak_flops=197e12,
    hbm_bytes=16 * 2**30,
    hbm_bw=819e9,
    ici_bw=50e9,
    ici_links=4,
)

TPU_V5E = SystemProfile(
    name="tpu-v5e-1",
    chips=1,
    mesh_shape=(1,),
    mesh_axes=("data",),
    providers=("pallas-tpu",),
    **_V5E,
)

TPU_V5E_POD = SystemProfile(
    name="tpu-v5e-pod-256",
    chips=256,
    mesh_shape=(16, 16),
    mesh_axes=("data", "model"),
    providers=("pallas-tpu",),
    **_V5E,
)

TPU_V5E_2POD = SystemProfile(
    name="tpu-v5e-2pod-512",
    chips=512,
    mesh_shape=(2, 16, 16),
    mesh_axes=("pod", "data", "model"),
    providers=("pallas-tpu",),
    **_V5E,
)

# The portability floor: any XLA-capable host, no system libraries.
PORTABLE_CPU = SystemProfile(
    name="portable-cpu",
    chip="cpu",
    chips=1,
    peak_flops=1e11,
    hbm_bytes=8 * 2**30,
    hbm_bw=50e9,
    ici_bw=1e9,
    ici_links=1,
    mesh_shape=(1,),
    mesh_axes=("data",),
    providers=(),
)

# A CPU host whose "library set" includes the Pallas interpreter and the
# blocked pure-XLA tier: what CPU CI deploys, so the hand-tiled kernels are
# exercised (through the pallas-interpret tier) rather than skipped.
CPU_INTERPRET = SystemProfile(
    name="cpu-pallas-interpret",
    chip="cpu",
    chips=1,
    peak_flops=1e11,
    hbm_bytes=8 * 2**30,
    hbm_bw=50e9,
    ici_bw=1e9,
    ici_links=1,
    mesh_shape=(1,),
    mesh_axes=("data",),
    providers=("pallas-interpret", "xla-blocked"),
)


@functools.lru_cache(maxsize=None)
def host_mesh_profile(
    mesh_shape: tuple[int, ...],
    mesh_axes: tuple[str, ...] | None = None,
    *,
    hbm_bytes: int = 8 * 2**30,
) -> SystemProfile:
    """A multi-chip host-platform (CPU) profile: N forced host devices
    standing in for an N-chip accelerator slice, so sharded serving replicas
    can be leased, deployed, metered, and parity-checked without TPUs
    (``XLA_FLAGS=--xla_force_host_platform_device_count=N`` must be set
    before jax initializes for ``build_mesh`` to find the devices).

    Leases acquired against this profile request ``chips = prod(mesh_shape)``
    — the replica-width unit the fleet's width-vs-count policy trades in.
    Per-chip roofline terms (peak_flops, hbm_bytes, hbm_bw) match
    PORTABLE_CPU so modeled step times stay comparable across widths. The
    lru_cache keeps the instance (and thus ``fingerprint()`` identity used
    by warm-deployment caches) stable for a given geometry."""
    if mesh_axes is None:
        mesh_axes = ("data", "model")[-len(mesh_shape):] if len(
            mesh_shape) <= 2 else ("pod", "data", "model")[-len(mesh_shape):]
    if len(mesh_axes) != len(mesh_shape):
        raise ValueError(
            f"mesh_axes {mesh_axes} does not match mesh_shape {mesh_shape}")
    chips = 1
    for d in mesh_shape:
        chips *= int(d)
    geom = "x".join(str(int(d)) for d in mesh_shape)
    return SystemProfile(
        name=f"cpu-mesh-{geom}",
        chip="cpu",
        chips=chips,
        peak_flops=1e11,
        hbm_bytes=hbm_bytes,
        hbm_bw=50e9,
        ici_bw=1e9,
        ici_links=1,
        mesh_shape=tuple(int(d) for d in mesh_shape),
        mesh_axes=tuple(mesh_axes),
        providers=(),
    )


# ---------------------------------------------------------------------------
# HLO collective parsing (roofline collective term; not in cost_analysis)
# ---------------------------------------------------------------------------
_COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "e4m3": 1, "e5m2": 1,
}


def _shape_bytes(shape_str: str) -> int:
    """'bf16[256,4096]' -> byte count. Tuples handled by caller."""
    shape_str = shape_str.strip()
    if "[" not in shape_str:
        return 0
    dt, dims = shape_str.split("[", 1)
    dims = dims.split("]", 1)[0]
    n = 1
    if dims:
        for d in dims.split(","):
            d = d.strip().lstrip("<=")  # dynamic dims "<=128"
            if d:
                n *= int(d)
    return n * _DTYPE_BYTES.get(dt.strip(), 4)


def _result_shapes(line: str) -> list[str]:
    """Shapes on the LHS of an HLO instruction (tuple results flattened)."""
    lhs = line.split("=", 1)[0]
    # e.g. "  %all-reduce.1 = (bf16[128,8]{1,0}, bf16[64]{0}) all-reduce(..."
    # or "  %ag = bf16[512,1024]{1,0} all-gather(..."
    rhs = line.split("=", 1)[1] if "=" in line else ""
    out, depth, cur = [], 0, ""
    # take the type prefix of the RHS up to the op name
    for tok in rhs.strip().split(" "):
        if any(tok.startswith(op) for op in _COLLECTIVE_OPS):
            break
        cur += tok
    cur = cur.strip()
    if cur.startswith("("):
        cur = cur[1:].rsplit(")", 1)[0]
        for part in cur.split("),"):
            part = part.split("{")[0]
            if "[" in part:
                out.append(part)
        # simpler: split on "]," boundaries
        out = []
        buf = ""
        for ch in cur:
            buf += ch
            if ch == "]":
                out.append(buf.strip().lstrip(","))
                buf = ""
    elif "[" in cur:
        out.append(cur.split("{")[0])
    return out


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-operand bytes of every collective op in an HLO module.

    Returns {op_kind: bytes} + {"total": sum}. Uses the *result* shapes
    (for all-gather that is the gathered size, for reduce-scatter the
    scattered size — a consistent, conservative proxy for wire bytes).
    """
    out = {k: 0 for k in _COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if not ls or "=" not in ls:
            continue
        body = ls.split("=", 1)[1]
        kind = None
        for op in _COLLECTIVE_OPS:
            # match op name at an instruction position: " all-reduce(" etc.
            if f" {op}(" in body or body.strip().startswith(f"{op}("):
                kind = op
                break
        # exclude -start/-done split pairs double count: count only -start
        # (async) or plain ops; '-done' carries the same shape.
        if kind is None:
            for op in _COLLECTIVE_OPS:
                if f" {op}-start(" in body:
                    kind = op
                    break
        if kind is None or f" {kind}-done(" in body:
            continue
        for shp in _result_shapes(ls):
            out[kind] += _shape_bytes(shp)
    out["total"] = sum(out[k] for k in _COLLECTIVE_OPS)
    return out


# ---------------------------------------------------------------------------
# Deployment pipeline
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CompiledArtifact:
    """A deployed XaaS program: one compiled executable + its analyses."""

    key: str
    profile: SystemProfile
    lowered: Any  # jax.stages.Lowered (None when restored from a store)
    compiled: Any  # jax.stages.Compiled
    lower_s: float
    compile_s: float
    cache_hit: bool
    # how this executable came to exist in this process: "cold"
    # (traced+compiled here), "warm" (in-process exe-cache hit), or "ir"
    # (deserialized from a persistent ArtifactStore)
    boot: str = "cold"

    _cost: dict | None = None
    _memory: Any = None
    _collectives: dict[str, int] | None = None

    def cost_analysis(self) -> dict:
        if self._cost is None:
            self._cost = compat.xla_cost_analysis(self.compiled)
        return self._cost

    def memory_analysis(self):
        if self._memory is None:
            self._memory = self.compiled.memory_analysis()
        return self._memory

    def collectives(self) -> dict[str, int]:
        if self._collectives is None:
            self._collectives = collective_bytes(self.compiled.as_text())
        return self._collectives

    @property
    def flops(self) -> float:
        return float(self.cost_analysis().get("flops", 0.0))

    @property
    def hbm_bytes(self) -> float:
        c = self.cost_analysis()
        return float(c.get("bytes accessed", 0.0))

    def __call__(self, *args, **kwargs):
        return self.compiled(*args, **kwargs)


class DeploymentCompiler:
    """Two-stage cache: traced IR per program, executable per (IR, target).

    ``deploy(fn, name, profile, in_shardings=..., args=...)``:
      stage 1 (portable): jit(fn).lower(*args) — cached on (name, arg
          shapes/dtypes). This is the 'container image' the paper ships.
      stage 2 (target): lowered.compile() — cached additionally on the
          profile fingerprint + sharding. This is deployment recompilation.
    """

    def __init__(self):
        self._ir_cache: dict[str, tuple[Any, float]] = {}
        self._exe_cache: dict[str, CompiledArtifact] = {}
        self.stats = {"ir_hits": 0, "ir_misses": 0, "exe_hits": 0, "exe_misses": 0}

    @staticmethod
    def _arg_key(args, kwargs) -> str:
        leaves = jax.tree.leaves((args, kwargs))
        parts = []
        for leaf in leaves:
            shape = getattr(leaf, "shape", None)
            dtype = getattr(leaf, "dtype", None)
            sh = getattr(leaf, "sharding", None)
            parts.append(f"{shape}:{dtype}:{sh}")
        return hashlib.sha1("|".join(parts).encode()).hexdigest()[:12]

    def lower(self, fn: Callable, name: str, args=(), kwargs=None,
              jit_kwargs: Mapping[str, Any] | None = None):
        """Stage 1: trace to portable IR (cached)."""
        kwargs = kwargs or {}
        key = f"{name}:{self._arg_key(args, kwargs)}:{id(fn)}"
        if key in self._ir_cache:
            self.stats["ir_hits"] += 1
            return key, *self._ir_cache[key]
        t0 = time.perf_counter()
        lowered = jax.jit(fn, **(jit_kwargs or {})).lower(*args, **kwargs)
        dt = time.perf_counter() - t0
        self._ir_cache[key] = (lowered, dt)
        self.stats["ir_misses"] += 1
        return key, lowered, dt

    def _store_key(self, name: str, args, kwargs, profile: SystemProfile,
                   extra: Mapping[str, Any] | None = None) -> str:
        """Process-stable artifact key for one deployed entrypoint. Unlike
        the in-process IR key it must NOT include id(fn); aot.bundle_key
        folds in jax/jaxlib version + platform so environment drift misses
        cleanly. ``extra`` carries caller identity fields — the container
        deploy path passes the probed kernel-tier fingerprint, so a tier
        change invalidates stored entrypoints exactly like engine bundles."""
        from repro.core import aot
        fields = {
            "family": f"entrypoint:{name}",
            "args": self._arg_key(args, kwargs),
            "profile": profile.fingerprint(),
        }
        if extra:
            fields.update(extra)
        return aot.bundle_key(fields)

    def _ir_restore(self, skey: str, name: str, profile: SystemProfile,
                    store) -> CompiledArtifact | None:
        """IR-boot rung for a deployed entrypoint: deserialize a stored
        executable instead of lower+compile. The stored meta carries the
        cost/collective analyses so the metering and dry-run paths keep
        working without the Lowered stage."""
        from repro.core import aot
        got = store.get(skey)
        if got is None:
            return None
        blobs, meta = got
        try:
            compiled = aot.deserialize_compiled(blobs["exe"])
        except Exception:
            return None
        return CompiledArtifact(
            key=f"{skey}@{profile.fingerprint()}",
            profile=profile,
            lowered=None,
            compiled=compiled,
            lower_s=0.0,
            compile_s=0.0,
            cache_hit=False,
            boot="ir",
            _cost=meta.get("cost"),
            _collectives=meta.get("collectives"),
        )

    def _persist(self, skey: str, name: str, art: CompiledArtifact,
                 store) -> None:
        from repro.core import aot
        try:
            blob = aot.serialize_compiled(art.compiled)
            meta = {
                "name": name,
                "cost": {k: float(v) for k, v in art.cost_analysis().items()
                         if isinstance(v, (int, float))},
                "collectives": art.collectives(),
            }
            store.put(skey, {"exe": blob}, meta=meta)
        except Exception as err:  # non-serializable exe: stay cold-bootable
            self.stats["persist_failures"] = (
                self.stats.get("persist_failures", 0) + 1)
            logger.debug("artifact persist skipped for %s: %s", name, err)

    def deploy(
        self,
        fn: Callable,
        name: str,
        profile: SystemProfile,
        *,
        args=(),
        kwargs=None,
        jit_kwargs: Mapping[str, Any] | None = None,
        store=None,
        store_extra: Mapping[str, Any] | None = None,
    ) -> CompiledArtifact:
        """Full deployment: lower (or reuse IR) + compile for `profile`.
        With ``store`` (an ArtifactStore), the boot ladder applies: a
        matching persisted executable deserializes instead of compiling
        (boot="ir"), and a cold compile persists for the next process."""
        skey = None
        if store is not None:
            skey = self._store_key(name, args, kwargs, profile, store_extra)
            cached = self._exe_cache.get(skey)
            if cached is not None:
                self.stats["exe_hits"] += 1
                return dataclasses.replace(cached, cache_hit=True,
                                           boot="warm")
            art = self._ir_restore(skey, name, profile, store)
            if art is not None:
                self._exe_cache[skey] = art
                self.stats["ir_boots"] = self.stats.get("ir_boots", 0) + 1
                return art
        ir_key, lowered, lower_s = self.lower(fn, name, args, kwargs, jit_kwargs)
        exe_key = f"{ir_key}@{profile.fingerprint()}"
        if exe_key in self._exe_cache:
            self.stats["exe_hits"] += 1
            art = self._exe_cache[exe_key]
            return dataclasses.replace(art, cache_hit=True, boot="warm")
        t0 = time.perf_counter()
        compiled = lowered.compile()
        compile_s = time.perf_counter() - t0
        art = CompiledArtifact(
            key=exe_key,
            profile=profile,
            lowered=lowered,
            compiled=compiled,
            lower_s=lower_s,
            compile_s=compile_s,
            cache_hit=False,
        )
        self._exe_cache[exe_key] = art
        self.stats["exe_misses"] += 1
        if skey is not None:
            self._exe_cache[skey] = art
            self._persist(skey, name, art, store)
        return art


# Module-level default compiler (one per process, like a local registry).
DEFAULT_COMPILER = DeploymentCompiler()
