"""XaaS core — the paper's contribution as a composable JAX layer.

Three Is (DESIGN.md §1):
  Infrastructure — hooks.py (flexible hooked libraries), container.py
      (performance-portable containers), recompile.py (deployment
      recompilation: ship IR, specialize at the target).
  Input/Output   — realized in distributed/ (ICI collectives) and
      checkpoint/ (sharded async I/O); core consumes their artifacts.
  Invocation     — scheduler.py (EASY backfill, interactive/batch/service
      coexistence), invocation.py (rFaaS-style leases), accounting.py
      (FaaS-grade fine-grained metering from compiled artifacts).
"""
from repro.core import hooks  # noqa: F401

__all__ = ["hooks"]
