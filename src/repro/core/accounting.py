"""Fine-grained accounting — the XaaS Invocation principle's billing half.

The paper: FaaS bills "on a millisecond scale for each function"; XaaS lifts
that model to long-running parallel jobs (Table 1's fine-grained-accounting
column extended to HPC workloads). The unit economics here:

  * every invocation is metered in **device-seconds** and **FLOP-seconds**
    derived from the *compiled artifact's* cost analysis — the same source of
    truth the roofline reads, so billed-FLOPs and analyzed-FLOPs can never
    diverge (an auditability property the paper's vision needs and that
    ``tests/test_accounting.py`` checks as an invariant).
  * charging granularity is one *step* (one compiled-program execution), the
    natural quantum of an XLA deployment — milliseconds at decode, seconds at
    train, exactly the paper's "fine-grained billing ... while supporting
    long-running workloads".

A ``Meter`` is the per-tenant ledger; a ``Bill`` is an immutable line item.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from collections import defaultdict
from typing import Iterable

__all__ = ["Bill", "Meter", "PriceSheet"]


@dataclasses.dataclass(frozen=True)
class PriceSheet:
    """Provider pricing: $/chip-hour plus a FLOP-efficiency rebate.

    ``rebate`` rewards high-utilization programs (the paper's incentive
    alignment: providers currently have "only indirect incentives to improve
    the performance of customer workloads" — a utilization-linked price is
    the direct incentive XaaS enables, because the platform *knows* the
    program's roofline fraction from its compiled artifact).
    """

    chip_hour_usd: float = 1.20  # v5e on-demand list-price ballpark
    rebate_at_peak: float = 0.30  # fraction discounted at 100% MFU

    def charge(self, device_s: float, mfu: float) -> float:
        mfu = min(max(mfu, 0.0), 1.0)
        return device_s / 3600.0 * self.chip_hour_usd * (1.0 - self.rebate_at_peak * mfu)


@dataclasses.dataclass(frozen=True)
class Bill:
    """One metered invocation (a compiled-program execution)."""

    tenant: str
    job_id: str
    kind: str  # train_step | prefill | decode | ...
    steps: int
    chips: int
    wall_s: float  # modeled or measured wall time for `steps` executions
    flops: float  # per-step HLO FLOPs (per chip, post-SPMD)
    bytes_hbm: float
    bytes_collective: float
    usd: float

    @property
    def device_s(self) -> float:
        return self.wall_s * self.chips

    @property
    def flop_s(self) -> float:
        """Total FLOPs executed across the fleet (the XaaS billing unit)."""
        return self.flops * self.chips * self.steps


class Meter:
    """Per-tenant usage ledger. Thread-compatible: one per scheduler."""

    def __init__(self, prices: PriceSheet | None = None):
        self.prices = prices or PriceSheet()
        self.bills: list[Bill] = []
        self._seq = itertools.count()

    def record(
        self,
        *,
        tenant: str,
        kind: str,
        steps: int,
        chips: int,
        wall_s: float,
        artifact=None,
        flops: float = 0.0,
        bytes_hbm: float = 0.0,
        bytes_collective: float = 0.0,
        peak_flops: float = 197e12,
        job_id: str | None = None,
    ) -> Bill:
        """Meter `steps` executions of one artifact.

        When `artifact` (core.recompile.CompiledArtifact) is given, FLOPs /
        bytes / peak come from its analyses — billing from the compiled
        truth, not from user claims.
        """
        if artifact is not None:
            flops = artifact.flops
            bytes_hbm = artifact.hbm_bytes
            bytes_collective = float(artifact.collectives()["total"])
            peak_flops = artifact.profile.peak_flops
            chips = chips or artifact.profile.chips
        mfu = 0.0
        if wall_s > 0 and peak_flops > 0 and steps > 0:
            mfu = (flops * steps) / (wall_s * peak_flops)
            mfu = min(mfu, 1.0)
        usd = self.prices.charge(wall_s * chips, mfu)
        bill = Bill(
            tenant=tenant,
            job_id=job_id or f"job-{next(self._seq)}",
            kind=kind,
            steps=steps,
            chips=chips,
            wall_s=wall_s,
            flops=flops,
            bytes_hbm=bytes_hbm,
            bytes_collective=bytes_collective,
            usd=usd,
        )
        self.bills.append(bill)
        return bill

    # ---- queries ----
    def total_usd(self, tenant: str | None = None) -> float:
        return sum(b.usd for b in self._select(tenant))

    def total_device_s(self, tenant: str | None = None) -> float:
        return sum(b.device_s for b in self._select(tenant))

    def total_flop_s(self, tenant: str | None = None) -> float:
        return sum(b.flop_s for b in self._select(tenant))

    def total_steps(self, kind: str, tenant: str | None = None) -> int:
        """Total metered step count for one bill kind (e.g. decode steps,
        served tokens) — the usage-quantum query the serving ledger uses."""
        return sum(b.steps for b in self._select(tenant) if b.kind == kind)

    def served_tokens(self, tenant: str | None = None) -> int:
        """Tokens served to a tenant through leased serving executors."""
        return self.total_steps("serve_tokens", tenant)

    def by_tenant(self) -> dict[str, float]:
        out: dict[str, float] = defaultdict(float)
        for b in self.bills:
            out[b.tenant] += b.usd
        return dict(out)

    def _select(self, tenant: str | None) -> Iterable[Bill]:
        return (b for b in self.bills if tenant is None or b.tenant == tenant)

    # ---- invariants (property-tested) ----
    def check_invariants(self) -> None:
        """Conservation: ledger totals equal the sum of parts; no negative
        charges; device-seconds additive."""
        assert all(b.usd >= 0 for b in self.bills)
        assert all(b.wall_s >= 0 and b.chips >= 0 for b in self.bills)
        total = self.total_usd()
        assert math.isclose(total, sum(self.by_tenant().values()), rel_tol=1e-9, abs_tol=1e-12)
