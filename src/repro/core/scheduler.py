"""High-performance allocation & scheduling — the XaaS Invocation principle.

The paper asks for allocation systems that (a) reduce waiting time, (b) let
interactive and batch jobs coexist, (c) support "potentially large requests
that need to launch thousands of container instances", (d) support run-forever
services, and (e) are "decentralized or at least parallelized".

This module implements a deterministic discrete-event cluster scheduler:

  * resource model: a fleet of `chips` (TPU chips); jobs request a chip
    count and a max runtime (walltime limit).
  * job classes: INTERACTIVE (latency-sensitive, FaaS-style — jump the
    queue, small), BATCH (run-to-completion, backfillable), SERVICE
    (run-forever; holds chips until cancelled — the paper's "committing
    some resources forever").
  * policy: priority FCFS + **EASY backfilling** — the head-of-queue job
    gets a reservation (earliest time enough chips free); any later job may
    start now iff it fits in the free chips *and* does not delay that
    reservation. This is the classic HPC utilization/fairness tradeoff the
    paper references ("backfilling a gap that a waiting larger job may
    cause").
  * elasticity: jobs may declare ``min_chips``; under pressure the scheduler
    starts them shrunk (elastic scale-down), growing at the next event — the
    FaaS "scale to zero / scale out" behavior lifted to parallel jobs.
  * preemption: ``preempt()`` evicts a RUNNING BATCH job for a
    latency-sensitive arrival (the interactive/batch coexistence story).
    Listeners fire *before* the chips are taken (a graceful checkpoint
    window — the fleet wires this to FTManager), progress is credited
    against the walltime limit, and the job is requeued at its class
    priority to restart when chips free up.
  * the state machine is event-driven with no global clock sweep — event
    handlers touch only per-job + free-pool state, which is what makes the
    design "parallelizable" (shardable by pool) per the paper.

It is a *simulator by construction* (virtual clock), but the same object
drives the real launcher: `launch/train.py` submits itself as a job and the
FT manager feeds real failure events in — one scheduler, simulated or live.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
from typing import Callable, Iterator

__all__ = ["JobClass", "JobState", "Job", "Cluster", "Event"]


class JobClass(enum.IntEnum):
    # ordering = queue priority (lower value served first)
    INTERACTIVE = 0
    SERVICE = 1
    BATCH = 2


class JobState(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"
    CANCELLED = "cancelled"


@dataclasses.dataclass
class Job:
    job_id: int
    tenant: str
    klass: JobClass
    chips: int  # requested
    runtime_s: float  # estimated/declared runtime (walltime limit)
    submit_s: float
    min_chips: int = 0  # 0 -> rigid (min == requested)
    state: JobState = JobState.PENDING
    start_s: float | None = None
    end_s: float | None = None
    granted_chips: int = 0
    preemptions: int = 0
    # bumped on every preemption; start/finish events record it so a stale
    # "finish" from a pre-preemption incarnation can't kill the restarted job
    incarnation: int = 0

    def __post_init__(self):
        if self.min_chips <= 0:
            self.min_chips = self.chips

    @property
    def wait_s(self) -> float:
        return (self.start_s if self.start_s is not None else 0.0) - self.submit_s

    @property
    def is_service(self) -> bool:
        return self.klass == JobClass.SERVICE


@dataclasses.dataclass(order=True)
class Event:
    time: float
    seq: int
    kind: str = dataclasses.field(compare=False)  # submit | finish | cancel | fail | preempt
    job_id: int = dataclasses.field(compare=False)
    # job incarnation the event was issued against (finish events only)
    ref: int = dataclasses.field(compare=False, default=0)


class Cluster:
    """Discrete-event scheduler over a homogeneous chip fleet."""

    def __init__(self, chips: int, *, backfill: bool = True):
        self.total_chips = chips
        self.free_chips = chips
        self.backfill = backfill
        self.now = 0.0
        self.jobs: dict[int, Job] = {}
        self.pending: list[int] = []  # queue order maintained on insert
        self.running: set[int] = set()
        self._events: list[Event] = []
        self._seq = itertools.count()
        self._id = itertools.count(1)
        # metrics
        self.utilization_chip_s = 0.0
        self._last_util_t = 0.0
        self.listeners: list[Callable[[str, Job], None]] = []

    # ------------------------------------------------------------------
    # submission API
    # ------------------------------------------------------------------
    def submit(
        self,
        *,
        tenant: str,
        chips: int,
        runtime_s: float,
        klass: JobClass = JobClass.BATCH,
        min_chips: int = 0,
        at: float | None = None,
    ) -> Job:
        if chips > self.total_chips:
            raise ValueError(
                f"job wants {chips} chips; cluster has {self.total_chips}")
        job = Job(
            job_id=next(self._id),
            tenant=tenant,
            klass=klass,
            chips=chips,
            runtime_s=runtime_s,
            submit_s=self.now if at is None else at,
            min_chips=min_chips,
        )
        self.jobs[job.job_id] = job
        self._push(Event(job.submit_s, next(self._seq), "submit", job.job_id))
        return job

    def cancel(self, job_id: int, at: float | None = None) -> None:
        self._push(Event(self.now if at is None else at, next(self._seq), "cancel", job_id))

    def fail(self, job_id: int, at: float | None = None) -> None:
        """External failure event (node crash) — consumed by ft/manager."""
        self._push(Event(self.now if at is None else at, next(self._seq), "fail", job_id))

    def preempt(self, job_id: int, at: float | None = None) -> None:
        """Evict a RUNNING preemptible job: listeners get a ("preempt", job)
        callback *before* the chips are released (the graceful checkpoint
        window), elapsed runtime is credited against the walltime limit, and
        the job is requeued PENDING at its class priority. SERVICE jobs are
        leases and are never preempted (no-op)."""
        self._push(Event(self.now if at is None else at, next(self._seq), "preempt", job_id))

    # ------------------------------------------------------------------
    # event loop
    # ------------------------------------------------------------------
    def _push(self, ev: Event) -> None:
        heapq.heappush(self._events, ev)

    def step(self) -> Event | None:
        """Process one event; returns it (None if queue empty)."""
        if not self._events:
            return None
        ev = heapq.heappop(self._events)
        self._advance_clock(ev.time)
        handler = getattr(self, f"_on_{ev.kind}")
        handler(ev)
        self._schedule_pass()
        return ev

    def run(self, until: float | None = None) -> None:
        while self._events:
            if until is not None and self._events[0].time > until:
                self._advance_clock(until)
                return
            self.step()

    def events_pending(self) -> bool:
        return bool(self._events)

    def advance_to(self, t: float) -> None:
        """Process every event due by `t`, then move the virtual clock to `t`
        even if the event queue empties first (``run(until=...)`` stops
        advancing once there are no events, which would freeze utilization
        accounting through idle stretches — the fleet tick loop needs the
        clock to keep integrating busy-chip seconds)."""
        self.run(until=t)
        if self.now < t:
            self._advance_clock(t)

    def _advance_clock(self, t: float) -> None:
        if t < self.now:
            t = self.now  # never go backwards (late-submitted events)
        busy = self.total_chips - self.free_chips
        self.utilization_chip_s += busy * (t - self.now)
        self.now = t

    # ------------------------------------------------------------------
    # handlers
    # ------------------------------------------------------------------
    def _enqueue(self, job: Job) -> None:
        # insertion keeping class priority then FCFS
        idx = len(self.pending)
        for i, jid in enumerate(self.pending):
            if self.jobs[jid].klass > job.klass:
                idx = i
                break
        self.pending.insert(idx, job.job_id)

    def _on_submit(self, ev: Event) -> None:
        job = self.jobs[ev.job_id]
        if job.state != JobState.PENDING:
            return
        self._enqueue(job)

    def _on_finish(self, ev: Event) -> None:
        job = self.jobs[ev.job_id]
        if job.state != JobState.RUNNING or ev.ref != job.incarnation:
            # ref mismatch: a finish scheduled before a preemption landing
            # after the restart — the restarted incarnation has its own
            return
        self._release(job, JobState.DONE)

    def _on_preempt(self, ev: Event) -> None:
        job = self.jobs[ev.job_id]
        if job.state != JobState.RUNNING or job.is_service:
            return
        # graceful window: chips still held while listeners checkpoint
        for fn in self.listeners:
            fn("preempt", job)
        elapsed = self.now - (job.start_s or 0.0)
        # progress up to the preemption checkpoint is credited: the restart
        # only owes the remainder of the declared walltime
        job.runtime_s = max(job.runtime_s - elapsed, 1e-9)
        job.incarnation += 1
        self.free_chips += job.granted_chips
        self.running.discard(job.job_id)
        job.granted_chips = 0
        job.state = JobState.PENDING
        job.start_s = None
        job.preemptions += 1
        self._enqueue(job)

    def _on_cancel(self, ev: Event) -> None:
        job = self.jobs[ev.job_id]
        if job.state == JobState.PENDING:
            self.pending.remove(job.job_id)
            job.state = JobState.CANCELLED
        elif job.state == JobState.RUNNING:
            self._release(job, JobState.CANCELLED)

    def _on_fail(self, ev: Event) -> None:
        job = self.jobs[ev.job_id]
        if job.state == JobState.RUNNING:
            self._release(job, JobState.FAILED)
            for fn in self.listeners:
                fn("fail", job)

    def _release(self, job: Job, state: JobState) -> None:
        self.free_chips += job.granted_chips
        self.running.discard(job.job_id)
        job.state = state
        job.end_s = self.now
        job.granted_chips = 0
        for fn in self.listeners:
            fn("release", job)

    # ------------------------------------------------------------------
    # scheduling pass: priority FCFS + EASY backfill + elastic shrink
    # ------------------------------------------------------------------
    def _start(self, job: Job, chips: int) -> None:
        job.state = JobState.RUNNING
        job.start_s = self.now
        job.granted_chips = chips
        self.free_chips -= chips
        self.running.add(job.job_id)
        self.pending.remove(job.job_id)
        if not job.is_service:  # services run until cancelled
            self._push(Event(self.now + job.runtime_s, next(self._seq), "finish",
                             job.job_id, ref=job.incarnation))
        for fn in self.listeners:
            fn("start", job)

    def _grow_elastic(self) -> None:
        """Give spare chips to shrunk elastic running jobs (largest deficit
        first) — scale-up half of elasticity."""
        if self.free_chips == 0:
            return
        grows = sorted(
            (j for j in (self.jobs[i] for i in self.running) if j.granted_chips < j.chips),
            key=lambda j: j.granted_chips - j.chips,
        )
        for job in grows:
            take = min(job.chips - job.granted_chips, self.free_chips)
            if take > 0:
                job.granted_chips += take
                self.free_chips -= take
                for fn in self.listeners:
                    fn("grow", job)
            if self.free_chips == 0:
                return

    def _earliest_free(self, need: int) -> float:
        """Earliest virtual time at which `need` chips are simultaneously
        free, assuming running jobs end at their walltime limits."""
        if need <= self.free_chips:
            return self.now
        ends = sorted(
            (
                (j.start_s + j.runtime_s if not j.is_service else float("inf"), j.granted_chips)
                for j in (self.jobs[i] for i in self.running)
            ),
        )
        free = self.free_chips
        for t, chips in ends:
            free += chips
            if free >= need:
                return t
        return float("inf")

    def _schedule_pass(self) -> None:
        progressed = True
        while progressed:
            progressed = False
            if not self.pending:
                break
            head = self.jobs[self.pending[0]]
            if head.chips <= self.free_chips:
                self._start(head, head.chips)
                progressed = True
                continue
            if head.min_chips <= self.free_chips:
                # elastic scale-down start
                self._start(head, self.free_chips)
                progressed = True
                continue
            if not self.backfill:
                break
            # EASY backfill: reserve for head; start any later job that
            # fits now and ends before the reservation (or uses chips the
            # reservation doesn't need).
            t_res = self._earliest_free(head.chips)
            # chips guaranteed free at t_res beyond head's need
            for jid in list(self.pending[1:]):
                job = self.jobs[jid]
                fits_now = job.chips <= self.free_chips
                if not fits_now:
                    continue
                ends_before = self.now + job.runtime_s <= t_res
                spare_at_res = (
                    self._free_at(t_res, excluding=None) - head.chips >= job.chips
                )
                if ends_before or spare_at_res or job.is_service and spare_at_res:
                    self._start(job, job.chips)
                    progressed = True
                    break
        self._grow_elastic()

    def _free_at(self, t: float, excluding=None) -> int:
        free = self.free_chips
        for j in (self.jobs[i] for i in self.running):
            if j is excluding or j.is_service:
                continue
            if j.start_s + j.runtime_s <= t:
                free += j.granted_chips
        return free

    # ------------------------------------------------------------------
    # metrics & invariants
    # ------------------------------------------------------------------
    @property
    def busy_chips(self) -> int:
        return self.total_chips - self.free_chips

    def utilization(self) -> float:
        if self.now <= 0:
            return 0.0
        return self.utilization_chip_s / (self.total_chips * self.now)

    def total_preemptions(self) -> int:
        return sum(j.preemptions for j in self.jobs.values())

    def mean_wait(self, klass: JobClass | None = None) -> float:
        waits = [
            j.wait_s
            for j in self.jobs.values()
            if j.start_s is not None and (klass is None or j.klass == klass)
        ]
        return sum(waits) / len(waits) if waits else 0.0

    def check_invariants(self) -> None:
        granted = sum(self.jobs[i].granted_chips for i in self.running)
        assert granted + self.free_chips == self.total_chips, (
            f"chip leak: {granted} granted + {self.free_chips} free "
            f"!= {self.total_chips}")
        assert 0 <= self.free_chips <= self.total_chips
        for i in self.running:
            j = self.jobs[i]
            assert j.state == JobState.RUNNING
            assert j.min_chips <= j.granted_chips <= j.chips
        for i in self.pending:
            assert self.jobs[i].state == JobState.PENDING
        # priority order within queue
        ks = [self.jobs[i].klass for i in self.pending]
        assert ks == sorted(ks), f"queue priority violated: {ks}"

    def drain(self) -> Iterator[Event]:
        while self._events:
            yield self.step()
