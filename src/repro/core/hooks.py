"""Accelerated-API hook registry — the XaaS 'flexible hooked libraries'.

The paper's Infrastructure principle: a portable container exposes *named
accelerated APIs* (BLAS, DNN, MPI, ...) whose concrete implementation is bound
by the provider at deploy time, without the application being rewritten.

Here every model compute hot-spot calls ``hooks.call("<api>", ...)``. Each API
has:
  * a fixed signature contract (the "ABI" the paper asks to standardize),
  * a *portable* reference implementation (pure jnp — the paper's
    lowest-common-denominator binary, always correct, runs anywhere XLA runs),
  * zero or more *system-optimized* implementations (Pallas TPU kernels),
    registered by provider tag and bound when a deployment's SystemProfile
    says the target supports them.

Binding is explicit and scoped (``with hooks.use(binding):``) so one process
can hold deployments for several target systems — exactly the multi-provider
story of the paper.

Probe-based specialization (the deploy-time half of the contract): an
implementation may carry a *probe* — a callable that compiles and runs a tiny
candidate kernel the way the tier would actually execute on the target. When
``bind(profile, probe=True)`` selects tiers, a probe failure rejects the tier
and dispatch falls back to the next priority, recording the rejection in the
binding's specialization manifest. This is what turns a JAX/XLA API-vintage
mismatch (see kernels/compat.py) into a visible fallback instead of a trace
error inside a deployed program. Probe outcomes are cached per
``(api, provider, profile.chip)`` so warm deployments never re-probe.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable, Mapping

__all__ = [
    "AcceleratedAPI",
    "Binding",
    "HookError",
    "TierChoice",
    "register_api",
    "register_impl",
    "available_impls",
    "bind",
    "use",
    "call",
    "current_binding",
    "get_api",
    "list_apis",
    "probe_impl",
    "clear_probe_cache",
]


class HookError(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class Implementation:
    provider: str
    fn: Callable[..., Any]
    # availability predicate over a SystemProfile (core.recompile.SystemProfile)
    supports: Callable[[Any], bool]
    priority: int = 0  # higher wins when several impls support a profile
    # deploy-time probe: compile+run a tiny candidate kernel the way this
    # tier would execute on `profile`; raising (or returning False) rejects
    # the tier at bind time. None = the tier is assumed bindable.
    probe: Callable[[Any], Any] | None = None


@dataclasses.dataclass(frozen=True)
class TierChoice:
    """Why one provider serves one API in a binding (manifest line)."""

    api: str
    provider: str  # "portable" or a registered provider tag
    priority: int
    probed: bool  # a probe ran (and passed) for the chosen tier
    # tiers that supported the profile but were rejected by their probe,
    # highest priority first: (provider, error message)
    rejected: tuple[tuple[str, str], ...] = ()

    def to_dict(self) -> dict:
        return {
            "provider": self.provider,
            "priority": self.priority,
            "probed": self.probed,
            "rejected": [list(r) for r in self.rejected],
        }


@dataclasses.dataclass
class AcceleratedAPI:
    name: str
    signature: str  # human-readable ABI contract
    reference: Callable[..., Any]
    impls: dict[str, Implementation] = dataclasses.field(default_factory=dict)


_REGISTRY: dict[str, AcceleratedAPI] = {}
_LOCK = threading.Lock()


class Binding(Mapping[str, Callable[..., Any]]):
    """Immutable api-name -> implementation mapping for one deployment."""

    def __init__(
        self,
        mapping: dict[str, Callable[..., Any]],
        label: str = "portable",
        choices: dict[str, TierChoice] | None = None,
    ):
        self._mapping = dict(mapping)
        self.label = label
        self.choices = dict(choices or {})

    def __getitem__(self, k: str) -> Callable[..., Any]:
        return self._mapping[k]

    def __iter__(self):
        return iter(self._mapping)

    def __len__(self):
        return len(self._mapping)

    def providers(self) -> dict[str, str]:
        return {k: getattr(v, "__xaas_provider__", "portable") for k, v in self._mapping.items()}

    def tier_fingerprint(self) -> tuple[tuple[str, str], ...]:
        """Stable, hashable (api, provider) pairs — the kernel-tier field of
        program-bundle cache keys and persisted-artifact keys. Programs
        traced (or serialized) under one tier set must never serve an
        engine bound to another; a changed fingerprint is exactly how a
        stale IR artifact gets invalidated."""
        return tuple(sorted(self.providers().items()))

    def manifest(self) -> dict:
        """Serializable specialization manifest: chosen tier per API, with
        probe provenance and the tiers that were rejected on the way down."""
        providers = self.providers()
        apis = {}
        for name in sorted(self._mapping):
            choice = self.choices.get(name)
            if choice is None:  # un-probed bind: provider known, provenance not
                choice = TierChoice(
                    api=name, provider=providers[name], priority=0, probed=False)
            apis[name] = choice.to_dict()
        return {"label": self.label, "apis": apis}

    def __repr__(self):
        return f"Binding({self.label}: {self.providers()})"


class _State(threading.local):
    def __init__(self):
        self.stack: list[Binding] = []


_STATE = _State()


def register_api(name: str, signature: str, reference: Callable[..., Any]) -> AcceleratedAPI:
    with _LOCK:
        if name in _REGISTRY:
            raise HookError(f"accelerated API {name!r} already registered")
        api = AcceleratedAPI(name=name, signature=signature, reference=reference)
        _REGISTRY[name] = api
        return api


def register_impl(
    api_name: str,
    provider: str,
    fn: Callable[..., Any],
    *,
    supports: Callable[[Any], bool] | None = None,
    priority: int = 0,
    probe: Callable[[Any], Any] | None = None,
) -> None:
    with _LOCK:
        api = _REGISTRY.get(api_name)
        if api is None:
            raise HookError(f"unknown accelerated API {api_name!r}")
        fn.__xaas_provider__ = provider  # type: ignore[attr-defined]
        api.impls[provider] = Implementation(
            provider=provider, fn=fn, supports=supports or (lambda profile: True),
            priority=priority, probe=probe,
        )
        # re-registering replaces the probe too: stale verdicts for the old
        # implementation must not govern the new one
        for key in [k for k in _PROBE_CACHE if k[:2] == (api_name, provider)]:
            del _PROBE_CACHE[key]


def get_api(name: str) -> AcceleratedAPI:
    api = _REGISTRY.get(name)
    if api is None:
        raise HookError(f"unknown accelerated API {name!r}")
    return api


def list_apis() -> list[str]:
    return sorted(_REGISTRY)


def available_impls(api_name: str, profile: Any = None) -> list[str]:
    api = get_api(api_name)
    out = ["portable"]
    for impl in sorted(api.impls.values(), key=lambda i: -i.priority):
        if profile is None or impl.supports(profile):
            out.append(impl.provider)
    return out


# probe outcome cache: (api, provider, profile.chip) -> (passed, error|None).
# Keyed on the chip kind, not the profile object: the probe compiles against
# the *local* toolchain, and two profiles for the same chip see the same
# toolchain. Warm deployments therefore never re-probe.
_PROBE_CACHE: dict[tuple[str, str, Any], tuple[bool, str | None]] = {}


def clear_probe_cache() -> None:
    _PROBE_CACHE.clear()


def probe_impl(api_name: str, provider: str, profile: Any) -> tuple[bool, str | None]:
    """Run (or recall) the deploy-time probe for one (api, provider) tier.

    Returns ``(passed, error_message)``. A tier without a probe passes by
    definition; probe exceptions and falsy non-None returns fail.
    """
    impl = get_api(api_name).impls.get(provider)
    if impl is None:
        raise HookError(f"no implementation {provider!r} for API {api_name!r}")
    if impl.probe is None:
        return True, None
    key = (api_name, provider, getattr(profile, "chip", None))
    cached = _PROBE_CACHE.get(key)
    if cached is not None:
        return cached
    try:
        out = impl.probe(profile)
        result = (True, None) if (out is None or out) else (
            False, "probe returned falsy")
    except Exception as e:  # noqa: BLE001 — any failure means "cannot bind"
        result = (False, f"{type(e).__name__}: {e}")
    _PROBE_CACHE[key] = result
    return result


def bind(
    profile: Any = None,
    *,
    overrides: Mapping[str, str] | None = None,
    probe: bool = False,
) -> Binding:
    """Build a deployment binding: best available impl per API for `profile`.

    `overrides` pins an API to a provider tag ("portable" or a registered
    provider), mirroring the paper's per-site library pinning.

    With ``probe=True`` (what ``XContainer.deploy`` uses), every candidate
    tier must pass its deploy-time probe before it may bind; a failing tier
    is skipped and the next priority is tried, down to the portable floor.
    Rejections are recorded on the binding's manifest. Overridden (pinned)
    tiers are NOT probed — a pin is an operator's explicit order.
    """
    overrides = dict(overrides or {})
    mapping: dict[str, Callable[..., Any]] = {}
    choices: dict[str, TierChoice] = {}
    label = getattr(profile, "name", "portable") if profile is not None else "portable"
    for name, api in _REGISTRY.items():
        choice = overrides.pop(name, None)
        if choice == "portable":
            mapping[name] = api.reference
            choices[name] = TierChoice(name, "portable", 0, probed=False)
            continue
        if choice is not None:
            if choice not in api.impls:
                raise HookError(f"no implementation {choice!r} for API {name!r}")
            mapping[name] = api.impls[choice].fn
            choices[name] = TierChoice(
                name, choice, api.impls[choice].priority, probed=False)
            continue
        best: Implementation | None = None
        rejected: list[tuple[str, str]] = []
        if profile is not None:
            candidates = sorted(
                (i for i in api.impls.values() if i.supports(profile)),
                key=lambda i: -i.priority)
            for impl in candidates:
                if probe:
                    ok, err = probe_impl(name, impl.provider, profile)
                    if not ok:
                        rejected.append((impl.provider, err or "probe failed"))
                        continue
                best = impl
                break
        if best is not None:
            mapping[name] = best.fn
            choices[name] = TierChoice(
                name, best.provider, best.priority,
                probed=probe and best.probe is not None,
                rejected=tuple(rejected))
        else:
            mapping[name] = api.reference
            choices[name] = TierChoice(
                name, "portable", 0, probed=False, rejected=tuple(rejected))
    if overrides:
        raise HookError(f"overrides for unknown APIs: {sorted(overrides)}")
    return Binding(mapping, label=label, choices=choices)


def current_binding() -> Binding | None:
    return _STATE.stack[-1] if _STATE.stack else None


@contextlib.contextmanager
def use(binding: Binding):
    _STATE.stack.append(binding)
    try:
        yield binding
    finally:
        _STATE.stack.pop()


def call(api_name: str, *args, **kwargs):
    """Invoke an accelerated API through the current deployment binding.

    Outside any ``use()`` scope the portable reference runs — a container is
    always runnable, just not specialized (the paper's portability floor).
    """
    binding = current_binding()
    if binding is not None and api_name in binding:
        return binding[api_name](*args, **kwargs)
    return get_api(api_name).reference(*args, **kwargs)
