"""Accelerated-API hook registry — the XaaS 'flexible hooked libraries'.

The paper's Infrastructure principle: a portable container exposes *named
accelerated APIs* (BLAS, DNN, MPI, ...) whose concrete implementation is bound
by the provider at deploy time, without the application being rewritten.

Here every model compute hot-spot calls ``hooks.call("<api>", ...)``. Each API
has:
  * a fixed signature contract (the "ABI" the paper asks to standardize),
  * a *portable* reference implementation (pure jnp — the paper's
    lowest-common-denominator binary, always correct, runs anywhere XLA runs),
  * zero or more *system-optimized* implementations (Pallas TPU kernels),
    registered by provider tag and bound when a deployment's SystemProfile
    says the target supports them.

Binding is explicit and scoped (``with hooks.use(binding):``) so one process
can hold deployments for several target systems — exactly the multi-provider
story of the paper.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable, Mapping

__all__ = [
    "AcceleratedAPI",
    "Binding",
    "HookError",
    "register_api",
    "register_impl",
    "available_impls",
    "bind",
    "use",
    "call",
    "current_binding",
    "get_api",
    "list_apis",
]


class HookError(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class Implementation:
    provider: str
    fn: Callable[..., Any]
    # availability predicate over a SystemProfile (core.recompile.SystemProfile)
    supports: Callable[[Any], bool]
    priority: int = 0  # higher wins when several impls support a profile


@dataclasses.dataclass
class AcceleratedAPI:
    name: str
    signature: str  # human-readable ABI contract
    reference: Callable[..., Any]
    impls: dict[str, Implementation] = dataclasses.field(default_factory=dict)


_REGISTRY: dict[str, AcceleratedAPI] = {}
_LOCK = threading.Lock()


class Binding(Mapping[str, Callable[..., Any]]):
    """Immutable api-name -> implementation mapping for one deployment."""

    def __init__(self, mapping: dict[str, Callable[..., Any]], label: str = "portable"):
        self._mapping = dict(mapping)
        self.label = label

    def __getitem__(self, k: str) -> Callable[..., Any]:
        return self._mapping[k]

    def __iter__(self):
        return iter(self._mapping)

    def __len__(self):
        return len(self._mapping)

    def providers(self) -> dict[str, str]:
        return {k: getattr(v, "__xaas_provider__", "portable") for k, v in self._mapping.items()}

    def __repr__(self):
        return f"Binding({self.label}: {self.providers()})"


class _State(threading.local):
    def __init__(self):
        self.stack: list[Binding] = []


_STATE = _State()


def register_api(name: str, signature: str, reference: Callable[..., Any]) -> AcceleratedAPI:
    with _LOCK:
        if name in _REGISTRY:
            raise HookError(f"accelerated API {name!r} already registered")
        api = AcceleratedAPI(name=name, signature=signature, reference=reference)
        _REGISTRY[name] = api
        return api


def register_impl(
    api_name: str,
    provider: str,
    fn: Callable[..., Any],
    *,
    supports: Callable[[Any], bool] | None = None,
    priority: int = 0,
) -> None:
    with _LOCK:
        api = _REGISTRY.get(api_name)
        if api is None:
            raise HookError(f"unknown accelerated API {api_name!r}")
        fn.__xaas_provider__ = provider  # type: ignore[attr-defined]
        api.impls[provider] = Implementation(
            provider=provider, fn=fn, supports=supports or (lambda profile: True), priority=priority
        )


def get_api(name: str) -> AcceleratedAPI:
    api = _REGISTRY.get(name)
    if api is None:
        raise HookError(f"unknown accelerated API {name!r}")
    return api


def list_apis() -> list[str]:
    return sorted(_REGISTRY)


def available_impls(api_name: str, profile: Any = None) -> list[str]:
    api = get_api(api_name)
    out = ["portable"]
    for impl in sorted(api.impls.values(), key=lambda i: -i.priority):
        if profile is None or impl.supports(profile):
            out.append(impl.provider)
    return out


def bind(profile: Any = None, *, overrides: Mapping[str, str] | None = None) -> Binding:
    """Build a deployment binding: best available impl per API for `profile`.

    `overrides` pins an API to a provider tag ("portable" or a registered
    provider), mirroring the paper's per-site library pinning.
    """
    overrides = dict(overrides or {})
    mapping: dict[str, Callable[..., Any]] = {}
    label = getattr(profile, "name", "portable") if profile is not None else "portable"
    for name, api in _REGISTRY.items():
        choice = overrides.pop(name, None)
        if choice == "portable":
            mapping[name] = api.reference
            continue
        if choice is not None:
            if choice not in api.impls:
                raise HookError(f"no implementation {choice!r} for API {name!r}")
            mapping[name] = api.impls[choice].fn
            continue
        best: Implementation | None = None
        if profile is not None:
            for impl in api.impls.values():
                if impl.supports(profile) and (best is None or impl.priority > best.priority):
                    best = impl
        mapping[name] = best.fn if best is not None else api.reference
    if overrides:
        raise HookError(f"overrides for unknown APIs: {sorted(overrides)}")
    return Binding(mapping, label=label)


def current_binding() -> Binding | None:
    return _STATE.stack[-1] if _STATE.stack else None


@contextlib.contextmanager
def use(binding: Binding):
    _STATE.stack.append(binding)
    try:
        yield binding
    finally:
        _STATE.stack.pop()


def call(api_name: str, *args, **kwargs):
    """Invoke an accelerated API through the current deployment binding.

    Outside any ``use()`` scope the portable reference runs — a container is
    always runnable, just not specialized (the paper's portability floor).
    """
    binding = current_binding()
    if binding is not None and api_name in binding:
        return binding[api_name](*args, **kwargs)
    return get_api(api_name).reference(*args, **kwargs)
