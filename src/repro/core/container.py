"""XContainer — the performance-portable container (paper Figure 2).

The paper's container stack, translated to JAX (DESIGN.md §1):

    domain layer      = the model: an ArchConfig + entrypoints (train_step /
                        prefill / decode) built from `models/`
    XaaS layer        = accelerated-API *requirements* (which hooks the
                        program calls) + logical sharding annotations
    provider layer    = a SystemProfile supplying hook implementations, mesh,
                        and the XLA compiler for the target chip

An ``XContainer`` is the shippable unit: a *recipe* that can be deployed onto
any provider profile. ``deploy()`` runs the paper's pipeline — bind hooks
(flexible hooked libraries), install sharding rules, lower to IR, compile at
the target (deployment recompilation) — and returns a ``Deployment`` holding
the compiled artifact plus everything accounting/roofline need.

Containers never contain weights. Weights are data (the paper's "data
gravity" lives in the checkpoint store); containers are programs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

import jax

from repro.core import hooks, recompile
from repro.distributed import sharding as shd

__all__ = ["XContainer", "Deployment", "build_mesh"]


def build_mesh(profile: recompile.SystemProfile) -> jax.sharding.Mesh:
    """Materialize the profile's mesh on the current backend's devices."""
    return jax.make_mesh(profile.mesh_shape, profile.mesh_axes)


@dataclasses.dataclass
class Deployment:
    """A container deployed on one provider system."""

    container: "XContainer"
    profile: recompile.SystemProfile
    mesh: jax.sharding.Mesh
    binding: hooks.Binding
    rules: shd.Rules
    artifacts: dict[str, recompile.CompiledArtifact]

    def artifact(self, entrypoint: str) -> recompile.CompiledArtifact:
        return self.artifacts[entrypoint]

    def __call__(self, entrypoint: str, *args, **kwargs):
        """Invoke a deployed entrypoint (data plane: compiled XLA only)."""
        return self.artifacts[entrypoint](*args, **kwargs)

    def providers(self) -> dict[str, str]:
        return self.binding.providers()

    def manifest(self) -> dict:
        """The specialization manifest: which tier serves each accelerated
        API on this deployment, with probe provenance
        (docs/kernel-portability.md), plus how each entrypoint's executable
        came to exist (cold compile / warm cache / IR restore — the boot
        ladder, docs/ir-containers.md)."""
        m = self.binding.manifest()
        return {
            "container": self.container.name,
            "profile": self.profile.name,
            "chip": self.profile.chip,
            "chips": self.profile.chips,
            # resolved mesh geometry + sharding rule set, next to the kernel
            # tiers: the specialization record answers "what grid does this
            # deployment span and how do logical axes land on it" the same
            # way it answers "which tier serves each API"
            "mesh": {
                "shape": tuple(int(s) for s in self.mesh.devices.shape),
                "axes": tuple(self.mesh.axis_names),
            },
            "sharding_rules": shd.rule_summary(self.rules),
            "apis": m["apis"],
            "entrypoint_boot": {
                ep: {"boot": art.boot, "cache_hit": art.cache_hit,
                     "lower_s": round(art.lower_s, 6),
                     "compile_s": round(art.compile_s, 6)}
                for ep, art in self.artifacts.items()
            },
        }


@dataclasses.dataclass
class XContainer:
    """A performance-portable program recipe.

    entrypoints: name -> (fn, make_args) where ``make_args(mesh)`` returns
    (args, kwargs) of ShapeDtypeStructs (dry-run) or real arrays, already
    annotated with shardings where needed; ``fn`` is traced under the hook
    binding + sharding rules, so the *same recipe* specializes per target.
    """

    name: str
    entrypoints: dict[str, tuple[Callable, Callable]]
    rules_2d: shd.Rules = dataclasses.field(default_factory=lambda: dict(shd.RULES_2D))
    rules_3d: shd.Rules = dataclasses.field(default_factory=lambda: dict(shd.RULES_3D))
    hook_overrides: Mapping[str, str] | None = None
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)
    # persistent AOT artifact store carried WITH the container (the "IR
    # half" of an XaaS source+IR container): deploy() persists compiled
    # entrypoints here and restores them in later processes, and serving
    # engines booted from this container IR-boot their data plane from it
    artifact_store: Any = None

    def rules_for(self, profile: recompile.SystemProfile) -> shd.Rules:
        return self.rules_3d if "pod" in profile.mesh_axes else self.rules_2d

    def deploy(
        self,
        profile: recompile.SystemProfile,
        *,
        mesh: jax.sharding.Mesh | None = None,
        compiler: recompile.DeploymentCompiler | None = None,
        entrypoints: list[str] | None = None,
        hook_overrides: Mapping[str, str] | None = None,
        probe: bool = True,
        artifact_store=None,
    ) -> Deployment:
        """Deploy onto `profile`: probe + bind hooks, install sharding rules,
        lower, compile. With ``probe`` (default) every candidate tier must
        pass its deploy-time probe before binding (hooks.bind); the chosen
        tier per API lands in ``meta["specialization"][profile.name]`` so
        warm re-deployments can report exactly what serves traffic."""
        compiler = compiler or recompile.DEFAULT_COMPILER
        store = (artifact_store if artifact_store is not None
                 else self.artifact_store)
        mesh = mesh if mesh is not None else build_mesh(profile)
        binding = hooks.bind(
            profile, overrides=hook_overrides or self.hook_overrides,
            probe=probe)
        rules = self.rules_for(profile)
        artifacts: dict[str, recompile.CompiledArtifact] = {}
        names = entrypoints or list(self.entrypoints)
        for ep in names:
            fn, make_args = self.entrypoints[ep]
            args, kwargs, jit_kwargs = make_args(mesh)
            with mesh, shd.use_rules(rules, mesh), hooks.use(binding):
                artifacts[ep] = compiler.deploy(
                    fn,
                    f"{self.name}/{ep}",
                    profile,
                    args=args,
                    kwargs=kwargs,
                    jit_kwargs=jit_kwargs,
                    store=store,
                    store_extra={"tiers": binding.tier_fingerprint()},
                )
        dep = Deployment(
            container=self,
            profile=profile,
            mesh=mesh,
            binding=binding,
            rules=rules,
            artifacts=artifacts,
        )
        self.meta.setdefault("specialization", {})[profile.name] = dep.manifest()
        return dep
