"""Invocation layer — FaaS lifted to long-running parallel jobs (rFaaS-style
leases, paper ref [6]).

The paper's Invocation principle: keep FaaS's fine-grained, transactional
invocation (and its billing + scale-to-zero) while "allowing much longer
runtimes and large parallel jobs". The concrete mechanism it cites is rFaaS:
*leases* on accelerator resources, acquired through the control plane, with
the data plane going direct (RDMA there; compiled XLA programs here — REST
never on the data path).

``InvocationService`` is that control plane:

  * ``acquire(tenant, chips, ...)`` -> Lease: backed by a scheduler job
    (INTERACTIVE for FaaS-style invokes, SERVICE for run-forever
    deployments). The lease pins a deployed container on a chip allocation.
  * ``invoke(lease, entrypoint, *args)``: executes the compiled artifact on
    the data plane and meters the execution into the tenant's ledger. Wall
    time is *modeled* from the artifact's roofline terms when we are not on
    real hardware (this container is CPU-only), measured otherwise —
    same code path, one flag.
  * ``release(lease)``: scale-to-zero. Warm artifacts stay in the
    deployment cache (the paper's container-reuse/warm-start story), so a
    re-acquire skips compilation: cold vs warm invoke latency is a benched
    claim (benchmarks/invocation_overhead.py).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Callable

from repro.core import accounting, container as xcontainer, recompile, scheduler

__all__ = ["Lease", "InvocationService", "ServingExecutor", "model_step_time"]


def model_step_time(artifact: recompile.CompiledArtifact) -> float:
    """Roofline-modeled per-step wall time for one chip (seconds).

    max(compute, memory, collective) — the standard overlap-optimistic
    roofline estimate; used to meter simulated invocations on CPU and as
    the scheduler's runtime estimate.
    """
    p = artifact.profile
    comp = artifact.flops / p.peak_flops
    mem = artifact.hbm_bytes / p.hbm_bw
    coll = artifact.collectives()["total"] / max(p.ici_bw * p.ici_links, 1.0)
    return max(comp, mem, coll, 1e-9)


@dataclasses.dataclass
class Lease:
    lease_id: int
    tenant: str
    job: scheduler.Job
    deployment: xcontainer.Deployment
    created_s: float
    # lease class within the tenant's fleet: "serve" for monolithic
    # replicas, "prefill"/"decode" for phase-specialized pool leases — the
    # rFaaS-style heterogeneous-pool allocation tag (docs/disaggregation.md)
    pool: str = "serve"
    active: bool = True

    @property
    def chips(self) -> int:
        return self.job.granted_chips


class InvocationService:
    """Control plane binding scheduler + deployments + metering."""

    def __init__(
        self,
        cluster: scheduler.Cluster,
        meter: accounting.Meter | None = None,
        *,
        measure_wall_time: bool = False,
    ):
        self.cluster = cluster
        self.meter = meter or accounting.Meter()
        self.measure = measure_wall_time
        self._leases: dict[int, Lease] = {}
        self._seq = itertools.count(1)
        # deployment cache: (container name, profile fingerprint) -> Deployment
        self._warm: dict[tuple[str, str], xcontainer.Deployment] = {}
        self.stats = {"cold_acquires": 0, "warm_acquires": 0, "invocations": 0}

    # ------------------------------------------------------------------
    def acquire(
        self,
        tenant: str,
        cont: xcontainer.XContainer,
        profile: recompile.SystemProfile,
        *,
        mesh=None,
        runtime_s: float = 3600.0,
        klass: scheduler.JobClass = scheduler.JobClass.INTERACTIVE,
        entrypoints: list[str] | None = None,
        pool: str = "serve",
    ) -> Lease:
        """Acquire a lease: schedule chips, deploy (or warm-reuse) the
        container."""
        job = self.cluster.submit(
            tenant=tenant, chips=profile.chips, runtime_s=runtime_s, klass=klass)
        self.cluster.run(until=self.cluster.now)  # process the submit event
        key = (cont.name, profile.fingerprint())
        dep = self._warm.get(key)
        if dep is None:
            dep = cont.deploy(profile, mesh=mesh, entrypoints=entrypoints)
            self._warm[key] = dep
            self.stats["cold_acquires"] += 1
        else:
            self.stats["warm_acquires"] += 1
        lease = Lease(
            lease_id=next(self._seq),
            tenant=tenant,
            job=job,
            deployment=dep,
            created_s=self.cluster.now,
            pool=pool,
        )
        self._leases[lease.lease_id] = lease
        return lease

    def invoke(self, lease: Lease, entrypoint: str, *args, steps: int = 1, **kwargs) -> Any:
        """Data-plane execution + metering. Returns the program's outputs."""
        if not lease.active:
            raise RuntimeError(f"lease {lease.lease_id} is released")
        art = lease.deployment.artifact(entrypoint)
        out = None
        if self.measure:
            t0 = time.perf_counter()
            for _ in range(steps):
                out = art(*args, **kwargs)
            wall = time.perf_counter() - t0
        else:
            out = art(*args, **kwargs) if args or kwargs else None
            wall = model_step_time(art) * steps
        self.meter.record(
            tenant=lease.tenant,
            kind=entrypoint,
            steps=steps,
            chips=art.profile.chips,
            wall_s=wall,
            artifact=art,
            job_id=f"lease-{lease.lease_id}",
        )
        self.stats["invocations"] += 1
        return out

    def acquire_serving(
        self,
        tenant: str,
        cont: xcontainer.XContainer,
        profile: recompile.SystemProfile,
        *,
        mesh=None,
        runtime_s: float = 3600.0,
        tenant_of: Callable[[int], str] | None = None,
        pool: str = "serve",
    ) -> "ServingExecutor":
        """Acquire a SERVICE-class lease whose deployment boots a serving
        engine (build ``cont`` with ``repro.serving.service.serving_container``).

        The lease pins the chip allocation for the engine's lifetime (the
        paper's long-lived high-performance allocation); the engine
        multiplexes fine-grained requests onto it, and every served token is
        metered into the tenant's ledger via the returned executor.
        """
        factory = cont.meta.get("engine_factory")
        if factory is None:
            raise ValueError(
                f"container {cont.name!r} has no meta['engine_factory']; "
                "build it with repro.serving.service.serving_container")
        lease = self.acquire(
            tenant, cont, profile, mesh=mesh, runtime_s=runtime_s,
            klass=scheduler.JobClass.SERVICE, pool=pool)
        engine = factory(lease.deployment)
        return ServingExecutor(service=self, lease=lease, engine=engine,
                               tenant_of=tenant_of)

    def release(self, lease: Lease) -> None:
        """Scale to zero: free the chips; keep the warm artifact cached."""
        if lease.active:
            lease.active = False
            self.cluster.cancel(lease.job.job_id)
            self.cluster.run(until=self.cluster.now)
            # the lease's chips MUST be back in the free pool (or already
            # re-granted to a queued job by the schedule pass) — a lease that
            # releases without its job letting go of chips is a chip leak
            assert lease.job.granted_chips == 0, (
                f"lease {lease.lease_id}: job {lease.job.job_id} still holds "
                f"{lease.job.granted_chips} chips after release")
            self.cluster.check_invariants()

    # ------------------------------------------------------------------
    def active_leases(self, tenant: str | None = None,
                      pool: str | None = None) -> list[Lease]:
        return [
            l for l in self._leases.values()
            if l.active and (tenant is None or l.tenant == tenant)
            and (pool is None or l.pool == pool)
        ]


class ServingExecutor:
    """Serving data plane bound to a SERVICE lease.

    Wraps the ``ServingEngine`` booted from the lease's deployment. Requests
    flow through the lease (``submit`` / ``run``); the hot loop inside the
    engine stays one fused compiled program — the control plane never touches
    the data path. After each drain, the delta of decode steps and served
    tokens is metered into the tenant's ledger:

      * ``serve_decode``: decode-step executions, billed with FLOPs/bytes
        from the deployment's compiled ``decode`` artifact (the same
        compiled-truth rule the rest of accounting follows).
      * ``serve_spec_verify``: replaces ``serve_decode`` on speculative
        engines — billed per decode-equivalent *position* verified (k+1
        per speculative step), so drafted-but-REJECTED work is still on the
        lease holder's bill: the tenant pays for the compute the proposer
        gambled, and the per-tenant token ledger still reconciles because
        ``serve_tokens`` only ever counts emitted tokens.
      * ``serve_tokens``: the per-token usage line (the FaaS billing quantum
        lifted to continuous batching) — queryable via
        ``Meter.served_tokens(tenant)``.

    Multi-tenant fleets set ``tenant_of`` (request_id -> tenant): decode
    steps stay billed to the lease holder (the fleet operator pays for the
    chips), while each served token is attributed to the tenant whose request
    produced it — so per-tenant totals reconcile across replicas.

    The executor is a context manager: ``with service.acquire_serving(...)
    as ex: ...`` releases the lease on exit even on error, so chips always
    return to the cluster free pool.
    """

    def __init__(self, service: InvocationService, lease: Lease, engine: Any,
                 tenant_of: Callable[[int], str] | None = None):
        self.service = service
        self.lease = lease
        self.engine = engine
        self.tenant_of = tenant_of
        self._tokens_billed: dict[int, int] = {}  # request_id -> tokens billed
        self._metered_steps = 0
        self._metered_positions = 0  # speculative verify positions billed
        self._metered_prefill = 0    # prefill token-positions billed

    def warmup(self) -> dict | None:
        """Pre-compile the engine's data-plane programs (warm-start).
        Returns the deployment's specialization manifest (chosen kernel tier
        per accelerated API), which the engine also logs."""
        return self.engine.warmup()

    def submit(self, request) -> None:
        if not self.lease.active:
            raise RuntimeError(f"lease {self.lease.lease_id} is released")
        self.engine.submit(request)

    def step(self) -> int:
        """One engine iteration through the lease (the fleet tick path;
        ``run`` remains the drain-to-completion path). Returns the number of
        host-visible active slots. Call ``meter_flush`` periodically to bill
        the accumulated delta."""
        if not self.lease.active:
            raise RuntimeError(f"lease {self.lease.lease_id} is released")
        return self.engine.step()

    def run(self, max_steps: int = 10_000) -> dict:
        """Drain the engine and meter the usage delta. Returns the engine's
        request_id -> RequestResult map (cumulative across runs)."""
        if not self.lease.active:
            raise RuntimeError(f"lease {self.lease.lease_id} is released")
        t0 = time.perf_counter()
        results = self.engine.run_to_completion(max_steps=max_steps)
        wall = time.perf_counter() - t0
        self._meter(wall)
        return results

    @property
    def unserved(self) -> int:
        return self.engine.stats.get("unserved", 0)

    def meter_flush(self, wall_s: float = 0.0) -> None:
        """Bill the usage delta since the last flush (decode steps to the
        lease holder, served tokens to each originating tenant). The fleet
        calls this on its own cadence with virtual wall time; ``run`` calls
        it with the measured drain wall time."""
        self._meter(wall_s)

    def _meter(self, wall_s: float) -> None:
        try:
            art = self.lease.deployment.artifact("decode")
        except KeyError:
            art = None
        steps = self.engine.stats["decode_steps"] - self._metered_steps
        job_id = f"lease-{self.lease.lease_id}"
        # prefill FLOPs on their own ledger line: a disaggregated fleet runs
        # prefill and decode on DIFFERENT pools' leases, so the bill must
        # show which pool's chips did which phase's work. Billed per padded
        # prefill token-position at the decode artifact's per-position cost
        # (one prefill position runs the same layer stack as one decode
        # step), with its own modeled wall — the flush-window wall_s stays
        # on the decode/verify line, so phases never double-bill one window.
        ptoks = self.engine.stats.get("prefill_tokens", 0) - self._metered_prefill
        if ptoks > 0:
            self.service.meter.record(
                tenant=self.lease.tenant, kind="serve_prefill", steps=ptoks,
                chips=self.lease.chips,
                wall_s=(model_step_time(art) * ptoks if art is not None else 0.0),
                artifact=art, job_id=job_id)
            self._metered_prefill += ptoks
        speculating = getattr(self.engine, "spec", None) is not None
        if speculating:
            # bill decode-equivalent verified POSITIONS, not program steps:
            # each speculative step runs k+1 positions' worth of target
            # compute, and the rejected share is real FLOPs the lease
            # gambled — it must land on the bill even though serve_tokens
            # never counts it
            positions = (self.engine.stats["spec_positions"]
                         - self._metered_positions)
            if positions > 0:
                if wall_s <= 0.0 and art is not None:
                    wall_s = model_step_time(art) * positions
                self.service.meter.record(
                    tenant=self.lease.tenant, kind="serve_spec_verify",
                    steps=positions, chips=self.lease.chips, wall_s=wall_s,
                    artifact=art, job_id=job_id)
                self._metered_positions += positions
            self._metered_steps = self.engine.stats["decode_steps"]
        elif steps > 0:
            if wall_s <= 0.0 and art is not None:
                # shutdown-path flush with no measured window: bill the
                # delta at the roofline-modeled step time (same rule as
                # `invoke` on simulated hardware) instead of zero chip-time
                wall_s = model_step_time(art) * steps
            self.service.meter.record(
                tenant=self.lease.tenant, kind="serve_decode", steps=steps,
                chips=self.lease.chips, wall_s=wall_s, artifact=art,
                job_id=job_id)
            self._metered_steps += steps
        # per-request token deltas, grouped by originating tenant (the lease
        # holder when no tenant_of map is installed)
        deltas: dict[str, int] = {}
        for rid, res in self.engine.results.items():
            served = len(res.tokens)
            billed = self._tokens_billed.get(rid, 0)
            if served > billed:
                tenant = self.tenant_of(rid) if self.tenant_of else self.lease.tenant
                deltas[tenant] = deltas.get(tenant, 0) + served - billed
                self._tokens_billed[rid] = served
        for tenant, tokens in sorted(deltas.items()):
            # pure usage-count line: wall already billed on the decode line
            self.service.meter.record(
                tenant=tenant, kind="serve_tokens", steps=tokens,
                chips=self.lease.chips, wall_s=0.0, job_id=job_id)
        self.service.stats["invocations"] += 1

    def release(self) -> None:
        """Scale to zero; the warm deployment stays cached for re-acquire.
        Any unbilled served tokens are flushed first so the ledger never
        loses usage on shutdown."""
        if self.lease.active:
            self.meter_flush()
        self.service.release(self.lease)

    def __enter__(self) -> "ServingExecutor":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.release()
