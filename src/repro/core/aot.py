"""Persistent AOT specialization: serialize compiled data-plane programs.

The XaaS container story (and the follow-up "XaaS containers" source+IR
design) wants a container to carry enough specialization state to boot at
native speed on a target it has seen before — without re-tracing and
re-compiling every program in a fresh process. jax's AOT path makes that
possible: a jitted function lowered+compiled for concrete avals yields an
executable that ``jax.experimental.serialize_executable`` can turn into
bytes and load back in another process on the same platform/version.

This module is the plumbing under the engine's boot ladder:

* :func:`serialize_compiled` / :func:`deserialize_compiled` — bytes <->
  ``Compiled`` (payload + in/out pytree defs, pickled together);
* :func:`runtime_fingerprint` — the jax/jaxlib/platform triple every
  artifact key embeds (a version or backend change must invalidate);
* :func:`bundle_key` / :func:`canonical_fields` — stable content key over
  the cfg x geometry x kernel-tier x spec fields of a program bundle;
* :class:`AotProgram` — a drop-in callable replacing a bare ``jax.jit``
  function: it fingerprints call shapes, memoizes one executable per
  fingerprint (compiling on miss), and accepts pre-built executables
  *installed* from a store (the IR-boot rung);
* :class:`AotRegistry` — the per-bundle collection of AotPrograms with
  whole-bundle export/install and compile accounting;
* :func:`explain_mismatch` — human-readable reasons why a store held no
  artifact for the current bundle (stale tier, bumped jax version, ...),
  mirroring how probe-tier rejections are recorded in the manifest.
"""
from __future__ import annotations

import hashlib
import json
import logging
import pickle
import time
from typing import Any, Callable, Mapping

import jax

logger = logging.getLogger(__name__)

__all__ = [
    "AOT_AVAILABLE", "AotProgram", "AotRegistry", "bundle_key",
    "canonical_fields", "deserialize_compiled", "explain_mismatch",
    "runtime_fingerprint", "serialize_compiled",
]

try:  # jax >= 0.4.x ships this under experimental; gate rather than require
    from jax.experimental import serialize_executable as _sx
    AOT_AVAILABLE = True
except ImportError:  # pragma: no cover - every pinned env has it
    _sx = None
    AOT_AVAILABLE = False


def serialize_compiled(compiled) -> bytes:
    """A ``Compiled`` (from ``jit_fn.lower(...).compile()``) -> bytes."""
    if not AOT_AVAILABLE:
        raise RuntimeError("jax.experimental.serialize_executable unavailable")
    payload, in_tree, out_tree = _sx.serialize(compiled)
    return pickle.dumps((payload, in_tree, out_tree))


def deserialize_compiled(data: bytes):
    """bytes -> a callable ``Compiled`` (raises on any malformed input)."""
    if not AOT_AVAILABLE:
        raise RuntimeError("jax.experimental.serialize_executable unavailable")
    payload, in_tree, out_tree = pickle.loads(data)
    return _sx.deserialize_and_load(payload, in_tree, out_tree)


def runtime_fingerprint() -> dict[str, str]:
    """The environment fields baked into every artifact key. A serialized
    XLA executable is only valid on the jax/jaxlib version and backend that
    produced it — any drift must miss the key and fall through to
    cold-boot. Module-level on purpose: tests monkeypatch this to simulate
    a version bump without reinstalling jax."""
    try:
        import jaxlib
        jaxlib_v = getattr(jaxlib, "__version__", jax.__version__)
    except ImportError:  # pragma: no cover
        jaxlib_v = jax.__version__
    return {
        "jax": jax.__version__,
        "jaxlib": jaxlib_v,
        "platform": jax.default_backend(),
    }


def canonical_fields(fields: Mapping[str, Any]) -> dict[str, str]:
    """Canonical (all-string) record of a bundle's identity fields plus the
    runtime fingerprint — what gets hashed into the key AND stored in the
    artifact's meta so a miss can be *explained* field by field."""
    rec = {str(k): repr(v) for k, v in fields.items()}
    rec.update(runtime_fingerprint())
    return rec


def bundle_key(fields: Mapping[str, Any]) -> str:
    """Content key for one program bundle: cfg x geometry x tier x spec
    fields (caller-supplied) x jax/jaxlib version x platform."""
    blob = json.dumps(canonical_fields(fields), sort_keys=True)
    return "aot-" + hashlib.sha256(blob.encode()).hexdigest()[:20]


def explain_mismatch(store, fields: Mapping[str, Any]) -> list[str]:
    """Why did ``store`` hold nothing for this bundle? Diff the current
    canonical fields against every stored artifact of the same family and
    report the differing fields — the boot ladder records these in the
    manifest the way probe rejections are recorded per tier."""
    want = canonical_fields(fields)
    reasons = []
    for key in store.keys():
        meta = store.meta(key)
        have = (meta or {}).get("fields")
        if not isinstance(have, dict):
            continue
        if have.get("family") != want.get("family"):
            continue
        # geometry/environment fields first: with >4 drifted fields the
        # truncation below must never hide "this executable was compiled
        # for a different mesh" behind cosmetic knob diffs — a wrong-mesh
        # install is the one the operator has to see
        front = ("mesh", "tiers", "jax", "jaxlib", "platform")
        keys = sorted(set(have) | set(want),
                      key=lambda k: (front.index(k) if k in front
                                     else len(front), k))
        diffs = [
            f"{k}: stored {have.get(k)} != current {want.get(k)}"
            for k in keys
            if have.get(k) != want.get(k)
        ]
        if diffs:
            reasons.append(f"stale artifact {key}: " + "; ".join(diffs[:4]))
    return reasons


class AotProgram:
    """One data-plane program behind a shape-fingerprint dispatch table.

    Wraps an (already ``jax.jit``-ed) function. Each call fingerprints the
    argument avals (shape/dtype/weak-type per leaf, pytree structure, python
    scalars by type, static args by repr) and dispatches to the compiled
    executable for that fingerprint — compiling via ``lower().compile()``
    on first sight. Executables restored from an artifact store are
    *installed* under their fingerprint and serve the same calls without
    any trace: that is the IR-boot rung.

    An installed executable that rejects the live call (aval drift the key
    failed to capture) is dropped and the call re-traces in place — the
    ladder's safety net: a stale artifact can cost a compile, never an
    error.
    """

    def __init__(self, name: str, jit_fn: Callable, *,
                 static_argnums: tuple[int, ...] = ()):
        self.name = name
        self.jit_fn = jit_fn
        self.static_argnums = frozenset(static_argnums)
        self.exes: dict[str, Any] = {}
        self.installed: set[str] = set()
        self.stats = {"compiles": 0, "installs": 0, "exe_hits": 0,
                      "fallbacks": 0}
        self.compile_s = 0.0

    # -- identity ------------------------------------------------------
    def signature(self, args) -> str:
        parts = []
        for i, a in enumerate(args):
            if i in self.static_argnums:
                parts.append(f"s{i}={a!r}")
                continue
            leaves, treedef = jax.tree_util.tree_flatten(a)
            sig = []
            for leaf in leaves:
                if isinstance(leaf, (bool, int, float, complex)) and type(
                        leaf) in (bool, int, float, complex):
                    # python scalars trace weak-typed; fingerprint by type
                    sig.append(f"py:{type(leaf).__name__}")
                else:
                    shape = tuple(getattr(leaf, "shape", ()))
                    dtype = getattr(leaf, "dtype", type(leaf).__name__)
                    weak = bool(getattr(leaf, "weak_type", False))
                    sig.append(f"{shape}:{dtype}:{int(weak)}")
            parts.append(f"a{i}={treedef}|{';'.join(sig)}")
        return hashlib.sha1("&".join(parts).encode()).hexdigest()[:16]

    # -- dispatch ------------------------------------------------------
    def _compile(self, args):
        t0 = time.perf_counter()
        exe = self.jit_fn.lower(*args).compile()
        self.compile_s += time.perf_counter() - t0
        self.stats["compiles"] += 1
        return exe

    def __call__(self, *args):
        fp = self.signature(args)
        exe = self.exes.get(fp)
        if exe is None:
            exe = self.exes[fp] = self._compile(args)
        else:
            self.stats["exe_hits"] += 1
        # executables compiled with static_argnums are called WITHOUT the
        # static args (they are baked into the trace)
        dyn = tuple(a for i, a in enumerate(args)
                    if i not in self.static_argnums)
        if fp in self.installed:
            try:
                return exe(*dyn)
            except Exception as err:
                # stale installed executable: drop to the cold rung for this
                # fingerprint only; a bad artifact never takes serving down
                logger.warning("aot %s@%s: installed executable rejected the "
                               "call (%s); re-tracing", self.name, fp, err)
                self.installed.discard(fp)
                self.stats["fallbacks"] += 1
                exe = self.exes[fp] = self._compile(args)
        return exe(*dyn)

    # -- persistence ---------------------------------------------------
    def export(self) -> dict[str, bytes]:
        """``{"name@fingerprint": bytes}`` for every serializable exe."""
        out = {}
        for fp, exe in self.exes.items():
            try:
                out[f"{self.name}@{fp}"] = serialize_compiled(exe)
            except Exception as err:  # non-serializable backend/exe: skip
                logger.debug("aot export skipped %s@%s: %s",
                             self.name, fp, err)
        return out

    def install(self, fp: str, blob: bytes) -> None:
        self.exes[fp] = deserialize_compiled(blob)
        self.installed.add(fp)
        self.stats["installs"] += 1


class AotRegistry:
    """All AotPrograms of one program bundle (one ``_Programs`` /
    ``_PagedPrograms`` instance): whole-bundle export to / install from an
    artifact store, plus the compile accounting the boot manifest reports.

    Blobs installed before their program is wrapped (construction order is
    not load order) wait in ``pending`` and attach at ``wrap()`` time.
    """

    def __init__(self):
        self.programs: dict[str, AotProgram] = {}
        self._pending: dict[str, bytes] = {}

    def wrap(self, name: str, jit_fn: Callable, *,
             static_argnums: tuple[int, ...] = ()) -> AotProgram:
        prog = self.programs.get(name)
        if prog is None:
            prog = self.programs[name] = AotProgram(
                name, jit_fn, static_argnums=static_argnums)
            for key in [k for k in self._pending if
                        k.rpartition("@")[0] == name]:
                blob = self._pending.pop(key)
                try:
                    prog.install(key.rpartition("@")[2], blob)
                except Exception as err:
                    logger.warning("aot deferred install %s failed: %s",
                                   key, err)
        return prog

    # -- persistence ---------------------------------------------------
    def export(self) -> dict[str, bytes]:
        blobs = {}
        for prog in self.programs.values():
            blobs.update(prog.export())
        return blobs

    def install(self, blobs: Mapping[str, bytes]) -> tuple[int, list[str]]:
        """Install ``{"name@fp": bytes}``; returns (installed, errors).
        Unknown program names are parked for later ``wrap()`` calls."""
        installed, errors = 0, []
        for key, blob in blobs.items():
            name, _, fp = key.rpartition("@")
            prog = self.programs.get(name)
            if prog is None:
                self._pending[key] = blob
                installed += 1  # counts as installed: attaches at wrap()
                continue
            try:
                prog.install(fp, blob)
                installed += 1
            except Exception as err:
                errors.append(f"{key}: {type(err).__name__}: {err}")
        return installed, errors

    # -- accounting ----------------------------------------------------
    def compiled_count(self) -> int:
        """Executables present (compiled or installed) — nonzero means the
        bundle is warm in-process."""
        return (sum(len(p.exes) for p in self.programs.values())
                + len(self._pending))

    def compile_count(self) -> int:
        return sum(p.stats["compiles"] for p in self.programs.values())

    def counts(self) -> dict[str, int]:
        out = {"programs": len(self.programs), "executables": 0,
               "compiled": 0, "installed": 0, "exe_hits": 0, "fallbacks": 0}
        for p in self.programs.values():
            out["executables"] += len(p.exes)
            out["compiled"] += p.stats["compiles"]
            out["installed"] += p.stats["installs"]
            out["exe_hits"] += p.stats["exe_hits"]
            out["fallbacks"] += p.stats["fallbacks"]
        return out

    def compile_seconds(self) -> float:
        return sum(p.compile_s for p in self.programs.values())
