"""Fault tolerance: failure detection/injection, elastic restart, straggler
mitigation — the runtime half of the paper's reliability story.

Paper context: HPC tolerates failures via checkpoint/restart; cloud engineers
for availability. XaaS needs both: long-running parallel jobs (HPC mode) on
infrastructure whose per-node failure rate at 1000+ nodes makes faults
routine, serving users who expect availability (cloud mode).

Components:

  * ``FailureInjector`` — deterministic simulated fault source (this
    container has one real device; the *control flow* is what we exercise).
    Poisson node failures + heavy-tailed straggler step times, seeded.
  * ``StragglerPolicy`` — step-time watchdog: an EWMA baseline; steps slower
    than `threshold ×` baseline mark the step's slowest replica; `grace`
    consecutive marks trigger mitigation (drop-replica = shrink, or
    re-dispatch). This models the bulk-synchronous straggler problem the
    paper's AI-training convergence case hits.
  * ``FTManager`` — wraps a train loop: catches failure events, consults the
    scheduler for the surviving allocation, re-meshes (possibly smaller),
    restores the last committed checkpoint onto the new topology (elastic),
    and resumes from the exact data step (pipeline determinism guarantees
    no sample loss/replay).

The same FTManager drives real deployments: `inject=None` and real exceptions
(XLA device errors) become the failure events.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

__all__ = ["FailureInjector", "FailureEvent", "StragglerPolicy", "FTManager",
           "RunReport"]


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    step: int
    kind: str  # "node_loss" | "straggler"
    detail: str = ""


class FailureInjector:
    """Seeded fault model: per-step node-loss probability + lognormal
    straggler tail on step time."""

    def __init__(self, *, seed: int = 0, p_node_loss: float = 0.0,
                 straggler_p: float = 0.0, straggler_mult: float = 4.0,
                 base_step_s: float = 1.0):
        self.rng = np.random.default_rng(seed)
        self.p_node_loss = p_node_loss
        self.straggler_p = straggler_p
        self.straggler_mult = straggler_mult
        self.base_step_s = base_step_s

    def step_time(self, step: int) -> tuple[float, bool]:
        """-> (simulated step seconds, is_straggler)."""
        t = self.base_step_s * float(self.rng.lognormal(0.0, 0.05))
        if self.rng.random() < self.straggler_p:
            return t * self.straggler_mult, True
        return t, False

    def node_fails(self, step: int) -> bool:
        return self.rng.random() < self.p_node_loss


@dataclasses.dataclass
class StragglerPolicy:
    threshold: float = 2.0  # × EWMA baseline
    grace: int = 2  # consecutive slow steps before mitigation
    ewma: float = 0.1

    _baseline: float = dataclasses.field(default=0.0, init=False)
    _slow_run: int = dataclasses.field(default=0, init=False)

    def observe(self, step_s: float) -> str | None:
        """Feed one step time; returns a mitigation action or None."""
        if self._baseline == 0.0:
            self._baseline = step_s
            return None
        slow = step_s > self.threshold * self._baseline
        # baseline learns only from non-outlier steps (else stragglers
        # poison the reference)
        if not slow:
            self._baseline = (1 - self.ewma) * self._baseline + self.ewma * step_s
            self._slow_run = 0
            return None
        self._slow_run += 1
        if self._slow_run >= self.grace:
            self._slow_run = 0
            return "mitigate"
        return None


@dataclasses.dataclass
class RunReport:
    steps_done: int
    restarts: int
    mitigations: int
    sim_time_s: float
    events: list[FailureEvent]
    final_metrics: dict


class FTManager:
    """Drives a fault-tolerant training run.

    Collaborators (all injected, so tests can fake any of them):
      make_step(mesh_size) -> (step_fn, state, start_data_step): builds the
          (possibly re-meshed) training callables after (re)start, restoring
          from the checkpoint store;
      save(state, step): checkpoint hook (called every `ckpt_every`);
      injector: fault source; policy: straggler watchdog.
    """

    def __init__(self, *, make_step: Callable, save: Callable,
                 injector: FailureInjector | None = None,
                 policy: StragglerPolicy | None = None,
                 ckpt_every: int = 10,
                 min_mesh: int = 1):
        self.make_step = make_step
        self.save = save
        self.injector = injector or FailureInjector()
        self.policy = policy or StragglerPolicy()
        self.ckpt_every = ckpt_every
        self.min_mesh = min_mesh

    # ---- scheduler-preemption surface (used by the serving fleet) ----
    def checkpoint(self, state: Any, step: int) -> int:
        """Commit a checkpoint outside the periodic cadence. The scheduler's
        graceful-preemption window (``Cluster.preempt`` fires listeners before
        taking the chips) calls this so a BATCH job loses no progress when an
        interactive scale-up evicts it. Returns the committed step."""
        self.save(state, step)
        return step

    def resume(self, mesh_size: int):
        """Rebuild (step_fn, state, data_step) from the last committed
        checkpoint — the restart path shared by node failures and
        preemption-requeue."""
        return self.make_step(mesh_size)

    def run(self, total_steps: int, *, mesh_size: int) -> RunReport:
        events: list[FailureEvent] = []
        restarts = mitigations = 0
        sim_time = 0.0
        step_fn, state, data_step = self.make_step(mesh_size)
        metrics: dict = {}
        while data_step < total_steps:
            # --- simulated fault plane ---
            if self.injector.node_fails(data_step):
                events.append(FailureEvent(data_step, "node_loss"))
                restarts += 1
                # elastic shrink: lose one node-equivalent, keep >= min_mesh
                mesh_size = max(self.min_mesh, mesh_size - 1)
                sim_time += 30.0  # restart cost (detection+re-mesh+restore)
                step_fn, state, data_step = self.make_step(mesh_size)
                continue
            dt, straggled = self.injector.step_time(data_step)
            action = self.policy.observe(dt)
            if straggled:
                events.append(FailureEvent(data_step, "straggler", f"{dt:.2f}s"))
            if action == "mitigate":
                mitigations += 1
                # drop-slowest-replica: shrink by one, no restore needed for
                # pure-DP replicas (grads are re-balanced next step); we
                # model it as a cheap re-mesh.
                if mesh_size > self.min_mesh:
                    mesh_size -= 1
                    sim_time += 5.0
                    step_fn, state, data_step = self.make_step(mesh_size)
                    continue
            # --- real compute plane ---
            state, metrics = step_fn(state, data_step)
            sim_time += dt
            data_step += 1
            if data_step % self.ckpt_every == 0:
                self.save(state, data_step)
        self.save(state, data_step)
        return RunReport(
            steps_done=data_step,
            restarts=restarts,
            mitigations=mitigations,
            sim_time_s=sim_time,
            events=events,
            final_metrics=metrics,
        )
