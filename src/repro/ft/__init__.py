"""Fault tolerance: failure injection, elastic restart, straggler policy."""
from repro.ft.manager import FailureInjector, FTManager, StragglerPolicy  # noqa: F401
