"""Data substrate: synthetic LM pipeline, host sharding, prefetch."""
from repro.data import pipeline  # noqa: F401
