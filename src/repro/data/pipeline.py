"""Data pipeline: deterministic synthetic LM streams, host-sharded loading,
and background prefetch.

Synthetic-but-structured data (zipf-distributed tokens with a first-order
Markov mixture) gives the training loop a learnable signal without external
datasets (the container is offline). The pipeline is *seeded by (stream,
step, host)*, so:

  * restart determinism: resuming from step k reproduces batch k exactly —
    checkpoint/restart never replays or skips data (the FT invariant
    tests/test_checkpoint.py asserts);
  * host sharding: each host materializes only its slice of the global
    batch (`host_slice`), the multi-host pattern on a real pod;
  * elastic re-shard: the global batch for step k is independent of host
    count, so a restart on fewer hosts sees the same token stream.

Audio archs get (B, K, S) codebook tokens with the MusicGen delay pattern
applied; VLM archs get a synthetic patch-embedding tensor alongside text.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np

from repro.models import frontends

__all__ = ["DataConfig", "SyntheticLM", "Prefetcher", "make_batch_specs"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    zipf_a: float = 1.3
    markov_k: int = 8  # periodic copy structure: token[t] depends on t-k
    # modality
    num_codebooks: int = 0  # >0 -> audio (B, K, S)
    num_image_tokens: int = 0  # >0 -> vlm patch embeds supplied
    vis_dim: int = frontends.VIS_DIM


class SyntheticLM:
    """Deterministic per-step synthetic batches."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # zipf over vocab, renormalized (static, shared by all steps)
        c = self.cfg
        ranks = np.arange(1, c.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-c.zipf_a)
        self._p = p / p.sum()

    def _rng(self, step: int, lane: int = 0) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, lane]))

    def _tokens(self, step: int, rows: int, lane: int = 0) -> np.ndarray:
        """(rows, S+1): zipf draws with every k-th position copied from t-k
        (learnable structure: a model that discovers the copy rule beats the
        unigram entropy floor)."""
        c = self.cfg
        rng = self._rng(step, lane)
        toks = rng.choice(c.vocab_size, size=(rows, c.seq_len + 1), p=self._p)
        k = c.markov_k
        if k > 0 and c.seq_len + 1 > k:
            idx = np.arange(k, c.seq_len + 1)
            copy_mask = (idx % k) == 0
            toks[:, idx[copy_mask]] = toks[:, idx[copy_mask] - k]
        return toks.astype(np.int32)

    def batch(self, step: int, *, host_id: int = 0, num_hosts: int = 1) -> dict:
        """Materialize this host's slice of global batch `step`."""
        c = self.cfg
        assert c.global_batch % num_hosts == 0
        rows = c.global_batch // num_hosts
        if c.num_codebooks:
            planes = [
                self._tokens(step, rows, lane=host_id * c.num_codebooks + j)
                for j in range(c.num_codebooks)
            ]
            t = np.stack(planes, axis=1)  # (rows, K, S+1)
            t = _delay_pattern(t)
            return {"tokens": t[..., :-1], "labels": t[..., 1:]}
        t = self._tokens(step, rows, lane=host_id)
        out = {"tokens": t[:, :-1], "labels": t[:, 1:]}
        if c.num_image_tokens:
            rng = self._rng(step, lane=10_000 + host_id)
            out["patch_embeds"] = rng.standard_normal(
                (rows, c.num_image_tokens, c.vis_dim), dtype=np.float32)
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def _delay_pattern(t: np.ndarray) -> np.ndarray:
    """MusicGen delay pattern: codebook j is shifted right by j steps so the
    model predicts codebook j at time t given codebooks < j at time t.
    t: (B, K, S). Pad slots get 0 (treated as a special token)."""
    b, k, s = t.shape
    out = np.zeros_like(t)
    for j in range(k):
        out[:, j, j:] = t[:, j, : s - j]
    return out


class Prefetcher:
    """Background-thread prefetch: hides host data-gen under device compute
    (the I/O half of the paper's communication/compute overlap, on the data
    path into the container)."""

    def __init__(self, source: SyntheticLM, *, start_step: int = 0, depth: int = 2,
                 host_id: int = 0, num_hosts: int = 1):
        self._src = source
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._host = (host_id, num_hosts)
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        hid, nh = self._host
        while not self._stop.is_set():
            b = self._src.batch(step, host_id=hid, num_hosts=nh)
            while not self._stop.is_set():
                try:
                    self._q.put((step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)


def make_batch_specs(cfg, shape, *, dtype="int32"):
    """ShapeDtypeStructs for a train batch of `shape` (dry-run stand-ins)."""
    import jax
    import jax.numpy as jnp

    b, s = shape.global_batch, shape.seq_len
    tok_shape = (b, cfg.num_codebooks, s) if cfg.frontend == "audio" else (b, s)
    out = {
        "tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32),
        "labels": jax.ShapeDtypeStruct(tok_shape, jnp.int32),
    }
    if cfg.frontend == "vlm":
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_image_tokens, frontends.VIS_DIM), jnp.bfloat16)
    return out
