"""Logical-axis sharding: t5x-style rules mapping logical names to mesh axes.

Model code annotates activations with *logical* axes via ``constraint(x,
"batch", "seq", ...)`` and never mentions mesh axes; a deployment installs a
rule set (``with use_rules(RULES_2D):``) that resolves logical names to mesh
axes. Outside a rules scope the constraints are no-ops, so the same model code
runs unsharded on one CPU device — the XaaS portability floor.

Parameter sharding is path-based: ``param_pspec_tree`` walks a param pytree
and matches parameter path suffixes against PARAM_RULES (consistent layer
naming in models/ makes this total).
"""
from __future__ import annotations

import contextlib
import re
import threading

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# logical axis name -> mesh axis (str | tuple | None)
Rules = dict[str, object]

# Single-pod production mesh (16, 16) = 256 chips.
RULES_2D: Rules = {
    "batch": "data",
    "seq": None,
    "kv_seq": None,  # flipped to "model" for sequence-sharded decode recipes
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "experts": "model",
    "expert_cap": None,
    "expert_group": "data",
    # MoE dispatch/combine width (the D dim of permutation-gather buffers):
    # sharded over model so dispatch memory is O(tokens*k*D/TP) per chip
    "moe_d": "model",
    "vocab": "model",
    "embed": None,
    # parameter hidden dims (PARAM_RULES only): "data" under FSDP recipes —
    # distinct from activation "embed" so batch/data never collide
    "p_embed": None,
    # serving-state batch dim (KV caches / recurrent states) — usually the
    # same as "batch", but decode recipes may replicate activations while
    # keeping the cache batch-sharded
    "state_batch": "data",
    "lru": "model",
    "stack": None,
}

# Multi-pod mesh (pod, data, model): pure DP across pods; the expert-major
# all-to-all layout (E, B*cap, D) keeps tokens pod-local via expert_cap.
RULES_3D: Rules = dict(RULES_2D, batch=("pod", "data"),
                       state_batch=("pod", "data"),
                       expert_group=("pod", "data"), expert_cap="pod")


class _State(threading.local):
    def __init__(self):
        self.rules: Rules | None = None
        self.mesh: jax.sharding.Mesh | None = None


_STATE = _State()


@contextlib.contextmanager
def use_rules(rules: Rules | None, mesh: jax.sharding.Mesh | None = None):
    prev = (_STATE.rules, _STATE.mesh)
    _STATE.rules = rules
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.rules, _STATE.mesh = prev


def current_rules() -> Rules | None:
    return _STATE.rules


def current_mesh() -> jax.sharding.Mesh | None:
    return _STATE.mesh


def mesh_geometry(
    mesh: jax.sharding.Mesh | None,
) -> tuple[tuple[int, ...], tuple[str, ...]] | None:
    """(device-grid shape, axis names) of a mesh — the geometry fingerprint
    that keys serving program bundles and persisted AOT artifacts. None is
    the unsharded single-device floor, so adding a mesh (or changing its
    shape) re-keys every compiled program while the floor keys stay put."""
    if mesh is None:
        return None
    return (tuple(int(s) for s in mesh.devices.shape),
            tuple(str(a) for a in mesh.axis_names))


def rule_summary(rules: Rules | None) -> dict[str, str | None]:
    """JSON-able view of a rule set (tuples joined with '+') for manifests
    and the launch CLI — logical axis -> mesh axis, sorted by logical name."""
    if rules is None:
        return {}
    out: dict[str, str | None] = {}
    for name in sorted(rules):
        entry = rules[name]
        if isinstance(entry, tuple):
            out[name] = "+".join(entry)
        else:
            out[name] = entry
    return out


def _axis_size(mesh, entry) -> int:
    names = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n


def resolve(*logical: str | None) -> P:
    rules = _STATE.rules or {}
    axes = []
    for name in logical:
        if name is None:
            axes.append(None)
        else:
            axes.append(rules.get(name))
    return P(*axes)


def guarded_spec(shape: tuple[int, ...], logical: tuple[str | None, ...]) -> P:
    """Resolve logical axes -> PartitionSpec, dropping (replicating) any axis
    whose dimension is not divisible by its mesh extent, and any mesh axis
    already claimed by an earlier dim (rule sets may map two logical axes to
    one mesh axis — e.g. EP over (data, model) plus FSDP p_embed->data; the
    first/leading use wins). This is the portability guard: archs whose head
    counts etc. don't divide the fixed production mesh still compile — the
    waste shows up honestly in the roofline terms instead of as a sharding
    error."""
    spec = resolve(*logical)
    mesh = _STATE.mesh
    if mesh is None:
        return spec
    out = []
    used: set[str] = set()
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        names = tuple(entry) if isinstance(entry, tuple) else (entry,)
        # drop axes already claimed AND axes the mesh doesn't have (a rule
        # set naming "model" must still deploy on a data-only mesh)
        names = tuple(n for n in names if n not in used and n in mesh.shape)
        # tuple entries degrade by dropping trailing axes until divisible
        # (e.g. batch=256 on ("pod","data","model")=512 -> ("pod","data")=32)
        while names and dim % _axis_size(mesh, names) != 0:
            names = names[:-1]
        if not names:
            out.append(None)
        else:
            out.append(names if len(names) > 1 else names[0])
            used.update(names)
    return P(*out)


def constraint(x: jax.Array, *logical: str | None) -> jax.Array:
    """Annotate activation sharding by logical axes; no-op outside rules."""
    if _STATE.rules is None:
        return x
    spec = guarded_spec(x.shape, logical)
    if all(a is None for a in spec):
        return x
    if _STATE.mesh is not None:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(_STATE.mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Parameter sharding by path
# ---------------------------------------------------------------------------
# (regex on ".../"-joined param path, logical axes for the trailing dims).
# Later rules win; first two dims of stacked-layer params get the extra
# leading "stack" axis automatically (detected by ndim mismatch).
PARAM_RULES: list[tuple[str, tuple[str | None, ...]]] = [
    (r"embed/w$", ("vocab", "p_embed")),
    (r"codebook_embed/w$", (None, "vocab", "p_embed")),
    (r"lm_head/w$", ("p_embed", "vocab")),
    (r"codebook_head/w$", (None, "p_embed", "vocab")),
    (r"patch_proj/w$", (None, "p_embed")),
    (r"w[qkv]/w$", ("p_embed", "heads")),
    (r"w[qkv]/b$", ("heads",)),
    (r"wo/w$", ("heads", "p_embed")),
    (r"wo/b$", (None,)),
    (r"(w_gate|w_up)/w$", ("p_embed", "ff")),
    (r"w_down/w$", ("ff", "p_embed")),
    # MoE expert weights: (E, D, F) / (E, F, D)
    (r"experts/w_gate$", ("experts", "p_embed", "ff")),
    (r"experts/w_up$", ("experts", "p_embed", "ff")),
    (r"experts/w_down$", ("experts", "ff", "p_embed")),
    (r"router/w$", ("p_embed", None)),
    (r"router/bias$", (None,)),
    # MLA
    (r"w_dq/w$", ("p_embed", None)),
    (r"w_uq/w$", (None, "heads")),
    (r"w_dkv/w$", ("p_embed", None)),
    (r"w_uk/w$", (None, "heads")),
    (r"w_uv/w$", (None, "heads")),
    # RG-LRU / recurrent blocks
    (r"(lru_in|lru_gate)/w$", ("p_embed", "lru")),
    (r"lru_out/w$", ("lru", "p_embed")),
    (r"(w_a|w_x)/w$", ("lru", "lru")),  # diagonal-ish gates stay lru-sharded
    (r"rglru/(lam|b_a|b_x)$", ("lru",)),
    (r"conv/(w|b)$", (None, "lru")),
    # xLSTM
    (r"(up_proj|up_gate)/w$", ("p_embed", "ff")),
    (r"down_proj/w$", ("ff", "p_embed")),
    (r"(wq_in|wk_in|wv_in)/w$", ("lru", None, None)),  # block-diag (nb,bs,bs)
    (r"(wi_in|wf_in|wo_in)/w$", ("ff", "heads")),
    (r"(wi_in|wf_in)/b$", ("heads",)),
    (r"slstm/(wz|wi|wf|wo)/w$", ("p_embed", "heads")),
    (r"slstm/(rz|ri|rf|ro)$", ("heads", None, None)),
    (r"slstm/(bz|bi|bf|bo)$", ("heads",)),
    # norms / scalars: replicated
    (r".*", (None,)),
]

_COMPILED = [(re.compile(pat), spec) for pat, spec in PARAM_RULES]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def logical_param_axes(params) -> object:
    """Pytree of logical-axis tuples parallel to `params`."""

    def annotate(path, leaf):
        s = _path_str(path)
        for pat, spec in _COMPILED:
            if pat.search(s):
                if len(spec) < leaf.ndim:
                    spec2 = ("stack",) * (leaf.ndim - len(spec)) + tuple(spec)
                elif len(spec) > leaf.ndim:
                    spec2 = tuple(spec[-leaf.ndim:])
                else:
                    spec2 = tuple(spec)
                return spec2
        raise AssertionError(f"no param rule matched {s}")

    return jax.tree_util.tree_map_with_path(annotate, params)


def param_pspecs(params) -> object:
    """Pytree of PartitionSpec for `params` under the current rules
    (divisibility-guarded when a mesh is installed)."""
    axes = logical_param_axes(params)
    is_axes = lambda t: (
        isinstance(t, tuple) and len(t) > 0
        and all(isinstance(a, str) or a is None for a in t))
    return jax.tree.map(
        lambda a, p: guarded_spec(p.shape, a), axes, params, is_leaf=is_axes)


def param_shardings(params, mesh) -> object:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), param_pspecs(params))


# ---------------------------------------------------------------------------
# Serving-state sharding by path (KV caches, recurrent states)
# ---------------------------------------------------------------------------
# NOTE: serving state uses the "state_batch" logical axis (not "batch") so
# recipes can replicate small per-token activations (2D weight-stationary TP
# at decode) without replicating the KV cache.
STATE_RULES: list[tuple[str, tuple[str | None, ...]]] = [
    (r"(^|/)(k|v)$", ("state_batch", "kv_seq", "kv_heads", None)),
    (r"ckv$", ("state_batch", "kv_seq", None)),
    (r"krope$", ("state_batch", "kv_seq", None)),
    (r"conv$", ("state_batch", None, "lru")),
    (r"(^|/)h$", ("state_batch", "lru")),
    (r"(^|/)c$", ("state_batch", "heads", "ff", None)),
    (r"(^|/)n$", ("state_batch", "heads", "ff")),
    (r"(^|/)m$", ("state_batch", "heads")),
]

_STATE_COMPILED = [(re.compile(pat), spec) for pat, spec in STATE_RULES]


def state_pspecs(states) -> object:
    """Pytree of PartitionSpec for a serving-state tree. Stacked (scanned)
    states get a leading replicated 'stack' dim by ndim mismatch, same as
    params. sLSTM (B, D) states match the (batch, lru) rule via trailing-dim
    truncation."""

    def annotate(path, leaf):
        s = _path_str(path)
        for pat, spec in _STATE_COMPILED:
            if pat.search(s):
                if len(spec) < leaf.ndim:
                    spec2 = ("stack",) * (leaf.ndim - len(spec)) + tuple(spec)
                elif len(spec) > leaf.ndim:
                    spec2 = ("state_batch",) + tuple(
                        spec[len(spec) - leaf.ndim + 1:])
                else:
                    spec2 = tuple(spec)
                return guarded_spec(leaf.shape, spec2)
        return guarded_spec(leaf.shape, ("state_batch",))

    return jax.tree_util.tree_map_with_path(annotate, states)


def state_shardings(states, mesh) -> object:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), state_pspecs(states))
