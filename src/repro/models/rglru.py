"""Griffin / RecurrentGemma recurrent block: conv1d + RG-LRU with gating.

The RG-LRU cell (Griffin eq. 1-4):
    r_t = sigmoid(W_a u_t + b_a)          (recurrence gate, block-diagonal)
    i_t = sigmoid(W_x u_t + b_x)          (input gate, block-diagonal)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * u_t)

The scan itself is the `linear_recurrence` accelerated hook (associative-scan
portable path; blocked Pallas scan on TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hooks
from repro.models import layers


def _init_lambda(key, width: int) -> jax.Array:
    # init so that a = exp(-c*softplus(lam)) is uniform in [0.9, 0.999]
    u = jax.random.uniform(key, (width,), jnp.float32, 0.9, 0.999)
    # softplus(lam) = -log(a)/c  =>  lam = softplus_inv(-log(a)/c)
    sp = -jnp.log(u) / 8.0
    return jnp.log(jnp.expm1(sp))


def init(key, cfg):
    r = cfg.rglru
    w = r.lru_width
    h = cfg.num_heads
    bw = w // h
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    return {
        "lru_in": layers.init_linear(ks[0], cfg.d_model, w, dtype=dt),
        "lru_gate": layers.init_linear(ks[1], cfg.d_model, w, dtype=dt),
        "conv": layers.init_conv1d(ks[2], w, r.conv_width, dtype=dt),
        "rglru": {
            "w_a": {"w": layers.trunc_normal(ks[3], (h, bw, bw), bw**-0.5, dt)},
            "w_x": {"w": layers.trunc_normal(ks[4], (h, bw, bw), bw**-0.5, dt)},
            "b_a": jnp.zeros((w,), dt),
            "b_x": jnp.zeros((w,), dt),
            "lam": _init_lambda(ks[5], w),
        },
        "lru_out": layers.init_linear(ks[6], w, cfg.d_model, dtype=dt),
    }


def _gates(p, cfg, u):
    """Block-diagonal gate projections. u: (..., W) -> (r, i, log_a, scale)."""
    r = cfg.rglru
    h = cfg.num_heads
    lead = u.shape[:-1]
    ub = u.reshape(*lead, h, r.lru_width // h).astype(jnp.float32)
    g = p["rglru"]
    ra = jnp.einsum("...hb,hbc->...hc", ub, g["w_a"]["w"].astype(jnp.float32))
    ix = jnp.einsum("...hb,hbc->...hc", ub, g["w_x"]["w"].astype(jnp.float32))
    ra = ra.reshape(*lead, r.lru_width) + g["b_a"].astype(jnp.float32)
    ix = ix.reshape(*lead, r.lru_width) + g["b_x"].astype(jnp.float32)
    rg = jax.nn.sigmoid(ra)
    ig = jax.nn.sigmoid(ix)
    log_a = -r.c * jax.nn.softplus(g["lam"].astype(jnp.float32)) * rg
    # sqrt(1 - a^2) with numerical floor
    scale = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    return ig, log_a, scale


def apply(p, cfg, x, positions=None, *, window=None):
    """Full-sequence Griffin recurrent block. x: (B, S, D) pre-normed."""
    del positions, window
    u = layers.conv1d(p["conv"], layers.linear(p["lru_in"], x))
    gate = jax.nn.gelu(layers.linear(p["lru_gate"], x).astype(jnp.float32))
    ig, log_a, scale = _gates(p, cfg, u)
    xin = (scale * ig * u.astype(jnp.float32)).astype(x.dtype)
    a = jnp.exp(log_a).astype(x.dtype)
    h = hooks.call("linear_recurrence", a, xin)
    y = (h.astype(jnp.float32) * gate).astype(x.dtype)
    return layers.linear(p["lru_out"], y)


def prefill(p, cfg, x, positions, max_len: int, *, window=None):
    """Full-sequence pass that also returns the final recurrent state."""
    del positions, window, max_len
    r = cfg.rglru
    u_pre = layers.linear(p["lru_in"], x)  # conv input, pre-conv (B, S, W)
    u = layers.conv1d(p["conv"], u_pre)
    gate = jax.nn.gelu(layers.linear(p["lru_gate"], x).astype(jnp.float32))
    ig, log_a, scale = _gates(p, cfg, u)
    xin = (scale * ig * u.astype(jnp.float32)).astype(x.dtype)
    a = jnp.exp(log_a).astype(x.dtype)
    h = hooks.call("linear_recurrence", a, xin)
    y = (h.astype(jnp.float32) * gate).astype(x.dtype)
    out = layers.linear(p["lru_out"], y)
    # state: last recurrent value (f32) + conv tail (last conv_width-1 inputs)
    s = x.shape[1]
    w = r.conv_width - 1
    if s >= w:
        conv_tail = u_pre[:, s - w:, :]
    else:
        conv_tail = jnp.pad(u_pre, ((0, 0), (w - s, 0), (0, 0)))
    return out, {"h": h[:, -1].astype(jnp.float32), "conv": conv_tail}


def init_state(cfg, batch: int, max_len: int, dtype):
    r = cfg.rglru
    return {
        "h": jnp.zeros((batch, r.lru_width), jnp.float32),
        "conv": jnp.zeros((batch, r.conv_width - 1, r.lru_width), dtype),
    }


def prefill_chunk(p, cfg, x, positions, state, start, lengths, *, window=None):
    """Continuation prefill: run the conv + RG-LRU over a chunk starting from
    an existing recurrent state (``h`` folded into the first step, conv tail
    carried through), and return the state at each row's last *real* chunk
    position (rows are right-padded to the chunk bucket)."""
    del positions, window
    r = cfg.rglru
    b, s, _ = x.shape
    u_pre = layers.linear(p["lru_in"], x)  # (B, Sc, W) pre-conv
    u, _ = layers.conv1d(p["conv"], u_pre, state["conv"])
    gate = jax.nn.gelu(layers.linear(p["lru_gate"], x).astype(jnp.float32))
    ig, log_a, scale = _gates(p, cfg, u)
    xin = scale * ig * u.astype(jnp.float32)
    # fold the initial state into the first step (h_1 = a_1 h_0 + x_1), same
    # f32 numerics as the reference recurrence's h0 handling
    xin = xin.at[:, 0].add(jnp.exp(log_a[:, 0]) * state["h"])
    xin = xin.astype(x.dtype)
    a = jnp.exp(log_a).astype(x.dtype)
    h = hooks.call("linear_recurrence", a, xin)
    y = (h.astype(jnp.float32) * gate).astype(x.dtype)
    out = layers.linear(p["lru_out"], y)
    # ragged state: per-row gather at the last real chunk position; the conv
    # tail is the window of pre-conv inputs ending there (prefix tail + chunk)
    sl = lengths - start  # (B,) real chunk lengths >= 1
    h_t = jnp.take_along_axis(h, (sl - 1)[:, None, None], axis=1)[:, 0]
    w = r.conv_width - 1
    ctx = jnp.concatenate([state["conv"].astype(u_pre.dtype), u_pre], axis=1)
    tail_idx = sl[:, None] + jnp.arange(w)[None, :]  # ctx[sl : sl+w] per row
    conv_tail = jnp.take_along_axis(ctx, tail_idx[:, :, None], axis=1)
    return out, {"h": h_t.astype(jnp.float32),
                 "conv": conv_tail.astype(state["conv"].dtype)}


def decode(p, cfg, x, state, lengths, *, window=None):
    """Single-step recurrent update. x: (B, D)."""
    del lengths, window
    u1, conv_state = layers.conv1d(p["conv"], layers.linear(p["lru_in"], x)[:, None, :],
                                   state["conv"])
    u = u1[:, 0]
    gate = jax.nn.gelu(layers.linear(p["lru_gate"], x).astype(jnp.float32))
    ig, log_a, scale = _gates(p, cfg, u)
    xin = scale * ig * u.astype(jnp.float32)
    h = jnp.exp(log_a) * state["h"] + xin
    y = (h * gate).astype(x.dtype)
    return layers.linear(p["lru_out"], y), {"h": h, "conv": conv_state}
