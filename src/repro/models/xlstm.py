"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, sequential scan with block-diagonal recurrence).

mLSTM training/prefill goes through the `mlstm` accelerated hook (stabilized
parallel form; chunkwise Pallas kernel on TPU). Decode uses the exact
recurrent form over (C, n, m) state. sLSTM is inherently sequential
(recurrent weight matrices) and runs as a lax.scan — no kernel, noted in
DESIGN.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hooks
from repro.models import layers


def _mlstm_dims(cfg):
    di = int(cfg.xlstm.mlstm_proj_factor * cfg.d_model)
    h = cfg.num_heads
    return di, h, di // h


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------
def _init_blocked(key, di: int, bs: int, dtype):
    """Block-diagonal projection (official xLSTM qkv_proj_blocksize):
    weight (di//bs, bs, bs) — near-banded, O(di*bs) params not O(di^2)."""
    return {"w": layers.trunc_normal(key, (di // bs, bs, bs), bs**-0.5, dtype)}


def _blocked_linear(p, x):
    """x: (..., di) -> (..., di) through the block-diagonal weight."""
    nb, bs, _ = p["w"].shape
    lead = x.shape[:-1]
    xb = x.reshape(*lead, nb, bs)
    y = jnp.einsum("...nb,nbc->...nc", xb, p["w"].astype(x.dtype))
    return y.reshape(*lead, nb * bs)


def init_mlstm(key, cfg):
    di, h, dh = _mlstm_dims(cfg)
    bs = cfg.xlstm.qkv_block_size
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 9)
    return {
        "up_proj": layers.init_linear(ks[0], cfg.d_model, di, dtype=dt),
        "up_gate": layers.init_linear(ks[1], cfg.d_model, di, dtype=dt),
        "conv": layers.init_conv1d(ks[2], di, cfg.xlstm.conv_width, dtype=dt),
        "wq_in": _init_blocked(ks[3], di, bs, dt),
        "wk_in": _init_blocked(ks[4], di, bs, dt),
        "wv_in": _init_blocked(ks[5], di, bs, dt),
        "wi_in": layers.init_linear(ks[6], di, h, bias=True, dtype=dt),
        "wf_in": layers.init_linear(ks[7], di, h, bias=True, dtype=dt),
        "head_norm": layers.init_norm(di, kind="rmsnorm", dtype=dt),
        "down_proj": layers.init_linear(ks[8], di, cfg.d_model, dtype=dt),
    }


def _mlstm_qkvif(p, cfg, x):
    di, h, dh = _mlstm_dims(cfg)
    lead = x.shape[:-1]
    inner = layers.linear(p["up_proj"], x)
    z = layers.linear(p["up_gate"], x)
    if x.ndim == 3:
        c = jax.nn.silu(layers.conv1d(p["conv"], inner))
        conv_state = None
    else:  # single-step handled by caller
        raise AssertionError("use decode()")
    q = _blocked_linear(p["wq_in"], c).reshape(*lead, h, dh)
    k = _blocked_linear(p["wk_in"], c).reshape(*lead, h, dh)
    v = _blocked_linear(p["wv_in"], inner).reshape(*lead, h, dh)
    ig = layers.linear(p["wi_in"], inner).astype(jnp.float32)
    fg = layers.linear(p["wf_in"], inner).astype(jnp.float32) + 3.0  # forget-bias init
    return q, k, v, ig, fg, z, conv_state


def apply_mlstm(p, cfg, x, positions=None, *, window=None):
    """x: (B, S, D) pre-normed -> (B, S, D)."""
    del positions, window
    b, s, _ = x.shape
    di, h, dh = _mlstm_dims(cfg)
    q, k, v, ig, fg, z, _ = _mlstm_qkvif(p, cfg, x)
    o = hooks.call("mlstm", q, k, v, ig, fg)
    o = layers.norm(p["head_norm"], o.reshape(b, s, di))
    y = o * jax.nn.silu(z.astype(jnp.float32)).astype(o.dtype)
    return layers.linear(p["down_proj"], y)


def prefill_mlstm(p, cfg, x, positions, max_len: int, *, window=None):
    """Full-sequence mLSTM + exact final (C, n, m) recurrent state.

    The final state has the closed form (with g_t = i_t + sum_{s>t} log f_s,
    m_T = max_t g_t, matching the stabilized decode recursion):
        C_T = sum_t exp(g_t - m_T) k_t v_t^T,   n_T = sum_t exp(g_t - m_T) k_t
    """
    del positions, window, max_len
    b, s, _ = x.shape
    di, h, dh = _mlstm_dims(cfg)
    q, k, v, ig, fg, z, _ = _mlstm_qkvif(p, cfg, x)
    o = hooks.call("mlstm", q, k, v, ig, fg)
    o = layers.norm(p["head_norm"], o.reshape(b, s, di))
    y = o * jax.nn.silu(z.astype(jnp.float32)).astype(o.dtype)
    out = layers.linear(p["down_proj"], y)

    log_f = jax.nn.log_sigmoid(fg)  # (B, S, H) f32
    log_f_cum = jnp.cumsum(log_f, axis=1)
    g = ig + (log_f_cum[:, -1:, :] - log_f_cum)  # (B, S, H)
    m_t = jnp.max(g, axis=1)  # (B, H)
    w = jnp.exp(g - m_t[:, None, :])  # (B, S, H)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    c_t = jnp.einsum("bsh,bshd,bshv->bhdv", w, kf, vf)
    n_t = jnp.einsum("bsh,bshd->bhd", w, kf)
    # conv state: last (conv_width - 1) pre-conv inputs (`inner`)
    inner = layers.linear(p["up_proj"], x)
    cw = cfg.xlstm.conv_width - 1
    conv_tail = inner[:, -cw:, :] if s >= cw else jnp.pad(
        inner, ((0, 0), (cw - s, 0), (0, 0)))
    return out, {"c": c_t, "n": n_t, "m": m_t, "conv": conv_tail}


def init_mlstm_state(cfg, batch: int, max_len: int, dtype):
    del max_len
    di, h, dh = _mlstm_dims(cfg)
    return {
        "c": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.xlstm.conv_width - 1, di), dtype),
    }


def prefill_mlstm_chunk(p, cfg, x, positions, state, start, lengths, *,
                        window=None):
    """Continuation prefill: stabilized parallel mLSTM over a chunk with an
    initial (C, n, m) state. The initial state enters every chunk position t
    with log-decay ``m0 + sum_{u<=t} log f_u`` and the per-row stabilizer is
    the max over that and the within-chunk decays, so the math matches the
    exact decode recursion step-by-step. Rows are right-padded: pad
    positions get f=1 / i=-inf so they neither decay nor contribute, which
    makes the final cumulative quantities land at each row's real length."""
    del positions, window
    b, s, _ = x.shape
    di, h, dh = _mlstm_dims(cfg)
    f32 = jnp.float32
    inner = layers.linear(p["up_proj"], x)
    z = layers.linear(p["up_gate"], x)
    c_seq, _ = layers.conv1d(p["conv"], inner, state["conv"])
    cx = jax.nn.silu(c_seq)
    q = _blocked_linear(p["wq_in"], cx).reshape(b, s, h, dh)
    k = _blocked_linear(p["wk_in"], cx).reshape(b, s, h, dh)
    v = _blocked_linear(p["wv_in"], inner).reshape(b, s, h, dh)
    ig = layers.linear(p["wi_in"], inner).astype(f32)
    fg = layers.linear(p["wf_in"], inner).astype(f32) + 3.0

    sl = lengths - start  # (B,) real chunk lengths
    pad = jnp.arange(s)[None, :] >= sl[:, None]  # (B, S)
    log_f = jax.nn.log_sigmoid(fg)
    log_f = jnp.where(pad[..., None], 0.0, log_f)   # pads: no decay
    ig = jnp.where(pad[..., None], -1e30, ig)       # pads: no contribution
    log_f_cum = jnp.cumsum(log_f, axis=1)  # (B, S, H)

    qf = q.astype(f32) * dh**-0.5
    kf = k.astype(f32)
    vf = v.astype(f32)
    # within-chunk decays + initial-state decay, shared row stabilizer
    log_d = (log_f_cum[:, :, None, :] - log_f_cum[:, None, :, :]
             + ig[:, None, :, :])  # (B, T, S, H)
    tpos = jnp.arange(s)[:, None]
    spos = jnp.arange(s)[None, :]
    log_d = jnp.where((spos <= tpos)[None, :, :, None], log_d, -1e30)
    g_init = state["m"][:, None, :] + log_f_cum  # (B, T, H)
    m_row = jnp.maximum(jnp.max(log_d, axis=2), g_init)  # (B, T, H)
    d = jnp.exp(log_d - m_row[:, :, None, :])
    scores = jnp.einsum("bthd,bshd->btsh", qf, kf) * d
    init_w = jnp.exp(g_init - m_row)  # (B, T, H)
    num = (jnp.einsum("btsh,bshd->bthd", scores, vf)
           + init_w[..., None] * jnp.einsum(
               "bthd,bhdv->bthv", qf, state["c"]))
    den = (jnp.sum(scores, axis=2)
           + init_w * jnp.einsum("bthd,bhd->bth", qf, state["n"]))
    denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_row))
    o = (num / denom[..., None]).reshape(b, s, di)
    o = layers.norm(p["head_norm"], o.astype(x.dtype))
    y = o * jax.nn.silu(z.astype(f32)).astype(o.dtype)
    out = layers.linear(p["down_proj"], y)

    # final state at each row's real length (pads are transparent, so the
    # last cumulative values ARE the values at position sl-1)
    f_total = log_f_cum[:, -1, :]  # (B, H)
    g = ig + (log_f_cum[:, -1:, :] - log_f_cum)  # (B, S, H)
    m_chunk = jnp.max(g, axis=1)  # (B, H)
    m_t = jnp.maximum(state["m"] + f_total, m_chunk)
    w = jnp.exp(g - m_t[:, None, :])  # (B, S, H); pads -> 0
    carry = jnp.exp(state["m"] + f_total - m_t)  # (B, H) initial-state decay
    c_t = (carry[..., None, None] * state["c"]
           + jnp.einsum("bsh,bshd,bshv->bhdv", w, kf, vf))
    n_t = carry[..., None] * state["n"] + jnp.einsum("bsh,bshd->bhd", w, kf)
    cw = cfg.xlstm.conv_width - 1
    ctx = jnp.concatenate([state["conv"].astype(inner.dtype), inner], axis=1)
    tail_idx = sl[:, None] + jnp.arange(cw)[None, :]
    conv_tail = jnp.take_along_axis(ctx, tail_idx[:, :, None], axis=1)
    return out, {"c": c_t, "n": n_t, "m": m_t,
                 "conv": conv_tail.astype(state["conv"].dtype)}


def decode_mlstm(p, cfg, x, state, lengths, *, window=None):
    """Exact recurrent mLSTM step. x: (B, D)."""
    del lengths, window
    b, _ = x.shape
    di, h, dh = _mlstm_dims(cfg)
    inner = layers.linear(p["up_proj"], x)
    z = layers.linear(p["up_gate"], x)
    c1, conv_state = layers.conv1d(p["conv"], inner[:, None, :], state["conv"])
    cx = jax.nn.silu(c1[:, 0])
    q = _blocked_linear(p["wq_in"], cx).reshape(b, h, dh).astype(jnp.float32) * dh**-0.5
    k = _blocked_linear(p["wk_in"], cx).reshape(b, h, dh).astype(jnp.float32)
    v = _blocked_linear(p["wv_in"], inner).reshape(b, h, dh).astype(jnp.float32)
    ig = layers.linear(p["wi_in"], inner).astype(jnp.float32)
    fg = layers.linear(p["wf_in"], inner).astype(jnp.float32) + 3.0
    log_f = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(log_f + state["m"], ig)
    f_s = jnp.exp(log_f + state["m"] - m_new)
    i_s = jnp.exp(ig - m_new)
    c = f_s[..., None, None] * state["c"] + i_s[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n = f_s[..., None] * state["n"] + i_s[..., None] * k
    num = jnp.einsum("bhd,bhdv->bhv", q, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)), jnp.exp(-m_new))
    o = (num / den[..., None]).reshape(b, di)
    o = layers.norm(p["head_norm"], o.astype(x.dtype))
    y = o * jax.nn.silu(z.astype(jnp.float32)).astype(o.dtype)
    return layers.linear(p["down_proj"], y), {"c": c, "n": n, "m": m_new, "conv": conv_state}


# ---------------------------------------------------------------------------
# sLSTM block (sequential scan; self-contained post-up-projection FFN)
# ---------------------------------------------------------------------------
def init_slstm(key, cfg):
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    dt = jnp.dtype(cfg.param_dtype)
    ff = int(cfg.xlstm.slstm_proj_factor * d)
    ks = jax.random.split(key, 12)
    gate_w = lambda kk: layers.init_linear(kk, d, d, dtype=dt)
    rec_w = lambda kk: layers.trunc_normal(kk, (h, dh, dh), dh**-0.5, dt)
    return {
        "conv": layers.init_conv1d(ks[0], d, cfg.xlstm.conv_width, dtype=dt),
        "slstm": {
            "wz": gate_w(ks[1]), "wi": gate_w(ks[2]),
            "wf": gate_w(ks[3]), "wo": gate_w(ks[4]),
            "rz": rec_w(ks[5]), "ri": rec_w(ks[6]),
            "rf": rec_w(ks[7]), "ro": rec_w(ks[8]),
            "bz": jnp.zeros((d,), dt), "bi": jnp.zeros((d,), dt),
            "bf": jnp.full((d,), 3.0, dt), "bo": jnp.zeros((d,), dt),
        },
        "head_norm": layers.init_norm(d, kind="rmsnorm", dtype=dt),
        "ffn_gate": layers.init_linear(ks[9], d, ff, dtype=dt),
        "ffn_up": layers.init_linear(ks[10], d, ff, dtype=dt),
        "ffn_down": layers.init_linear(ks[11], ff, d, dtype=dt),
    }


def _slstm_cell(sp, h_prev, c_prev, n_prev, m_prev, zt, it, ft, ot, nheads):
    """One sLSTM step, all f32. h_prev: (B, D); gate pre-acts: (B, D)."""
    b, d = h_prev.shape
    dh = d // nheads
    hb = h_prev.reshape(b, nheads, dh)
    rec = lambda r: jnp.einsum("bhd,hdc->bhc", hb, r.astype(jnp.float32)).reshape(b, d)
    z = jnp.tanh(zt + rec(sp["rz"]))
    i_pre = it + rec(sp["ri"])
    f_pre = ft + rec(sp["rf"])
    o = jax.nn.sigmoid(ot + rec(sp["ro"]))
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + m_prev, i_pre)
    i_s = jnp.exp(i_pre - m_new)
    f_s = jnp.exp(log_f + m_prev - m_new)
    c = f_s * c_prev + i_s * z
    n = f_s * n_prev + i_s
    h = o * c / jnp.maximum(n, 1e-6)
    return h, c, n, m_new


def apply_slstm(p, cfg, x, positions=None, *, window=None):
    """x: (B, S, D) pre-normed -> (B, S, D). Sequential lax.scan over time."""
    del positions, window
    b, s, d = x.shape
    h_heads = cfg.num_heads
    cx = jax.nn.silu(layers.conv1d(p["conv"], x))
    sp = p["slstm"]
    f32 = jnp.float32
    zt = (layers.linear(sp["wz"], x) + sp["bz"]).astype(f32)
    it = (layers.linear(sp["wi"], cx) + sp["bi"]).astype(f32)
    ft = (layers.linear(sp["wf"], cx) + sp["bf"]).astype(f32)
    ot = (layers.linear(sp["wo"], x) + sp["bo"]).astype(f32)

    def step(carry, gates):
        h, c, n, m = carry
        z_t, i_t, f_t, o_t = gates
        h, c, n, m = _slstm_cell(sp, h, c, n, m, z_t, i_t, f_t, o_t, h_heads)
        return (h, c, n, m), h

    zeros = jnp.zeros((b, d), f32)
    init = (zeros, zeros, zeros, jnp.full((b, d), -1e30, f32))
    gates_t = tuple(jnp.moveaxis(g, 1, 0) for g in (zt, it, ft, ot))
    _, hs = jax.lax.scan(step, init, gates_t)
    h_seq = jnp.moveaxis(hs, 0, 1).astype(x.dtype)  # (B,S,D)
    y = layers.norm(p["head_norm"], h_seq)
    g = layers.linear(p["ffn_gate"], y)
    u = layers.linear(p["ffn_up"], y)
    return layers.linear(p["ffn_down"], jax.nn.gelu(g.astype(f32)).astype(u.dtype) * u)


def prefill_slstm(p, cfg, x, positions, max_len: int, *, window=None):
    """Full-sequence sLSTM; the scan's final carry IS the serving state."""
    del positions, window, max_len
    b, s, d = x.shape
    h_heads = cfg.num_heads
    cx = jax.nn.silu(layers.conv1d(p["conv"], x))
    sp = p["slstm"]
    f32 = jnp.float32
    zt = (layers.linear(sp["wz"], x) + sp["bz"]).astype(f32)
    it = (layers.linear(sp["wi"], cx) + sp["bi"]).astype(f32)
    ft = (layers.linear(sp["wf"], cx) + sp["bf"]).astype(f32)
    ot = (layers.linear(sp["wo"], x) + sp["bo"]).astype(f32)

    def step(carry, gates):
        h, c, n, m = carry
        z_t, i_t, f_t, o_t = gates
        h, c, n, m = _slstm_cell(sp, h, c, n, m, z_t, i_t, f_t, o_t, h_heads)
        return (h, c, n, m), h

    zeros = jnp.zeros((b, d), f32)
    init = (zeros, zeros, zeros, jnp.full((b, d), -1e30, f32))
    gates_t = tuple(jnp.moveaxis(g, 1, 0) for g in (zt, it, ft, ot))
    (hT, cT, nT, mT), hs = jax.lax.scan(step, init, gates_t)
    h_seq = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    y = layers.norm(p["head_norm"], h_seq)
    g = layers.linear(p["ffn_gate"], y)
    u = layers.linear(p["ffn_up"], y)
    out = layers.linear(p["ffn_down"], jax.nn.gelu(g.astype(f32)).astype(u.dtype) * u)
    cw = cfg.xlstm.conv_width - 1
    conv_tail = x[:, -cw:, :] if s >= cw else jnp.pad(x, ((0, 0), (cw - s, 0), (0, 0)))
    return out, {"h": hT, "c": cT, "n": nT, "m": mT, "conv": conv_tail}


def init_slstm_state(cfg, batch: int, max_len: int, dtype):
    del max_len
    d = cfg.d_model
    f32 = jnp.float32
    return {
        "h": jnp.zeros((batch, d), f32),
        "c": jnp.zeros((batch, d), f32),
        "n": jnp.zeros((batch, d), f32),
        "m": jnp.full((batch, d), -1e30, f32),
        "conv": jnp.zeros((batch, cfg.xlstm.conv_width - 1, d), dtype),
    }


def prefill_slstm_chunk(p, cfg, x, positions, state, start, lengths, *,
                        window=None):
    """Continuation prefill: the sequential sLSTM scan seeded with the
    existing carry; emits the carry at every step so each right-padded row's
    state is gathered at its real length."""
    del positions, window
    b, s, d = x.shape
    h_heads = cfg.num_heads
    f32 = jnp.float32
    cx_seq, _ = layers.conv1d(p["conv"], x, state["conv"])
    cx = jax.nn.silu(cx_seq)
    sp = p["slstm"]
    zt = (layers.linear(sp["wz"], x) + sp["bz"]).astype(f32)
    it = (layers.linear(sp["wi"], cx) + sp["bi"]).astype(f32)
    ft = (layers.linear(sp["wf"], cx) + sp["bf"]).astype(f32)
    ot = (layers.linear(sp["wo"], x) + sp["bo"]).astype(f32)

    def step(carry, gates):
        hh, cc, nn, mm = carry
        z_t, i_t, f_t, o_t = gates
        hh, cc, nn, mm = _slstm_cell(sp, hh, cc, nn, mm, z_t, i_t, f_t, o_t,
                                     h_heads)
        return (hh, cc, nn, mm), (hh, cc, nn, mm)

    init = (state["h"], state["c"], state["n"], state["m"])
    gates_t = tuple(jnp.moveaxis(g, 1, 0) for g in (zt, it, ft, ot))
    _, (hs, cs, ns, ms) = jax.lax.scan(step, init, gates_t)
    h_seq = jnp.moveaxis(hs, 0, 1)  # (B, S, D) f32
    y = layers.norm(p["head_norm"], h_seq.astype(x.dtype))
    g = layers.linear(p["ffn_gate"], y)
    u = layers.linear(p["ffn_up"], y)
    out = layers.linear(p["ffn_down"],
                        jax.nn.gelu(g.astype(f32)).astype(u.dtype) * u)

    sl = lengths - start  # (B,) real chunk lengths
    gi = (sl - 1)[:, None, None]
    gather = lambda seq: jnp.take_along_axis(
        jnp.moveaxis(seq, 0, 1), gi, axis=1)[:, 0]
    cw = cfg.xlstm.conv_width - 1
    ctx = jnp.concatenate([state["conv"].astype(x.dtype), x], axis=1)
    tail_idx = sl[:, None] + jnp.arange(cw)[None, :]
    conv_tail = jnp.take_along_axis(ctx, tail_idx[:, :, None], axis=1)
    return out, {"h": gather(hs), "c": gather(cs), "n": gather(ns),
                 "m": gather(ms),
                 "conv": conv_tail.astype(state["conv"].dtype)}


def decode_slstm(p, cfg, x, state, lengths, *, window=None):
    del lengths, window
    sp = p["slstm"]
    f32 = jnp.float32
    c1, conv_state = layers.conv1d(p["conv"], x[:, None, :], state["conv"])
    cx = jax.nn.silu(c1[:, 0])
    zt = (layers.linear(sp["wz"], x) + sp["bz"]).astype(f32)
    it = (layers.linear(sp["wi"], cx) + sp["bi"]).astype(f32)
    ft = (layers.linear(sp["wf"], cx) + sp["bf"]).astype(f32)
    ot = (layers.linear(sp["wo"], x) + sp["bo"]).astype(f32)
    h, c, n, m = _slstm_cell(sp, state["h"], state["c"], state["n"], state["m"],
                             zt, it, ft, ot, cfg.num_heads)
    y = layers.norm(p["head_norm"], h.astype(x.dtype))
    g = layers.linear(p["ffn_gate"], y)
    u = layers.linear(p["ffn_up"], y)
    out = layers.linear(p["ffn_down"], jax.nn.gelu(g.astype(f32)).astype(u.dtype) * u)
    return out, {"h": h, "c": c, "n": n, "m": m, "conv": conv_state}
