"""Functional model zoo: mixers + FFNs + assembly (see transformer.py)."""
from repro.models import (  # noqa: F401
    attention,
    ffn,
    frontends,
    layers,
    mla,
    moe,
    rglru,
    transformer,
    xlstm,
)
