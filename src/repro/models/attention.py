"""Global / local attention mixer with GQA-MQA, RoPE, and KV-cache decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hooks
from repro.distributed import sharding
from repro.models import layers


def init(key, cfg, *, window: int | None = None):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "wq": layers.init_linear(ks[0], cfg.d_model, cfg.num_heads * hd, bias=cfg.qkv_bias, dtype=dt),
        "wk": layers.init_linear(ks[1], cfg.d_model, cfg.num_kv_heads * hd, bias=cfg.qkv_bias, dtype=dt),
        "wv": layers.init_linear(ks[2], cfg.d_model, cfg.num_kv_heads * hd, bias=cfg.qkv_bias, dtype=dt),
        "wo": layers.init_linear(ks[3], cfg.num_heads * hd, cfg.d_model, bias=False, dtype=dt),
    }


def _qkv(p, cfg, x, positions):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = layers.linear(p["wq"], x).reshape(b, s, cfg.num_heads, hd)
    k = layers.linear(p["wk"], x).reshape(b, s, cfg.num_kv_heads, hd)
    v = layers.linear(p["wv"], x).reshape(b, s, cfg.num_kv_heads, hd)
    if cfg.pos == "rope":
        q = layers.apply_rope(q, positions, theta=cfg.rope_theta)
        k = layers.apply_rope(k, positions, theta=cfg.rope_theta)
    return q, k, v


def _pad_heads(q, k, v):
    """§Perf: pad query heads so their count is a multiple of
    `__pad_heads__` (the model-axis size) — 56/40/24-head archs otherwise
    replicate the whole attention computation on every model rank.

    GQA mapping is preserved by padding WITHIN groups: each kv head's group
    grows g -> g' (zero q-heads interleaved per group), so original q head
    (group j, slot r) keeps attending to kv head j. MHA (g == 1) instead
    appends tiled kv heads + zero q heads (identity mapping preserved).
    Returns (q, k, v, unpad) where unpad(o) restores the original heads.
    """
    rules = sharding.current_rules() or {}
    mult = rules.get("__pad_heads__")
    b, s, hq, dh = q.shape
    ident = lambda o: o
    if not mult or hq % mult == 0:
        return q, k, v, ident
    hkv = k.shape[2]
    g = hq // hkv
    if g == 1:
        hq_pad = ((hq + mult - 1) // mult) * mult
        reps = (hq_pad + hkv - 1) // hkv
        k = jnp.tile(k, (1, 1, reps, 1))[:, :, :hq_pad]
        v = jnp.tile(v, (1, 1, reps, 1))[:, :, :hq_pad]
        q = jnp.pad(q, ((0, 0), (0, 0), (0, hq_pad - hq), (0, 0)))
        return q, k, v, (lambda o: o[:, :, :hq])
    # smallest g' >= g with hkv * g' divisible by mult
    gp = g
    while (hkv * gp) % mult:
        gp += 1
    qg = q.reshape(b, s, hkv, g, dh)
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, gp - g), (0, 0)))
    q = qg.reshape(b, s, hkv * gp, dh)

    def unpad(o):
        og = o.reshape(*o.shape[:2], hkv, gp, o.shape[-1])
        return og[:, :, :, :g].reshape(*o.shape[:2], hkv * g, o.shape[-1])

    return q, k, v, unpad


def apply(p, cfg, x, positions, *, window: int | None = None):
    """Full-sequence attention (train / prefill). x: (B, S, D) pre-normed."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    q, k, v, unpad = _pad_heads(q, k, v)
    q = sharding.constraint(q, "batch", "seq", "heads", None)
    k = sharding.constraint(k, "batch", "seq", "kv_heads", None)
    v = sharding.constraint(v, "batch", "seq", "kv_heads", None)
    o = hooks.call(
        "attention", q, k, v, causal=True, window=window,
        logit_softcap=cfg.logit_softcap,
    )
    o = unpad(o)
    o = sharding.constraint(o, "batch", "seq", None, None)
    return layers.linear(p["wo"], o.reshape(b, s, -1))


def prefill(p, cfg, x, positions, max_len: int, *, window: int | None = None):
    """Full-prompt attention + KV-cache build. x: (B, S, D), S <= max_len.

    Returns (y (B,S,D), state with caches sized max_len)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    qp, kp, vp, unpad = _pad_heads(q, k, v)  # cache keeps the UNpadded k/v
    qp = sharding.constraint(qp, "batch", "seq", "heads", None)
    o = hooks.call(
        "attention", qp, kp, vp, causal=True, window=window,
        logit_softcap=cfg.logit_softcap,
    )
    o = unpad(o)
    y = layers.linear(p["wo"], o.reshape(b, s, -1))
    state = init_state(cfg, b, max_len, k.dtype)
    k_cache = jax.lax.dynamic_update_slice(state["k"], k, (0, 0, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(state["v"], v, (0, 0, 0, 0))
    k_cache = sharding.constraint(k_cache, "batch", "kv_seq", "kv_heads", None)
    v_cache = sharding.constraint(v_cache, "batch", "kv_seq", "kv_heads", None)
    return y, {"k": k_cache, "v": v_cache}


def init_state(cfg, batch: int, max_len: int, dtype):
    hd = cfg.resolved_head_dim
    shape = (batch, max_len, cfg.num_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill_chunk(p, cfg, x, positions, state, start, lengths, *,
                  window: int | None = None):
    """Continue a prefill from per-row offset ``start``: the chunk's K/V are
    scattered into the existing cache at absolute positions and the chunk's
    queries attend the whole cache (restored prefix + chunk) with absolute
    causality — the suffix-only half of prefix-cache reuse.

    x: (B, Sc, D) pre-normed (right-padded chunk); positions: (B, Sc)
    absolute positions start + [0..Sc); lengths: (B,) total valid entries
    after the chunk (start + real chunk length). Pad rows (chunk index >=
    lengths - start) are dropped from the cache write and produce garbage
    outputs the caller ignores.
    """
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    max_len = state["k"].shape[1]
    valid = jnp.arange(s)[None, :] < (lengths - start)[:, None]
    idx = jnp.where(valid, positions, max_len)  # out-of-range pads -> dropped
    bidx = jnp.arange(b)[:, None]
    k_cache = state["k"].at[bidx, idx].set(k.astype(state["k"].dtype),
                                           mode="drop")
    v_cache = state["v"].at[bidx, idx].set(v.astype(state["v"].dtype),
                                           mode="drop")
    k_cache = sharding.constraint(k_cache, "batch", "kv_seq", "kv_heads", None)
    v_cache = sharding.constraint(v_cache, "batch", "kv_seq", "kv_heads", None)
    o = hooks.call(
        "chunk_attention", q, k_cache, v_cache, positions=positions,
        window=window, logit_softcap=cfg.logit_softcap,
    )
    y = layers.linear(p["wo"], o.reshape(b, s, -1))
    return y, {"k": k_cache, "v": v_cache}


def init_paged_state(cfg, num_pages: int, page_size: int, dtype):
    """Paged KV pool: ``num_pages`` fixed-size pages shared by every request
    (physical page 0 is the engine's reserved null page). Same tree structure
    as :func:`init_state` with the (batch, max_len) axes replaced by
    (num_pages, page_size)."""
    hd = cfg.resolved_head_dim
    shape = (num_pages, page_size, cfg.num_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill_chunk_paged(p, cfg, x, positions, state, block_tables, page_size,
                        start, lengths, *, window: int | None = None):
    """`prefill_chunk` against a paged KV pool: the chunk's K/V are scattered
    through the row's block table and the queries attend the gathered logical
    cache. Pad entries (chunk index >= lengths - start) are routed to the
    reserved null page 0 instead of dropped — same stale-beyond-the-length
    contract, no owned page is ever touched by a pad write.

    state: {"k","v"} (P, page, Hkv, Dh) pools; block_tables: (B, N) int32.
    """
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    n = block_tables.shape[1]
    # pads AND positions past the table's capacity route to the null page
    # (the contiguous path drops both via mode="drop"; spec verify chunks
    # near max_len can carry positions >= n*page_size)
    valid = (jnp.arange(s)[None, :] < (lengths - start)[:, None]) \
        & (positions < n * page_size)
    page_idx = jnp.clip(positions // page_size, 0, n - 1)
    phys = jnp.take_along_axis(block_tables, page_idx, axis=1)
    phys = jnp.where(valid, phys, 0)
    offset = positions % page_size
    k_pool = state["k"].at[phys, offset].set(k.astype(state["k"].dtype))
    v_pool = state["v"].at[phys, offset].set(v.astype(state["v"].dtype))
    o = hooks.call(
        "paged_chunk_attention", q, k_pool, v_pool, block_tables,
        positions=positions, window=window, logit_softcap=cfg.logit_softcap,
    )
    y = layers.linear(p["wo"], o.reshape(b, s, -1))
    return y, {"k": k_pool, "v": v_pool}


def decode_paged(p, cfg, x, state, block_tables, page_size, lengths, *,
                 window: int | None = None):
    """Single-token decode against a paged KV pool. Rows with lengths == 0
    (empty slots, rows still prefilling) write to the reserved null page 0;
    active rows write at index lengths-1 inside their own last page, which
    the engine guarantees is exclusively owned (copy-on-write happens before
    the step when a prefix-shared page would be written)."""
    b, _ = x.shape
    hd = cfg.resolved_head_dim
    pos = (lengths - 1).astype(jnp.int32)
    q = layers.linear(p["wq"], x).reshape(b, 1, cfg.num_heads, hd)
    k = layers.linear(p["wk"], x).reshape(b, 1, cfg.num_kv_heads, hd)
    v = layers.linear(p["wv"], x).reshape(b, 1, cfg.num_kv_heads, hd)
    if cfg.pos == "rope":
        q = layers.apply_rope(q, pos[:, None], theta=cfg.rope_theta)
        k = layers.apply_rope(k, pos[:, None], theta=cfg.rope_theta)
    n = block_tables.shape[1]
    safe = jnp.maximum(pos, 0)
    page_idx = jnp.clip(safe // page_size, 0, n - 1)
    phys = jnp.take_along_axis(block_tables, page_idx[:, None], axis=1)[:, 0]
    phys = jnp.where(lengths > 0, phys, 0)
    offset = safe % page_size
    k_pool = state["k"].at[phys, offset].set(k[:, 0].astype(state["k"].dtype))
    v_pool = state["v"].at[phys, offset].set(v[:, 0].astype(state["v"].dtype))
    o = hooks.call(
        "paged_decode_attention", q[:, 0], k_pool, v_pool, block_tables,
        lengths=lengths, window=window, logit_softcap=cfg.logit_softcap,
    )
    y = layers.linear(p["wo"], o.reshape(b, -1))
    return y, {"k": k_pool, "v": v_pool}


def decode(p, cfg, x, state, lengths, *, window: int | None = None):
    """Single-token decode. x: (B, D); lengths: (B,) valid entries *including*
    the current token, which is written at index lengths-1."""
    b, _ = x.shape
    hd = cfg.resolved_head_dim
    pos = (lengths - 1).astype(jnp.int32)
    q = layers.linear(p["wq"], x).reshape(b, 1, cfg.num_heads, hd)
    k = layers.linear(p["wk"], x).reshape(b, 1, cfg.num_kv_heads, hd)
    v = layers.linear(p["wv"], x).reshape(b, 1, cfg.num_kv_heads, hd)
    if cfg.pos == "rope":
        q = layers.apply_rope(q, pos[:, None], theta=cfg.rope_theta)
        k = layers.apply_rope(k, pos[:, None], theta=cfg.rope_theta)
    bidx = jnp.arange(b)
    k_cache = state["k"].at[bidx, pos].set(k[:, 0].astype(state["k"].dtype))
    v_cache = state["v"].at[bidx, pos].set(v[:, 0].astype(state["v"].dtype))
    k_cache = sharding.constraint(k_cache, "batch", "kv_seq", "kv_heads", None)
    v_cache = sharding.constraint(v_cache, "batch", "kv_seq", "kv_heads", None)
    o = hooks.call(
        "decode_attention", q[:, 0], k_cache, v_cache, lengths=lengths,
        window=window, logit_softcap=cfg.logit_softcap,
    )
    y = layers.linear(p["wo"], o.reshape(b, -1))
    return y, {"k": k_cache, "v": v_cache}
