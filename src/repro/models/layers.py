"""Shared model primitives: inits, linear (BLAS-hooked), norms, RoPE, conv1d.

Everything is functional: ``init_*`` builds a param pytree, ``apply``-style
functions consume it. Weight layout is always (in_features, out_features) so
the tensor-parallel sharding rules in distributed/sharding.py can match on
logical axis names attached via ``repro.distributed.sharding.logical``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import hooks


def trunc_normal(key, shape, scale, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


def grad_dtype_barrier(x: jax.Array) -> jax.Array:
    """Identity whose backward casts the cotangent to x.dtype.

    f32-accumulating dots (preferred_element_type) hand back f32 weight
    cotangents; without this barrier the scan-over-layers transpose
    accumulates stacked-param grads in f32 — 2x the bf16 footprint
    (3.4 GB/chip extra for the 671B expert stack). Applied per block to the
    scanned layer params in transformer.apply_layers.
    """
    dt = x.dtype

    @jax.custom_vjp
    def _ident(y):
        return y

    def _fwd(y):
        return y, None

    def _bwd(_, g):
        return (g.astype(dt),)

    _ident.defvjp(_fwd, _bwd)
    return _ident(x)


# ---------------------------------------------------------------------------
# Linear
# ---------------------------------------------------------------------------
def init_linear(key, d_in: int, d_out: int, *, bias: bool = False, dtype=jnp.float32):
    p = {"w": trunc_normal(key, (d_in, d_out), d_in**-0.5, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x: jax.Array) -> jax.Array:
    """x: (..., d_in) -> (..., d_out) through the BLAS hook."""
    y = hooks.call("matmul", x, p["w"])
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_norm(d: int, *, kind: str = "rmsnorm", dtype=jnp.float32):
    # NOTE: kind is inferred from structure at apply time ("b" present =>
    # layernorm) so param pytrees stay string-free (vmap/eval_shape-safe).
    if kind == "rmsnorm":
        return {"w": jnp.zeros((d,), dtype)}
    if kind == "layernorm":
        return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}
    raise ValueError(kind)


def norm(p, x: jax.Array) -> jax.Array:
    if "b" not in p:
        return hooks.call("rmsnorm", x, p["w"])
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
    return (y * p["w"].astype(jnp.float32) + p["b"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, *, theta: float = 10000.0) -> jax.Array:
    """x: (B, S, H, D) with D even; positions: (B, S) or (S,) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int, offset: int = 0) -> jax.Array:
    """(S, D) classic transformer sinusoidal table, f32."""
    pos = jnp.arange(offset, offset + seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-dim * jnp.log(10000.0) / d_model)
    ang = pos * inv
    out = jnp.zeros((seq_len, d_model), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang))
    out = out.at[:, 1::2].set(jnp.cos(ang))
    return out


# ---------------------------------------------------------------------------
# Depthwise causal temporal conv (Griffin / mLSTM front conv)
# ---------------------------------------------------------------------------
def init_conv1d(key, d: int, width: int, dtype=jnp.float32):
    return {"w": trunc_normal(key, (width, d), (width * d) ** -0.5, dtype),
            "b": jnp.zeros((d,), dtype)}


def conv1d(p, x: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv along time. x: (B, S, D); w: (W, D).

    If `state` (B, W-1, D) is given (decode), returns (y, new_state) for a
    single-step or chunk update; else returns y for the full sequence.
    """
    w = p["w"].astype(jnp.float32)
    width = w.shape[0]
    xf = x.astype(jnp.float32)
    if state is not None:
        ctx = jnp.concatenate([state.astype(jnp.float32), xf], axis=1)
    else:
        ctx = jnp.pad(xf, ((0, 0), (width - 1, 0), (0, 0)))
    s = x.shape[1]
    y = jnp.zeros_like(xf)
    for i in range(width):
        y = y + ctx[:, i : i + s, :] * w[i][None, None, :]
    y = y + p["b"].astype(jnp.float32)
    y = y.astype(x.dtype)
    if state is not None:
        return y, ctx[:, -(width - 1):, :].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------
def init_embedding(key, vocab: int, d: int, dtype=jnp.float32):
    return {"w": trunc_normal(key, (vocab, d), 1.0, dtype)}


def embed(p, tokens: jax.Array) -> jax.Array:
    return p["w"][tokens]


def unembed(p, x: jax.Array) -> jax.Array:
    """Tied LM head: (..., D) @ (V, D)^T -> (..., V), f32 logits."""
    return jnp.dot(x, p["w"].T, preferred_element_type=jnp.float32)
