"""DeepSeek-V2/V3 Multi-head Latent Attention.

Train/prefill uses the naive (decompressed) form; decode uses the *absorbed*
form: the KV cache stores only the compressed latent (kv_lora_rank) plus the
shared RoPE key (qk_rope_head_dim) per token — 576 values/token for V3 —
and attention runs MQA-style in latent space with W_UK/W_UV absorbed into the
query/output projections.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hooks
from repro.distributed import sharding
from repro.models import layers


def init(key, cfg):
    m = cfg.mla
    dt = jnp.dtype(cfg.param_dtype)
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "w_dq": layers.init_linear(ks[0], cfg.d_model, m.q_lora_rank, dtype=dt),
        "q_norm": layers.init_norm(m.q_lora_rank, kind=cfg.norm, dtype=dt),
        "w_uq": layers.init_linear(ks[1], m.q_lora_rank, cfg.num_heads * qk_head, dtype=dt),
        "w_dkv": layers.init_linear(
            ks[2], cfg.d_model, m.kv_lora_rank + m.qk_rope_head_dim, dtype=dt
        ),
        "kv_norm": layers.init_norm(m.kv_lora_rank, kind=cfg.norm, dtype=dt),
        "w_uk": layers.init_linear(ks[3], m.kv_lora_rank, cfg.num_heads * m.qk_nope_head_dim, dtype=dt),
        "w_uv": layers.init_linear(ks[4], m.kv_lora_rank, cfg.num_heads * m.v_head_dim, dtype=dt),
        "wo": layers.init_linear(ks[5], cfg.num_heads * m.v_head_dim, cfg.d_model, dtype=dt),
    }


def _queries(p, cfg, x, positions):
    """-> q_nope (B,*,H,nope), q_rope (B,*,H,rope) with RoPE applied."""
    m = cfg.mla
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    lead = x.shape[:-1]
    cq = layers.norm(p["q_norm"], layers.linear(p["w_dq"], x))
    q = layers.linear(p["w_uq"], cq).reshape(*lead, cfg.num_heads, qk_head)
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = layers.apply_rope(q[..., m.qk_nope_head_dim :], positions, theta=cfg.rope_theta)
    return q_nope, q_rope


def _latents(p, cfg, x, positions):
    """-> c_kv (B,*,kv_lora) normed, k_rope (B,*,1,rope) with RoPE."""
    m = cfg.mla
    ckv_full = layers.linear(p["w_dkv"], x)
    c_kv = layers.norm(p["kv_norm"], ckv_full[..., : m.kv_lora_rank])
    k_rope = ckv_full[..., m.kv_lora_rank :][..., None, :]  # single shared head
    k_rope = layers.apply_rope(k_rope, positions, theta=cfg.rope_theta)
    return c_kv, k_rope


def apply(p, cfg, x, positions, *, window=None):
    """Naive decompressed MLA for train/prefill. x: (B, S, D) pre-normed."""
    del window
    m = cfg.mla
    b, s, _ = x.shape
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    q_nope, q_rope = _queries(p, cfg, x, positions)
    c_kv, k_rope = _latents(p, cfg, x, positions)
    k_nope = layers.linear(p["w_uk"], c_kv).reshape(b, s, cfg.num_heads, m.qk_nope_head_dim)
    v = layers.linear(p["w_uv"], c_kv).reshape(b, s, cfg.num_heads, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, q_rope.shape)], axis=-1)
    q = sharding.constraint(q, "batch", "seq", "heads", None)
    k = sharding.constraint(k, "batch", "seq", "heads", None)
    v = sharding.constraint(v, "batch", "seq", "heads", None)
    o = hooks.call("attention", q, k, v, causal=True, scale=scale)
    return layers.linear(p["wo"], o.reshape(b, s, -1))


def prefill(p, cfg, x, positions, max_len: int, *, window=None):
    """Naive-form prefill + compressed-latent cache build."""
    del window
    m = cfg.mla
    b, s, _ = x.shape
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    q_nope, q_rope = _queries(p, cfg, x, positions)
    c_kv, k_rope = _latents(p, cfg, x, positions)
    k_nope = layers.linear(p["w_uk"], c_kv).reshape(b, s, cfg.num_heads, m.qk_nope_head_dim)
    v = layers.linear(p["w_uv"], c_kv).reshape(b, s, cfg.num_heads, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, q_rope.shape)], axis=-1)
    o = hooks.call("attention", q, k, v, causal=True, scale=scale)
    y = layers.linear(p["wo"], o.reshape(b, s, -1))
    state = init_state(cfg, b, max_len, c_kv.dtype)
    ckv = jax.lax.dynamic_update_slice(state["ckv"], c_kv, (0, 0, 0))
    krope = jax.lax.dynamic_update_slice(state["krope"], k_rope[:, :, 0, :], (0, 0, 0))
    ckv = sharding.constraint(ckv, "batch", "kv_seq", None)
    krope = sharding.constraint(krope, "batch", "kv_seq", None)
    return y, {"ckv": ckv, "krope": krope}


def init_state(cfg, batch: int, max_len: int, dtype):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
    }


def prefill_chunk(p, cfg, x, positions, state, start, lengths, *, window=None):
    """Continuation prefill against the compressed-latent cache: the chunk's
    latents are scattered in at absolute positions, the whole cache is
    decompressed to naive K/V, and the chunk's queries attend it with
    absolute causality (see attention.prefill_chunk for the contract)."""
    del window
    m = cfg.mla
    b, s, _ = x.shape
    max_len = state["ckv"].shape[1]
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    q_nope, q_rope = _queries(p, cfg, x, positions)
    c_kv, k_rope = _latents(p, cfg, x, positions)
    valid = jnp.arange(s)[None, :] < (lengths - start)[:, None]
    idx = jnp.where(valid, positions, max_len)  # out-of-range pads -> dropped
    bidx = jnp.arange(b)[:, None]
    ckv = state["ckv"].at[bidx, idx].set(
        c_kv.astype(state["ckv"].dtype), mode="drop")
    krope = state["krope"].at[bidx, idx].set(
        k_rope[:, :, 0, :].astype(state["krope"].dtype), mode="drop")
    ckv = sharding.constraint(ckv, "batch", "kv_seq", None)
    krope = sharding.constraint(krope, "batch", "kv_seq", None)
    k_nope = layers.linear(p["w_uk"], ckv).reshape(
        b, max_len, cfg.num_heads, m.qk_nope_head_dim)
    v = layers.linear(p["w_uv"], ckv).reshape(
        b, max_len, cfg.num_heads, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope,
         jnp.broadcast_to(krope[:, :, None, :],
                          (b, max_len, cfg.num_heads, m.qk_rope_head_dim))],
        axis=-1)
    o = hooks.call("chunk_attention", q, k, v, positions=positions, scale=scale)
    y = layers.linear(p["wo"], o.reshape(b, s, -1))
    return y, {"ckv": ckv, "krope": krope}


def init_paged_state(cfg, num_pages: int, page_size: int, dtype):
    """Paged compressed-latent pool (page 0 reserved as the null page)."""
    m = cfg.mla
    return {
        "ckv": jnp.zeros((num_pages, page_size, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((num_pages, page_size, m.qk_rope_head_dim), dtype),
    }


def _gather_pages(pool, block_tables):
    """(P, page, ...) pool + (B, N) tables -> (B, N*page, ...) logical cache
    (same contract as kernels/ref.py::gather_pages; local copy keeps the
    model layer off the kernels package)."""
    g = pool[block_tables]
    return g.reshape((g.shape[0], g.shape[1] * g.shape[2]) + g.shape[3:])


def prefill_chunk_paged(p, cfg, x, positions, state, block_tables, page_size,
                        start, lengths, *, window=None):
    """`prefill_chunk` against paged latent pools: scatter the chunk's
    latents through the block table, gather the logical caches, and run the
    identical decompress-and-attend body. The latent cache is single-"head"
    and tiny (kv_lora_rank + rope per token), so the portable gather is the
    paged tier here — the paged Pallas kernel targets the GQA K/V layout."""
    del window
    m = cfg.mla
    b, s, _ = x.shape
    n = block_tables.shape[1]
    max_len = n * page_size
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    q_nope, q_rope = _queries(p, cfg, x, positions)
    c_kv, k_rope = _latents(p, cfg, x, positions)
    # pads AND positions past the table's capacity route to the null page
    # (the contiguous path drops both via mode="drop")
    valid = (jnp.arange(s)[None, :] < (lengths - start)[:, None]) \
        & (positions < max_len)
    page_idx = jnp.clip(positions // page_size, 0, n - 1)
    phys = jnp.where(valid, jnp.take_along_axis(block_tables, page_idx, axis=1), 0)
    offset = positions % page_size
    ckv_pool = state["ckv"].at[phys, offset].set(c_kv.astype(state["ckv"].dtype))
    krope_pool = state["krope"].at[phys, offset].set(
        k_rope[:, :, 0, :].astype(state["krope"].dtype))
    ckv = _gather_pages(ckv_pool, block_tables)
    krope = _gather_pages(krope_pool, block_tables)
    k_nope = layers.linear(p["w_uk"], ckv).reshape(
        b, max_len, cfg.num_heads, m.qk_nope_head_dim)
    v = layers.linear(p["w_uv"], ckv).reshape(
        b, max_len, cfg.num_heads, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope,
         jnp.broadcast_to(krope[:, :, None, :],
                          (b, max_len, cfg.num_heads, m.qk_rope_head_dim))],
        axis=-1)
    o = hooks.call("chunk_attention", q, k, v, positions=positions, scale=scale)
    y = layers.linear(p["wo"], o.reshape(b, s, -1))
    return y, {"ckv": ckv_pool, "krope": krope_pool}


def decode_paged(p, cfg, x, state, block_tables, page_size, lengths, *,
                 window=None):
    """Absorbed-form decode against paged latent pools: scatter the current
    token's latents through the block table, gather the logical caches, and
    run the identical absorbed attention body."""
    del window
    m = cfg.mla
    b, _ = x.shape
    n = block_tables.shape[1]
    pos = (lengths - 1).astype(jnp.int32)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    q_nope, q_rope = _queries(p, cfg, x[:, None, :], pos[:, None])
    q_nope = q_nope.reshape(b, cfg.num_heads, m.qk_nope_head_dim)
    q_rope = q_rope.reshape(b, cfg.num_heads, m.qk_rope_head_dim)
    c_kv_t, k_rope_t = _latents(p, cfg, x[:, None, :], pos[:, None])
    safe = jnp.maximum(pos, 0)
    page_idx = jnp.clip(safe // page_size, 0, n - 1)
    phys = jnp.take_along_axis(block_tables, page_idx[:, None], axis=1)[:, 0]
    phys = jnp.where(lengths > 0, phys, 0)
    offset = safe % page_size
    ckv_pool = state["ckv"].at[phys, offset].set(
        c_kv_t[:, 0].astype(state["ckv"].dtype))
    krope_pool = state["krope"].at[phys, offset].set(
        k_rope_t[:, 0, 0].astype(state["krope"].dtype))
    ckv = _gather_pages(ckv_pool, block_tables)
    krope = _gather_pages(krope_pool, block_tables)
    w_uk = p["w_uk"]["w"].reshape(m.kv_lora_rank, cfg.num_heads, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bhn,chn->bhc", q_nope.astype(jnp.float32), w_uk.astype(jnp.float32))
    q_cat = jnp.concatenate([q_lat.astype(x.dtype), q_rope], axis=-1)
    k_cat = jnp.concatenate([ckv, krope], axis=-1)[:, :, None, :]
    v_lat = ckv[:, :, None, :]
    o_lat = hooks.call("decode_attention", q_cat, k_cat, v_lat, lengths=lengths, scale=scale)
    w_uv = p["w_uv"]["w"].reshape(m.kv_lora_rank, cfg.num_heads, m.v_head_dim)
    o = jnp.einsum("bhc,chv->bhv", o_lat.astype(jnp.float32), w_uv.astype(jnp.float32))
    y = layers.linear(p["wo"], o.astype(x.dtype).reshape(b, -1))
    return y, {"ckv": ckv_pool, "krope": krope_pool}


def decode(p, cfg, x, state, lengths, *, window=None):
    """Absorbed-form decode. x: (B, D); cache = latent (576/token for V3)."""
    del window
    m = cfg.mla
    b, _ = x.shape
    pos = (lengths - 1).astype(jnp.int32)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    q_nope, q_rope = _queries(p, cfg, x[:, None, :], pos[:, None])
    q_nope = q_nope.reshape(b, cfg.num_heads, m.qk_nope_head_dim)
    q_rope = q_rope.reshape(b, cfg.num_heads, m.qk_rope_head_dim)
    c_kv_t, k_rope_t = _latents(p, cfg, x[:, None, :], pos[:, None])
    bidx = jnp.arange(b)
    ckv = state["ckv"].at[bidx, pos].set(c_kv_t[:, 0].astype(state["ckv"].dtype))
    krope = state["krope"].at[bidx, pos].set(k_rope_t[:, 0, 0].astype(state["krope"].dtype))
    ckv = sharding.constraint(ckv, "batch", "kv_seq", None)
    krope = sharding.constraint(krope, "batch", "kv_seq", None)
    # absorb W_UK into the query: q_lat[b,h,c] = sum_n q_nope[b,h,n] W_UK[c,(h,n)]
    w_uk = p["w_uk"]["w"].reshape(m.kv_lora_rank, cfg.num_heads, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bhn,chn->bhc", q_nope.astype(jnp.float32), w_uk.astype(jnp.float32))
    q_cat = jnp.concatenate([q_lat.astype(x.dtype), q_rope], axis=-1)  # (B,H,cr+rope)
    k_cat = jnp.concatenate([ckv, krope], axis=-1)[:, :, None, :]  # (B,S,1,cr+rope)
    v_lat = ckv[:, :, None, :]  # (B,S,1,cr)
    o_lat = hooks.call("decode_attention", q_cat, k_cat, v_lat, lengths=lengths, scale=scale)
    # absorb W_UV into the output: v[b,h,v] = sum_c o_lat[b,h,c] W_UV[c,(h,v)]
    w_uv = p["w_uv"]["w"].reshape(m.kv_lora_rank, cfg.num_heads, m.v_head_dim)
    o = jnp.einsum("bhc,chv->bhv", o_lat.astype(jnp.float32), w_uv.astype(jnp.float32))
    y = layers.linear(p["wo"], o.astype(x.dtype).reshape(b, -1))
    return y, {"ckv": ckv, "krope": krope}
