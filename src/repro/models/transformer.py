"""Model assembly: embeddings/frontends + prefix blocks + scanned layer
pattern + LM head, with train (``forward``), prefill, and decode paths.

Layer layout follows ``ArchConfig``: ``prefix`` blocks are unrolled
(heterogeneous head, e.g. DeepSeek-V3's first dense layers); the ``pattern``
(one period of the layer mixture, e.g. Griffin's [rglru, rglru, local_attn])
repeats ``scan_repeats`` times via ``lax.scan`` over stacked params so HLO
size stays flat at any depth — essential for 61-88 layer archs on a 512-way
mesh.

Every mixer implements one contract (init / apply / prefill / init_state /
decode); this module only dispatches and owns the residual structure,
sharding constraints, and the scan.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed import sharding
from repro.models import attention, ffn, frontends, layers, mla, moe, rglru, xlstm


# ---------------------------------------------------------------------------
# Mixer dispatch table
# ---------------------------------------------------------------------------
class _MixerAdapter:
    def __init__(self, init, apply, prefill, init_state, decode,
                 prefill_chunk, *, init_paged_state=None, decode_paged=None,
                 prefill_chunk_paged=None):
        self.init = init
        self.apply = apply
        self.prefill = prefill
        self.init_state = init_state
        self.decode = decode
        # continuation prefill from an existing state at a per-row position
        # offset (the suffix-only half of prefix-cache reuse)
        self.prefill_chunk = prefill_chunk
        # paged-KV variants (vLLM-style shared page pool + per-row block
        # tables); None for recurrent mixers, whose state is not positional
        # and cannot be paged
        self.init_paged_state = init_paged_state
        self.decode_paged = decode_paged
        self.prefill_chunk_paged = prefill_chunk_paged


_MIXERS: dict[str, _MixerAdapter] = {
    "global_attn": _MixerAdapter(
        attention.init, attention.apply, attention.prefill,
        attention.init_state, attention.decode, attention.prefill_chunk,
        init_paged_state=attention.init_paged_state,
        decode_paged=attention.decode_paged,
        prefill_chunk_paged=attention.prefill_chunk_paged),
    "local_attn": _MixerAdapter(
        attention.init, attention.apply, attention.prefill,
        attention.init_state, attention.decode, attention.prefill_chunk,
        init_paged_state=attention.init_paged_state,
        decode_paged=attention.decode_paged,
        prefill_chunk_paged=attention.prefill_chunk_paged),
    "mla": _MixerAdapter(
        mla.init, mla.apply, mla.prefill, mla.init_state, mla.decode,
        mla.prefill_chunk,
        init_paged_state=mla.init_paged_state,
        decode_paged=mla.decode_paged,
        prefill_chunk_paged=mla.prefill_chunk_paged),
    "rglru": _MixerAdapter(
        rglru.init, rglru.apply, rglru.prefill, rglru.init_state,
        rglru.decode, rglru.prefill_chunk),
    "mlstm": _MixerAdapter(
        xlstm.init_mlstm, xlstm.apply_mlstm, xlstm.prefill_mlstm,
        xlstm.init_mlstm_state, xlstm.decode_mlstm,
        xlstm.prefill_mlstm_chunk),
    "slstm": _MixerAdapter(
        xlstm.init_slstm, xlstm.apply_slstm, xlstm.prefill_slstm,
        xlstm.init_slstm_state, xlstm.decode_slstm,
        xlstm.prefill_slstm_chunk),
}


def supports_paged_kv(cfg) -> bool:
    """True when every mixer in the arch has a paged-KV path (attention
    family: global/local attention + MLA). Recurrent mixers carry
    non-positional state that cannot live in a shared page pool."""
    return all(_MIXERS[s.mixer].decode_paged is not None
               for s in tuple(cfg.prefix) + tuple(cfg.pattern))


def _window(cfg, spec) -> int | None:
    return cfg.local_window if spec.mixer == "local_attn" else None


# ---------------------------------------------------------------------------
# Block = norm + mixer (+ norm + ffn), pre-norm residual
# ---------------------------------------------------------------------------
def init_block(key, cfg, spec):
    k1, k2 = jax.random.split(key)
    dt = jnp.dtype(cfg.param_dtype)
    p: dict[str, Any] = {
        "norm1": layers.init_norm(cfg.d_model, kind=cfg.norm, dtype=dt),
        "mixer": _MIXERS[spec.mixer].init(k1, cfg),
    }
    if spec.ffn != "none":
        if not cfg.parallel_residual:
            p["norm2"] = layers.init_norm(cfg.d_model, kind=cfg.norm, dtype=dt)
        p["ffn"] = moe.init(k2, cfg) if spec.ffn == "moe" else ffn.init(k2, cfg, kind=spec.ffn)
    return p


def _apply_ffn(p, cfg, spec, h):
    """-> (out, aux_loss scalar)."""
    if spec.ffn == "moe":
        if h.ndim == 2:
            # decode: treat the whole batch as one routing group (1, B, D)
            out, metrics = moe.apply(p["ffn"], cfg, h[None])
            return out[0], metrics["moe_aux_loss"]
        out, metrics = moe.apply(p["ffn"], cfg, h)
        return out, metrics["moe_aux_loss"]
    return ffn.apply(p["ffn"], cfg, h, kind=spec.ffn), jnp.float32(0.0)


def apply_block(p, cfg, spec, x, positions):
    """Full-sequence block. x: (B, S, D) -> ((B, S, D), aux_loss)."""
    n1 = layers.norm(p["norm1"], x)
    h = _MIXERS[spec.mixer].apply(p["mixer"], cfg, n1, positions, window=_window(cfg, spec))
    aux = jnp.float32(0.0)
    if cfg.parallel_residual and spec.ffn != "none":
        f, aux = _apply_ffn(p, cfg, spec, n1)
        x = x + h + f
    else:
        x = x + h
        if spec.ffn != "none":
            f, aux = _apply_ffn(p, cfg, spec, layers.norm(p["norm2"], x))
            x = x + f
    return sharding.constraint(x, "batch", "seq", "embed"), aux


def prefill_block(p, cfg, spec, x, positions, max_len):
    """Like apply_block but also returns the mixer's serving state."""
    n1 = layers.norm(p["norm1"], x)
    h, state = _MIXERS[spec.mixer].prefill(
        p["mixer"], cfg, n1, positions, max_len, window=_window(cfg, spec))
    if cfg.parallel_residual and spec.ffn != "none":
        f, _ = _apply_ffn(p, cfg, spec, n1)
        x = x + h + f
    else:
        x = x + h
        if spec.ffn != "none":
            f, _ = _apply_ffn(p, cfg, spec, layers.norm(p["norm2"], x))
            x = x + f
    return sharding.constraint(x, "batch", "seq", "embed"), state


def prefill_chunk_block(p, cfg, spec, x, positions, state, start, lengths,
                        *, block_tables=None, page_size=None):
    """Like prefill_block but continues from an existing mixer state at a
    per-row position offset (positions: (B, Sc) absolute). When
    ``block_tables`` is given, ``state`` is a shared page pool and writes
    land through the per-row block table instead of a per-slot cache."""
    n1 = layers.norm(p["norm1"], x)
    ad = _MIXERS[spec.mixer]
    if block_tables is not None:
        if ad.prefill_chunk_paged is None:
            raise NotImplementedError(
                f"mixer {spec.mixer!r} has no paged-KV prefill path")
        h, new_state = ad.prefill_chunk_paged(
            p["mixer"], cfg, n1, positions, state, block_tables, page_size,
            start, lengths, window=_window(cfg, spec))
    else:
        h, new_state = ad.prefill_chunk(
            p["mixer"], cfg, n1, positions, state, start, lengths,
            window=_window(cfg, spec))
    if cfg.parallel_residual and spec.ffn != "none":
        f, _ = _apply_ffn(p, cfg, spec, n1)
        x = x + h + f
    else:
        x = x + h
        if spec.ffn != "none":
            f, _ = _apply_ffn(p, cfg, spec, layers.norm(p["norm2"], x))
            x = x + f
    return sharding.constraint(x, "batch", "seq", "embed"), new_state


def init_block_state(cfg, spec, batch, max_len, dtype):
    return _MIXERS[spec.mixer].init_state(cfg, batch, max_len, dtype)


def decode_block(p, cfg, spec, x, state, lengths, *, block_tables=None,
                 page_size=None):
    """Single-token block. x: (B, D) -> ((B, D), new_state)."""
    n1 = layers.norm(p["norm1"], x)
    ad = _MIXERS[spec.mixer]
    if block_tables is not None:
        if ad.decode_paged is None:
            raise NotImplementedError(
                f"mixer {spec.mixer!r} has no paged-KV decode path")
        h, new_state = ad.decode_paged(
            p["mixer"], cfg, n1, state, block_tables, page_size, lengths,
            window=_window(cfg, spec))
    else:
        h, new_state = ad.decode(
            p["mixer"], cfg, n1, state, lengths, window=_window(cfg, spec))
    if cfg.parallel_residual and spec.ffn != "none":
        f, _ = _apply_ffn(p, cfg, spec, n1)
        x = x + h + f
    else:
        x = x + h
        if spec.ffn != "none":
            f, _ = _apply_ffn(p, cfg, spec, layers.norm(p["norm2"], x))
            x = x + f
    return x, new_state


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------
def init_model(key, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    n_keys = 6 + len(cfg.prefix) + len(cfg.pattern)
    ks = list(jax.random.split(key, n_keys))
    params: dict[str, Any] = {}
    if cfg.frontend == "audio":
        params["codebook_embed"] = frontends.init_audio_embed(ks[0], cfg)
        params["codebook_head"] = frontends.init_audio_heads(ks[1], cfg)
    else:
        params["embed"] = layers.init_embedding(ks[0], cfg.vocab_size, cfg.d_model, dtype=dt)
        if not cfg.tie_embeddings:
            params["lm_head"] = {
                "w": layers.trunc_normal(ks[1], (cfg.d_model, cfg.vocab_size),
                                         cfg.d_model**-0.5, dt)
            }
    if cfg.frontend == "vlm":
        params["frontend"] = frontends.init_vlm(ks[2], cfg)
    params["final_norm"] = layers.init_norm(cfg.d_model, kind=cfg.norm, dtype=dt)
    params["prefix"] = tuple(
        init_block(ks[3 + i], cfg, spec) for i, spec in enumerate(cfg.prefix)
    )
    scan = []
    base = 3 + len(cfg.prefix)
    for j, spec in enumerate(cfg.pattern):
        kj = jax.random.split(ks[base + j], cfg.scan_repeats)
        scan.append(jax.vmap(lambda kk, spec=spec: init_block(kk, cfg, spec))(kj))
    params["scan"] = tuple(scan)
    return params


# ---------------------------------------------------------------------------
# Embedding / head ends
# ---------------------------------------------------------------------------
def embed_inputs(params, cfg, tokens, patch_embeds=None):
    """-> (x (B, S, D), positions (B, S) or (S,))."""
    if cfg.frontend == "audio":
        x = frontends.audio_embed(params["codebook_embed"], tokens)
    else:
        x = layers.embed(params["embed"], tokens)
    x = x.astype(jnp.dtype(cfg.activ_dtype))
    if cfg.frontend == "vlm":
        assert patch_embeds is not None, "vlm arch requires patch_embeds"
        vis = frontends.project_patches(params["frontend"], cfg, patch_embeds)
        x = jnp.concatenate([vis, x], axis=1)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    if cfg.pos == "sinusoidal":
        x = x + layers.sinusoidal_positions(s, cfg.d_model).astype(x.dtype)
    return sharding.constraint(x, "batch", "seq", "embed"), positions


def lm_logits(params, cfg, x):
    """x: (B, S, D) -> f32 logits (B, S, V) (or (B, K, S, V) for audio)."""
    x = layers.norm(params["final_norm"], x)
    if cfg.frontend == "audio":
        logits = frontends.audio_logits(params["codebook_head"], x)
        return sharding.constraint(logits, "batch", None, "seq", "vocab")
    if cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], x)
    else:
        logits = jnp.dot(x, params["lm_head"]["w"], preferred_element_type=jnp.float32)
    return sharding.constraint(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# Full-sequence forward (train)
# ---------------------------------------------------------------------------
def apply_layers(params, cfg, x, positions, *, remat: str | None = "full"):
    """Runs prefix + scanned blocks. -> (x, total_aux_loss).

    Remat is applied PER BLOCK (not per pattern period): backward
    rematerializes one block at a time, so peak memory is one block's
    internals even for multi-block periods (xLSTM's 7:1 pattern would
    otherwise hold 8 blocks of chunk-scan residuals live at once).
    """
    aux = jnp.float32(0.0)
    block = _maybe_remat(
        lambda p, spec, x: apply_block(p, cfg, spec, x, positions), remat)
    for p, spec in zip(params["prefix"], cfg.prefix):
        x, a = block(p, spec, x)
        aux = aux + a

    if cfg.scan_repeats == 0:
        return x, aux

    def body(carry, layer_params):
        x, aux = carry
        # keep the scan's stacked-param cotangent accumulator in param dtype
        layer_params = jax.tree.map(layers.grad_dtype_barrier, layer_params)
        for j, spec in enumerate(cfg.pattern):
            x, a = block(layer_params[j], spec, x)
            aux = aux + a
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(body, (x, aux), params["scan"])
    return x, aux


def _maybe_remat(fn, remat: str | None):
    if remat is None:
        return fn
    policies = {
        "full": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        "save_anything": jax.checkpoint_policies.everything_saveable,
    }
    return jax.checkpoint(fn, policy=policies[remat], prevent_cse=False,
                          static_argnums=(1,))


def forward(params, cfg, tokens, *, patch_embeds=None, remat: str | None = "full"):
    """Train/eval forward. tokens: (B, S) int32 ((B, K, S) for audio).

    Returns (logits, aux_loss): logits f32 (B, S_total, V) — for vlm,
    S_total = num_image_tokens + S_text; (B, K, S, V) for audio.
    """
    x, positions = embed_inputs(params, cfg, tokens, patch_embeds)
    x, aux = apply_layers(params, cfg, x, positions, remat=remat)
    return lm_logits(params, cfg, x), aux


# ---------------------------------------------------------------------------
# Serving: states, prefill, decode
# ---------------------------------------------------------------------------
def init_states(cfg, batch: int, max_len: int, dtype):
    """Per-layer serving state: prefix list + stacked scan states."""
    prefix = tuple(
        init_block_state(cfg, spec, batch, max_len, dtype) for spec in cfg.prefix
    )
    scan = []
    for spec in cfg.pattern:
        one = init_block_state(cfg, spec, batch, max_len, dtype)
        # tile (not zeros): mLSTM/sLSTM stabilizer `m` inits to a -1e30 fill
        scan.append(jax.tree.map(
            lambda a: jnp.tile(a[None], (cfg.scan_repeats,) + (1,) * a.ndim), one))
    return {"prefix": prefix, "scan": tuple(scan)}


def init_paged_states(cfg, num_pages: int, page_size: int, dtype):
    """Paged serving state: one shared page pool per layer instead of a
    per-slot contiguous cache. Physical page 0 is the null page — inactive
    rows' writes are routed there and it is never handed to a request, so
    the usable pool is ``num_pages - 1`` pages."""
    def one(spec):
        ad = _MIXERS[spec.mixer]
        if ad.init_paged_state is None:
            raise NotImplementedError(
                f"mixer {spec.mixer!r} has no paged-KV state; paged serving "
                "requires an attention-family arch (see supports_paged_kv)")
        return ad.init_paged_state(cfg, num_pages, page_size, dtype)

    prefix = tuple(one(spec) for spec in cfg.prefix)
    scan = []
    for spec in cfg.pattern:
        st = one(spec)
        scan.append(jax.tree.map(
            lambda a: jnp.tile(a[None], (cfg.scan_repeats,) + (1,) * a.ndim), st))
    return {"prefix": prefix, "scan": tuple(scan)}


def prefill(params, cfg, tokens, max_len: int, *, patch_embeds=None):
    """Process a full prompt, building serving state.

    Returns (logits_last (B, V) f32, states, lengths (B,)).
    """
    x, positions = embed_inputs(params, cfg, tokens, patch_embeds)
    b, s = x.shape[:2]
    prefix_states = []
    for p, spec in zip(params["prefix"], cfg.prefix):
        x, st = prefill_block(p, cfg, spec, x, positions, max_len)
        prefix_states.append(st)

    scan_states = ()
    if cfg.scan_repeats:
        def body(x, layer_params):
            states = []
            for j, spec in enumerate(cfg.pattern):
                x, st = prefill_block(layer_params[j], cfg, spec, x, positions, max_len)
                states.append(st)
            return x, tuple(states)

        x, scan_states = jax.lax.scan(body, x, params["scan"])

    logits = lm_logits(params, cfg, x[:, -1:])
    lengths = jnp.full((b,), s, jnp.int32)
    states = {"prefix": tuple(prefix_states), "scan": scan_states}
    if cfg.frontend == "audio":
        return logits[:, :, 0], states, lengths
    return logits[:, 0], states, lengths


def _chunk_embed(params, cfg, tokens, start):
    """Embed a continuation chunk at absolute positions start + [0, Sc).
    Shared front end of :func:`prefill_chunk` and :func:`verify_chunk`.
    Returns (x (B, Sc, D), positions (B, Sc))."""
    if cfg.frontend == "vlm":
        raise NotImplementedError(
            "chunked prefill does not support the vlm frontend")
    if cfg.frontend == "audio":
        x = frontends.audio_embed(params["codebook_embed"], tokens)
    else:
        x = layers.embed(params["embed"], tokens)
    x = x.astype(jnp.dtype(cfg.activ_dtype))
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    b, s = x.shape[:2]
    positions = start[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    if cfg.pos == "sinusoidal":
        d = cfg.d_model
        pos = positions[..., None].astype(jnp.float32)  # (B, Sc, 1)
        dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, None, :]
        inv = jnp.exp(-dim * jnp.log(10000.0) / d)
        ang = pos * inv
        pe = jnp.zeros((b, s, d), jnp.float32)
        pe = pe.at[..., 0::2].set(jnp.sin(ang)).at[..., 1::2].set(jnp.cos(ang))
        x = x + pe.astype(x.dtype)
    return sharding.constraint(x, "batch", "seq", "embed"), positions


def prefill_chunk(params, cfg, tokens, states, start, lengths, *,
                  block_tables=None, page_size=None):
    """Continue a prefill from per-row position ``start``: process a
    (right-padded) token chunk at absolute positions [start, start+Sc) on top
    of existing serving ``states`` (e.g. a prefix restored from a prefix
    cache; fresh init_states + start=0 gives a plain ragged prefill).

    tokens: (B, Sc) int32 ((B, K, Sc) audio), each row's real suffix at the
    FRONT, zero-padded at the tail; start: (B,) int32 prefix lengths already
    in ``states``; lengths: (B,) int32 total valid entries after the chunk
    (start + real chunk length, >= start + 1).

    Returns (logits at each row's last real position (B, V) f32 ((B, K, V)
    audio), new_states, lengths).
    """
    x, positions = _chunk_embed(params, cfg, tokens, start)

    new_prefix = []
    for p, spec, st in zip(params["prefix"], cfg.prefix, states["prefix"]):
        x, st2 = prefill_chunk_block(p, cfg, spec, x, positions, st, start,
                                     lengths, block_tables=block_tables,
                                     page_size=page_size)
        new_prefix.append(st2)

    new_scan = states["scan"]
    if cfg.scan_repeats:
        def body(x, xs):
            layer_params, layer_states = xs
            outs = []
            for j, spec in enumerate(cfg.pattern):
                x, st2 = prefill_chunk_block(
                    layer_params[j], cfg, spec, x, positions, layer_states[j],
                    start, lengths, block_tables=block_tables,
                    page_size=page_size)
                outs.append(st2)
            return x, tuple(outs)

        x, new_scan = jax.lax.scan(body, x, (params["scan"], states["scan"]))

    last = (lengths - start - 1)[:, None, None]  # each row's last real chunk pos
    x_last = jnp.take_along_axis(x, last, axis=1)  # (B, 1, D)
    logits = lm_logits(params, cfg, x_last)
    new_states = {"prefix": tuple(new_prefix), "scan": new_scan}
    if cfg.frontend == "audio":
        return logits[:, :, 0], new_states, lengths
    return logits[:, 0], new_states, lengths


def verify_chunk(params, cfg, tokens, states, start, *, block_tables=None,
                 page_size=None):
    """Speculative-verification forward: process a (B, C) token chunk at
    absolute positions [start, start+C) and return the logits at EVERY
    position — one target forward verifies C = K+1 speculative positions
    per row (the last accepted token plus K drafted tokens).

    Reuses the :func:`prefill_chunk` per-mixer machinery, so cache writes
    land at absolute positions and rejected positions are rolled back for
    free by the right-aligned layout: they sit beyond the committed decode
    length, masked out of every later read and overwritten by the next
    chunk's writes before they could ever become visible. Only valid for
    archs whose whole serving state is positional (attention / MLA KV);
    recurrent mixers advance non-positional state irreversibly — use
    :func:`verify_stepwise` for those.

    tokens: (B, C) int32; start: (B,) int32 tokens already in the caches.
    Returns (logits (B, C, V) f32, new_states).
    """
    if cfg.frontend == "audio":
        raise NotImplementedError(
            "speculative verification does not support the audio frontend")
    x, positions = _chunk_embed(params, cfg, tokens, start)
    c = tokens.shape[1]
    lengths = start + c  # every chunk position is written (none are pads)

    new_prefix = []
    for p, spec, st in zip(params["prefix"], cfg.prefix, states["prefix"]):
        x, st2 = prefill_chunk_block(p, cfg, spec, x, positions, st, start,
                                     lengths, block_tables=block_tables,
                                     page_size=page_size)
        new_prefix.append(st2)

    new_scan = states["scan"]
    if cfg.scan_repeats:
        def body(x, xs):
            layer_params, layer_states = xs
            outs = []
            for j, spec in enumerate(cfg.pattern):
                x, st2 = prefill_chunk_block(
                    layer_params[j], cfg, spec, x, positions, layer_states[j],
                    start, lengths, block_tables=block_tables,
                    page_size=page_size)
                outs.append(st2)
            return x, tuple(outs)

        x, new_scan = jax.lax.scan(body, x, (params["scan"], states["scan"]))

    logits = lm_logits(params, cfg, x)  # (B, C, V): all positions
    return logits, {"prefix": tuple(new_prefix), "scan": new_scan}


def verify_stepwise(params, cfg, tokens, states, lengths, active):
    """Sequential speculative verification for archs with recurrent
    (non-positional) serving state: run C single-token decode steps and
    return the state tree after EVERY step, so the caller can roll the
    recurrent leaves back to the accepted boundary (positional leaves roll
    back for free via the decode length mask, exactly as in
    :func:`verify_chunk`).

    tokens: (B, C) int32 — [last accepted token, draft_1 .. draft_K];
    lengths: (B,) int32 tokens already in the caches; active: (B,) bool
    (inactive rows' lengths do not advance, matching the fused decode step).
    Returns (logits (B, C, V) f32, [states after step 1, ..., after step C]).
    """
    if cfg.frontend == "audio":
        raise NotImplementedError(
            "speculative verification does not support the audio frontend")
    logits_all, states_all = [], []
    st, lens = states, lengths
    inc = active.astype(jnp.int32)
    for i in range(tokens.shape[1]):
        lens = lens + inc
        lg, st = decode_step(params, cfg, tokens[:, i], st, lens)
        logits_all.append(lg)
        states_all.append(st)
    return jnp.stack(logits_all, axis=1), states_all


def decode_step(params, cfg, tokens, states, lengths, *, block_tables=None,
                page_size=None):
    """One decode step for the whole stack.

    tokens: (B,) int32 ((B, K) for audio) — the token(s) at position
    lengths-1 (i.e. the cache slot being written this step).
    Returns (logits (B, V) / (B, K, V) f32, new_states).
    """
    if cfg.frontend == "audio":
        x = frontends.audio_embed(params["codebook_embed"], tokens[:, :, None])[:, 0]
    else:
        x = layers.embed(params["embed"], tokens)
    x = x.astype(jnp.dtype(cfg.activ_dtype))
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    if cfg.pos == "sinusoidal":
        # per-example position: lengths-1
        d = cfg.d_model
        pos = (lengths - 1).astype(jnp.float32)[:, None]
        dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
        inv = jnp.exp(-dim * jnp.log(10000.0) / d)
        ang = pos * inv
        pe = jnp.zeros((x.shape[0], d), jnp.float32)
        pe = pe.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
        x = x + pe.astype(x.dtype)
    x = sharding.constraint(x, "batch", "embed")

    new_prefix = []
    for p, spec, st in zip(params["prefix"], cfg.prefix, states["prefix"]):
        x, st2 = decode_block(p, cfg, spec, x, st, lengths,
                              block_tables=block_tables, page_size=page_size)
        new_prefix.append(st2)

    new_scan = states["scan"]
    if cfg.scan_repeats:
        def body(x, xs):
            layer_params, layer_states = xs
            new_states = []
            for j, spec in enumerate(cfg.pattern):
                x, st2 = decode_block(layer_params[j], cfg, spec, x,
                                      layer_states[j], lengths,
                                      block_tables=block_tables,
                                      page_size=page_size)
                new_states.append(st2)
            return x, tuple(new_states)

        x, new_scan = jax.lax.scan(body, x, (params["scan"], states["scan"]))

    logits = lm_logits(params, cfg, x[:, None, :])
    new_states = {"prefix": tuple(new_prefix), "scan": new_scan}
    if cfg.frontend == "audio":
        return logits[:, :, 0], new_states
    return logits[:, 0], new_states


def decode_and_sample(params, cfg, tokens, states, lengths, key, sample_fn,
                      *, block_tables=None, page_size=None):
    """Fused decode + sample: ONE traced program for the serving hot path.

    ``sample_fn(key, logits) -> int32 ids`` runs inside the same jit as the
    decode, so per-slot sampling (vectorized temperature/top-k) costs no
    extra dispatch and no host round-trip — the serving engine's whole
    per-step data plane compiles to a single XLA executable around this.

    Returns (new_tokens (B,) / (B, K) int32, new_states, logits).
    """
    logits, new_states = decode_step(params, cfg, tokens, states, lengths,
                                     block_tables=block_tables,
                                     page_size=page_size)
    return sample_fn(key, logits), new_states, logits


# ---------------------------------------------------------------------------
# Analytic parameter counts (MODEL_FLOPS and accounting)
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=64)
def _param_shapes(cfg):
    return jax.eval_shape(lambda: init_model(jax.random.key(0), cfg))


def param_counts(cfg) -> dict[str, int]:
    """total / embed (tables) / routed (MoE expert) / active per-token."""
    shapes = _param_shapes(cfg)
    total = embed_n = routed = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(shapes):
        s = sharding._path_str(path)
        n = int(leaf.size)
        total += n
        if "embed/w" in s or "codebook_embed" in s:
            embed_n += n
        if "/experts/" in s or s.endswith("experts/w_gate") or s.endswith(
            "experts/w_up") or s.endswith("experts/w_down"):
            routed += n
    active = total - routed
    if cfg.moe:
        active += routed * cfg.moe.top_k // cfg.moe.num_experts
    return {
        "total": total,
        "embed": embed_n,
        "routed": routed,
        "active": active,
        "active_nonembed": active - embed_n,
        "total_nonembed": total - embed_n,
    }
