"""Mixture-of-Experts FFN: sort-based capacity dispatch (no one-hot einsums).

Dispatch is the production-style sorted/ragged scheme (MegaBlocks/MaxText
lineage) rather than GShard one-hot einsums: one-hot dispatch inflates HLO
FLOPs by O(E) and destroys the roofline compute term. Here routing costs only
a per-group argsort + scatter (static shapes, vmapped over groups), and the
expert compute is the `moe_mlp` accelerated hook (Pallas grouped-matmul on
TPU).

Supports top-k routing with renormalized gates, optional DeepSeek-V3
aux-loss-free bias routing, shared experts, and a Switch-style load-balance
auxiliary loss metric.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import hooks
from repro.distributed import sharding
from repro.models import layers


def capacity(cfg, tokens_per_group: int) -> int:
    m = cfg.moe
    c = math.ceil(tokens_per_group * m.top_k * m.capacity_factor / m.num_experts)
    return max(8, int(math.ceil(c / 8) * 8))


def init(key, cfg):
    m = cfg.moe
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": {"w": layers.trunc_normal(ks[0], (cfg.d_model, m.num_experts),
                                            cfg.d_model**-0.5, jnp.float32)},
        "experts": {
            "w_gate": layers.trunc_normal(ks[1], (m.num_experts, cfg.d_model, m.d_expert),
                                          cfg.d_model**-0.5, dt),
            "w_up": layers.trunc_normal(ks[2], (m.num_experts, cfg.d_model, m.d_expert),
                                        cfg.d_model**-0.5, dt),
            "w_down": layers.trunc_normal(ks[3], (m.num_experts, m.d_expert, cfg.d_model),
                                          m.d_expert**-0.5, dt),
        },
    }
    if m.bias_routing:
        p["router"]["bias"] = jnp.zeros((m.num_experts,), jnp.float32)
    if m.num_shared_experts:
        d_sh = m.d_shared * m.num_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": layers.init_linear(kk[0], cfg.d_model, d_sh, dtype=dt),
            "w_up": layers.init_linear(kk[1], cfg.d_model, d_sh, dtype=dt),
            "w_down": layers.init_linear(kk[2], d_sh, cfg.d_model, dtype=dt),
        }
    return p


def _route_group(flat_ids: jax.Array, num_experts: int, cap: int):
    """Per-group routing plan. flat_ids: (T*k,) expert assignment per slot.

    Returns (dest, token_slot, keep): dest[i] in [0, E*C] is the bucket index
    for sorted slot i (E*C = dropped), token_slot[i] = which flat slot it came
    from."""
    n = flat_ids.shape[0]
    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]
    seg_start = jnp.searchsorted(sorted_ids, jnp.arange(num_experts), side="left")
    rank = jnp.arange(n) - seg_start[sorted_ids]
    keep = rank < cap
    dest = jnp.where(keep, sorted_ids * cap + rank, num_experts * cap)
    return dest, order, keep


def router(p, cfg, x):
    """x: (..., D) -> (probs(...,k), ids(...,k), full_probs(...,E))."""
    m = cfg.moe
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32), p["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)
    select = probs + p["router"]["bias"] if m.bias_routing else probs
    _, ids = jax.lax.top_k(select, m.top_k)
    gates = jnp.take_along_axis(probs, ids, axis=-1)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, ids, probs


def apply(p, cfg, x):
    """x: (B, S, D) pre-normed. Returns (out (B,S,D), metrics dict).

    All bulk data movement is GATHERS over permutation indices (never a
    (tokens, D) scatter): XLA SPMD partitions gathers on the untouched D dim,
    so dispatch/combine shard over "model" ("moe_d" rule) instead of
    replicating + all-reducing — the scatter formulation cost ~1 GiB/chip/
    layer in replicated u32/f32 dispatch buffers on the 671B dry-run.
    """
    m = cfg.moe
    b, s, d = x.shape
    k = m.top_k
    cap = capacity(cfg, s)
    e = m.num_experts
    nslots = e * cap
    gates, ids, probs = router(p, cfg, x)  # (B,S,k), (B,S,k), (B,S,E)

    flat_ids = ids.reshape(b, s * k)
    dest, order, keep = jax.vmap(lambda f: _route_group(f, e, cap))(flat_ids)
    token_slot = order // k  # (B, S*k) token index per sorted slot

    # ---- dispatch: rows in sorted-by-expert order, then bucket order ----
    dispatched = jnp.take_along_axis(x, token_slot[..., None], axis=1)
    dispatched = sharding.constraint(dispatched, "expert_group", None, "moe_d")
    # zero row appended: empty bucket slots gather it via the sentinel index
    dispatched = jnp.concatenate(
        [dispatched, jnp.zeros((b, 1, d), dispatched.dtype)], axis=1)
    # inverse slot map: bucket position -> sorted slot (sentinel = s*k)
    sorted_idx = jnp.broadcast_to(jnp.arange(s * k, dtype=jnp.int32), (b, s * k))
    inv = jnp.full((b, nslots + 1), s * k, jnp.int32)
    inv = jax.vmap(lambda iv, d_, i_: iv.at[d_].set(i_))(inv, dest, sorted_idx)
    buckets = jnp.take_along_axis(dispatched, inv[:, :nslots, None], axis=1)
    buckets = buckets.reshape(b, e, cap, d)
    buckets = sharding.constraint(
        buckets, "expert_group", "experts", None, "moe_d")
    # all-to-all: regroup so experts own their buckets (E leading, sharded).
    inputs = buckets.transpose(1, 0, 2, 3).reshape(e, b * cap, d)
    inputs = sharding.constraint(inputs, "experts", "expert_cap", "moe_d")

    out_buckets = hooks.call(
        "moe_mlp", inputs, p["experts"]["w_gate"], p["experts"]["w_up"], p["experts"]["w_down"]
    )
    out_buckets = sharding.constraint(out_buckets, "experts", "expert_cap", "moe_d")
    out_buckets = out_buckets.reshape(e, b, cap, d).transpose(1, 0, 2, 3)
    out_buckets = sharding.constraint(
        out_buckets, "expert_group", "experts", None, "moe_d")
    out_flat = out_buckets.reshape(b, nslots, d)

    # ---- combine: pure gathers -> (B,S,k,D) -> gate-weighted sum over k ----
    perm_inv = jnp.argsort(order, axis=-1)  # flat slot t*k+j -> sorted pos
    bucket_of_flat = jnp.take_along_axis(dest, perm_inv, axis=-1)  # (B,S*k)
    keep_flat = jnp.take_along_axis(keep, perm_inv, axis=-1)
    vals = jnp.take_along_axis(
        out_flat, jnp.minimum(bucket_of_flat, nslots - 1)[..., None], axis=1)
    vals = sharding.constraint(vals, "expert_group", None, "moe_d")
    w = gates.reshape(b, s * k) * keep_flat  # (B, S*k) f32
    out = jnp.sum(
        (vals * w[..., None].astype(vals.dtype)).reshape(b, s, k, d), axis=2)

    # ---- shared experts (dense branch) ----
    if m.num_shared_experts:
        sh = p["shared"]
        g = layers.linear(sh["w_gate"], x)
        u = layers.linear(sh["w_up"], x)
        out = out + layers.linear(sh["w_down"], jax.nn.silu(g) * u)

    # ---- metrics: Switch load-balance loss + drop fraction ----
    assign_frac = jnp.zeros((e,), jnp.float32).at[ids.reshape(-1)].add(1.0) / (
        b * s * m.top_k
    )
    importance = jnp.mean(probs.reshape(-1, e), axis=0)
    aux_loss = e * jnp.sum(assign_frac * importance) * m.aux_loss_coef
    metrics = {
        "moe_aux_loss": aux_loss,
        "moe_dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
        "moe_load": assign_frac,
    }
    return out.astype(x.dtype), metrics


def update_router_bias(bias: jax.Array, load: jax.Array, *, rate: float = 1e-3) -> jax.Array:
    """DeepSeek-V3 aux-loss-free balancing: nudge per-expert selection bias
    against the observed load imbalance (applied outside the gradient)."""
    err = jnp.mean(load) - load  # positive for under-loaded experts
    return bias + rate * jnp.sign(err)
