"""Dense FFN variants: SwiGLU (llama lineage) and GELU MLP (classic)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import sharding
from repro.models import layers


def init(key, cfg, *, kind: str):
    dt = jnp.dtype(cfg.param_dtype)
    dff = cfg.dense_d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": layers.init_linear(ks[0], cfg.d_model, dff, dtype=dt),
            "w_up": layers.init_linear(ks[1], cfg.d_model, dff, dtype=dt),
            "w_down": layers.init_linear(ks[2], dff, cfg.d_model, dtype=dt),
        }
    if kind == "gelu_mlp":
        return {
            "w_up": layers.init_linear(ks[0], cfg.d_model, dff, dtype=dt),
            "w_down": layers.init_linear(ks[1], dff, cfg.d_model, dtype=dt),
        }
    raise ValueError(kind)


def apply(p, cfg, x, *, kind: str = "swiglu"):
    """x: (..., D) pre-normed -> (..., D)."""
    if "w_gate" in p:
        act = jax.nn.gelu if kind == "geglu" else jax.nn.silu
        g = layers.linear(p["w_gate"], x)
        u = layers.linear(p["w_up"], x)
        h = (act(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(x.dtype)
    else:
        u = layers.linear(p["w_up"], x)
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    if x.ndim == 3:
        h = sharding.constraint(h, "batch", "seq", "ff")
    return layers.linear(p["w_down"], h)
