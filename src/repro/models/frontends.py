"""Modality frontend STUBS (per the assignment: the transformer backbone is
real; the modality encoder is not).

vlm  — llava-next anyres: ``input_specs()`` supplies *precomputed* patch
       features (B, num_image_tokens, vis_dim) as the vision tower's output;
       here we own only the multimodal projector (2-layer MLP, llava-style)
       into d_model, prepended to the text embeddings.
audio — musicgen over EnCodec tokens: K codebook embedding tables summed at
       the input, K parallel LM heads at the output. EnCodec itself is the
       stub; the delay-pattern interleave is applied in the data pipeline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers

VIS_DIM = 1024  # CLIP-L/14 feature width (the stubbed vision tower's output)


def init_vlm(key, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    return {
        "patch_proj": layers.init_linear(k1, VIS_DIM, cfg.d_model, bias=True, dtype=dt),
        "patch_proj2": layers.init_linear(k2, cfg.d_model, cfg.d_model, bias=True, dtype=dt),
    }


def project_patches(p, cfg, patch_embeds: jax.Array) -> jax.Array:
    """(B, I, VIS_DIM) -> (B, I, D): llava mlp2x_gelu projector."""
    h = layers.linear(p["patch_proj"], patch_embeds.astype(jnp.dtype(cfg.activ_dtype)))
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
    return layers.linear(p["patch_proj2"], h)


def init_audio_embed(key, cfg):
    """K codebook embedding tables, stacked (K, V, D)."""
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "w": layers.trunc_normal(
            key, (cfg.num_codebooks, cfg.vocab_size, cfg.d_model), 1.0, dt
        )
    }


def audio_embed(p, tokens: jax.Array) -> jax.Array:
    """tokens: (B, K, S) int -> (B, S, D) summed codebook embeddings."""
    k = tokens.shape[1]
    embs = [p["w"][i][tokens[:, i]] for i in range(k)]
    return sum(embs)


def init_audio_heads(key, cfg):
    """K parallel LM heads, stacked (K, D, V)."""
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "w": layers.trunc_normal(
            key, (cfg.num_codebooks, cfg.d_model, cfg.vocab_size),
            cfg.d_model**-0.5, dt,
        )
    }


def audio_logits(p, x: jax.Array) -> jax.Array:
    """x: (B, S, D) -> (B, K, S, V) f32 logits."""
    return jnp.einsum(
        "bsd,kdv->bksv", x, p["w"], preferred_element_type=jnp.float32
    )
