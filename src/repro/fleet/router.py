"""Admission router: load-aware placement with session and prompt-bucket
affinity.

The fleet's replicas are not interchangeable at the margin: each engine keeps
per-slot cache state sized by its prompt buckets and reuses compiled
programs per (batch, bucket) shape, so a replica that has recently admitted a
bucket serves that bucket with zero compilation or cache-geometry churn. The
router therefore places each request by:

  1. **session affinity** — a returning session goes back to its previous
     replica (conversation caches and per-tenant working set stay hot),
     unless that replica is overloaded relative to the fleet floor;
  2. **bucket affinity** — otherwise prefer, among non-overloaded replicas,
     one whose hot-bucket set already contains the request's prompt bucket;
  3. **least load** — otherwise the replica with the fewest outstanding
     decode tokens (queued + remaining in-flight), ties broken by lowest
     replica id so placement is deterministic.

The router only needs a tiny protocol from a replica: ``replica_id``,
``accepting``, ``outstanding_tokens()``, ``bucket_for(prompt_len)`` and
``hot_buckets`` — tests drive it with plain fakes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import numpy as np

from repro.serving.sampling import SamplingConfig

__all__ = ["FleetRequest", "Router"]


@dataclasses.dataclass
class FleetRequest:
    """A serving request addressed to the fleet (not yet to a replica)."""

    request_id: int
    tenant: str
    session: str
    prompt: np.ndarray
    max_new_tokens: int
    arrival_s: float
    sampling: SamplingConfig = dataclasses.field(default_factory=SamplingConfig)

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.prompt).shape[-1])


class Router:
    """Places :class:`FleetRequest` objects onto fleet replicas."""

    def __init__(self, *, session_affinity: bool = True,
                 bucket_affinity: bool = True, overload_factor: float = 2.0,
                 slack_tokens: int = 8):
        self.session_affinity = session_affinity
        self.bucket_affinity = bucket_affinity
        # a replica is "overloaded" for affinity purposes when its load
        # exceeds overload_factor * fleet_min + slack_tokens: affinity should
        # bend placement, never create a hotspot
        self.overload_factor = overload_factor
        self.slack_tokens = slack_tokens
        self._sessions: dict[str, int] = {}  # session -> replica_id
        self.stats = {"routed": 0, "session_hits": 0, "bucket_hits": 0,
                      "least_loaded": 0}

    def route(self, req: FleetRequest, replicas: Sequence[Any]):
        """Pick the replica for ``req``; records the session pin. Raises
        RuntimeError when no replica is accepting (the fleet keeps
        ``min_replicas`` >= 1, so this means misuse)."""
        accepting = [r for r in replicas if r.accepting]
        if not accepting:
            raise RuntimeError("router: no accepting replicas in the fleet")
        loads = {r.replica_id: r.outstanding_tokens() for r in accepting}
        limit = self.overload_factor * min(loads.values()) + self.slack_tokens
        self.stats["routed"] += 1

        chosen = None
        if self.session_affinity:
            rid = self._sessions.get(req.session)
            if rid is not None and rid in loads and loads[rid] <= limit:
                chosen = next(r for r in accepting if r.replica_id == rid)
                self.stats["session_hits"] += 1
        if chosen is None and self.bucket_affinity:
            hot = [r for r in accepting
                   if r.bucket_for(req.prompt_len) in r.hot_buckets
                   and loads[r.replica_id] <= limit]
            if hot:
                chosen = min(hot, key=lambda r: (loads[r.replica_id], r.replica_id))
                self.stats["bucket_hits"] += 1
        if chosen is None:
            chosen = min(accepting,
                         key=lambda r: (loads[r.replica_id], r.replica_id))
            self.stats["least_loaded"] += 1
        self._sessions[req.session] = chosen.replica_id
        return chosen

    def forget_replica(self, replica_id: int) -> None:
        """Drop session pins to a draining/released replica so returning
        sessions re-route instead of chasing a dead replica."""
        self._sessions = {s: r for s, r in self._sessions.items()
                          if r != replica_id}
