"""Admission router: load-aware placement with session, prefix, and
prompt-bucket affinity.

The fleet's replicas are not interchangeable at the margin: each engine keeps
per-slot cache state sized by its prompt buckets, reuses compiled programs
per (batch, bucket) shape, and (when enabled) holds a radix prefix cache of
prompt KV/recurrent state, so a replica that has recently served a prompt
family serves it again with less prefill compute and zero compilation churn.
The router therefore places each request by:

  1. **session affinity** — a returning session goes back to its previous
     replica (conversation caches and per-tenant working set stay hot),
     unless that replica is overloaded relative to the fleet floor;
  2. **prefix affinity** — otherwise prefer, among non-overloaded replicas,
     the one advertising the longest cached prefix of this prompt (its
     radix prefix cache can skip that much prefill). Ranked above bucket
     affinity because a prefix hit saves real compute, not just a
     compilation;
  3. **bucket affinity** — otherwise prefer, among non-overloaded replicas,
     one whose hot-bucket set already contains the request's prompt bucket;
  4. **least load** — otherwise the replica with the fewest outstanding
     decode tokens (queued + remaining in-flight), ties broken by lowest
     replica id so placement is deterministic.

The router only needs a tiny protocol from a replica: ``replica_id``,
``accepting``, ``outstanding_tokens()``, ``bucket_for(prompt_len)``,
``hot_buckets`` and (optionally) ``cached_prefix_len(prompt)`` — tests drive
it with plain fakes.

Session pins are recorded only when session affinity is enabled, and the pin
map is an LRU bounded by ``max_sessions``: a long-lived fleet serving an
unbounded stream of one-shot sessions must not grow host memory without
bound (the bug this bound fixed: ``_sessions`` grew by one entry per session
forever, even with affinity disabled).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Sequence

import numpy as np

from repro.serving.sampling import SamplingConfig

__all__ = ["FleetRequest", "Router"]


@dataclasses.dataclass
class FleetRequest:
    """A serving request addressed to the fleet (not yet to a replica)."""

    request_id: int
    tenant: str
    session: str
    prompt: np.ndarray
    max_new_tokens: int
    arrival_s: float
    sampling: SamplingConfig = dataclasses.field(default_factory=SamplingConfig)

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.prompt).shape[-1])


class Router:
    """Places :class:`FleetRequest` objects onto fleet replicas."""

    def __init__(self, *, session_affinity: bool = True,
                 prefix_affinity: bool = True, bucket_affinity: bool = True,
                 overload_factor: float = 2.0, slack_tokens: int = 8,
                 max_sessions: int = 4096):
        self.session_affinity = session_affinity
        self.prefix_affinity = prefix_affinity
        self.bucket_affinity = bucket_affinity
        # a replica is "overloaded" for affinity purposes when its load
        # exceeds overload_factor * fleet_min + slack_tokens: affinity should
        # bend placement, never create a hotspot
        self.overload_factor = overload_factor
        self.slack_tokens = slack_tokens
        self.max_sessions = max_sessions
        self._sessions: OrderedDict[str, int] = OrderedDict()  # LRU pin map
        self.stats = {"routed": 0, "session_hits": 0, "prefix_hits": 0,
                      "bucket_hits": 0, "least_loaded": 0,
                      "sessions_evicted": 0, "handoff_routes": 0,
                      "handoff_session_hits": 0, "handoff_prefix_hits": 0,
                      "handoff_free_pages": 0}

    def route(self, req: FleetRequest, replicas: Sequence[Any]):
        """Pick the replica for ``req``; records the session pin (only when
        session affinity is on). Raises RuntimeError when no replica is
        accepting (the fleet keeps ``min_replicas`` >= 1, so this means
        misuse)."""
        accepting = [r for r in replicas if r.accepting]
        if not accepting:
            raise RuntimeError("router: no accepting replicas in the fleet")
        loads = {r.replica_id: r.outstanding_tokens() for r in accepting}
        limit = self.overload_factor * min(loads.values()) + self.slack_tokens
        self.stats["routed"] += 1

        chosen = None
        if self.session_affinity:
            rid = self._sessions.get(req.session)
            if rid is not None and rid in loads and loads[rid] <= limit:
                chosen = next(r for r in accepting if r.replica_id == rid)
                self.stats["session_hits"] += 1
        if chosen is None and self.prefix_affinity:
            cands = []
            for r in accepting:
                if loads[r.replica_id] > limit:
                    continue
                fn = getattr(r, "cached_prefix_len", None)
                plen = int(fn(req.prompt)) if fn is not None else 0
                if plen > 0:
                    cands.append((plen, r))
            if cands:
                best = max(p for p, _ in cands)
                chosen = min((r for p, r in cands if p == best),
                             key=lambda r: (loads[r.replica_id], r.replica_id))
                self.stats["prefix_hits"] += 1
        if chosen is None and self.bucket_affinity:
            hot = [r for r in accepting
                   if r.bucket_for(req.prompt_len) in r.hot_buckets
                   and loads[r.replica_id] <= limit]
            if hot:
                chosen = min(hot, key=lambda r: (loads[r.replica_id], r.replica_id))
                self.stats["bucket_hits"] += 1
        if chosen is None:
            chosen = min(accepting,
                         key=lambda r: (loads[r.replica_id], r.replica_id))
            self.stats["least_loaded"] += 1
        if self.session_affinity:
            self._sessions[req.session] = chosen.replica_id
            self._sessions.move_to_end(req.session)
            while len(self._sessions) > self.max_sessions:
                self._sessions.popitem(last=False)
                self.stats["sessions_evicted"] += 1
        return chosen

    def route_handoff(self, session: str, prompt, replicas: Sequence[Any]):
        """Place a handoff-ready request (KV already computed on a prefill
        replica) onto a decode replica. Ordering differs from admission
        routing: the decode side never re-runs prefill, so bucket affinity
        is irrelevant — what matters is (1) the session's previous decode
        replica, (2) the longest cached prefix (a shared-prefix install can
        alias pages on a future request), then (3) the most free KV pages
        (an install needs headroom NOW, not least decode load). Returns
        ``None`` when no replica is accepting — the caller falls back to
        monolithic colocation."""
        accepting = [r for r in replicas if r.accepting]
        if not accepting:
            return None
        self.stats["handoff_routes"] += 1
        chosen = None
        if self.session_affinity:
            rid = self._sessions.get(session)
            if rid is not None:
                chosen = next((r for r in accepting if r.replica_id == rid),
                              None)
                if chosen is not None:
                    self.stats["handoff_session_hits"] += 1
        if chosen is None and self.prefix_affinity:
            cands = []
            for r in accepting:
                fn = getattr(r, "cached_prefix_len", None)
                plen = int(fn(prompt)) if fn is not None else 0
                if plen > 0:
                    cands.append((plen, r))
            if cands:
                best = max(p for p, _ in cands)
                chosen = min((r for p, r in cands if p == best),
                             key=lambda r: r.replica_id)
                self.stats["handoff_prefix_hits"] += 1
        if chosen is None:
            def free_pages(r) -> int:
                bm = getattr(getattr(r, "engine", None), "block_manager", None)
                return bm.free_pages if bm is not None else 0
            chosen = max(accepting,
                         key=lambda r: (free_pages(r), -r.replica_id))
            self.stats["handoff_free_pages"] += 1
        if self.session_affinity:
            self._sessions[session] = chosen.replica_id
            self._sessions.move_to_end(session)
            while len(self._sessions) > self.max_sessions:
                self._sessions.popitem(last=False)
                self.stats["sessions_evicted"] += 1
        return chosen

    def forget_session(self, session: str) -> None:
        """Drop one session's pin (e.g. when the fleet learns the session
        completed); returning sessions simply re-route."""
        self._sessions.pop(session, None)

    def forget_replica(self, replica_id: int) -> None:
        """Drop session pins to a draining/released replica so returning
        sessions re-route instead of chasing a dead replica."""
        self._sessions = OrderedDict(
            (s, r) for s, r in self._sessions.items() if r != replica_id)
