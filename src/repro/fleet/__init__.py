"""Elastic serving fleet: multi-replica control plane over the scheduler,
invocation, and engine layers (docs/fleet.md).

  * :mod:`repro.fleet.manager`    — FleetManager / Replica / BatchWorkload
  * :mod:`repro.fleet.router`     — load + affinity admission routing
  * :mod:`repro.fleet.autoscaler` — SLO-driven scale-up / scale-to-min policy
  * :mod:`repro.fleet.traffic`    — deterministic seeded workload traces
  * :mod:`repro.fleet.disagg`     — prefill/decode pool split + KV handoff
"""
from repro.fleet.autoscaler import SLO, Autoscaler, choose_replica_width
from repro.fleet.disagg import (DisaggConfig, DisaggFleetManager, HandoffTicket,
                                KVHandoff)
from repro.fleet.manager import (BatchWorkload, FleetConfig, FleetManager,
                                 FleetReport, Replica, ReplicaState,
                                 replica_bytes_per_chip)
from repro.fleet.router import FleetRequest, Router
from repro.fleet.traffic import (TraceRequest, bursty_trace, diurnal_trace,
                                 materialize, steady_trace)

__all__ = [
    "SLO", "Autoscaler", "BatchWorkload", "DisaggConfig", "DisaggFleetManager",
    "FleetConfig", "FleetManager", "FleetReport", "FleetRequest",
    "HandoffTicket", "KVHandoff", "Replica", "ReplicaState", "Router",
    "TraceRequest", "bursty_trace", "choose_replica_width", "diurnal_trace",
    "materialize", "replica_bytes_per_chip", "steady_trace",
]
