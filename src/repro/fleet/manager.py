"""Fleet control plane: N replica serving engines behind SERVICE leases,
SLO-driven elasticity, and interactive/batch coexistence via preemption.

This is the cluster-level layer the paper's allocation-model principle asks
for: long-running, performance-sensitive serving gets FaaS-style elasticity
("the flexibility and efficient resource utilization of serverless") without
giving up the leased, warm, compiled-data-plane execution model.

  * :class:`FleetManager` owns the replicas. Each replica is a
    ``ServingEngine`` booted behind its **own SERVICE lease** from
    ``InvocationService`` — so the warm-deployment cache (compiled decode
    artifact) and the engine program cache (jitted data-plane bundle) are
    shared across replicas, and every replica surfaces its specialization
    manifest at boot.
  * Requests are placed by the affinity :class:`~repro.fleet.router.Router`;
    completions feed the :class:`~repro.fleet.autoscaler.Autoscaler`, whose
    "up" decisions acquire a new lease (preempting BATCH training jobs
    through ``Cluster.preempt`` when the cluster is full — each preemption
    checkpoints through ``FTManager`` and requeues) and whose "down"
    decisions drain a replica and **release** its lease back to the free
    pool (scale-to-min).
  * Time is virtual: the fleet advances in ``tick_s`` steps, each tick
    running ONE real fused decode program per replica with work. The same
    objects run live under ``launch/serve.py --fleet``; latency, chip-second
    and utilization numbers come from the scheduler's virtual clock, so runs
    are deterministic given a trace seed.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import logging
import time
from typing import Any, Sequence

import numpy as np

from repro.core import recompile, scheduler
from repro.core.invocation import InvocationService, ServingExecutor
from repro.distributed import sharding as shd
from repro.fleet.autoscaler import SLO, Autoscaler, choose_replica_width
from repro.fleet.router import FleetRequest, Router
from repro.ft.manager import FTManager
from repro.serving.engine import Request, _bucket

__all__ = ["FleetConfig", "Replica", "ReplicaState", "BatchWorkload",
           "FleetManager", "FleetReport"]

logger = logging.getLogger(__name__)


class ReplicaState(enum.Enum):
    BOOTING = "booting"      # lease held, engine warming; accepts (queues) traffic
    SERVING = "serving"      # in rotation
    DRAINING = "draining"    # finishes in-flight + queued work, admits nothing
    RELEASED = "released"    # lease released, chips back in the free pool


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    tenant: str = "fleet-op"      # the lease holder: pays for chips
    min_replicas: int = 1
    max_replicas: int = 4
    # per-replica engine geometry
    slots: int = 2
    max_len: int = 96
    prompt_buckets: tuple[int, ...] = (16, 32, 48)
    sync_every: int = 1
    # radix prefix-cache byte budget per replica (0 disables KV reuse)
    prefix_cache_mb: float = 16.0
    # speculative decoding per replica (0 disables): every engine drafts
    # spec_k tokens per step and verifies them in one fused program with
    # lossless rejection sampling; acceptance telemetry lands per replica
    # in the fleet report
    spec_k: int = 0
    spec_proposer: str = "ngram"   # "ngram" | "draft"
    spec_draft_arch: str | None = None
    # paged KV per replica (None keeps contiguous per-slot KV strips): page
    # granularity in tokens, pool size in pages (None = full provisioning),
    # and the free-page watermark fraction admission respects
    page_size: int | None = None
    kv_pages: int | None = None
    kv_watermark: float = 0.05
    prefill_chunk_tokens: int | None = None
    # per-replica mesh geometry (None = single-chip replicas, the floor).
    # A (1, 2) mesh makes every replica a 2-chip tensor/expert-parallel
    # engine behind a 2-chip SERVICE lease: params + KV pools sharded by
    # the logical-axis rules, and the lease metered across ALL its chips.
    mesh_shape: tuple[int, ...] | None = None
    # candidate widths for the width-vs-count policy: when set, build()
    # calls autoscaler.choose_replica_width over these options under the
    # cluster's chip budget and records the chosen point in the timeline
    # (docs/sharding.md#replica-width-vs-replica-count)
    mesh_options: tuple[tuple[int, ...], ...] | None = None
    # virtual-time knobs
    tick_s: float = 0.05          # one fused decode round per replica per tick
    warm_boot_s: float = 0.5      # in-process program bundle already compiled
    cold_boot_s: float = 2.0      # first deploy: trace+compile the data plane
    ir_boot_s: float = 0.15       # IR-boot: deserialize persisted executables
    meter_every_s: float = 2.0    # ledger flush cadence
    settle_s: float = 40.0        # sim horizon past the last arrival
    # persistent AOT artifact store (checkpoint.store.ArtifactStore or
    # None): carried into the serving container so every replica boots
    # through the IR-boot ladder and cold compiles persist for the next
    # process (docs/ir-containers.md)
    artifact_store: Any = None


def replica_bytes_per_chip(cfg, fleet: "FleetConfig",
                           mesh_shape: tuple[int, ...]) -> int:
    """Modeled per-chip device bytes of ONE replica at the given width:
    params + the full KV pool (paged or contiguous, at this fleet's
    geometry), each leaf divided by the product of the mesh axes its
    logical-axis spec actually lands on. Pure shape arithmetic — abstract
    mesh, ``eval_shape`` trees, nothing materialized — so the width policy
    can be consulted before any engine exists (and for widths the local
    host cannot even build)."""
    import jax
    import jax.numpy as jnp

    from repro.models import transformer

    dt = jnp.dtype(cfg.activ_dtype)
    params = jax.eval_shape(
        lambda: transformer.init_model(jax.random.key(0), cfg))
    if fleet.page_size:
        kv_pages = fleet.kv_pages or (
            fleet.slots * (fleet.max_len // fleet.page_size) + 1)
        states = jax.eval_shape(lambda: transformer.init_paged_states(
            cfg, kv_pages, fleet.page_size, dt))
    else:
        states = jax.eval_shape(lambda: transformer.init_states(
            cfg, fleet.slots, fleet.max_len, dt))
    axes = (("data", "model")[-len(mesh_shape):] if len(mesh_shape) <= 2
            else ("pod", "data", "model")[-len(mesh_shape):])
    # abstract mesh: guarded_spec only reads mesh.shape, so one repeated
    # real device stands in for the whole grid
    devs = np.array(
        jax.devices() * int(np.prod(mesh_shape)))[: int(np.prod(mesh_shape))]
    mesh = jax.sharding.Mesh(devs.reshape(mesh_shape), axes)
    with shd.use_rules(dict(shd.RULES_2D), mesh):
        pspecs = shd.param_pspecs(params)
        sspecs = shd.state_pspecs(states)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def per_chip(leaf, spec) -> int:
        denom = 1
        for entry in tuple(spec):
            if entry is None:
                continue
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                denom *= sizes[a]
        nbytes = int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
        return nbytes // max(denom, 1)

    is_spec = lambda x: isinstance(x, jax.sharding.PartitionSpec)
    total = 0
    for tree, specs in ((params, pspecs), (states, sspecs)):
        leaves = jax.tree.leaves(tree)
        specl = jax.tree.leaves(specs, is_leaf=is_spec)
        total += sum(per_chip(l, s) for l, s in zip(leaves, specl))
    return total


class Replica:
    """One serving engine behind its own SERVICE lease."""

    def __init__(self, replica_id: int, executor: ServingExecutor, *,
                 boot_until_s: float, started_s: float, boot: str,
                 pool: str = "serve"):
        self.replica_id = replica_id
        self.executor = executor
        self.engine = executor.engine
        self.pool = pool          # "serve" | "prefill" | "decode"
        self.state = ReplicaState.BOOTING
        self.boot = boot          # predicted rung: "warm" | "ir" | "cold"
        self.boot_path: str | None = None   # rung warmup() actually took
        self.boot_cost_s = 0.0    # virtual boot latency charged at scale-up
        self.boot_wall_s = 0.0    # real wall-clock of warmup()
        self.boot_until_s = boot_until_s
        self.started_s = started_s
        self.released_s: float | None = None
        self.chips = executor.lease.job.granted_chips
        # the engine's actual mesh geometry (None for single-device): what
        # report() surfaces per replica next to chips, so "2 chips" is
        # visibly a (1,2) tensor-parallel grid and not two engines
        self.mesh = shd.mesh_geometry(getattr(executor.engine, "mesh", None))
        self.hot_buckets: set[int] = set()
        self.manifest: dict | None = None
        self.last_flush_s = started_s
        self.harvested = 0  # results already seen by FleetManager._harvest

    # ---- router protocol ----
    @property
    def accepting(self) -> bool:
        return self.state in (ReplicaState.BOOTING, ReplicaState.SERVING)

    def outstanding_tokens(self) -> int:
        """Queued + remaining in-flight decode tokens — the router's load
        signal. Prefill-only replicas never decode, so their load is the
        prompt tokens still to be prefilled instead."""
        eng = self.engine
        if getattr(eng, "role", "both") == "prefill":
            queued = sum(int(np.asarray(r.prompt).shape[-1]) for r in eng.queue)
            admitting = sum(st["plen"] - st["pos"]
                            for st in eng._admitting.values())
            return queued + admitting
        queued = sum(r.max_new_tokens for r in eng.queue)
        inflight = sum(
            max(r.max_new_tokens - len(eng.generated[i]), 0)
            for i, r in enumerate(eng.active) if r is not None)
        return queued + inflight

    def bucket_for(self, prompt_len: int) -> int:
        return _bucket(prompt_len, self.engine.prompt_buckets)

    def cached_prefix_len(self, prompt) -> int:
        """Longest usable cached prefix this replica advertises for the
        router's prefix-affinity layer (0 when the cache is disabled)."""
        cache = self.engine.prefix_cache
        if cache is None:
            return 0
        t = np.asarray(prompt)
        return cache.match(t, limit=t.shape[-1] - 1).usable

    # ---- manager internals ----
    def has_work(self) -> bool:
        eng = self.engine
        return bool(eng.queue) or any(r is not None for r in eng.active)

    def busy_slots(self) -> int:
        return sum(r is not None for r in self.engine.active)


@dataclasses.dataclass
class _BatchJob:
    job: scheduler.Job
    total_steps: int
    ft: FTManager
    progress: float = 0.0   # virtual training steps completed
    ckpt_step: int = 0      # last committed checkpoint


class BatchWorkload:
    """Preemptible BATCH training jobs sharing the cluster with the fleet.

    Each job's progress advances in virtual time; checkpoints go through the
    same ``FTManager`` save hook real training uses. The scheduler's graceful
    preemption window (``Cluster.preempt`` fires listeners *before* taking
    the chips) triggers a final checkpoint, and when a requeued job restarts,
    ``FTManager.resume`` restores progress from the last committed step — the
    paper's interactive/batch coexistence with no lost work.
    """

    def __init__(self, cluster: scheduler.Cluster, *, tenant: str = "train",
                 step_s: float = 1.0, ckpt_every: int = 5,
                 store_factory=None):
        """``store_factory(job_id) -> CheckpointStore`` makes checkpoints hit
        real storage; the default keeps them in memory (same FTManager code
        path, no disk)."""
        self.cluster = cluster
        self.tenant = tenant
        self.step_s = step_s
        self.ckpt_every = ckpt_every
        self._store_factory = store_factory
        self.jobs: dict[int, _BatchJob] = {}
        self.stats = {"submitted": 0, "checkpoints": 0, "preemptions": 0,
                      "resumes": 0}
        cluster.listeners.append(self._on_event)

    def submit(self, *, chips: int, total_steps: int) -> scheduler.Job:
        job = self.cluster.submit(
            tenant=self.tenant, chips=chips,
            runtime_s=total_steps * self.step_s,
            klass=scheduler.JobClass.BATCH)
        store = self._store_factory(job.job_id) if self._store_factory else None
        mem: dict[int, Any] = {}  # in-memory fallback: step -> state

        def save(state, step):
            if store is not None:
                store.save(int(step), {"data_step": np.asarray(state["data_step"])},
                           meta={"job": job.job_id}, blocking=True)
            else:
                mem[int(step)] = state

        def make_step(mesh_size):
            if store is not None:
                last = store.latest_step() or 0
            else:
                last = max(mem) if mem else 0
            return None, {"data_step": np.asarray(last)}, last

        ft = FTManager(make_step=make_step, save=save,
                       ckpt_every=self.ckpt_every, min_mesh=1)
        self.jobs[job.job_id] = _BatchJob(job=job, total_steps=total_steps, ft=ft)
        self.stats["submitted"] += 1
        return job

    def _on_event(self, kind: str, job: scheduler.Job) -> None:
        entry = self.jobs.get(job.job_id)
        if entry is None:
            return
        if kind == "preempt":
            # graceful window: chips still held — commit a final checkpoint
            step = int(entry.progress)
            entry.ckpt_step = entry.ft.checkpoint(
                {"data_step": np.asarray(step)}, step)
            self.stats["checkpoints"] += 1
            self.stats["preemptions"] += 1
            logger.info("batch job %d preempted at step %d (checkpointed)",
                        job.job_id, step)
        elif kind == "start" and job.preemptions > 0:
            # requeued job restarting: restore from the committed checkpoint
            _, state, step = entry.ft.resume(job.granted_chips)
            entry.progress = float(step)
            self.stats["resumes"] += 1
            logger.info("batch job %d resumed from checkpoint step %d",
                        job.job_id, step)

    def tick(self, now: float, dt: float) -> None:
        for entry in self.jobs.values():
            if entry.job.state != scheduler.JobState.RUNNING:
                continue
            entry.progress = min(entry.progress + dt / self.step_s,
                                 entry.total_steps)
            step = int(entry.progress)
            if step - entry.ckpt_step >= self.ckpt_every:
                entry.ft.save({"data_step": np.asarray(step)}, step)
                entry.ckpt_step = step
                self.stats["checkpoints"] += 1

    def summary(self) -> dict:
        return {
            **self.stats,
            "jobs": {
                jid: {"state": e.job.state.value, "preemptions": e.job.preemptions,
                      "progress_steps": round(e.progress, 2),
                      "total_steps": e.total_steps, "ckpt_step": e.ckpt_step}
                for jid, e in self.jobs.items()
            },
        }


@dataclasses.dataclass
class FleetReport:
    """Everything a benchmark or CI assertion needs from one fleet run."""

    requests: int
    served: int
    tokens: int
    duration_s: float
    latency_p50_s: float
    latency_p95_s: float
    latency_p99_s: float
    # real wall-clock engine-side latency telemetry (unlike the virtual-time
    # latencies above): TTFT = submit -> first token on the host, TPOT =
    # decode wall per output token after the first, aggregated over every
    # completed request across replicas
    ttft_p50_s: float
    ttft_p95_s: float
    tpot_p50_s: float
    tpot_p95_s: float
    tokens_per_s: float            # virtual-time throughput
    serving_chip_s: float          # chip-seconds held by SERVICE leases
    utilization: float             # cluster busy fraction (all job classes)
    scale_ups: int
    scale_downs: int
    lease_releases: int
    preemptions: int               # BATCH preemptions triggered by scale-up
    tokens_by_tenant: dict[str, int]
    metered_by_tenant: dict[str, int]
    reconciled: bool               # ledger totals match served tokens per tenant
    prefix_cache: dict             # fleet-wide prefix reuse + router affinity
    speculative: dict              # fleet-wide draft/accept telemetry
    paged_kv: dict                 # fleet-wide page-pool occupancy/CoW telemetry
    boot: dict                     # per-rung boot counts + latencies + the
                                   # expected cost of the next scale-up
    replicas: list[dict]
    batch: dict
    decisions: list[tuple[float, str, str]]
    # virtual-time TTFT (arrival -> first token tick): includes queueing
    # delay, which the wall-clock ttft_s above cannot see — the disagg
    # benchmark's headline metric
    ttft_virtual_p50_s: float = 0.0
    ttft_virtual_p95_s: float = 0.0
    ttft_virtual_p99_s: float = 0.0
    phase_metering: dict = dataclasses.field(default_factory=dict)
    disagg: dict = dataclasses.field(default_factory=dict)
    # the chosen point on the replica-width vs replica-count curve (empty
    # when the fleet runs fixed single-chip replicas): mesh shape, chips
    # per replica, and the policy's reason string
    width_decision: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["decisions"] = [[round(t, 3), a, r] for t, a, r in self.decisions]
        return d


class FleetManager:
    """Owns the replica set and runs the virtual-time serving loop."""

    def __init__(self, service: InvocationService, container, profile,
                 *, config: FleetConfig | None = None,
                 autoscaler: Autoscaler | None = None,
                 router: Router | None = None,
                 batch: BatchWorkload | None = None,
                 width_decision: dict | None = None):
        self.service = service
        self.cluster = service.cluster
        self.container = container
        self.profile = profile
        self.cfg = config or FleetConfig()
        self.width_decision = width_decision or {}
        self.autoscaler = autoscaler or Autoscaler(
            SLO(), self.cfg.min_replicas, self.cfg.max_replicas)
        self.router = router or Router()
        self.batch = batch
        self.replicas: list[Replica] = []
        self._rid = itertools.count()
        self._req_tenant: dict[int, str] = {}
        self._arrival: dict[int, float] = {}
        self._completion: dict[int, float] = {}
        self._req_tokens: dict[int, int] = {}
        # virtual-time TTFT: first tick at which a request had >= 1 token,
        # minus arrival. Complements the wall-clock ttft_s telemetry (which
        # measures host compute, not queueing) — queueing delay under load is
        # exactly what disaggregation improves, so benchmarks gate on this.
        self._ttft_virtual: dict[int, float] = {}
        self.counters = {"scale_ups": 0, "scale_downs": 0, "lease_releases": 0,
                         "preempts_triggered": 0, "scale_up_failures": 0}
        self.timeline: list[tuple[float, str]] = []
        if self.width_decision:
            self.timeline.append(
                (0.0, f"width decision: {self.width_decision['reason']}"))
        self.now = 0.0
        self._last_meter = 0.0

    # ------------------------------------------------------------------
    def _by_state(self, *states: ReplicaState) -> list[Replica]:
        return [r for r in self.replicas if r.state in states]

    def _tenant_of(self, request_id: int) -> str:
        return self._req_tenant.get(request_id, self.cfg.tenant)

    # ------------------------------------------------------------------
    # elasticity actions
    # ------------------------------------------------------------------
    def _container_for(self, pool: str | None):
        """Container a new replica in ``pool`` deploys. The monolithic fleet
        has one container; disaggregated subclasses map pool -> role-
        specialized container (distinct names, so warm-deployment caching
        never aliases a prefill bundle into a decode replica)."""
        return self.container

    def scale_up(self, now: float, *, initial: bool = False,
                 pool: str | None = None) -> Replica | None:
        """Acquire one more SERVICE lease and boot a replica behind it. When
        the cluster is full, RUNNING BATCH jobs are preempted (youngest
        first: least progress to requeue) until the lease's job starts; if
        even preemption can't free enough chips, the lease is released and
        the attempt recorded as a failure. ``initial`` marks the
        min-footprint boots at fleet start, which are NOT counted as elastic
        scale-ups (otherwise the 'did the autoscaler act' assertions in the
        benchmark/CI would be vacuously true)."""
        warm_before = self.service.stats["warm_acquires"]
        ex = self.service.acquire_serving(
            self.cfg.tenant, self._container_for(pool), self.profile,
            tenant_of=self._tenant_of, pool=pool or "serve")
        job = ex.lease.job
        if job.state != scheduler.JobState.RUNNING:
            victims = sorted(
                (self.cluster.jobs[i] for i in self.cluster.running
                 if self.cluster.jobs[i].klass == scheduler.JobClass.BATCH),
                key=lambda j: -(j.start_s or 0.0))  # youngest first
            for victim in victims:
                self.cluster.preempt(victim.job_id)
                self.cluster.run(until=self.cluster.now)
                self.counters["preempts_triggered"] += 1
                self.timeline.append(
                    (now, f"preempt batch job {victim.job_id} for scale-up"))
                if job.state == scheduler.JobState.RUNNING:
                    break
        if job.state != scheduler.JobState.RUNNING:
            ex.release()
            self.counters["scale_up_failures"] += 1
            self.timeline.append((now, "scale-up failed: no preemptible capacity"))
            return None
        # predicted boot rung, from MODELED state only: "warm" when THIS
        # fleet's deployment cache hit (a previous replica already deployed
        # the same container), else the engine's persisted-IR-vs-cold
        # preview. The engine's warm rung is deliberately not consulted —
        # the in-process program bundle can be hot for reasons outside this
        # fleet's virtual history (another fleet run earlier in the same
        # process), and virtual boot cost must stay hermetic per manager.
        # warmup() still takes the cheapest REAL rung; r.boot_path records
        # it separately.
        if self.service.stats["warm_acquires"] > warm_before:
            boot = "warm"
        else:
            preview = getattr(ex.engine, "boot_path_preview", None)
            boot = (preview(assume_fresh_process=True)
                    if preview is not None else "cold")
        boot_s = self._boot_cost_s(boot)
        replica = Replica(next(self._rid), ex, boot_until_s=now + boot_s,
                          started_s=now, boot=boot, pool=pool or "serve")
        replica.boot_cost_s = boot_s
        self.replicas.append(replica)
        if not initial:
            self.counters["scale_ups"] += 1
        ptag = f" [{pool}]" if pool else ""
        # the width half of every elasticity step is explicit in the
        # timeline: "added a replica (1 chip)" vs "added a widened replica
        # (mesh 1x2, 2 chips)" — a widened scale-up spends the chip budget
        # chips-per-replica at a time, the tradeoff the t=0 width decision
        # picked
        if replica.mesh is not None and replica.chips > 1:
            geom = "x".join(str(d) for d in replica.mesh[0])
            wtag = f" widened replica (mesh {geom}, {replica.chips} chips):"
        else:
            wtag = " replica (1 chip):" if not initial else ": replica"
        verb = "boot" if initial else "scale-up: added"
        self.timeline.append(
            (now, f"{verb}{wtag} {replica.replica_id}{ptag} "
                  f"({boot} boot, lease {ex.lease.lease_id})"))
        return replica

    def drain(self, replica: Replica, now: float) -> None:
        """Take a replica out of rotation; its lease is released once the
        queue and in-flight slots empty."""
        replica.state = ReplicaState.DRAINING
        self.router.forget_replica(replica.replica_id)
        self.timeline.append((now, f"drain: replica {replica.replica_id}"))

    def _release_drained(self, now: float) -> None:
        for r in self._by_state(ReplicaState.DRAINING):
            if r.has_work():
                continue
            # scale-to-min is the moment this replica's compiled corpus is
            # most complete (live traffic exercised shapes warmup's sweep
            # missed, e.g. spec_step_for(k) for k seen only under load) —
            # persist so the NEXT boot is a full IR hit
            if getattr(r.engine, "artifact_store", None) is not None:
                persisted = r.engine.persist_programs()
                self.timeline.append(
                    (now, f"persist: replica {r.replica_id} "
                          f"{persisted.get('persisted', 0)} executables"))
            r.executor.meter_flush(max(now - r.last_flush_s, 0.0))
            r.executor.release()  # asserts chips returned to the free pool
            r.state = ReplicaState.RELEASED
            r.released_s = now
            self.counters["scale_downs"] += 1
            self.counters["lease_releases"] += 1
            self.timeline.append(
                (now, f"release: replica {r.replica_id} lease "
                      f"{r.executor.lease.lease_id} (scale-to-min)"))

    # ------------------------------------------------------------------
    # per-tick phases
    # ------------------------------------------------------------------
    def submit(self, req: FleetRequest, now: float) -> Replica:
        self._req_tenant[req.request_id] = req.tenant
        self._arrival[req.request_id] = req.arrival_s
        replica = self.router.route(req, self.replicas)
        replica.hot_buckets.add(replica.bucket_for(req.prompt_len))
        replica.executor.submit(Request(
            request_id=req.request_id, prompt=req.prompt,
            max_new_tokens=req.max_new_tokens, sampling=req.sampling))
        return replica

    def _promote_boots(self, now: float) -> None:
        for r in self._by_state(ReplicaState.BOOTING):
            if now >= r.boot_until_s:
                t0 = time.perf_counter()
                r.manifest = r.executor.warmup()
                r.boot_wall_s = time.perf_counter() - t0
                boot = (r.manifest or {}).get("boot") or {}
                r.boot_path = boot.get("path", r.boot)
                r.state = ReplicaState.SERVING
                self.timeline.append(
                    (now, f"serving: replica {r.replica_id} "
                          f"({r.boot_path}-boot {r.boot_wall_s:.2f}s)"))

    def _boot_cost_s(self, path: str) -> float:
        return {"warm": self.cfg.warm_boot_s,
                "ir": self.cfg.ir_boot_s}.get(path, self.cfg.cold_boot_s)

    def _expected_boot_s(self, pool: str | None = None) -> float:
        """Virtual boot cost the NEXT scale-up would pay, from modeled
        fleet state: any live replica means this fleet's deployment cache
        is hot (warm boot); with none, a stocked artifact store IR-boots;
        otherwise cold. With ``pool`` given, only same-pool replicas
        answer (pool bundles are role-keyed, so a decode replica cannot
        vouch for a prefill boot)."""
        for r in self._by_state(ReplicaState.SERVING, ReplicaState.BOOTING,
                                ReplicaState.DRAINING):
            if pool is not None and r.pool != pool:
                continue
            return self.cfg.warm_boot_s
        store = self.cfg.artifact_store
        if store is not None and store.keys():
            return self.cfg.ir_boot_s
        return self.cfg.cold_boot_s

    def _step_replicas(self, now: float) -> None:
        for r in self._by_state(ReplicaState.SERVING, ReplicaState.DRAINING):
            if r.has_work():
                r.executor.step()

    def _harvest(self, now: float) -> None:
        done_t = now + self.cfg.tick_s
        for r in self.replicas:
            results = r.engine.results
            if len(results) == r.harvested:
                continue
            # results is insertion-ordered and retirement only appends, so
            # everything past the cursor is new — no full rescan per tick
            for rid, res in itertools.islice(results.items(), r.harvested, None):
                self._completion[rid] = done_t
                self._req_tokens[rid] = len(res.tokens)
                # single-tick requests retire before _stamp_ttft sees them
                self._ttft_virtual.setdefault(rid, done_t - self._arrival[rid])
                self._record_completion(done_t, rid, res)
            r.harvested = len(results)

    def _record_completion(self, done_t: float, rid: int, res) -> None:
        """Feed one completion into the autoscaler. The monolithic fleet
        records end-to-end latency into the default pool; disaggregated
        subclasses split the sample into per-pool SLO signals."""
        self.autoscaler.record_completion(done_t, done_t - self._arrival[rid])

    def _stamp_ttft(self, now: float) -> None:
        """Record virtual TTFT for any in-flight request whose first token
        landed this tick (the tick's results become visible at now+tick_s,
        matching ``_harvest``'s completion stamps)."""
        t = now + self.cfg.tick_s
        for r in self._by_state(ReplicaState.SERVING, ReplicaState.DRAINING):
            eng = r.engine
            for i, req in enumerate(eng.active):
                if req is None or not eng.generated[i]:
                    continue
                rid = req.request_id
                if rid not in self._ttft_virtual and rid in self._arrival:
                    self._ttft_virtual[rid] = t - self._arrival[rid]

    def _post_step(self, now: float) -> None:
        """Hook between replica stepping and harvest — the disaggregated
        fleet pumps KV handoffs (export -> transfer -> install) here."""

    def _autoscale(self, now: float) -> None:
        serving = self._by_state(ReplicaState.SERVING)
        booting = self._by_state(ReplicaState.BOOTING)
        queued = sum(len(r.engine.queue)
                     for r in self._by_state(ReplicaState.BOOTING,
                                             ReplicaState.SERVING,
                                             ReplicaState.DRAINING))
        busy = sum(r.busy_slots() for r in serving)
        # booting slots count toward queue capacity: a replica already on its
        # way up shouldn't trigger another scale-up for the same backlog
        total = sum(r.engine.slots for r in serving + booting)
        action = self.autoscaler.decide(
            now, serving=len(serving), booting=len(booting), queued=queued,
            busy_slots=busy, total_slots=total,
            boot_cost_s=self._expected_boot_s())
        if action == "up":
            self.scale_up(now)
        elif action == "down" and serving:
            victim = min(serving,
                         key=lambda r: (r.outstanding_tokens(), r.replica_id))
            self.drain(victim, now)

    def _meter_tick(self, now: float) -> None:
        if now - self._last_meter < self.cfg.meter_every_s:
            return
        self._last_meter = now
        for r in self._by_state(ReplicaState.BOOTING, ReplicaState.SERVING,
                                ReplicaState.DRAINING):
            r.executor.meter_flush(max(now - r.last_flush_s, 0.0))
            r.last_flush_s = now

    def _boot_initial(self) -> None:
        """Boot the fleet's minimum footprint at t=0 (not counted as elastic
        scale-ups). Disaggregated subclasses boot each pool to its own
        minimum."""
        while len(self._by_state(ReplicaState.BOOTING, ReplicaState.SERVING)) \
                < self.autoscaler.min_replicas:
            if self.scale_up(0.0, initial=True) is None:
                raise RuntimeError(
                    "fleet: cannot boot min_replicas — cluster too small even "
                    "with BATCH preemption")

    # ------------------------------------------------------------------
    def run_trace(self, requests: Sequence[FleetRequest], *,
                  until_s: float | None = None) -> FleetReport:
        """Drive the fleet through a trace in virtual time and return the
        report. By default runs until every request is served AND the fleet
        has settled back to ``min_replicas`` (so scale-to-min is part of
        every run). An explicit ``until_s`` is a hold-until horizon: the
        fleet keeps simulating (idle at min footprint) to exactly that time,
        which is what makes chip-second comparisons across allocation
        policies share one accounting window."""
        reqs = sorted(requests, key=lambda r: (r.arrival_s, r.request_id))
        explicit_horizon = until_s is not None
        horizon = until_s if explicit_horizon else (
            (reqs[-1].arrival_s if reqs else 0.0) + self.cfg.settle_s)
        self._boot_initial()
        i, t = 0, 0.0
        while True:
            while i < len(reqs) and reqs[i].arrival_s <= t:
                self.submit(reqs[i], t)
                i += 1
            self._promote_boots(t)
            self._step_replicas(t)
            self._post_step(t)
            self._stamp_ttft(t)
            self._harvest(t)
            self._autoscale(t)
            if self.batch is not None:
                self.batch.tick(t, self.cfg.tick_s)
            self._meter_tick(t)
            self._release_drained(t)
            self.cluster.advance_to(t)
            self.now = t
            done = i >= len(reqs) and len(self._completion) >= len(reqs)
            settled = (not self._by_state(ReplicaState.BOOTING,
                                          ReplicaState.DRAINING)
                       and len(self._by_state(ReplicaState.SERVING))
                       <= self.autoscaler.min_replicas)
            if explicit_horizon:
                if done and t >= horizon:
                    break
            elif done and (settled or t >= horizon):
                break
            if t >= horizon + 120.0:  # safety: never loop forever
                logger.warning("fleet: horizon safety stop at t=%.1f "
                               "(%d/%d served)", t, len(self._completion),
                               len(reqs))
                break
            t += self.cfg.tick_s
        for r in self._by_state(ReplicaState.BOOTING, ReplicaState.SERVING,
                                ReplicaState.DRAINING):
            r.executor.meter_flush(max(t - r.last_flush_s, 0.0))
            r.last_flush_s = t
        return self.report()

    def shutdown(self) -> None:
        """Release every remaining lease (end of the fleet's life); the
        warm deployment stays cached for the next fleet."""
        for r in self._by_state(ReplicaState.BOOTING, ReplicaState.SERVING):
            self.drain(r, self.now)
        guard = 0
        while self._by_state(ReplicaState.DRAINING) and guard < 100_000:
            self._step_replicas(self.now)
            self._harvest(self.now)
            self._release_drained(self.now)
            guard += 1

    # ------------------------------------------------------------------
    def report(self) -> FleetReport:
        lats = [self._completion[rid] - self._arrival[rid]
                for rid in self._completion]
        tokens_by_tenant: dict[str, int] = {}
        for rid, n in self._req_tokens.items():
            tenant = self._tenant_of(rid)
            tokens_by_tenant[tenant] = tokens_by_tenant.get(tenant, 0) + n
        metered = {tenant: self.service.meter.served_tokens(tenant)
                   for tenant in tokens_by_tenant}
        tokens = sum(self._req_tokens.values())
        reconciled = (metered == tokens_by_tenant
                      and self.service.meter.served_tokens() == tokens)
        self.cluster.check_invariants()
        self.service.meter.check_invariants()
        pct = (lambda q: float(np.percentile(lats, q)) if lats else 0.0)
        serving_chip_s = sum(
            ((r.released_s if r.released_s is not None else self.now)
             - r.started_s) * r.chips
            for r in self.replicas)

        def _replica_prefix(r: Replica) -> dict | None:
            eng = r.engine
            if eng.prefix_cache is None:
                return None
            h, m = eng.stats["prefix_hits"], eng.stats["prefix_misses"]
            return {
                "hits": h,
                "misses": m,
                "hit_rate": round(h / max(h + m, 1), 4),
                "hit_tokens": eng.stats["prefix_hit_tokens"],
                "prefill_tokens": eng.stats["prefill_tokens"],
                **{k: v for k, v in eng.prefix_cache.report().items()
                   if k in ("nodes", "bytes", "evictions", "inserts")},
            }

        per_replica_prefix = {r.replica_id: _replica_prefix(r)
                              for r in self.replicas}
        per_replica_spec = {r.replica_id: r.engine.spec_summary()
                            for r in self.replicas}
        sagg = [s for s in per_replica_spec.values() if s]
        drafted = sum(s["drafted"] for s in sagg)
        accepted = sum(s["accepted"] for s in sagg)
        spec_summary = {
            "enabled": bool(sagg),
            "drafted": drafted,
            "accepted": accepted,
            "acceptance_rate": round(accepted / max(drafted, 1), 4),
            "steps": sum(s["steps"] for s in sagg),
        }
        per_replica_paged = {r.replica_id: r.engine.paged_summary()
                             for r in self.replicas}
        pagg = [p for p in per_replica_paged.values() if p]
        paged_summary = {
            "enabled": bool(pagg),
            "pages_total": sum(p["pages_total"] for p in pagg),
            "pages_in_use": sum(p["pages_in_use"] for p in pagg),
            "peak_in_use": sum(p["peak_in_use"] for p in pagg),
            "cow_copies": sum(p["cow_copies"] for p in pagg),
            "cow_shared_pages": sum(p["cow_shared_pages"] for p in pagg),
            "preemptions": sum(p["preemptions"] for p in pagg),
            "admit_skips": sum(p["admit_skips"] for p in pagg),
        }
        ttfts, tpots = [], []
        for r in self.replicas:
            for res in r.engine.results.values():
                ttfts.append(res.ttft_s)
                if len(res.tokens) > 1:
                    tpots.append(res.tpot_s)
        tvs = [self._ttft_virtual[rid] for rid in self._completion
               if rid in self._ttft_virtual]
        rpct = (lambda xs, q: float(np.percentile(xs, q)) if xs else 0.0)
        agg = [p for p in per_replica_prefix.values() if p]
        hits = sum(p["hits"] for p in agg)
        misses = sum(p["misses"] for p in agg)
        prefix_summary = {
            "enabled": bool(agg),
            "hits": hits,
            "misses": misses,
            "hit_rate": round(hits / max(hits + misses, 1), 4),
            "hit_tokens": sum(p["hit_tokens"] for p in agg),
            "prefill_tokens": sum(p["prefill_tokens"] for p in agg),
            "prefix_affinity_routes": self.router.stats.get("prefix_hits", 0),
            "session_affinity_routes": self.router.stats.get("session_hits", 0),
        }
        booted = [r for r in self.replicas if r.boot_path is not None]
        paths: dict[str, int] = {}
        wall_by_path: dict[str, float] = {}
        for r in booted:
            paths[r.boot_path] = paths.get(r.boot_path, 0) + 1
            wall_by_path[r.boot_path] = round(
                wall_by_path.get(r.boot_path, 0.0) + r.boot_wall_s, 6)
        boot_summary = {
            "paths": paths,
            "wall_s_by_path": wall_by_path,
            "virtual_boot_s": round(sum(r.boot_cost_s for r in booted), 6),
            "expected_next_boot_s": self._expected_boot_s(),
        }
        return FleetReport(
            requests=len(self._arrival),
            served=len(self._completion),
            tokens=tokens,
            duration_s=self.now,
            latency_p50_s=pct(50),
            latency_p95_s=pct(95),
            latency_p99_s=pct(99),
            ttft_p50_s=rpct(ttfts, 50),
            ttft_p95_s=rpct(ttfts, 95),
            tpot_p50_s=rpct(tpots, 50),
            tpot_p95_s=rpct(tpots, 95),
            tokens_per_s=tokens / max(self.now, 1e-9),
            serving_chip_s=serving_chip_s,
            utilization=self.cluster.utilization(),
            scale_ups=self.counters["scale_ups"],
            scale_downs=self.counters["scale_downs"],
            lease_releases=self.counters["lease_releases"],
            preemptions=self.counters["preempts_triggered"],
            tokens_by_tenant=tokens_by_tenant,
            metered_by_tenant=metered,
            reconciled=reconciled,
            prefix_cache=prefix_summary,
            speculative=spec_summary,
            paged_kv=paged_summary,
            boot=boot_summary,
            replicas=[{
                "id": r.replica_id,
                "chips": r.chips,
                "mesh": (None if r.mesh is None
                         else {"shape": list(r.mesh[0]),
                               "axes": list(r.mesh[1])}),
                "boot": r.boot,
                "boot_path": r.boot_path,
                "boot_s": round(r.boot_cost_s, 3),
                "boot_wall_s": round(r.boot_wall_s, 3),
                "start_s": round(r.started_s, 3),
                "end_s": (round(r.released_s, 3)
                          if r.released_s is not None else None),
                "state": r.state.value,
                "prefix": per_replica_prefix[r.replica_id],
                "spec": per_replica_spec[r.replica_id],
                "paged": per_replica_paged[r.replica_id],
                "tiers": ({api: c["provider"]
                           for api, c in r.manifest.get("apis", {}).items()}
                          if r.manifest else None),
            } for r in self.replicas],
            batch=self.batch.summary() if self.batch else {},
            decisions=list(self.autoscaler.decisions),
            ttft_virtual_p50_s=rpct(tvs, 50),
            ttft_virtual_p95_s=rpct(tvs, 95),
            ttft_virtual_p99_s=rpct(tvs, 99),
            phase_metering={
                "prefill_tokens": self.service.meter.total_steps("serve_prefill"),
                "decode_steps": self.service.meter.total_steps("serve_decode"),
                "spec_positions": self.service.meter.total_steps(
                    "serve_spec_verify"),
            },
            disagg=self._disagg_summary(),
            width_decision=dict(self.width_decision),
        )

    def _disagg_summary(self) -> dict:
        """Handoff/pool telemetry — empty for the monolithic fleet."""
        return {}

    def token_streams(self) -> dict[int, list[int]]:
        """Completed token stream per request id across every replica — the
        byte-parity surface benchmarks compare between fleet topologies."""
        out: dict[int, list[int]] = {}
        for r in self.replicas:
            for rid, res in r.engine.results.items():
                out[rid] = list(res.tokens)
        return out

    # ------------------------------------------------------------------
    @classmethod
    def build(cls, cfg, params, *, chips: int,
              fleet: FleetConfig | None = None, slo: SLO | None = None,
              profile: recompile.SystemProfile | None = None,
              batch_jobs: Sequence[tuple[int, int]] = (),
              batch_step_s: float = 1.0, batch_ckpt_every: int = 5,
              store_factory=None) -> "FleetManager":
        """Assemble a complete fleet on a fresh cluster: scheduler, invocation
        service, serving container, optional BATCH coexistence jobs
        (``batch_jobs`` = [(chips, total_steps), ...])."""
        from repro.serving.service import serving_container

        fleet = fleet or FleetConfig()
        # ---- replica width: fixed by mesh_shape, or chosen over
        # mesh_options by the width-vs-count policy under this cluster's
        # chip budget (the chosen point lands in the timeline + report) ----
        mesh_shape = fleet.mesh_shape
        width_decision: dict = {}
        if fleet.mesh_options:
            base = profile or recompile.PORTABLE_CPU
            per_chip = {tuple(o): replica_bytes_per_chip(cfg, fleet, tuple(o))
                        for o in fleet.mesh_options}
            mesh_shape, reason = choose_replica_width(
                options=[tuple(o) for o in fleet.mesh_options],
                chip_budget=chips, bytes_per_chip=per_chip,
                hbm_bytes=base.hbm_bytes, min_replicas=fleet.min_replicas)
            if int(np.prod(mesh_shape)) == 1:
                mesh_shape = None  # narrowest point: plain 1-chip replicas
            width_decision = {
                "mesh_shape": list(mesh_shape) if mesh_shape else [1],
                "chips_per_replica": (int(np.prod(mesh_shape))
                                      if mesh_shape else 1),
                "reason": reason,
                "options": [list(o) for o in fleet.mesh_options],
                "bytes_per_chip": {
                    "x".join(map(str, k)): v for k, v in per_chip.items()},
            }
            fleet = dataclasses.replace(fleet, mesh_shape=mesh_shape)
        if mesh_shape is not None:
            if profile is None or profile.chips != int(np.prod(mesh_shape)):
                profile = recompile.host_mesh_profile(tuple(mesh_shape))
        else:
            profile = profile or recompile.PORTABLE_CPU
        service = InvocationService(scheduler.Cluster(chips=chips))
        spec = None
        if fleet.spec_k > 0:
            from repro.serving.speculative import SpecConfig
            spec = SpecConfig(k=fleet.spec_k, proposer=fleet.spec_proposer,
                              draft_arch=fleet.spec_draft_arch)
        cont = serving_container(
            cfg, params, slots=fleet.slots, max_len=fleet.max_len,
            prompt_buckets=fleet.prompt_buckets, sync_every=fleet.sync_every,
            prefix_cache_bytes=int(fleet.prefix_cache_mb * (1 << 20)) or None,
            spec=spec, page_size=fleet.page_size, kv_pages=fleet.kv_pages,
            kv_watermark=fleet.kv_watermark,
            prefill_chunk_tokens=fleet.prefill_chunk_tokens,
            mesh_shape=mesh_shape,
            artifact_store=fleet.artifact_store)
        batch = None
        if batch_jobs:
            batch = BatchWorkload(service.cluster, step_s=batch_step_s,
                                  ckpt_every=batch_ckpt_every,
                                  store_factory=store_factory)
            for bchips, bsteps in batch_jobs:
                batch.submit(chips=bchips, total_steps=bsteps)
            service.cluster.run(until=service.cluster.now)
        return cls(service, cont, profile, config=fleet,
                   autoscaler=Autoscaler(slo or SLO(), fleet.min_replicas,
                                         fleet.max_replicas),
                   batch=batch, width_decision=width_decision)
