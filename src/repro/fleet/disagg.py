"""Disaggregated prefill/decode fleet: phase-specialized replica pools with
KV page handoff.

The XaaS converged model wins by specializing execution per workload phase
while keeping one lease/container abstraction (PAPER.md's Invocation
principle; rFaaS leases are the pool-allocation primitive). A monolithic
serving replica interleaves two phases with opposite resource shapes:

  * **prefill** is compute-bound and bursty — one long prompt occupies a
    slot for many chunked-prefill ticks, and every tick it runs starves the
    co-resident decode batch;
  * **decode** is memory-bound and steady — one token per slot per tick,
    latency set by KV residency, not FLOPs.

This module splits the fleet into a prefill-specialized pool (chunk cap =
``max_len``: a prompt admits in as few ticks as the bucket allows, because
there is no co-resident decode to protect) and a decode-specialized pool
(admits requests by *installing* already-computed KV pages — never runs a
prompt it can avoid), connected by a :class:`KVHandoff` transfer plane:

  prefill replica                        decode replica
  ─────────────────                      ─────────────────
  chunked prefill (full-width)           continuous decode batch
  first token = argmax(prefill logits)   ...
  export_pages -> gather -> host         |
      HandoffPacket {pages, shas} ──────>│ verify shas
      (virtual link: nbytes/bw + lat)    │ install_pages -> scatter
  decref on install ack <────────────────│ admit slot mid-decode

TTFT is charged at prefill completion (the first token is host-visible the
tick the prompt finishes — the handoff delays the *second* token, not the
first), which is exactly why the split wins: TTFT p99 under a prefill-heavy
burst no longer queues behind decode, and decode TPOT no longer stalls
behind prompt chunks. Fallback preserves liveness and byte parity: when the
prefill pool is empty or the handoff link backlogs past a watermark, new
requests are colocated monolithically on the decode pool (which keeps full
prefill capability), and a sha-mismatched transfer is dropped and recomputed
monolithically rather than trusted.

The autoscaler sizes the two pools independently — prefill against a TTFT
SLO, decode against a TPOT SLO — with per-pool cooldown/window state
(:mod:`repro.fleet.autoscaler`) and per-pool boot-cost awareness: each
pool's containers carry a role-keyed AOT bundle in the shared artifact
store, so a decode replica never compiles (or even loads) prefill-only
programs and vice versa.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Sequence

import numpy as np

from repro.core import recompile, scheduler
from repro.core.invocation import InvocationService
from repro.fleet.autoscaler import SLO, Autoscaler
from repro.fleet.manager import (BatchWorkload, FleetConfig, FleetManager,
                                 Replica, ReplicaState)
from repro.fleet.router import FleetRequest, Router
from repro.serving.engine import HandoffPacket, Request

__all__ = ["DisaggConfig", "HandoffTicket", "KVHandoff", "DisaggFleetManager"]


@dataclasses.dataclass(frozen=True)
class DisaggConfig:
    """Pool sizing, handoff link model, and per-pool SLOs."""

    prefill_min: int = 1
    prefill_max: int = 2
    decode_min: int = 1
    decode_max: int = 2
    # per-pool engine geometry overrides (None = inherit FleetConfig)
    prefill_slots: int | None = None
    decode_slots: int | None = None
    # prefill pool chunk cap (None = max_len: admit in as few ticks as the
    # bucket ladder allows — there is no co-resident decode to protect)
    prefill_chunk_tokens: int | None = None
    # virtual handoff link: one serialized device->host->device staged copy
    # at a time, nbytes / bandwidth + latency per transfer
    handoff_bandwidth_bytes_per_s: float = 8 * (1 << 30)
    handoff_latency_s: float = 0.005
    # submit-time fallback trigger: pending+ready transfers above this
    # colocate new requests on the decode pool instead
    handoff_backlog_watermark: int = 8
    # per-pool SLOs: prefill pool defends TTFT, decode pool defends TPOT
    prefill_slo: SLO = dataclasses.field(default_factory=lambda: SLO(
        p95_target_s=1.0, queue_high_per_slot=1.0))
    decode_slo: SLO = dataclasses.field(default_factory=lambda: SLO(
        p95_target_s=0.12, queue_high_per_slot=2.0))


@dataclasses.dataclass
class HandoffTicket:
    """One KV page transfer in flight on the virtual link."""

    packet: HandoffPacket
    src: Replica
    submitted_s: float
    ready_s: float
    retries: int = 0


class KVHandoff:
    """Virtual-time KV page transfer plane between replica pools.

    Models one serialized staging link (device->host on the source, wire,
    host->device on the destination): each transfer occupies the link for
    ``nbytes / bandwidth`` and lands ``latency_s`` later. Integrity and
    lifetime are the *engines'* contract (`export_pages` pins the source
    pages, per-page shas travel with the payload, install verifies before
    scatter, the manager decrefs the source only after a successful
    install); this class only sequences time and backlog.
    """

    def __init__(self, *, bandwidth_bytes_per_s: float = 8 * (1 << 30),
                 latency_s: float = 0.005):
        self.bandwidth = float(bandwidth_bytes_per_s)
        self.latency_s = float(latency_s)
        self._pending: deque[HandoffTicket] = deque()   # in transfer order
        self._ready: deque[HandoffTicket] = deque()     # landed, not installed
        self._link_free_s = 0.0
        self.stats = {"submitted": 0, "delivered": 0, "installed": 0,
                      "sha_rejected": 0, "recomputed": 0, "retries": 0,
                      "bytes": 0, "transfer_s": 0.0, "wait_s": 0.0,
                      "max_backlog": 0}

    @property
    def backlog(self) -> int:
        return len(self._pending) + len(self._ready)

    def submit(self, now: float, packet: HandoffPacket, src: Replica) -> HandoffTicket:
        xfer = packet.nbytes / max(self.bandwidth, 1.0) + self.latency_s
        ready = max(now, self._link_free_s) + xfer
        self._link_free_s = ready
        t = HandoffTicket(packet=packet, src=src, submitted_s=now, ready_s=ready)
        self._pending.append(t)
        self.stats["submitted"] += 1
        self.stats["bytes"] += packet.nbytes
        self.stats["transfer_s"] += xfer
        self.stats["max_backlog"] = max(self.stats["max_backlog"], self.backlog)
        return t

    def take_ready(self, now: float) -> list[HandoffTicket]:
        """Move landed transfers to the ready set and return it (caller
        installs what it can and requeues the rest)."""
        while self._pending and self._pending[0].ready_s <= now:
            t = self._pending.popleft()
            self.stats["delivered"] += 1
            self.stats["wait_s"] += now - t.submitted_s
            self._ready.append(t)
        out = list(self._ready)
        self._ready.clear()
        return out

    def requeue(self, tickets: Sequence[HandoffTicket]) -> None:
        for t in tickets:
            t.retries += 1
            self.stats["retries"] += 1
            self._ready.append(t)


class DisaggFleetManager(FleetManager):
    """FleetManager with phase-specialized pools and a KV handoff plane.

    The base class owns leases, ticks, metering, harvest, and reporting;
    this subclass overrides placement (``submit``), the inter-pool data
    plane (``_post_step``), per-pool SLO feedback (``_record_completion``),
    and per-pool elasticity (``_autoscale`` / ``_boot_initial``).
    """

    def __init__(self, service: InvocationService, prefill_container,
                 decode_container, profile, *,
                 config: FleetConfig | None = None,
                 disagg: DisaggConfig | None = None,
                 autoscaler: Autoscaler | None = None,
                 router: Router | None = None,
                 batch: BatchWorkload | None = None):
        self.dcfg = disagg or DisaggConfig()
        d = self.dcfg
        super().__init__(service, decode_container, profile, config=config,
                         autoscaler=autoscaler or Autoscaler(
                             SLO(), d.prefill_min + d.decode_min,
                             d.prefill_max + d.decode_max),
                         router=router, batch=batch)
        # base `settled` logic compares against autoscaler.min_replicas
        self.autoscaler.min_replicas = d.prefill_min + d.decode_min
        self.autoscaler.max_replicas = d.prefill_max + d.decode_max
        self.prefill_container = prefill_container
        self.decode_container = decode_container
        self.handoff = KVHandoff(
            bandwidth_bytes_per_s=d.handoff_bandwidth_bytes_per_s,
            latency_s=d.handoff_latency_s)
        self._req_session: dict[int, str] = {}
        self.pool_counters = {"scale_ups_prefill": 0, "scale_ups_decode": 0,
                              "fallback_submits": 0}
        self._pool_peak = {"prefill": 0, "decode": 0}

    # ------------------------------------------------------------------
    def _container_for(self, pool: str | None):
        return (self.prefill_container if pool == "prefill"
                else self.decode_container)

    def _pool(self, pool: str, *states: ReplicaState) -> list[Replica]:
        states = states or (ReplicaState.BOOTING, ReplicaState.SERVING,
                            ReplicaState.DRAINING)
        return [r for r in self.replicas
                if r.pool == pool and r.state in states]

    def scale_up(self, now: float, *, initial: bool = False,
                 pool: str | None = None) -> Replica | None:
        r = super().scale_up(now, initial=initial, pool=pool)
        if r is not None and not initial and pool in ("prefill", "decode"):
            self.pool_counters[f"scale_ups_{pool}"] += 1
        return r

    def _boot_initial(self) -> None:
        for pool, n in (("decode", self.dcfg.decode_min),
                        ("prefill", self.dcfg.prefill_min)):
            while len(self._pool(pool, ReplicaState.BOOTING,
                                 ReplicaState.SERVING)) < n:
                if self.scale_up(0.0, initial=True, pool=pool) is None:
                    raise RuntimeError(
                        f"disagg fleet: cannot boot {pool} pool minimum "
                        f"({n}) — cluster too small even with BATCH "
                        "preemption")

    # ------------------------------------------------------------------
    # placement: new requests -> prefill pool; fallback -> colocate on decode
    # ------------------------------------------------------------------
    def submit(self, req: FleetRequest, now: float) -> Replica:
        self._req_tenant[req.request_id] = req.tenant
        self._arrival[req.request_id] = req.arrival_s
        self._req_session[req.request_id] = req.session
        prefill = [r for r in self.replicas if r.pool == "prefill"]
        colocate = (not any(r.accepting for r in prefill)
                    or self.handoff.backlog > self.dcfg.handoff_backlog_watermark)
        if colocate:
            # decode-role engines keep full prefill capability precisely for
            # this path: liveness (and byte parity) never depend on the
            # handoff plane being healthy
            self.pool_counters["fallback_submits"] += 1
            candidates = [r for r in self.replicas if r.pool == "decode"]
        else:
            candidates = prefill
        replica = self.router.route(req, candidates)
        replica.hot_buckets.add(replica.bucket_for(req.prompt_len))
        replica.executor.submit(Request(
            request_id=req.request_id, prompt=req.prompt,
            max_new_tokens=req.max_new_tokens, sampling=req.sampling))
        return replica

    # ------------------------------------------------------------------
    # the inter-pool data plane, pumped once per tick
    # ------------------------------------------------------------------
    def _post_step(self, now: float) -> None:
        t = now + self.cfg.tick_s
        # 1) collect finished prefill exports onto the virtual link. TTFT is
        # stamped HERE: the first token is host-visible the tick prefill
        # completes — the transfer delays the second token, not the first.
        for r in self.replicas:
            if r.pool != "prefill":
                continue
            out = getattr(r.engine, "handoff_out", None)
            while out:
                pkt = out.popleft()
                rid = pkt.request.request_id
                self._ttft_virtual.setdefault(rid, t - self._arrival[rid])
                self.handoff.submit(now, pkt, r)
        # 2) install landed transfers on the decode pool
        decode = [r for r in self._pool("decode", ReplicaState.SERVING)]
        retry = []
        for ticket in self.handoff.take_ready(now):
            pkt = ticket.packet
            rid = pkt.request.request_id
            session = self._req_session.get(rid, str(rid))
            target = self.router.route_handoff(session, pkt.prompt, decode)
            if target is None or not target.engine.can_install(pkt):
                retry.append(ticket)  # capacity: try again next tick
                continue
            if target.engine.install_handoff(pkt):
                # decref-on-source only after a VERIFIED install: the pin
                # taken by export_pages is the transfer's reference
                ticket.src.engine.release_handoff(pkt)
                self.handoff.stats["installed"] += 1
                target.hot_buckets.add(target.bucket_for(
                    int(np.asarray(pkt.prompt).shape[-1])))
            else:
                # sha mismatch: the payload is not the KV the source hashed.
                # Never trust it — drop the ticket, unpin the source pages,
                # and recompute the request monolithically on the decode pool
                ticket.src.engine.release_handoff(pkt)
                self.handoff.stats["sha_rejected"] += 1
                self._recompute(pkt, decode)
        self.handoff.requeue(retry)

    def _recompute(self, pkt: HandoffPacket, decode: list[Replica]) -> None:
        req = pkt.request
        fr = FleetRequest(
            request_id=req.request_id, tenant=self._tenant_of(req.request_id),
            session=self._req_session.get(req.request_id, str(req.request_id)),
            prompt=pkt.prompt, max_new_tokens=req.max_new_tokens,
            arrival_s=self._arrival.get(req.request_id, 0.0),
            sampling=req.sampling)
        replica = self.router.route(fr, decode or self.replicas)
        replica.executor.submit(Request(
            request_id=req.request_id, prompt=pkt.prompt,
            max_new_tokens=req.max_new_tokens, sampling=req.sampling))
        self.handoff.stats["recomputed"] += 1
        self.timeline.append(
            (self.now, f"handoff sha reject: request {req.request_id} "
                       f"recomputed on replica {replica.replica_id}"))

    # ------------------------------------------------------------------
    # per-pool SLO feedback + elasticity
    # ------------------------------------------------------------------
    def _record_completion(self, done_t: float, rid: int, res) -> None:
        lat = done_t - self._arrival[rid]
        ttft = self._ttft_virtual.get(rid, lat)
        self.autoscaler.record_completion(done_t, ttft, pool="prefill")
        n = self._req_tokens.get(rid, 1)
        if n > 1:
            tpot = max(lat - ttft, 0.0) / (n - 1)
            self.autoscaler.record_completion(done_t, tpot, pool="decode")

    def _autoscale(self, now: float) -> None:
        d = self.dcfg
        for pool, slo, lo, hi in (
                ("prefill", d.prefill_slo, d.prefill_min, d.prefill_max),
                ("decode", d.decode_slo, d.decode_min, d.decode_max)):
            serving = self._pool(pool, ReplicaState.SERVING)
            booting = self._pool(pool, ReplicaState.BOOTING)
            self._pool_peak[pool] = max(self._pool_peak[pool],
                                        len(serving) + len(booting))
            queued = sum(len(r.engine.queue) for r in self._pool(pool))
            if pool == "decode":
                # transfers in flight / awaiting install are decode-pool work
                # the queue can't see yet
                queued += self.handoff.backlog
            busy = sum(r.busy_slots() for r in serving)
            total = sum(r.engine.slots for r in serving + booting)
            action = self.autoscaler.decide(
                now, serving=len(serving), booting=len(booting),
                queued=queued, busy_slots=busy, total_slots=total,
                boot_cost_s=self._expected_boot_s(pool), pool=pool, slo=slo,
                min_replicas=lo, max_replicas=hi)
            if action == "up":
                self.scale_up(now, pool=pool)
            elif action == "down" and serving:
                victim = min(serving, key=lambda r: (r.outstanding_tokens(),
                                                     r.replica_id))
                self.drain(victim, now)

    # ------------------------------------------------------------------
    def shutdown(self) -> None:
        # drain the handoff plane in virtual time first: tickets only land
        # at their ready_s, and prefill replicas may still be exporting
        guard = 0
        while guard < 100_000 and (
                self.handoff.backlog
                or any(r.pool == "prefill" and r.has_work()
                       for r in self._by_state(ReplicaState.SERVING,
                                               ReplicaState.DRAINING))):
            self.now += self.cfg.tick_s
            self._step_replicas(self.now)
            self._post_step(self.now)
            self._stamp_ttft(self.now)
            self._harvest(self.now)
            guard += 1
        super().shutdown()

    def _disagg_summary(self) -> dict:
        d = self.dcfg
        pools = {}
        for pool, lo, hi in (("prefill", d.prefill_min, d.prefill_max),
                             ("decode", d.decode_min, d.decode_max)):
            live = self._pool(pool, ReplicaState.BOOTING, ReplicaState.SERVING)
            pools[pool] = {
                "min": lo, "max": hi,
                "live": len(live),
                "peak": self._pool_peak[pool],
                "ever": sum(r.pool == pool for r in self.replicas),
                "scale_ups": self.pool_counters[f"scale_ups_{pool}"],
            }
        return {
            "enabled": True,
            "handoff": {**self.handoff.stats, "backlog": self.handoff.backlog},
            "fallback_submits": self.pool_counters["fallback_submits"],
            "pools": pools,
        }

    # ------------------------------------------------------------------
    @classmethod
    def build(cls, cfg, params, *, chips: int,
              fleet: FleetConfig | None = None,
              disagg: DisaggConfig | None = None,
              profile: recompile.SystemProfile | None = None,
              batch_jobs: Sequence[tuple[int, int]] = (),
              batch_step_s: float = 1.0, batch_ckpt_every: int = 5,
              store_factory=None) -> "DisaggFleetManager":
        """Assemble a disaggregated fleet on a fresh cluster: one
        role-specialized container per pool (distinct names, shared artifact
        store — the pools share one compiled-program corpus but each boots
        only its own role-keyed bundle)."""
        from repro.serving.service import serving_container

        fleet = fleet or FleetConfig()
        disagg = disagg or DisaggConfig()
        if fleet.page_size is None:
            raise ValueError("disaggregation requires paged KV: set "
                             "FleetConfig.page_size (and kv_pages)")
        profile = profile or recompile.PORTABLE_CPU
        service = InvocationService(scheduler.Cluster(chips=chips))
        spec = None
        if fleet.spec_k > 0:
            from repro.serving.speculative import SpecConfig
            spec = SpecConfig(k=fleet.spec_k, proposer=fleet.spec_proposer,
                              draft_arch=fleet.spec_draft_arch)
        common = dict(
            prompt_buckets=fleet.prompt_buckets, sync_every=fleet.sync_every,
            prefix_cache_bytes=int(fleet.prefix_cache_mb * (1 << 20)) or None,
            page_size=fleet.page_size, kv_pages=fleet.kv_pages,
            kv_watermark=fleet.kv_watermark, max_len=fleet.max_len,
            artifact_store=fleet.artifact_store)
        pre_cont = serving_container(
            cfg, params, slots=disagg.prefill_slots or fleet.slots,
            role="prefill", spec=None,
            prefill_chunk_tokens=(disagg.prefill_chunk_tokens
                                  or fleet.max_len),
            **common)
        dec_cont = serving_container(
            cfg, params, slots=disagg.decode_slots or fleet.slots,
            role="decode", spec=spec,
            prefill_chunk_tokens=fleet.prefill_chunk_tokens,
            **common)
        batch = None
        if batch_jobs:
            batch = BatchWorkload(service.cluster, step_s=batch_step_s,
                                  ckpt_every=batch_ckpt_every,
                                  store_factory=store_factory)
            for bchips, bsteps in batch_jobs:
                batch.submit(chips=bchips, total_steps=bsteps)
            service.cluster.run(until=service.cluster.now)
        return cls(service, pre_cont, dec_cont, profile, config=fleet,
                   disagg=disagg, batch=batch)
