"""SLO-driven autoscaling policy for the serving fleet.

Pure decision logic — no engines, no scheduler — so the policy is unit
testable and the manager stays the only place with side effects. Two input
signals, two SLO knobs:

  * **queue pressure**: queued requests per serving slot above
    ``queue_high_per_slot`` means admission is falling behind — scale up
    *before* latency degrades (queue depth leads p95 by construction).
  * **tail latency**: windowed p95 of completed-request latency above
    ``p95_target_s`` means the SLO is already being violated — scale up.

Scale-down is deliberately slower than scale-up (classic asymmetric
hysteresis): the fleet must be *sustained* idle — no queue, busy-slot
fraction under ``low_util`` — for ``idle_drain_s`` before one replica is
drained, and consecutive scale-downs are spaced by ``down_cooldown_s``.
Scale-ups only need ``up_cooldown_s`` (roughly one boot time) between them
so a burst can ramp the fleet to max in a few windows.

All mutable state — latency windows, cooldown clocks, idle timers — is
keyed by **pool** so a disaggregated fleet can size its prefill and decode
pools independently: a scale-up in one pool must never consume the other
pool's cooldown budget, and a TTFT sample must never pollute the TPOT
window. Single-pool fleets use the implicit ``"default"`` pool and see no
behavior change.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Sequence

import numpy as np

__all__ = ["SLO", "Autoscaler", "choose_replica_width"]


def choose_replica_width(
    *,
    options: Sequence[tuple[int, ...]],
    chip_budget: int,
    bytes_per_chip: dict[tuple[int, ...], int],
    hbm_bytes: int,
    min_replicas: int = 1,
) -> tuple[tuple[int, ...], str]:
    """Trade replica count against shard width under a fixed chip budget.

    Pure policy (unit-testable, like :class:`Autoscaler`): given candidate
    per-replica mesh shapes, the modeled per-chip bytes of one replica at
    each width (params + KV pool divided across its shards), and the
    profile's per-chip HBM, pick the mesh every replica of this fleet will
    use and say why. The rule is deliberately simple and explicit:

      1. a width whose per-chip footprint exceeds HBM cannot serve at all —
         drop it (this is what FORCES widening for big configs);
      2. among the widths that fit, prefer the narrowest: under a fixed
         chip budget, N narrow replicas beat N/w wide ones on aggregate
         throughput (each wide replica pays collective overhead for the
         same chips) and on elasticity granularity;
      3. the chosen point must leave room for ``min_replicas`` replicas
         inside the budget — if the only memory-fitting width cannot, it
         is still chosen (the fleet will fail loudly at boot), but the
         reason records the conflict.

    Returns (mesh_shape, reason). The manager logs the reason in the
    timeline so a fleet run shows WHERE on the width-vs-count curve it sat.
    """
    if not options:
        raise ValueError("choose_replica_width: no width options")
    opts = sorted(options, key=lambda s: int(np.prod(s)))
    sized = [(o, int(np.prod(o)), bytes_per_chip[tuple(o)]) for o in opts]
    fitting = [(o, c, b) for o, c, b in sized if b <= hbm_bytes]
    gib = 1 / (1 << 30)
    if not fitting:
        o, c, b = sized[-1]  # least-oversubscribed width
        return tuple(o), (
            f"width {'x'.join(map(str, o))} ({c} chips/replica): no option "
            f"fits per-chip HBM ({b * gib:.2f} GiB > {hbm_bytes * gib:.2f} "
            f"GiB even at max width)")
    o, c, b = fitting[0]
    max_reps = chip_budget // c
    dropped = [f"{'x'.join(map(str, eo))} needs {eb * gib:.2f} GiB/chip"
               for eo, ec, eb in sized if eb > hbm_bytes and ec < c]
    why_wide = ("; widened past " + ", ".join(dropped)) if dropped else ""
    budget_note = ("" if max_reps >= min_replicas else
                   f"; WARNING: only {max_reps} replicas fit the "
                   f"{chip_budget}-chip budget (< min {min_replicas})")
    return tuple(o), (
        f"width {'x'.join(map(str, o))} ({c} chips/replica): per-chip "
        f"{b * gib:.2f} GiB fits {hbm_bytes * gib:.2f} GiB HBM, up to "
        f"{max_reps} replicas under the {chip_budget}-chip budget"
        f"{why_wide}{budget_note}")


@dataclasses.dataclass(frozen=True)
class SLO:
    """The fleet's service-level objective and scaling hysteresis knobs."""

    p95_target_s: float = 1.5      # windowed p95 completion latency target
    queue_high_per_slot: float = 1.0  # queued requests per serving slot
    low_util: float = 0.25         # busy-slot fraction considered idle
    window_s: float = 8.0          # latency observation window
    min_window_samples: int = 4    # p95 needs this many completions
    up_cooldown_s: float = 1.0     # >= one boot time: let the new replica land
    down_cooldown_s: float = 4.0
    idle_drain_s: float = 3.0      # sustained idle before draining a replica
    # boot-cost awareness: the queue trigger scales by
    # 1 / (1 + boot_cost_s / boot_norm_s) — an expensive (cold) boot must
    # start EARLIER to land before the backlog violates the SLO, while a
    # cheap IR-boot replica can afford to wait for a deeper queue.
    # boot_norm_s is the boot cost that halves the queue threshold.
    boot_norm_s: float = 2.0


class Autoscaler:
    """Decides "up" / "down" / None from fleet metrics snapshots.

    One instance serves any number of pools: pass ``pool=`` to
    :meth:`record_completion` / :meth:`decide` and each pool gets its own
    latency window, cooldown clocks, and idle timer. Per-call ``slo`` /
    ``min_replicas`` / ``max_replicas`` overrides let pools run different
    targets (e.g. prefill vs TTFT, decode vs TPOT) without separate
    instances.
    """

    def __init__(self, slo: SLO | None = None, min_replicas: int = 1,
                 max_replicas: int = 4):
        assert 1 <= min_replicas <= max_replicas
        self.slo = slo or SLO()
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        # per-pool state, lazily created on first touch
        self._window: dict[str, deque[tuple[float, float]]] = {}
        self._last_up: dict[str, float] = {}
        self._last_down: dict[str, float] = {}
        self._idle_since: dict[str, float | None] = {}
        self.decisions: list[tuple[float, str, str]] = []  # (t, action, reason)

    def _w(self, pool: str) -> deque[tuple[float, float]]:
        return self._window.setdefault(pool, deque())

    # ------------------------------------------------------------------
    def record_completion(self, now: float, latency_s: float, *,
                          pool: str = "default") -> None:
        self._w(pool).append((now, latency_s))

    def p95(self, now: float, *, pool: str = "default",
            slo: SLO | None = None) -> float | None:
        slo = slo or self.slo
        self._purge(now, pool, slo)
        w = self._w(pool)
        if len(w) < slo.min_window_samples:
            return None
        return float(np.percentile([l for _, l in w], 95))

    def _purge(self, now: float, pool: str, slo: SLO) -> None:
        w = self._w(pool)
        while w and w[0][0] < now - slo.window_s:
            w.popleft()

    # ------------------------------------------------------------------
    def decide(self, now: float, *, serving: int, booting: int,
               queued: int, busy_slots: int, total_slots: int,
               boot_cost_s: float = 0.0, pool: str = "default",
               slo: SLO | None = None, min_replicas: int | None = None,
               max_replicas: int | None = None) -> str | None:
        """One scaling decision per call. ``serving``/``booting`` are replica
        counts; ``queued`` is pool-wide queued requests; ``busy_slots`` /
        ``total_slots`` are over SERVING replicas only. ``boot_cost_s`` is
        the expected boot latency of the NEXT replica (the manager derives
        it from the engines' boot-ladder preview): the longer a replica
        takes to come up, the earlier the queue trigger fires so it lands
        before the backlog blows the SLO. All counts must already be scoped
        to ``pool`` by the caller."""
        slo = slo or self.slo
        lo = self.min_replicas if min_replicas is None else min_replicas
        hi = self.max_replicas if max_replicas is None else max_replicas
        p95 = self.p95(now, pool=pool, slo=slo)
        active = serving + booting
        last_up = self._last_up.get(pool, -float("inf"))
        last_down = self._last_down.get(pool, -float("inf"))
        tag = "" if pool == "default" else f"{pool}: "
        queue_high = slo.queue_high_per_slot * total_slots
        if boot_cost_s > 0 and slo.boot_norm_s > 0:
            queue_high /= 1.0 + boot_cost_s / slo.boot_norm_s

        if active < hi and now - last_up >= slo.up_cooldown_s:
            reason = None
            if queued > queue_high:
                reason = (f"{tag}queue {queued} > {queue_high:.1f} "
                          f"({slo.queue_high_per_slot:g}/slot x {total_slots}"
                          f", boot {boot_cost_s:g}s)")
            elif p95 is not None and p95 > slo.p95_target_s:
                reason = f"{tag}p95 {p95:.2f}s > target {slo.p95_target_s:g}s"
            if reason is not None:
                self._last_up[pool] = now
                self._idle_since[pool] = None
                self.decisions.append((now, "up", reason))
                return "up"

        idle = queued == 0 and busy_slots <= slo.low_util * total_slots
        if idle:
            if self._idle_since.get(pool) is None:
                self._idle_since[pool] = now
        else:
            self._idle_since[pool] = None
        idle_since = self._idle_since.get(pool)
        if (serving > lo and booting == 0
                and idle_since is not None
                and now - idle_since >= slo.idle_drain_s
                and now - last_down >= slo.down_cooldown_s):
            self._last_down[pool] = now
            self.decisions.append(
                (now, "down",
                 f"{tag}idle {now - idle_since:.1f}s "
                 f"(busy {busy_slots}/{total_slots}, queue 0)"))
            return "down"
        return None
