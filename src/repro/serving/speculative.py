"""Speculative decoding proposers: draft K tokens cheaply, verify them all
with ONE target forward.

The serving decode path is memory-bandwidth-bound: every emitted token costs
a full target-model forward whose arithmetic intensity is ~1 (the paper's
low-overhead-computing pillar names exactly this regime). Speculative
decoding amortizes that forward over K drafted tokens — the fused data plane
verifies all K+1 positions in one jitted program
(``transformer.verify_chunk`` / ``verify_stepwise``) and the lossless
rejection-sampling rule (``sampling.accept_speculative``) keeps the emitted
distribution byte-identical to plain decoding. Same contract as every other
XaaS specialization: a faster backend that is *observationally equivalent*.

Two proposers, one protocol (``bind`` / ``warmup`` / ``admit`` /
``propose`` / ``retire``):

  * :class:`NGramProposer` — model-free prompt-lookup drafting: the longest
    recent n-gram suffix of the request's own token history is located
    earlier in the history and the tokens that followed it are drafted.
    Zero device work, deterministic, CI-friendly; shines on repetitive
    continuations and the shared-prefix / multi-turn traffic the radix
    prefix cache already targets.
  * :class:`DraftModelProposer` — a small same-family config (e.g.
    qwen2-0.5b drafting for qwen2.5-14b) runs its own fused greedy decode
    loop in the same ``_Programs`` style as the engine: per step, ONE jitted
    program advances the draft cache through [last, d_1 .. d_K] — K+1 draft
    decode steps — so the draft cache covers every position the target can
    commit, and rejected draft positions roll back for free under the same
    right-aligned stale-beyond-the-length-mask rule the target cache uses.
    Restricted to attention-family draft configs for exactly that reason.

Proposers are deliberately *deterministic* (point-mass q): the rejection
rule then degenerates to accept-with-probability-p(d), which stays lossless
(see ``accept_speculative``) without shipping a (B, K, V) proposal
distribution through the data plane each step.
"""
from __future__ import annotations

import dataclasses
import logging

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.serving.prefix_cache import state_batch_axes

__all__ = ["SpecConfig", "NGramProposer", "DraftModelProposer",
           "has_recurrent_state", "make_proposer"]

logger = logging.getLogger(__name__)

_RECURRENT_MIXERS = frozenset({"rglru", "mlstm", "slstm"})


def has_recurrent_state(cfg) -> bool:
    """True when any mixer carries non-positional serving state, which a
    parallel verify chunk would advance irreversibly — the engine then
    verifies stepwise with per-step state snapshots instead."""
    return any(s.mixer in _RECURRENT_MIXERS
               for s in tuple(cfg.prefix) + tuple(cfg.pattern))


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Engine-level speculative decoding configuration.

    k: drafted tokens per decode step (the verify program covers k+1
       positions; each step emits between 1 and k+1 tokens).
    proposer: "ngram" (prompt-lookup) or "draft" (small draft model).
    ngram_min/ngram_max: suffix n-gram lengths the lookup tries (longest
       first).
    draft_arch: config id of the draft model (proposer="draft"); must share
       the target's vocabulary and be attention-family.
    draft_seed: init seed used when no draft params are supplied (demo /
       benchmark use; real deployments pass trained params).
    """

    k: int = 4
    proposer: str = "ngram"
    ngram_max: int = 3
    ngram_min: int = 1
    draft_arch: str | None = None
    draft_seed: int = 0

    def __post_init__(self):
        assert self.k >= 1, "spec k must be >= 1"
        assert self.proposer in ("ngram", "draft"), self.proposer
        assert 1 <= self.ngram_min <= self.ngram_max


class NGramProposer:
    """Prompt-lookup drafting over the request's own token history.

    For each active slot, try suffix lengths n = ngram_max .. ngram_min:
    find an earlier occurrence of the history's last n tokens and draft the
    (up to) k tokens that followed it. Among candidate occurrences the most
    recent one that still has k continuation tokens wins (falling back to
    the occurrence with the longest continuation), so periodic generations
    draft whole cycle continuations instead of one-token stubs. Pure host
    numpy — the control plane drafts, the data plane only verifies.
    """

    kind = "ngram"

    def __init__(self, k: int, *, ngram_max: int = 3, ngram_min: int = 1):
        self.k = k
        self.ngram_max = ngram_max
        self.ngram_min = ngram_min

    # --- engine protocol (host-only proposer: mostly no-ops) ---
    def bind(self, engine) -> None:
        pass

    def warmup(self) -> None:
        pass

    def admit(self, slot: int, prompt) -> None:
        pass

    def retire(self, slot: int) -> None:
        pass

    def propose(self, engine, drafts: np.ndarray, ndraft: np.ndarray) -> None:
        for i, req in enumerate(engine.active):
            if req is None:
                continue
            d = self.lookup(engine.history(i), self.k)
            n = d.shape[0]
            drafts[i, :n] = d
            ndraft[i] = n

    def lookup(self, history: np.ndarray, k: int) -> np.ndarray:
        h = np.asarray(history, np.int32)
        length = int(h.shape[0])
        for n in range(min(self.ngram_max, length - 1), self.ngram_min - 1, -1):
            win = np.lib.stride_tricks.sliding_window_view(h, n)
            hits = np.flatnonzero((win == h[length - n:]).all(axis=1))
            hits = hits[hits < length - n]  # exclude the suffix itself
            if not hits.size:
                continue
            avail = length - (hits + n)
            full = hits[avail >= k]
            j = int(full.max()) if full.size else int(hits[np.argmax(avail)])
            return h[j + n: j + n + k]
        return h[:0]


class DraftModelProposer:
    """A small target-family model drafting greedily through its own fused
    decode loop.

    The draft model keeps its own (slots, max_len) serving-state tree in the
    same right-aligned absolute-position layout as the target: admission
    prefill writes the prompt at [0, L), and each ``propose`` runs one
    jitted program of k+1 draft decode steps processing
    [last, d_1 .. d_K] at positions [L, L+k] — one position PAST the last
    draft, so the draft cache already covers the bonus position when the
    target accepts everything. Rejected draft positions sit beyond the next
    step's length mask and are overwritten before they can be read: the
    identical free-rollback rule the target's verify chunk relies on, which
    is why the draft config must be attention-family (purely positional
    state).
    """

    kind = "draft"

    def __init__(self, draft_cfg, draft_params, k: int):
        if draft_cfg.frontend in ("audio", "vlm"):
            raise NotImplementedError(
                f"draft model frontend {draft_cfg.frontend!r} unsupported")
        if has_recurrent_state(draft_cfg):
            raise NotImplementedError(
                "draft model must be attention-family: recurrent state has "
                "no free rollback for rejected drafts (use an ngram "
                "proposer, or an attention draft config)")
        self.cfg = draft_cfg
        self.params = draft_params
        self.k = k

    def bind(self, engine) -> None:
        assert self.cfg.vocab_size == engine.cfg.vocab_size, (
            "draft and target models must share a vocabulary")
        dcfg = self.cfg
        k = self.k
        dt = jnp.dtype(dcfg.activ_dtype)
        geom = (engine.slots, engine.max_len, engine.prompt_buckets)
        if getattr(self, "_bound_geom", None) == geom:
            # re-bound to a fresh engine of the same geometry: keep the
            # compiled programs, just reset the draft state tree
            self.states = transformer.init_states(
                dcfg, self.slots, self.max_len, dt)
            return
        self._bound_geom = geom
        self.slots = engine.slots
        self.max_len = engine.max_len
        self.buckets = engine.prompt_buckets
        max_len = self.max_len
        self.states = transformer.init_states(dcfg, self.slots, max_len, dt)

        # per-leaf batch axis for the single-row admission scatter — the
        # shared structural probe the engine bundle and StateOps use
        axes = state_batch_axes(dcfg, max_len, dt)

        @jax.jit
        def prefill_assign(params, states, tokens, slot, length):
            """Prefill one prompt from scratch and scatter its draft state
            into row ``slot``."""
            one = transformer.init_states(dcfg, 1, max_len, dt)
            _, one, _ = transformer.prefill_chunk(
                params, dcfg, tokens, one, jnp.zeros((1,), jnp.int32), length)

            def put(ax, dst, src):
                row = jax.lax.dynamic_index_in_dim(src, 0, ax, keepdims=False)
                return jax.lax.dynamic_update_index_in_dim(
                    dst, row.astype(dst.dtype), slot, ax)

            return jax.tree.map(put, axes, states, one)

        self._prefill_assign = prefill_assign

        @jax.jit
        def draft_k(params, states, last, lengths, active):
            """Greedy-draft k tokens in one program: k+1 draft decode steps
            process [last, d_1 .. d_K] so the draft cache covers every
            position the target can commit this round."""
            cur, st, lens = last, states, lengths
            inc = active.astype(jnp.int32)
            toks = []
            for _ in range(k + 1):
                lens = lens + inc
                lg, st = transformer.decode_step(params, dcfg, cur, st, lens)
                cur = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                toks.append(cur)
            return jnp.stack(toks[:k], axis=1), st

        self._draft_k = draft_k

    def warmup(self) -> None:
        """Compile the per-bucket admission prefill and the draft loop."""
        zero = jnp.zeros((self.slots,), jnp.int32)
        for sb in self.buckets:
            self.states = self._prefill_assign(
                self.params, self.states, jnp.zeros((1, sb), jnp.int32),
                jnp.int32(0), jnp.ones((1,), jnp.int32))
        drafts, self.states = self._draft_k(
            self.params, self.states, zero, zero,
            jnp.zeros((self.slots,), bool))
        jax.block_until_ready(drafts)

    def admit(self, slot: int, prompt) -> None:
        # deferred import: engine imports this module at load time
        from repro.serving.engine import _bucket

        t = np.asarray(prompt, np.int32).reshape(-1)
        sb = _bucket(t.shape[0], self.buckets)
        padded = np.zeros((1, sb), np.int32)
        padded[0, : t.shape[0]] = t
        self.states = self._prefill_assign(
            self.params, self.states, jnp.asarray(padded), jnp.int32(slot),
            jnp.asarray([t.shape[0]], jnp.int32))

    def retire(self, slot: int) -> None:
        pass  # the row is overwritten wholesale at the next admission

    def propose(self, engine, drafts: np.ndarray, ndraft: np.ndarray) -> None:
        active = np.array([r is not None for r in engine.active])
        if not active.any():
            return
        d, self.states = self._draft_k(
            self.params, self.states,
            jnp.asarray(engine.last_tokens(), jnp.int32),
            jnp.asarray(engine.cache_lengths(), jnp.int32),
            jnp.asarray(active))
        d = np.asarray(jax.device_get(d))
        drafts[active] = d[active]
        ndraft[active] = self.k


def make_proposer(spec: SpecConfig, cfg, *, draft_cfg=None, draft_params=None):
    """Build the proposer a :class:`SpecConfig` names. For the draft kind,
    ``draft_cfg``/``draft_params`` override ``spec.draft_arch`` (tests pass
    the target's own params for a perfect-acceptance self-draft)."""
    if spec.proposer == "ngram":
        return NGramProposer(spec.k, ngram_max=spec.ngram_max,
                             ngram_min=spec.ngram_min)
    if draft_cfg is None:
        from repro import configs
        assert spec.draft_arch, "SpecConfig(proposer='draft') needs draft_arch"
        draft_cfg = configs.get_config(spec.draft_arch)
    if draft_params is None:
        logger.warning(
            "draft model %s: initializing RANDOM params (seed %d) — "
            "acceptance will be near-floor; pass trained draft params for "
            "real speedups", draft_cfg.name, spec.draft_seed)
        draft_params = transformer.init_model(
            jax.random.key(spec.draft_seed), draft_cfg)
    return DraftModelProposer(draft_cfg, draft_params, spec.k)
