"""Paged KV block manager: free-list page allocator with ref-counted
copy-on-write sharing, plus a radix prefix cache that shares pages by
ALIASING instead of copying.

The slot engine reserves a contiguous ``max_len`` KV strip per slot, so a
replica's concurrency is capped by ``slots`` no matter how short its
requests are. vLLM's observation is that KV memory should be paged like
virtual memory: the pool is cut into fixed-size pages, each request holds a
per-row *block table* (logical page j -> physical page id), and pages are
allocated on demand as the sequence grows. Short requests then hold pages
proportional to their length, and one replica sustains hundreds of in-flight
requests in the same KV budget.

Three pieces live here — all pure host-side control plane (the device-side
gather/scatter lives in ``models/attention.py``/``mla.py`` and the paged
kernels):

  * :class:`BlockManager` — LIFO free-list allocator over physical pages
    with per-page refcounts. Physical page 0 is the **null page**: inactive
    rows' writes are routed there and it is never allocated. Refcounts make
    pages shareable: a prefix-cache hit aliases the cached pages into the
    new request's block table (incref) instead of copying KV; the last,
    partially-filled page of a shared prefix is **copy-on-write** — the
    engine copies it to a fresh page before a request writes into it while
    ``ref > 1``. A **watermark** holds back a fraction of the pool at
    admission time so in-flight requests can keep growing without
    immediately hitting preemption.
  * :class:`PagedPrefixCache` — radix tree over prompt tokens, as in
    ``prefix_cache.PrefixCache``, but each node holds the *page-id list*
    covering its whole prefix ``[0, depth_end)`` rather than extracted
    state slices. Insert donates the request's prompt pages (incref — zero
    copies, zero device work); restore increfs the matched pages straight
    into the new request's block table. Byte accounting counts DISTINCT
    pages held (nodes alias each other's pages), and LRU eviction drops
    unreferenced-by-any-node leaves under a byte budget. No pins are
    needed: a request's own increfs keep its pages alive even if the node
    it restored from is evicted mid-flight.
  * :func:`pages_for` — the one place the tokens->pages rounding rule
    lives.

Determinism: the free list is a LIFO stack seeded in descending order, so
an identical admit/retire/fork/CoW sequence always yields identical page
assignments — asserted by the block-manager property tests and relied on
by the byte-parity tests against the slot engine.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

__all__ = ["BlockManager", "PagedPrefixCache", "PagedMatch", "pages_for"]


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to hold ``tokens`` cache entries."""
    return -(-int(tokens) // int(page_size))


class BlockManager:
    """Free-list page allocator with refcounts, CoW, and a watermark.

    ``num_pages`` counts physical pages INCLUDING the reserved null page 0;
    the allocatable pool is ``num_pages - 1`` pages. ``watermark`` is the
    fraction of the allocatable pool held back from admission-time
    allocation (decode growth may still use it — it exists precisely so
    admission cannot starve in-flight requests of growth room).
    """

    def __init__(self, num_pages: int, page_size: int, *,
                 watermark: float = 0.05):
        assert num_pages >= 2, "need at least the null page + one real page"
        assert page_size >= 1
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        # LIFO stack, seeded descending so page 1 allocates first
        self._free: list[int] = list(range(self.num_pages - 1, 0, -1))
        self.ref = np.zeros(self.num_pages, np.int32)
        pool = self.num_pages - 1
        self.watermark_pages = min(pool - 1, max(0, int(round(pool * watermark))))
        self.stats = {"allocs": 0, "frees": 0, "cow_copies": 0,
                      "peak_in_use": 0, "alloc_failures": 0,
                      "exports": 0, "installs": 0}

    # ------------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def can_alloc(self, n: int, *, respect_watermark: bool = False) -> bool:
        reserve = self.watermark_pages if respect_watermark else 0
        return len(self._free) - n >= reserve

    # ------------------------------------------------------------------
    def alloc(self, n: int = 1) -> list[int]:
        """Allocate ``n`` pages with refcount 1. Callers gate on
        :meth:`can_alloc`; running dry anyway is a bug (the engine preempts
        before it can happen)."""
        if n > len(self._free):
            self.stats["alloc_failures"] += 1
            raise RuntimeError(
                f"KV page pool exhausted: want {n}, free {len(self._free)}")
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            assert self.ref[p] == 0, f"allocated page {p} has live refs"
            self.ref[p] = 1
        self.stats["allocs"] += n
        self.stats["peak_in_use"] = max(self.stats["peak_in_use"], self.in_use)
        return out

    def incref(self, pages) -> None:
        for p in pages:
            assert 0 < p < self.num_pages, f"incref of invalid page {p}"
            assert self.ref[p] > 0, f"incref of free page {p}"
            self.ref[p] += 1

    def decref(self, pages) -> None:
        """Drop one reference per page; pages reaching 0 return to the free
        list (LIFO, in the order given — deterministic)."""
        for p in pages:
            assert 0 < p < self.num_pages, f"decref of invalid page {p}"
            assert self.ref[p] > 0, f"double free of page {p}"
            self.ref[p] -= 1
            if self.ref[p] == 0:
                self._free.append(p)
                self.stats["frees"] += 1

    def export_pages(self, pages) -> None:
        """Pin ``pages`` for a cross-replica handoff: the transfer ticket
        takes its OWN reference per page (incref), so the source slot can
        retire — and even be preempted or reused — while the pages stay
        resident until the handoff plane releases them (install confirmed
        on the destination, or the ticket is dropped). Mirrors
        :meth:`PagedPrefixCache.insert`'s donate-by-alias discipline."""
        self.incref(pages)
        self.stats["exports"] += len(list(pages))

    def install_pages(self, n: int) -> list[int]:
        """Allocate ``n`` fresh pages to receive handed-off KV content from
        another replica's pool. Pure alloc with its own stat — the device
        scatter that fills them is the engine's job. Callers gate on
        :meth:`can_alloc` exactly like admission-time allocation."""
        out = self.alloc(n)
        self.stats["installs"] += n
        return out

    def cow(self, page: int) -> int:
        """Copy-on-write bookkeeping: allocate a private copy target for
        ``page`` and release the caller's share of the original. The caller
        owns the DEVICE copy (pool[new] <- pool[old]) — this is control
        plane only. Requires ``ref[page] > 1`` (with one ref a copy would
        be pointless)."""
        assert self.ref[page] > 1, f"CoW of unshared page {page}"
        (new,) = self.alloc(1)
        self.decref([page])
        self.stats["cow_copies"] += 1
        return new

    # ------------------------------------------------------------------
    def utilization(self, total_tokens: int) -> dict:
        """Occupancy + internal fragmentation given the engine's count of
        live cache entries (sum of active request lengths). Fragmentation
        is the fraction of in-use page capacity not holding a live token —
        the slack the page-granular rounding costs (the slot engine's
        equivalent figure is ``1 - sum(len)/(slots*max_len)``)."""
        cap = self.in_use * self.page_size
        shared = int(np.sum(self.ref > 1))
        return {
            "pages_total": self.num_pages - 1,
            "pages_free": self.free_pages,
            "pages_in_use": self.in_use,
            "watermark_pages": self.watermark_pages,
            "fragmentation": 0.0 if cap == 0 else max(
                0.0, 1.0 - total_tokens / cap),
            "cow_shared_pages": shared,
            "cow_share_ratio": 0.0 if self.in_use == 0 else shared / self.in_use,
        }

    def report(self) -> dict:
        return dict(self.stats)

    def __repr__(self) -> str:  # debugging aid
        return (f"BlockManager(pages={self.num_pages}, free={self.free_pages},"
                f" page_size={self.page_size})")


# ---------------------------------------------------------------------------
# Paged radix prefix cache
# ---------------------------------------------------------------------------
class _PNode:
    __slots__ = ("tokens", "children", "parent", "pages", "true_len",
                 "depth_end", "last_use")

    def __init__(self, tokens: np.ndarray, parent: "_PNode | None"):
        self.tokens = tokens              # (K, seg) edge label
        self.children: dict[tuple, _PNode] = {}
        self.parent = parent
        self.pages: list[int] = []        # pages covering [0, depth_end)
        self.true_len = int(tokens.shape[-1])
        self.depth_end = 0
        self.last_use = 0

    @property
    def depth_start(self) -> int:
        return self.depth_end - self.true_len


@dataclasses.dataclass
class PagedMatch:
    """Radix lookup result. ``pages`` covers ``[0, usable)`` cache entries
    (``pages_for(usable, ps)`` ids); content past ``usable`` inside the last
    page belongs to a diverging cached suffix — masked out of every read by
    the length, and CoW-protected against the new request's writes."""

    path: list  # [(node, cols_used)]
    raw_len: int
    usable: int
    pages: list[int]


class PagedPrefixCache:
    """Radix prefix cache that shares KV by page aliasing (see module
    docstring). All sharing goes through ``bm`` refcounts; ``page_bytes``
    is the device footprint of ONE page summed across every layer's pools
    (the engine computes it from the paged state tree)."""

    def __init__(self, bm: BlockManager, *, capacity_bytes: int,
                 page_bytes: int):
        self.bm = bm
        self.capacity_bytes = int(capacity_bytes)
        self.page_bytes = max(1, int(page_bytes))
        self.root = _PNode(np.zeros((1, 0), np.int32), None)
        self._holds: dict[int, int] = {}  # page id -> # nodes listing it
        self.bytes = 0                    # distinct held pages * page_bytes
        self.nodes = 0
        self._tick = 0
        self.stats = {"inserts": 0, "splits": 0, "evictions": 0,
                      "evicted_bytes": 0, "hits": 0, "hit_tokens": 0}

    # ------------------------------------------------------------------
    @staticmethod
    def _norm(prompt) -> np.ndarray:
        t = np.asarray(prompt, np.int32)
        return t[None, :] if t.ndim == 1 else t

    def _hold(self, pages) -> None:
        self.bm.incref(pages)
        for p in pages:
            c = self._holds.get(p, 0)
            if c == 0:
                self.bytes += self.page_bytes
            self._holds[p] = c + 1

    def _unhold(self, pages) -> None:
        for p in pages:
            c = self._holds[p]
            if c == 1:
                del self._holds[p]
                self.bytes -= self.page_bytes
            else:
                self._holds[p] = c - 1
        self.bm.decref(pages)

    def _touch(self, path) -> None:
        self._tick += 1
        for node, _ in path:
            node.last_use = self._tick

    # ------------------------------------------------------------------
    def match(self, prompt, *, limit: int | None = None) -> PagedMatch:
        """Longest cached prefix; ``limit`` caps the usable depth (the
        engine passes len(prompt)-1 so the prefill suffix is never empty).
        The matched pages are NOT yet referenced for the caller — callers
        that keep them must :meth:`BlockManager.incref` them in the same
        control-plane tick (there is no device work in between, so nothing
        can evict the node first)."""
        toks = self._norm(prompt)
        length = toks.shape[-1]
        if limit is None:
            limit = length
        path: list = []
        node, depth = self.root, 0
        while depth < length:
            child = node.children.get(tuple(int(v) for v in toks[:, depth]))
            if child is None:
                break
            w = min(child.true_len, length - depth)
            span = toks[:, depth:depth + w]
            eq = np.all(child.tokens[:, :w] == span, axis=0)
            m = w if eq.all() else int(np.argmax(~eq))
            if m == 0:
                break
            path.append((child, m))
            depth += m
            if m < child.true_len:
                break
            node = child
        usable = min(depth, limit)
        pages: list[int] = []
        if usable > 0:
            deepest = path[-1][0]
            pages = deepest.pages[:pages_for(usable, self.bm.page_size)]
            self._touch(path)
            self.stats["hits"] += 1
            self.stats["hit_tokens"] += usable
        return PagedMatch(path=path, raw_len=depth, usable=usable, pages=pages)

    # ------------------------------------------------------------------
    def _split(self, node: _PNode, m: int) -> _PNode:
        """Split ``node``'s edge at offset m. The new head's page list is a
        prefix of ``node``'s — pure aliasing, no device work."""
        parent = node.parent
        head_tok = node.tokens[:, :m]
        head = _PNode(head_tok, parent)
        head.depth_end = node.depth_start + m
        head.last_use = node.last_use
        head.pages = node.pages[:pages_for(head.depth_end, self.bm.page_size)]
        self._hold(head.pages)
        node.tokens = node.tokens[:, m:]
        node.true_len -= m
        node.parent = head
        parent.children[tuple(int(v) for v in head_tok[:, 0])] = head
        head.children[tuple(int(v) for v in node.tokens[:, 0])] = node
        self.nodes += 1
        self.stats["splits"] += 1
        return head

    def insert(self, prompt, pages: list[int]) -> None:
        """Donate ``pages`` — the request's block-table entries covering its
        prompt ``[0, len(prompt))`` — to the tree. The cache takes its OWN
        references (incref); the donor keeps writing its decode suffix into
        the tail page, which is fine: cached content only spans prompt
        positions, and the donor's first tail write CoWs it out of the
        shared page anyway (its ref is now > 1)."""
        toks = self._norm(prompt)
        length = toks.shape[-1]
        assert len(pages) == pages_for(length, self.bm.page_size), (
            f"insert: {len(pages)} pages cannot cover {length} tokens")
        match = self.match(prompt)
        depth = match.raw_len
        if depth >= length:
            return  # already fully cached
        node = match.path[-1][0] if match.path else self.root
        if match.path and match.path[-1][1] < node.true_len:
            node = self._split(node, match.path[-1][1])
        leaf = _PNode(toks[:, depth:], node)
        leaf.depth_end = length
        leaf.pages = list(pages)
        self._hold(leaf.pages)
        node.children[tuple(int(v) for v in leaf.tokens[:, 0])] = leaf
        self.nodes += 1
        self.stats["inserts"] += 1
        self._touch(match.path + [(leaf, length - depth)])
        self.evict_to_budget()

    # ------------------------------------------------------------------
    def evict_to_budget(self) -> None:
        """Drop least-recently-used leaves until distinct-page bytes fit the
        budget. Same leaf-first strategy as the slot cache, minus pins —
        in-flight requests hold their own page refs, so eviction can never
        free a page out from under one."""
        while self.bytes > self.capacity_bytes:
            leaves = sorted(
                (n for n in self._iter_nodes()
                 if not n.children and n.parent is not None),
                key=lambda n: n.last_use)
            evicted = False
            for victim in leaves:
                if self.bytes <= self.capacity_bytes:
                    break
                before = self.bytes
                del victim.parent.children[
                    tuple(int(v) for v in victim.tokens[:, 0])]
                self._unhold(victim.pages)
                self.nodes -= 1
                self.stats["evictions"] += 1
                self.stats["evicted_bytes"] += before - self.bytes
                evicted = True
            if not evicted:
                return  # only the root left; nothing to drop

    def reclaim(self, pages_needed: int) -> bool:
        """Evict LRU leaves until the block manager can hand out
        ``pages_needed`` pages, or the tree is empty. Returns whether the
        allocator can now satisfy the request — the engine's first line of
        defense before preempting a running request. Note eviction only
        releases the CACHE's reference: pages still referenced by in-flight
        block tables stay resident (they were never extra memory — the
        cache entry merely aliased them)."""
        while self.bm.free_pages < pages_needed:
            leaves = sorted(
                (n for n in self._iter_nodes()
                 if not n.children and n.parent is not None),
                key=lambda n: n.last_use)
            if not leaves:
                break
            victim = leaves[0]
            before = self.bytes
            del victim.parent.children[
                tuple(int(v) for v in victim.tokens[:, 0])]
            self._unhold(victim.pages)
            self.nodes -= 1
            self.stats["evictions"] += 1
            self.stats["evicted_bytes"] += before - self.bytes
        return self.bm.free_pages >= pages_needed

    def _iter_nodes(self):
        stack = [self.root]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n.parent is not None:
                yield n

    # ------------------------------------------------------------------
    def report(self) -> dict:
        return {**self.stats, "nodes": self.nodes, "bytes": self.bytes,
                "capacity_bytes": self.capacity_bytes,
                "distinct_pages": len(self._holds)}
