"""Token sampling: greedy / temperature / top-k, audio multi-codebook aware."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["SamplingConfig", "sample"]


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    temperature: float = 0.0  # 0 -> greedy
    top_k: int = 0  # 0 -> full distribution


def sample(key: jax.Array, logits: jax.Array, cfg: SamplingConfig) -> jax.Array:
    """logits (..., V) f32 -> token ids (...,) int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k > 0:
        kth = jnp.sort(logits, axis=-1)[..., -cfg.top_k][..., None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
