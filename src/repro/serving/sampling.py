"""Token sampling: greedy / temperature / top-k, audio multi-codebook aware.

Two forms:
  * ``SamplingConfig`` + ``sample`` — the scalar, host-side form (one request,
    Python-branching on temperature/top_k; cheap, but each call is its own
    device program).
  * ``SamplingParams`` + ``sample_batched`` — the vectorized, device-side form:
    per-slot temperature/top_k carried as ``(B,)`` arrays so the whole batch
    samples inside ONE jitted program with no host branching. This is what the
    fused serving data plane uses (nothing slow on the data path).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["SamplingConfig", "SamplingParams", "sample", "sample_batched"]


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    temperature: float = 0.0  # 0 -> greedy
    top_k: int = 0  # 0 -> full distribution


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-slot sampling parameters as device arrays (vectorized
    ``SamplingConfig``): a pytree, so it traces straight through ``jax.jit``."""

    temperature: jax.Array  # (B,) f32; <= 0 -> greedy for that slot
    top_k: jax.Array  # (B,) int32; <= 0 -> full distribution

    @classmethod
    def from_configs(cls, cfgs: list[SamplingConfig]) -> "SamplingParams":
        return cls(
            temperature=jnp.asarray([c.temperature for c in cfgs], jnp.float32),
            top_k=jnp.asarray([c.top_k for c in cfgs], jnp.int32),
        )


def sample(key: jax.Array, logits: jax.Array, cfg: SamplingConfig) -> jax.Array:
    """logits (..., V) f32 -> token ids (...,) int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k > 0:
        kth = jnp.sort(logits, axis=-1)[..., -cfg.top_k][..., None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_batched(key: jax.Array, logits: jax.Array,
                   params: SamplingParams) -> jax.Array:
    """Vectorized per-row sampling, jit-safe (no host branching).

    logits: (B, V) f32 (or (B, K, V) for audio multi-codebook); params fields
    are (B,) and broadcast over trailing dims. Rows with temperature <= 0
    decode greedily; rows with top_k <= 0 sample the full distribution.
    Returns int32 ids of shape logits.shape[:-1].
    """
    v = logits.shape[-1]
    bshape = (-1,) + (1,) * (logits.ndim - 1)
    temp = params.temperature.reshape(bshape)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / jnp.maximum(temp, 1e-6)

    def _mask_topk(s):
        # per-row top-k threshold: k-th largest value (k clamped into [1, V])
        k = jnp.clip(jnp.where(params.top_k > 0, params.top_k, v), 1, v)
        kth_idx = jnp.broadcast_to(k.reshape(bshape) - 1, s.shape[:-1] + (1,))
        kth = jnp.take_along_axis(-jnp.sort(-s, axis=-1), kth_idx, axis=-1)
        return jnp.where(s < kth, -jnp.inf, s)

    # the O(V log V) sort only runs when some sampling row restricts to top-k
    needs_topk = jnp.any((params.top_k > 0) & (params.temperature > 0.0))
    masked = jax.lax.cond(needs_topk, _mask_topk, lambda s: s, scaled)
    sampled = jax.random.categorical(key, masked, axis=-1).astype(jnp.int32)
    gate = params.temperature.reshape((-1,) + (1,) * (greedy.ndim - 1)) > 0.0
    return jnp.where(gate, sampled, greedy)
