"""Token sampling: greedy / temperature / top-k, audio multi-codebook aware.

Two forms:
  * ``SamplingConfig`` + ``sample`` — the scalar, host-side form (one request,
    Python-branching on temperature/top_k; cheap, but each call is its own
    device program).
  * ``SamplingParams`` + ``sample_batched`` — the vectorized, device-side form:
    per-slot temperature/top_k carried as ``(B,)`` arrays so the whole batch
    samples inside ONE jitted program with no host branching. This is what the
    fused serving data plane uses (nothing slow on the data path).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["SamplingConfig", "SamplingParams", "accept_speculative", "sample",
           "sample_batched", "spec_target_probs"]


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    temperature: float = 0.0  # 0 -> greedy
    top_k: int = 0  # 0 -> full distribution


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-slot sampling parameters as device arrays (vectorized
    ``SamplingConfig``): a pytree, so it traces straight through ``jax.jit``."""

    temperature: jax.Array  # (B,) f32; <= 0 -> greedy for that slot
    top_k: jax.Array  # (B,) int32; <= 0 -> full distribution

    @classmethod
    def from_configs(cls, cfgs: list[SamplingConfig]) -> "SamplingParams":
        return cls(
            temperature=jnp.asarray([c.temperature for c in cfgs], jnp.float32),
            top_k=jnp.asarray([c.top_k for c in cfgs], jnp.int32),
        )


def sample(key: jax.Array, logits: jax.Array, cfg: SamplingConfig) -> jax.Array:
    """logits (..., V) f32 -> token ids (...,) int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k > 0:
        # clamp into [1, V] like sample_batched: an unclamped top_k > V
        # wraps JAX's negative index (V < k < 2V behaves like top_k = 2V-k)
        k = min(cfg.top_k, logits.shape[-1])
        kth = jnp.sort(logits, axis=-1)[..., -k][..., None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_batched(key: jax.Array, logits: jax.Array,
                   params: SamplingParams) -> jax.Array:
    """Vectorized per-row sampling, jit-safe (no host branching).

    logits: (B, V) f32 (or (B, K, V) for audio multi-codebook); params fields
    are (B,) and broadcast over trailing dims. Rows with temperature <= 0
    decode greedily; rows with top_k <= 0 sample the full distribution.
    Returns int32 ids of shape logits.shape[:-1].
    """
    v = logits.shape[-1]
    bshape = (-1,) + (1,) * (logits.ndim - 1)
    temp = params.temperature.reshape(bshape)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / jnp.maximum(temp, 1e-6)

    def _mask_topk(s):
        # per-row top-k threshold: k-th largest value (k clamped into [1, V])
        k = jnp.clip(jnp.where(params.top_k > 0, params.top_k, v), 1, v)
        kth_idx = jnp.broadcast_to(k.reshape(bshape) - 1, s.shape[:-1] + (1,))
        kth = jnp.take_along_axis(-jnp.sort(-s, axis=-1), kth_idx, axis=-1)
        return jnp.where(s < kth, -jnp.inf, s)

    # the O(V log V) sort only runs when some sampling row restricts to top-k
    needs_topk = jnp.any((params.top_k > 0) & (params.temperature > 0.0))
    masked = jax.lax.cond(needs_topk, _mask_topk, lambda s: s, scaled)
    sampled = jax.random.categorical(key, masked, axis=-1).astype(jnp.int32)
    gate = params.temperature.reshape((-1,) + (1,) * (greedy.ndim - 1)) > 0.0
    return jnp.where(gate, sampled, greedy)


# ---------------------------------------------------------------------------
# Speculative decoding: lossless batched rejection sampling
# ---------------------------------------------------------------------------
def spec_target_probs(logits: jax.Array, params: SamplingParams) -> jax.Array:
    """The per-position target distribution speculative verification samples
    from: temperature-scaled, top-k-masked softmax — the SAME modified
    distribution ``sample_batched`` draws from, applied over a (B, C, V)
    block of verified positions. Greedy rows (temperature <= 0) are handled
    by the caller via argmax and never read these probabilities."""
    b, c, v = logits.shape
    temp = jnp.maximum(params.temperature, 1e-6)[:, None, None]
    scaled = logits / temp
    kk = jnp.clip(jnp.where(params.top_k > 0, params.top_k, v), 1, v)

    def _mask(s):
        kth_idx = jnp.broadcast_to(kk[:, None, None] - 1, (b, c, 1))
        kth = jnp.take_along_axis(-jnp.sort(-s, axis=-1), kth_idx, axis=-1)
        return jnp.where(s < kth, -jnp.inf, s)

    needs_topk = jnp.any((params.top_k > 0) & (params.temperature > 0.0))
    masked = jax.lax.cond(needs_topk, _mask, lambda s: s, scaled)
    return jax.nn.softmax(masked, axis=-1)


def accept_speculative(
    key: jax.Array,
    logits: jax.Array,
    drafts: jax.Array,
    ndraft: jax.Array,
    params: SamplingParams,
    draft_probs: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Batched LOSSLESS rejection sampling over K drafted tokens per row.

    The standard speculative-sampling rule (Leviathan et al. / Chen et al.):
    draft i is accepted with probability min(1, p_i(d_i) / q_i(d_i)); at the
    first rejection the corrected token is sampled from the residual
    normalize(max(p - q, 0)); if every draft is accepted a bonus token is
    sampled from the K+1-th target distribution. The emitted stream is
    distributed EXACTLY as sampling from the target alone — acceleration
    never changes the output distribution. Greedy rows (temperature <= 0)
    reduce to exact prefix match against argmax, so greedy streams are
    byte-identical to non-speculative decoding.

    logits: (B, C=K+1, V) target logits at the verified positions;
    drafts: (B, K) int32 drafted tokens; ndraft: (B,) int32 how many are
    real (<= K; positions past ndraft are never accepted);
    draft_probs: (B, K, V) proposer distribution at each drafted position,
    or None for deterministic (point-mass) proposers — the rule then
    degenerates to accept-with-probability-p_i(d_i) and a residual with the
    drafted token removed, still lossless.

    Returns (tokens (B, C) int32, accepted (B,) int32): tokens[:, :a] are
    the accepted drafts, tokens[:, a] the corrected/bonus token; entries
    past a are zero. Every row always emits accepted + 1 tokens.
    """
    b, c, v = logits.shape
    k = c - 1
    ku, kr = jax.random.split(key)

    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (B, C)
    greedy_row = params.temperature <= 0.0                      # (B,)
    p = spec_target_probs(logits, params)                       # (B, C, V)

    kmask = jnp.arange(k)[None, :] < ndraft[:, None]
    p_d = jnp.take_along_axis(p[:, :k], drafts[..., None], axis=-1)[..., 0]
    if draft_probs is None:
        q_d = jnp.ones_like(p_d)
    else:
        q_d = jnp.take_along_axis(
            draft_probs, drafts[..., None], axis=-1)[..., 0]
    u = jax.random.uniform(ku, (b, k))
    # u < p/q, written mul-form so q == 0 accepts iff p > 0 (no div-by-zero)
    acc_stoch = u * q_d < p_d
    acc_greedy = drafts == greedy_tok[:, :k]
    acc = jnp.where(greedy_row[:, None], acc_greedy, acc_stoch) & kmask
    accepted = jnp.sum(jnp.cumprod(acc.astype(jnp.int32), axis=1), axis=1)

    # boundary position `accepted`: residual resample after a rejection,
    # plain target distribution for the bonus token after a clean sweep
    p_b = jnp.take_along_axis(p, accepted[:, None, None], axis=1)[:, 0]
    di = jnp.clip(accepted, 0, k - 1)
    if draft_probs is None:
        d_b = jnp.take_along_axis(drafts, di[:, None], axis=1)[:, 0]
        q_b = jax.nn.one_hot(d_b, v, dtype=p_b.dtype)
    else:
        q_b = jnp.take_along_axis(draft_probs, di[:, None, None], axis=1)[:, 0]
    rejected = accepted < ndraft
    residual = jnp.maximum(p_b - q_b, 0.0)
    rs = residual.sum(axis=-1, keepdims=True)
    residual = jnp.where(rs > 0, residual / rs, p_b)
    dist = jnp.where(rejected[:, None], residual, p_b)
    stoch = jax.random.categorical(
        kr, jnp.log(jnp.maximum(dist, 1e-38)), axis=-1).astype(jnp.int32)
    # greedy target is a point mass at argmax: the residual after removing
    # any rejected draft is still that same point mass
    greedy_b = jnp.take_along_axis(greedy_tok, accepted[:, None], axis=1)[:, 0]
    final = jnp.where(greedy_row, greedy_b, stoch)

    idx = jnp.arange(c)[None, :]
    padded = jnp.pad(drafts, ((0, 0), (0, 1)))
    out = jnp.where(idx < accepted[:, None], padded, 0)
    out = jnp.where(idx == accepted[:, None], final[:, None], out)
    return out, accepted
