"""Serving-as-a-Service glue: an ``XContainer`` whose deployment boots a
``ServingEngine``.

This is how serving becomes a first-class leased XaaS workload instead of a
hand-constructed engine: the container's ``meta['engine_factory']`` is the
boot hook ``InvocationService.acquire_serving`` calls after scheduling a
SERVICE-class lease and deploying the container. The container also carries a
real ``decode`` entrypoint through the deployment compiler, so the lease's
ledger meters decode FLOPs from the *compiled artifact* (billing from the
compiled truth, same as every other XaaS workload) rather than from user
claims.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import container as xcontainer
from repro.models import transformer
from repro.serving.engine import ServingEngine

__all__ = ["serving_container"]


def serving_container(
    cfg,
    params,
    *,
    slots: int = 8,
    max_len: int = 512,
    prompt_buckets: tuple[int, ...] = (32, 128, 512),
    fused: bool = True,
    sync_every: int = 1,
    prefix_cache_bytes: int | None = None,
    spec=None,
    draft_params=None,
    page_size: int | None = None,
    kv_pages: int | None = None,
    kv_watermark: float = 0.05,
    prefill_chunk_tokens: int | None = None,
    role: str = "both",
    name: str | None = None,
    artifact_store=None,
    mesh_shape: tuple[int, ...] | None = None,
    rules=None,
) -> xcontainer.XContainer:
    """Build a deployable serving container for one model.

    ``deploy()`` compiles the ``decode`` entrypoint (the metering artifact);
    ``meta['engine_factory'](deployment)`` boots the continuous-batching
    engine bound to that deployment. ``spec`` (a
    ``repro.serving.speculative.SpecConfig``) turns on speculative decoding
    in every engine booted from this container; ``draft_params`` optionally
    supplies trained draft-model weights for the "draft" proposer kind.
    ``artifact_store`` (a ``repro.checkpoint.store.ArtifactStore``) makes
    the container a source+IR container: deployed entrypoints and the
    engine's whole data-plane bundle persist as serialized executables, so
    a later PROCESS boots from cached IR instead of re-tracing (the
    IR-boot rung — docs/ir-containers.md).

    ``mesh_shape`` makes every engine booted from this container a
    *sharded* replica: its data plane traces under ``use_rules`` on a mesh
    of that geometry (the deployment's own mesh when it matches, else one
    built from the local devices), params and KV pools get NamedShardings
    from the logical-axis rule trees, and the SERVICE lease must be
    acquired against a profile whose ``chips`` equals the mesh size so
    metering bills every chip the replica spans. ``rules`` overrides the
    deployment's logical-axis rule set (default: the deployment's own —
    RULES_2D/RULES_3D by profile). ``mesh_shape=None`` keeps today's
    single-device engine untouched (the portability floor).
    """
    dt = jnp.dtype(cfg.activ_dtype)

    def decode_fn(params_, tokens, states, lengths):
        return transformer.decode_step(params_, cfg, tokens, states, lengths)

    def make_args(mesh):
        pshapes = jax.eval_shape(lambda: transformer.init_model(jax.random.key(0), cfg))
        sshapes = jax.eval_shape(lambda: transformer.init_states(cfg, slots, max_len, dt))
        if cfg.frontend == "audio":
            tok = jax.ShapeDtypeStruct((slots, cfg.num_codebooks), jnp.int32)
        else:
            tok = jax.ShapeDtypeStruct((slots,), jnp.int32)
        lens = jax.ShapeDtypeStruct((slots,), jnp.int32)
        return (pshapes, tok, sshapes, lens), {}, {}

    def engine_factory(deployment) -> ServingEngine:
        # the engine inherits the deployment's probed hook binding + its
        # specialization manifest: traffic is served by exactly the tiers
        # deploy() bound, and warmup() reports them
        proposer = None
        if spec is not None and draft_params is not None:
            from repro.serving.speculative import make_proposer
            proposer = make_proposer(spec, cfg, draft_params=draft_params)
        mesh = None
        eng_rules = None
        if mesh_shape is not None:
            # prefer the deployment's own mesh (built from the lease's
            # profile) so the engine shards exactly the devices the lease
            # granted; build one only when the profile is single-device
            # (e.g. a sharded container deployed for offline tracing)
            dep_geom = tuple(int(s) for s in deployment.mesh.devices.shape)
            if dep_geom == tuple(mesh_shape):
                mesh = deployment.mesh
            else:
                axes = ("data", "model")[-len(mesh_shape):]
                mesh = jax.make_mesh(tuple(mesh_shape), axes)
            eng_rules = rules if rules is not None else deployment.rules
        return ServingEngine(
            cfg, params, slots=slots, max_len=max_len,
            prompt_buckets=prompt_buckets, fused=fused, sync_every=sync_every,
            prefix_cache_bytes=prefix_cache_bytes,
            spec=spec, proposer=proposer,
            page_size=page_size, kv_pages=kv_pages,
            kv_watermark=kv_watermark,
            prefill_chunk_tokens=prefill_chunk_tokens,
            role=role,
            artifact_store=artifact_store,
            mesh=mesh, rules=eng_rules,
            binding=deployment.binding, manifest=deployment.manifest())

    # geometry in the name: the warm-deployment cache keys on (name, profile),
    # so two serving containers for the same arch but different slot/cache
    # geometry (incl. paged vs contiguous KV) must never alias each other's
    # compiled decode artifact
    paged_tag = f"-p{page_size}x{kv_pages or 0}" if page_size else ""
    role_tag = f"-{role}" if role != "both" else ""
    mesh_tag = ("-m" + "x".join(str(int(d)) for d in mesh_shape)
                if mesh_shape else "")
    return xcontainer.XContainer(
        name=name or (f"serve-{cfg.name}-b{slots}x{max_len}"
                      f"{paged_tag}{role_tag}{mesh_tag}"),
        entrypoints={"decode": (decode_fn, make_args)},
        meta={
            "engine_factory": engine_factory,
            "arch": cfg.name,
            "slots": slots,
            "max_len": max_len,
        },
        artifact_store=artifact_store,
    )
