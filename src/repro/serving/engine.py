"""Continuous-batching serving engine over the functional model zoo.

The XaaS serving story: a SERVICE-class lease holds a fixed chip allocation;
inside it, this engine multiplexes many short FaaS-style requests onto one
compiled decode program (the paper's "fine-grained transactional computations"
running on a long-lived high-performance allocation).

Design (vLLM-shape, JAX-native):
  * fixed slot count B (the compiled decode batch) with per-slot state inside
    the *stacked* KV/recurrent caches; slots are recycled across requests
    (continuous batching).
  * a FUSED per-step program: decode, per-slot sampling (temperature/top-k
    carried as (B,) device arrays), length update, and EOS/max-token
    done-flag computation all happen inside one ``jax.jit`` — the host syncs
    a single packed "tokens | active | done" row batch per step (or one
    stacked fetch every ``sync_every`` steps). Nothing slow on the data
    path, per the paper's Invocation principle.
  * batched admission: all admissible queued requests sharing a *suffix*
    bucket prefill in ONE batched program call (batch padded to a power of
    two so the compiled-program count stays bounded at
    #buckets x log2(slots)+1). Prompts are right-padded (absolute positions
    [0, L)), so with the optional radix prefix cache
    (``prefix_cache_bytes``) admission restores the longest cached prefix
    with a jitted scatter and prefills ONLY the suffix tokens — the largest
    prefill-compute lever under shared system prompts / multi-turn traffic.
  * slot admission writes the prefilled per-slot state into the batched
    state tree with a jitted scatter (`_assign`), so admission is O(state of
    one slot), not O(whole cache).
  * all host-side logic (queueing, retirement bookkeeping) is control plane;
    every data-plane array op is jit'd. REST never touches the data path.

``fused=False`` keeps the legacy host-loop step (B scalar ``sample`` calls +
per-token ``device_get`` + per-slot length sync) as the "before" reference for
``benchmarks/serving_throughput.py``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import hashlib
import logging
import time
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aot, hooks
from repro.distributed import sharding as shd
from repro.models import transformer
from repro.serving import speculative
from repro.serving.block_manager import (BlockManager, PagedPrefixCache,
                                         pages_for)
from repro.serving.prefix_cache import (PrefixCache, StateOps,
                                        state_batch_axes, state_pos_axes)
from repro.serving.sampling import (SamplingConfig, SamplingParams,
                                    accept_speculative, sample, sample_batched)

__all__ = ["Request", "RequestResult", "HandoffPacket", "ServingEngine",
           "clear_program_caches"]

logger = logging.getLogger(__name__)

_NO_LIMIT = 1 << 30


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: Any  # (S,) int32 (or (K, S) audio)
    max_new_tokens: int
    sampling: SamplingConfig = dataclasses.field(default_factory=SamplingConfig)
    eos_id: int | None = None


@dataclasses.dataclass
class RequestResult:
    request_id: int
    tokens: list[int] | list[tuple]  # generated tokens (tuples for audio)
    prefill_steps: int = 1
    decode_steps: int = 0
    # per-request latency telemetry (real wall-clock seconds): time to first
    # token (submit -> first sampled token visible on the host) and total
    # decode wall time after admission. With sync_every > 1, decode_s is
    # measured at the flush that retired the request (token visibility, not
    # device completion — the honest serving-side number).
    ttft_s: float = 0.0
    decode_s: float = 0.0

    @property
    def tpot_s(self) -> float:
        """Time per output token after the first (0 for 1-token results)."""
        n = len(self.tokens)
        return self.decode_s / (n - 1) if n > 1 else 0.0


@dataclasses.dataclass
class HandoffPacket:
    """A finished prefill staged for transfer to a decode replica — the
    unit of the disaggregated fleet's KV handoff plane.

    ``pages`` are the SOURCE replica's physical page ids; the packet holds
    one ticket reference per page (``BlockManager.export_pages``) so they
    stay resident after the source slot is freed, until the handoff plane
    confirms the install (or drops the packet) and decrefs them. ``payload``
    is the device->host staged copy: one ``(max_blocks, ...)`` array per
    state leaf in deterministic tree order, of which the first
    ``pages_for(length, page_size)`` rows are real. ``shas`` hash each real
    page's content across every leaf — the destination re-hashes before
    scattering, so a corrupted transfer is rejected (and the request falls
    back to a full local prefill) instead of silently decoding garbage.
    """

    request: Request
    prompt: np.ndarray        # (S,) int32 full prompt (affinity + fallback)
    length: int               # prompt tokens resident in the pages
    first_token: int          # sampled from the prefill logits at the source
    ttft_s: float             # source-side wall TTFT (virtual time is the
                              # fleet's job)
    pages: list[int]          # source physical page ids (ticket-referenced)
    payload: list[np.ndarray]
    shas: list[str]
    nbytes: int               # real-page bytes (the transfer cost model input)


def _page_shas(payload: list[np.ndarray], npages: int) -> list[str]:
    """Per-page content hash over every state leaf's row j (leaves in
    deterministic tree order) — the handoff plane's end-to-end integrity
    check between a source gather and a destination scatter."""
    out = []
    for j in range(npages):
        h = hashlib.sha256()
        for leaf in payload:
            h.update(np.ascontiguousarray(leaf[j]).tobytes())
        out.append(h.hexdigest())
    return out


def _bucket(n: int, buckets: tuple[int, ...]) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


def _pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


class _Programs:
    """The compiled data-plane program bundle for one (arch config, slot
    geometry, kernel-tier set).

    Every program the engine executes is pure in (params, state, ctrl), so
    nothing engine-instance-specific is baked into a trace — which means the
    bundle can be SHARED across engine instances. That is what makes a fleet
    replica boot *warm*: the first engine for a geometry pays trace+compile,
    every later replica (and every re-boot after a scale-to-zero release)
    reuses the same jitted programs, the serving analogue of the
    warm-deployment cache in ``InvocationService``.

    The cache key includes the hook binding's chosen providers: programs
    traced under one kernel tier must never serve an engine bound to another.

    Every program is registered in an :class:`repro.core.aot.AotRegistry`,
    so the bundle's compiled executables can ALSO be exported to a
    persistent ``ArtifactStore`` and re-installed in a later process — the
    IR-boot rung below this in-process warm cache (see
    ``ServingEngine.warmup``'s boot ladder and docs/ir-containers.md).
    """

    def __init__(self, cfg, slots: int, max_len: int):
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        dt = jnp.dtype(cfg.activ_dtype)
        # per-leaf slot/batch + positional axes, found structurally (the
        # shared probe in prefix_cache — same rule StateOps uses)
        state_axes = state_batch_axes(cfg, max_len, dt)
        self.state_axes = state_axes
        self.pos_axes = state_pos_axes(cfg, max_len, dt)
        self._spec_steps: dict[int, Any] = {}
        # every program below is registered here behind a shape-fingerprint
        # dispatcher, so the whole bundle can be exported to / installed
        # from a persistent artifact store (the IR-boot rung)
        self.aot = aot.AotRegistry()

        @jax.jit
        def fused_step(params, key, states, ctrl):
            """decode + sample + length update + done flags, one program."""
            active = ctrl["active"]
            lengths = ctrl["lengths"] + active.astype(jnp.int32)
            key, sub = jax.random.split(key)
            sp = SamplingParams(ctrl["temp"], ctrl["topk"])
            toks, new_states, _ = transformer.decode_and_sample(
                params, cfg, ctrl["last"], states, lengths, sub,
                lambda k, lg: sample_batched(k, lg, sp))
            gen = ctrl["gen"] + active.astype(jnp.int32)
            first = toks if toks.ndim == 1 else toks[:, 0]
            done = active & (
                (gen >= ctrl["max_new"])
                | ((ctrl["eos"] >= 0) & (first == ctrl["eos"]))
                | (lengths >= max_len))
            amask = active if toks.ndim == 1 else active[:, None]
            toks = jnp.where(amask, toks, 0)
            packed = jnp.concatenate([
                toks.reshape(slots, -1),
                active.astype(jnp.int32)[:, None],
                done.astype(jnp.int32)[:, None],
            ], axis=1)
            new_ctrl = dict(
                ctrl,
                lengths=jnp.where(done, 0, lengths),
                active=active & ~done,
                gen=gen,
                last=toks,
            )
            return key, new_states, new_ctrl, packed

        self.fused_step = self.aot.wrap("fused_step", fused_step)

        @jax.jit
        def prefill_chunk(params, tokens, states, start, lengths):
            # tokens: (N, Sc) right-padded suffix chunk ((N, K, Sc) audio);
            # states: batch state tree with any cached prefix already
            # restored at [0, start) per row; full prefill is start == 0
            return transformer.prefill_chunk(params, cfg, tokens, states,
                                             start, lengths)

        self.prefill_chunk = self.aot.wrap("prefill_chunk", prefill_chunk)

        dt_ = dt

        @functools.partial(jax.jit, static_argnums=(0,))
        def init_batch(n):
            return transformer.init_states(cfg, n, max_len, dt_)

        self.init_batch = self.aot.wrap("init_batch", init_batch,
                                        static_argnums=(0,))

        # structure-aware extract/restore programs for the prefix cache
        # (shared across engine instances like every other program here)
        self.state_ops = StateOps(cfg, max_len, dt, aot=self.aot)

        self.sample_first = self.aot.wrap("sample_first",
                                          jax.jit(sample_batched))

        @jax.jit
        def assign(states, batch_states, ctrl, src, slot, length, first_tok,
                   temp, topk, max_new, eos):
            """Scatter prefilled request `src` of a batched prefill into
            engine slot `slot`, and arm its control-block entries."""
            def put(ax, dst, s):
                row = jax.lax.dynamic_index_in_dim(s, src, ax, keepdims=False)
                return jax.lax.dynamic_update_index_in_dim(
                    dst, row.astype(dst.dtype), slot, ax)
            new_states = jax.tree.map(put, state_axes, states, batch_states)
            new_ctrl = dict(
                ctrl,
                lengths=ctrl["lengths"].at[slot].set(length),
                active=ctrl["active"].at[slot].set(True),
                gen=ctrl["gen"].at[slot].set(1),
                temp=ctrl["temp"].at[slot].set(temp),
                topk=ctrl["topk"].at[slot].set(topk),
                max_new=ctrl["max_new"].at[slot].set(max_new),
                eos=ctrl["eos"].at[slot].set(eos),
                last=ctrl["last"].at[slot].set(first_tok),
            )
            return new_states, new_ctrl

        self.assign = self.aot.wrap("assign", assign)

        @jax.jit
        def decode(params, tokens, states, lengths):
            return transformer.decode_step(params, cfg, tokens, states, lengths)

        self.decode = self.aot.wrap("decode", decode)  # legacy (unfused) step

    # ------------------------------------------------------------------
    def spec_step_for(self, k: int):
        """The fused speculative step program for draft length ``k``,
        memoized per bundle so engines (and fleet replicas) sharing a
        geometry share the compiled verify program too."""
        prog = self._spec_steps.get(k)
        if prog is None:
            prog = self._spec_steps[k] = self.aot.wrap(
                f"spec_step_k{k}", self._build_spec_step(k))
        return prog

    def _build_spec_step(self, k: int):
        """One jitted program per speculative step: verify all K+1 positions
        for every slot, run lossless rejection sampling, truncate at
        EOS/budget/cache-capacity, and update the device control block —
        the host fetches a single packed ``tokens*|emitted|active|done``
        matrix, exactly like the plain fused step but with up to K+1 tokens
        per slot per sync.

        Rollback is free for positional state (rejected cache writes sit
        beyond the committed length mask); archs with recurrent mixers
        verify stepwise and the program rolls their non-positional leaves
        back by selecting the per-step snapshot at each row's accepted
        boundary.
        """
        cfg, slots, max_len = self.cfg, self.slots, self.max_len
        c = k + 1
        stepwise = speculative.has_recurrent_state(cfg)
        state_axes, pos_axes = self.state_axes, self.pos_axes

        @jax.jit
        def spec_step(params, key, states, ctrl, drafts, ndraft):
            active = ctrl["active"]
            length = ctrl["lengths"]
            tokens = jnp.concatenate([ctrl["last"][:, None], drafts], axis=1)
            if stepwise:
                logits, steps = transformer.verify_stepwise(
                    params, cfg, tokens, states, length, active)
            else:
                logits, new_states = transformer.verify_chunk(
                    params, cfg, tokens, states, length)
            key, sub = jax.random.split(key)
            sp = SamplingParams(ctrl["temp"], ctrl["topk"])
            out, accepted = accept_speculative(sub, logits, drafts, ndraft, sp)
            if stepwise:
                # recurrent rollback: state after processing 1 + accepted
                # tokens is the snapshot at index `accepted`; positional
                # leaves keep the final write set (masked rollback)
                sel = jnp.clip(accepted, 0, c - 1)
                bidx = jnp.arange(slots)

                def pick(ba, pa, *leaves):
                    if pa != -1:
                        return leaves[-1]
                    arr = jnp.moveaxis(jnp.stack(leaves, 0), ba + 1, 1)
                    return jnp.moveaxis(arr[sel, bidx], 0, ba)

                new_states = jax.tree.map(pick, state_axes, pos_axes, *steps)

            emit = accepted + 1
            idx = jnp.arange(c)[None, :]
            eos_hit = ((idx < emit[:, None]) & (ctrl["eos"][:, None] >= 0)
                       & (out == ctrl["eos"][:, None]))
            any_eos = eos_hit.any(axis=1)
            first_eos = jnp.argmax(eos_hit, axis=1)
            m = jnp.where(any_eos, first_eos + 1, emit)
            m = jnp.minimum(m, jnp.maximum(ctrl["max_new"] - ctrl["gen"], 1))
            m = jnp.where(active, m, 0)
            new_len = length + m
            gen = ctrl["gen"] + m
            done = active & ((gen >= ctrl["max_new"])
                             | (any_eos & (first_eos < m))
                             | (new_len >= max_len))
            out = jnp.where(idx < m[:, None], out, 0)
            last = jnp.take_along_axis(
                out, jnp.maximum(m - 1, 0)[:, None], axis=1)[:, 0]
            packed = jnp.concatenate([
                out,
                m[:, None],
                active.astype(jnp.int32)[:, None],
                done.astype(jnp.int32)[:, None],
            ], axis=1)
            new_ctrl = dict(
                ctrl,
                lengths=jnp.where(done, 0, new_len),
                active=active & ~done,
                gen=gen,
                last=last,
            )
            return key, new_states, new_ctrl, packed

        return spec_step


_PROGRAMS: dict[tuple, _Programs] = {}


def _programs_for(cfg, slots: int, max_len: int,
                  binding: hooks.Binding | None,
                  mesh_key=None) -> _Programs:
    tiers = None if binding is None else binding.tier_fingerprint()
    # mesh geometry is part of program identity: the same arch x slot
    # geometry traced under a (1,2) mesh compiles different (SPMD) programs
    # than the single-device floor, and an engine must never serve through a
    # bundle traced for another mesh
    key = (cfg, slots, max_len, tiers, mesh_key)
    prog = _PROGRAMS.get(key)
    if prog is None:
        prog = _PROGRAMS[key] = _Programs(cfg, slots, max_len)
    return prog


def paged_page_axes(cfg, page_size: int, dtype):
    """Per-leaf page axis of the paged serving-state tree (the axis whose
    extent tracks the pool's page count), found structurally the same way
    ``state_batch_axes`` finds slot axes."""
    s2 = jax.eval_shape(
        lambda: transformer.init_paged_states(cfg, 2, page_size, dtype))
    s3 = jax.eval_shape(
        lambda: transformer.init_paged_states(cfg, 3, page_size, dtype))

    def axis(a, b):
        for i, (x, y) in enumerate(zip(a.shape, b.shape)):
            if x != y:
                return i
        raise AssertionError(f"paged state leaf has no page axis: {a.shape}")

    return jax.tree.map(axis, s2, s3)


class _PagedPrograms:
    """Compiled data-plane bundle for one PAGED geometry (arch config, slot
    count, max_len, page size, pool size, kernel-tier set) — the paged
    analogue of :class:`_Programs`, shared across engine instances the same
    way. The block-table array is an explicit program input, so host-side
    page remaps (growth, CoW, preemption) never retrace anything."""

    def __init__(self, cfg, slots: int, max_len: int, page_size: int,
                 num_pages: int):
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.page_size = page_size
        self.num_pages = num_pages
        self.max_blocks = max_len // page_size
        dt = jnp.dtype(cfg.activ_dtype)
        self.page_axes = paged_page_axes(cfg, page_size, dt)
        self._spec_steps: dict[int, Any] = {}
        self.aot = aot.AotRegistry()  # export/install, like _Programs

        @jax.jit
        def fused_step(params, key, states, ctrl, bt):
            """decode through block tables + sample + length update + done
            flags, one program (text frontend only — paged mode rejects
            audio/vlm at engine construction)."""
            active = ctrl["active"]
            lengths = ctrl["lengths"] + active.astype(jnp.int32)
            key, sub = jax.random.split(key)
            sp = SamplingParams(ctrl["temp"], ctrl["topk"])
            toks, new_states, _ = transformer.decode_and_sample(
                params, cfg, ctrl["last"], states, lengths, sub,
                lambda k, lg: sample_batched(k, lg, sp),
                block_tables=bt, page_size=page_size)
            gen = ctrl["gen"] + active.astype(jnp.int32)
            done = active & (
                (gen >= ctrl["max_new"])
                | ((ctrl["eos"] >= 0) & (toks == ctrl["eos"]))
                | (lengths >= max_len))
            toks = jnp.where(active, toks, 0)
            packed = jnp.concatenate([
                toks[:, None],
                active.astype(jnp.int32)[:, None],
                done.astype(jnp.int32)[:, None],
            ], axis=1)
            new_ctrl = dict(
                ctrl,
                lengths=jnp.where(done, 0, lengths),
                active=active & ~done,
                gen=gen,
                last=toks,
            )
            return key, new_states, new_ctrl, packed

        self.fused_step = self.aot.wrap("fused_step", fused_step)

        @jax.jit
        def prefill_chunk(params, tokens, states, start, lengths, bt):
            # tokens: (N, Sc) right-padded chunk; writes land in the shared
            # pools through per-row block tables — no per-slot scatter
            # (`_assign`) afterwards, admission is zero-copy
            return transformer.prefill_chunk(
                params, cfg, tokens, states, start, lengths,
                block_tables=bt, page_size=page_size)

        self.prefill_chunk = self.aot.wrap("prefill_chunk", prefill_chunk)

        @jax.jit
        def arm(ctrl, slot, length, first_tok, temp, topk, max_new, eos):
            """Arm a slot's control-block entries once its chunked prefill
            completes (the paged analogue of `_assign`, ctrl-only)."""
            return dict(
                ctrl,
                lengths=ctrl["lengths"].at[slot].set(length),
                active=ctrl["active"].at[slot].set(True),
                gen=ctrl["gen"].at[slot].set(1),
                temp=ctrl["temp"].at[slot].set(temp),
                topk=ctrl["topk"].at[slot].set(topk),
                max_new=ctrl["max_new"].at[slot].set(max_new),
                eos=ctrl["eos"].at[slot].set(eos),
                last=ctrl["last"].at[slot].set(first_tok),
            )

        self.arm = self.aot.wrap("arm", arm)

        @jax.jit
        def release(ctrl, slot):
            return dict(
                ctrl,
                lengths=ctrl["lengths"].at[slot].set(0),
                active=ctrl["active"].at[slot].set(False))

        self.release = self.aot.wrap("release", release)

        page_axes = self.page_axes

        @jax.jit
        def copy_page(states, src, dst):
            """Copy-on-write device op: pool[dst] <- pool[src] in every
            layer's pools (scan-stacked pools copy across all repeats)."""
            def f(ax, leaf):
                row = jax.lax.dynamic_index_in_dim(leaf, src, ax,
                                                   keepdims=False)
                return jax.lax.dynamic_update_index_in_dim(leaf, row, dst, ax)
            return jax.tree.map(f, page_axes, states)

        self.copy_page = self.aot.wrap("copy_page", copy_page)

        @jax.jit
        def gather_pages(states, idx):
            """Stage pages OUT for a cross-replica handoff: pull the rows
            named by ``idx`` ((max_blocks,) int32, padded with the null
            page) out of every layer's pools onto a leading page axis. The
            host slices off the real rows and ships them."""
            def f(ax, leaf):
                return jnp.moveaxis(jnp.take(leaf, idx, axis=ax), ax, 0)
            return jax.tree.map(f, page_axes, states)

        self.gather_pages = self.aot.wrap("gather_pages", gather_pages)

        @jax.jit
        def scatter_pages(states, payload, idx):
            """Install handed-off pages: write payload row j into physical
            page ``idx[j]`` of every pool. Pad rows target the reserved
            null page 0, which no armed slot's length-masked attention ever
            reads."""
            def f(ax, leaf, rows):
                moved = jnp.moveaxis(leaf, ax, 0)
                return jnp.moveaxis(moved.at[idx].set(rows), 0, ax)
            return jax.tree.map(f, page_axes, states, payload)

        self.scatter_pages = self.aot.wrap("scatter_pages", scatter_pages)

        self.sample_first = self.aot.wrap("sample_first",
                                          jax.jit(sample_batched))

    # ------------------------------------------------------------------
    def spec_step_for(self, k: int):
        prog = self._spec_steps.get(k)
        if prog is None:
            prog = self._spec_steps[k] = self.aot.wrap(
                f"spec_step_k{k}", self._build_spec_step(k))
        return prog

    def _build_spec_step(self, k: int):
        """Fused speculative step through block tables. Paged mode is
        attention-family only, so the stepwise (recurrent-rollback) variant
        of `_Programs._build_spec_step` never applies: rejected cache
        writes sit beyond the committed length mask, exactly as in the
        contiguous verify path."""
        cfg, max_len, page_size = self.cfg, self.max_len, self.page_size
        c = k + 1

        @jax.jit
        def spec_step(params, key, states, ctrl, drafts, ndraft, bt):
            active = ctrl["active"]
            length = ctrl["lengths"]
            tokens = jnp.concatenate([ctrl["last"][:, None], drafts], axis=1)
            logits, new_states = transformer.verify_chunk(
                params, cfg, tokens, states, length,
                block_tables=bt, page_size=page_size)
            key, sub = jax.random.split(key)
            sp = SamplingParams(ctrl["temp"], ctrl["topk"])
            out, accepted = accept_speculative(sub, logits, drafts, ndraft, sp)
            emit = accepted + 1
            idx = jnp.arange(c)[None, :]
            eos_hit = ((idx < emit[:, None]) & (ctrl["eos"][:, None] >= 0)
                       & (out == ctrl["eos"][:, None]))
            any_eos = eos_hit.any(axis=1)
            first_eos = jnp.argmax(eos_hit, axis=1)
            m = jnp.where(any_eos, first_eos + 1, emit)
            m = jnp.minimum(m, jnp.maximum(ctrl["max_new"] - ctrl["gen"], 1))
            m = jnp.where(active, m, 0)
            new_len = length + m
            gen = ctrl["gen"] + m
            done = active & ((gen >= ctrl["max_new"])
                             | (any_eos & (first_eos < m))
                             | (new_len >= max_len))
            out = jnp.where(idx < m[:, None], out, 0)
            last = jnp.take_along_axis(
                out, jnp.maximum(m - 1, 0)[:, None], axis=1)[:, 0]
            packed = jnp.concatenate([
                out,
                m[:, None],
                active.astype(jnp.int32)[:, None],
                done.astype(jnp.int32)[:, None],
            ], axis=1)
            new_ctrl = dict(
                ctrl,
                lengths=jnp.where(done, 0, new_len),
                active=active & ~done,
                gen=gen,
                last=last,
            )
            return key, new_states, new_ctrl, packed

        return spec_step


_PAGED_PROGRAMS: dict[tuple, _PagedPrograms] = {}


def _paged_programs_for(cfg, slots: int, max_len: int, page_size: int,
                        num_pages: int,
                        binding: hooks.Binding | None,
                        role: str = "both",
                        mesh_key=None) -> _PagedPrograms:
    tiers = None if binding is None else binding.tier_fingerprint()
    # role is in the key even though the programs are role-agnostic: a
    # phase-specialized pool's bundle must contain exactly ITS programs
    # (a decode replica's persisted artifact never carries — or recompiles —
    # the prefill pool's wide chunk programs). mesh_key: see _programs_for.
    key = (cfg, slots, max_len, page_size, num_pages, tiers, role, mesh_key)
    prog = _PAGED_PROGRAMS.get(key)
    if prog is None:
        prog = _PAGED_PROGRAMS[key] = _PagedPrograms(
            cfg, slots, max_len, page_size, num_pages)
    return prog


def clear_program_caches() -> None:
    """Drop every in-process program bundle — the warm-boot cache. The next
    engine for ANY geometry re-enters the boot ladder below the warm rung
    (IR-boot if its artifact store holds the bundle, else cold). This is
    how tests and benchmarks measure cross-process boot behavior without
    forking a fresh interpreter."""
    _PROGRAMS.clear()
    _PAGED_PROGRAMS.clear()


class ServingEngine:
    """Continuous-batching engine for one deployed model.

    fused: run the whole per-step loop as one compiled program (default);
        False keeps the legacy host-side loop for before/after benchmarks.
    sync_every: fetch the packed per-step result every k fused steps (k > 1
        trades per-token latency for k-fold fewer host<->device syncs; slots
        that finish mid-window idle until the next sync).
    prefix_cache_bytes: byte budget for the radix prefix cache (None/0
        disables reuse). With a cache, admission looks up the longest cached
        prefix of each prompt, scatters its per-layer state into the batch
        with a jitted restore, prefills only the suffix, and donates the
        full-prompt state back to the tree (ref-counted while the slot
        serves, LRU-evicted under the budget).

    Prompts are RIGHT-padded into their bucket (real tokens at positions
    [0, L), pads at the tail, dropped from the caches): absolute positions
    are what make a shared token prefix produce identical state regardless
    of total prompt length — and, as a bonus, pad tokens no longer pollute
    attention the way the old left-pad layout let them.
    """

    def __init__(
        self,
        cfg,
        params,
        *,
        slots: int = 8,
        max_len: int = 512,
        prompt_buckets: tuple[int, ...] = (32, 128, 512),
        rng: jax.Array | None = None,
        fused: bool = True,
        sync_every: int = 1,
        binding: hooks.Binding | None = None,
        manifest: dict | None = None,
        prefix_cache_bytes: int | None = None,
        spec: speculative.SpecConfig | None = None,
        proposer=None,
        page_size: int | None = None,
        kv_pages: int | None = None,
        kv_watermark: float = 0.05,
        prefill_chunk_tokens: int | None = None,
        role: str = "both",
        artifact_store=None,
        mesh: jax.sharding.Mesh | None = None,
        rules: shd.Rules | None = None,
    ):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        # ---- per-deployment mesh + sharding rules: every data-plane
        # program (fused decode/sample, chunked prefill, spec verify, paged
        # KV ops) traces under `use_rules(rules, mesh)` so the model code's
        # logical-axis constraint() annotations resolve to real mesh axes.
        # mesh=None is the untouched portability floor: constraints no-op,
        # programs trace single-device, bundle keys unchanged. ----
        if mesh is not None and rules is None:
            rules = dict(shd.RULES_2D)
        if mesh is not None and int(mesh.shape.get("data", 1)) > 1:
            # a serving replica shards model/expert-parallel only; data
            # parallelism is MORE replicas (the fleet's width-vs-count
            # tradeoff), not a batch axis inside one engine
            raise ValueError(
                f"serving mesh {dict(mesh.shape)} has data axis > 1; use a "
                f"(1, M) mesh and scale replica COUNT for data parallelism")
        self.mesh = mesh
        self.rules = rules if mesh is not None else None
        self._mesh_key = shd.mesh_geometry(mesh)
        # persistent AOT artifact store (checkpoint.store.ArtifactStore or
        # None): enables the IR-boot rung of warmup()'s boot ladder —
        # compiled executables serialized by a previous process deserialize
        # here instead of re-tracing
        self.artifact_store = artifact_store
        # the deployment's hook binding: data-plane programs trace under it,
        # so the engine serves through the tiers the deployment probed+bound
        # (None = portable floor). `manifest` is the deployment's
        # specialization record, reported by warmup().
        self.binding = binding
        self.manifest = manifest
        # max_len is ALWAYS the final bucket: a prompt longer than the largest
        # configured bucket but <= max_len must land in a bucket that can hold
        # it (otherwise the pad count goes negative and jnp.pad crashes).
        self.prompt_buckets = tuple(
            sorted({b for b in prompt_buckets if b < max_len} | {max_len}))
        self.rng = rng if rng is not None else jax.random.key(0)
        self.fused = fused
        self.sync_every = max(int(sync_every), 1)
        # ---- speculative decoding (draft at admission+decode, verify in
        # the fused step, lossless rejection sampling) ----
        self.spec = spec
        self.proposer = None
        if spec is not None:
            if not fused:
                raise ValueError(
                    "speculative decoding requires the fused data plane")
            if cfg.frontend in ("audio", "vlm"):
                raise NotImplementedError(
                    f"speculative decoding unsupported for the "
                    f"{cfg.frontend!r} frontend")
            if self.sync_every > 1:
                # the proposer drafts from the emitted token history, so
                # every speculative step must sync its packed result — the
                # win is up to k+1 tokens per sync instead of k steps/sync
                logger.warning(
                    "speculative decoding overrides sync_every=%d -> 1",
                    self.sync_every)
                self.sync_every = 1
            self.proposer = proposer or speculative.make_proposer(spec, cfg)

        # ---- phase specialization (disaggregated fleets): a "prefill"
        # engine runs chunked prefill ONLY — finished prompts leave as
        # HandoffPackets on `handoff_out` instead of arming a decode slot.
        # A "decode" engine is a full engine that ADDITIONALLY admits
        # requests by installing already-computed KV pages
        # (install_handoff), which is what lets the disagg fleet fall back
        # to monolithic colocation on a decode replica when the prefill
        # pool is empty or the handoff plane backlogs. ----
        if role not in ("both", "prefill", "decode"):
            raise ValueError(f"unknown engine role {role!r}")
        if role != "both" and page_size is None:
            raise ValueError(
                "phase-specialized engine roles require paged KV "
                "(the handoff plane moves pages, not slot strips)")
        if role == "prefill" and spec is not None:
            raise ValueError(
                "a prefill-only engine never decodes; speculative decoding "
                "belongs to the decode pool")
        self.role = role
        self.handoff_out: deque[HandoffPacket] = deque()

        # ---- paged KV (vLLM-style): a shared page pool + per-slot block
        # tables instead of per-slot contiguous max_len cache strips, so a
        # replica's concurrency is bounded by TOKENS held, not slots*max_len.
        # page_size=None keeps the slot engine (the parity baseline). ----
        self.paged = page_size is not None
        self.page_size = page_size
        self.block_manager: BlockManager | None = None
        if self.paged:
            if not fused:
                raise ValueError("paged KV requires the fused data plane")
            if cfg.frontend in ("audio", "vlm"):
                raise NotImplementedError(
                    f"paged KV unsupported for the {cfg.frontend!r} frontend")
            if not transformer.supports_paged_kv(cfg):
                raise NotImplementedError(
                    "paged KV requires an attention-family arch (recurrent "
                    "mixers carry non-positional state that cannot be paged)")
            if max_len % page_size:
                raise ValueError(
                    f"max_len {max_len} must be a multiple of "
                    f"page_size {page_size}")
            self.max_blocks = max_len // page_size
            if kv_pages is None:
                # full provisioning (every slot can reach max_len) — the
                # parity geometry; under-provision for the memory win
                kv_pages = slots * self.max_blocks + 1
            if kv_pages - 1 < self.max_blocks:
                raise ValueError(
                    f"kv_pages={kv_pages} cannot hold one max_len sequence "
                    f"({self.max_blocks} pages + the reserved null page)")
            self.kv_pages = kv_pages
            self.block_manager = BlockManager(
                kv_pages, page_size, watermark=kv_watermark)

        dt = jnp.dtype(cfg.activ_dtype)
        if self.paged:
            self.states = transformer.init_paged_states(
                cfg, self.kv_pages, page_size, dt)
        else:
            self.states = transformer.init_states(cfg, slots, max_len, dt)
        # device-side control block: everything the fused step needs to run
        # without consulting the host. (B,) arrays + the last sampled tokens.
        self.ctrl = {
            "lengths": jnp.zeros((slots,), jnp.int32),
            "active": jnp.zeros((slots,), bool),
            "gen": jnp.zeros((slots,), jnp.int32),
            "temp": jnp.zeros((slots,), jnp.float32),
            "topk": jnp.zeros((slots,), jnp.int32),
            "max_new": jnp.full((slots,), _NO_LIMIT, jnp.int32),
            "eos": jnp.full((slots,), -1, jnp.int32),
            "last": self._zero_tokens(slots),
        }
        if self.mesh is not None:
            # NamedSharding placement from the logical-axis rule trees:
            # params via PARAM_RULES (MoE expert weights land expert-parallel
            # on the model axis), KV pools / recurrent states via STATE_RULES
            # (kv_heads on model; slot/page axis on data). The small (B,)
            # control block replicates — it is host-mirrored every step.
            with shd.use_rules(self.rules, self.mesh):
                self.params = jax.device_put(
                    self.params, shd.param_shardings(self.params, self.mesh))
                self.states = jax.device_put(
                    self.states, shd.state_shardings(self.states, self.mesh))
            rep = jax.sharding.NamedSharding(
                self.mesh, jax.sharding.PartitionSpec())
            self.ctrl = {k: jax.device_put(v, rep)
                         for k, v in self.ctrl.items()}
        # host-side slot table (control plane only)
        self.active: list[Request | None] = [None] * slots
        self.generated: list[list] = [[] for _ in range(slots)]
        self.queue: deque[Request] = deque()
        self.results: dict[int, RequestResult] = {}
        self._seen_ids: set[int] = set()
        self._pending: list[jax.Array] = []  # un-synced packed step results
        self.stats = {
            "prefills": 0,          # requests prefilled
            "prefill_calls": 0,     # batched prefill program executions
            "prefill_tokens": 0,    # padded token-positions run through prefill
            "decode_steps": 0,
            "retired": 0,
            "host_syncs_decode": 0,  # blocking device->host syncs on the decode path
            "host_syncs_admit": 0,   # blocking syncs during admission
            "unserved": 0,
            "prefix_hits": 0,        # admissions that reused a cached prefix
            "prefix_misses": 0,      # cache enabled but no usable prefix
            "prefix_hit_tokens": 0,  # prompt tokens restored instead of prefilled
            # ---- speculative decoding telemetry ----
            "spec_steps": 0,         # speculative verify program executions
            "spec_slot_steps": 0,    # active slots summed over those steps
            "spec_drafted": 0,       # draft tokens offered for verification
            "spec_accepted": 0,      # draft tokens accepted (and emitted)
            "spec_emitted": 0,       # total tokens emitted by spec steps
            "spec_positions": 0,     # decode-equivalent positions verified
                                     # (k+1 per step; rejected ones included
                                     # — the lease pays for drafted work)
            # ---- paged-KV telemetry (always present; nonzero only when
            # page_size is set) ----
            "chunk_prefill_calls": 0,  # batched chunk programs run
            "preemptions": 0,          # requests evicted to recompute
            "admit_skips": 0,          # watermark skips that let later
                                       # requests admit out of order
            # ---- disaggregation telemetry (role != "both") ----
            "handoffs_out": 0,         # finished prefills exported as packets
            "handoffs_in": 0,          # packets installed into decode slots
            "handoff_sha_rejects": 0,  # packets refused on page-sha mismatch
            # ---- latency telemetry (real wall-clock; per-request values
            # live in RequestResult.ttft_s / decode_s) ----
            "ttft_sum_s": 0.0,
            "decode_sum_s": 0.0,
        }

        # ---- compiled programs: shared per (cfg, geometry, tier-set) so
        # replica boots after the first are warm (see _Programs) ----
        if self.paged:
            pprogs = _paged_programs_for(
                cfg, slots, max_len, page_size, self.kv_pages, binding,
                role=self.role, mesh_key=self._mesh_key)
            self._paged_progs = pprogs
            self._fused_step_paged = pprogs.fused_step
            self._prefill_chunk_paged = pprogs.prefill_chunk
            self._arm = pprogs.arm
            self._release_ctrl = pprogs.release
            self._copy_page = pprogs.copy_page
            self._gather_pages = pprogs.gather_pages
            self._scatter_pages = pprogs.scatter_pages
            self._sample_first = pprogs.sample_first
            self._spec_step = (pprogs.spec_step_for(spec.k)
                               if spec is not None else None)
            # device footprint of ONE page summed across every layer's
            # pools — the unit of the paged prefix cache's byte budget
            self.page_bytes = sum(
                int(np.prod(l.shape)) // l.shape[ax]
                * jnp.dtype(l.dtype).itemsize
                for l, ax in zip(jax.tree.leaves(self.states),
                                 jax.tree.leaves(pprogs.page_axes)))
            self.prefix_cache = (
                PagedPrefixCache(self.block_manager,
                                 capacity_bytes=prefix_cache_bytes,
                                 page_bytes=self.page_bytes)
                if prefix_cache_bytes else None)
        else:
            progs = _programs_for(cfg, slots, max_len, binding,
                                  mesh_key=self._mesh_key)
            self._progs = progs
            self._fused_step = progs.fused_step
            self._prefill_chunk = progs.prefill_chunk
            self._init_batch = progs.init_batch
            self._sample_first = progs.sample_first
            self._assign = progs.assign
            self._decode = progs.decode  # legacy (unfused) step

            self._spec_step = (progs.spec_step_for(spec.k)
                               if spec is not None else None)

            self.prefix_cache = (
                PrefixCache(progs.state_ops, capacity_bytes=prefix_cache_bytes)
                if prefix_cache_bytes else None)
        self._slot_pins: list = [None] * slots

        # ---- paged host-side control plane ----
        # block tables mirror: logical page j of slot i -> physical page id.
        # A slot's row stays ZERO (the null page) until its chunked prefill
        # completes and the slot is armed — so device programs running over
        # all B rows (decode, spec verify) can never write a mid-prefill
        # row's real pages.
        self._bt_host = (np.zeros((slots, self.max_blocks), np.int32)
                         if self.paged else None)
        self._bt_dev: jax.Array | None = None
        self._bt_dirty = True
        self._pages: list[list[int]] = [[] for _ in range(slots)]
        self._admitting: dict[int, dict] = {}   # slot -> chunked-prefill state
        self._admit_seq = [0] * slots           # admission order (preempt youngest)
        self._seq = 0
        self._slot_submit = [0.0] * slots       # original submit time (preempt restore)
        self._chunk_cap = (int(prefill_chunk_tokens) if prefill_chunk_tokens
                           else self.prompt_buckets[-1])
        self._chunk_widths = tuple(sorted(
            {min(b, self._chunk_cap) for b in self.prompt_buckets}))

        # host mirrors for the proposer control plane (spec mode only): the
        # per-slot token history (prompt + emitted), cache length, and
        # pending last token, kept in lockstep with the device control block
        # by the per-step packed sync
        self._hist: list[np.ndarray | None] = [None] * slots
        self._len_host = np.zeros((slots,), np.int64)
        self._last_host = np.zeros((slots,), np.int64)
        if self.proposer is not None:
            self.proposer.bind(self)
        if self.manifest is not None and spec is not None:
            # surface the acceleration mode next to the kernel tiers: the
            # operator should see HOW traffic is served from one record
            self.manifest = dict(self.manifest, speculative={
                "proposer": self.proposer.kind, "k": spec.k})
        if self.manifest is not None and self.paged:
            self.manifest = dict(self.manifest, paged_kv={
                "page_size": self.page_size,
                "kv_pages": self.kv_pages,
                "watermark_pages": self.block_manager.watermark_pages,
                "page_bytes": self.page_bytes,
                "role": self.role,
            })

        # latency bookkeeping (satellite telemetry: TTFT / decode wall)
        self._submit_s: dict[int, float] = {}
        self._slot_ttft = [0.0] * slots
        self._admit_s = [0.0] * slots

        # ---- persistent-AOT bundle identity: every field that selects a
        # distinct compiled program set. bundle_key() folds in the
        # jax/jaxlib version + platform, so environment drift invalidates
        # stored artifacts the same way a tier change does. ----
        self._aot_fields = {
            "family": f"serving:{cfg.name}",
            "kind": "paged" if self.paged else "slots",
            "role": self.role,
            "cfg": cfg,
            "slots": slots,
            "max_len": max_len,
            "prompt_buckets": self.prompt_buckets,
            "fused": self.fused,
            "tiers": (None if binding is None
                      else binding.tier_fingerprint()),
            "spec": (None if spec is None
                     else (spec.k, getattr(self.proposer, "kind", None))),
            "page_size": self.page_size,
            "kv_pages": getattr(self, "kv_pages", None),
            "chunk_widths": self._chunk_widths if self.paged else None,
            "prefix_cache": self.prefix_cache is not None,
            # mesh geometry fingerprint: IR-boot must never install an
            # executable traced for a different device grid — a (1,2)
            # bundle deserialized onto a single-device replica (or vice
            # versa) would crash or silently misplace every array
            "mesh": self._mesh_key,
        }
        self._bundle_key = aot.bundle_key(self._aot_fields)

    # ------------------------------------------------------------------
    def _bound(self):
        """Tracing/execution scope for the data plane: jit programs trace on
        first call, and the trace must happen under (a) the deployment's
        hook binding so the probed tiers actually serve traffic, and (b) the
        deployment's mesh + sharding rules so the model's logical-axis
        constraints resolve to mesh axes and every program lowers SPMD.
        Unsharded engines with no binding get a plain nullcontext — the
        portability floor stays byte-identical."""
        stack = contextlib.ExitStack()
        if self.mesh is not None:
            stack.enter_context(self.mesh)
            stack.enter_context(shd.use_rules(self.rules, self.mesh))
        if self.binding is not None:
            stack.enter_context(hooks.use(self.binding))
        return stack

    def _aot_registry(self) -> aot.AotRegistry:
        return (self._paged_progs if self.paged else self._progs).aot

    def boot_path_preview(self, *, assume_fresh_process: bool = False) -> str:
        """Which rung of the boot ladder warmup() WOULD take right now,
        without compiling anything — what the fleet's boot-cost-aware
        autoscaler consults before paying for a scale-up.

        ``assume_fresh_process`` skips the warm rung: the answer is then
        "ir" or "cold" as if no program in this process had ever compiled —
        what a virtual-time fleet uses to cost a boot whose warm/cold state
        it models itself (the in-process bundle may be hot for reasons
        outside the fleet's own history, e.g. another fleet in the same
        benchmark process)."""
        if (not assume_fresh_process
                and self._aot_registry().compiled_count() > 0):
            return "warm"
        if (self.artifact_store is not None
                and aot.AOT_AVAILABLE
                and self.artifact_store.contains(self._bundle_key)):
            return "ir"
        return "cold"

    def warmup(self) -> dict:
        """Boot the data plane through the three-rung ladder and return the
        full specialization manifest (ALWAYS a dict — even when every
        program was already a cache hit — with the boot record under
        ``"boot"``):

        1. **warm**  — the in-process program bundle already holds compiled
           executables (a previous replica of this geometry paid for them);
        2. **ir**    — the artifact store holds a bundle for this exact
           cfg x geometry x tier x spec x jax-version x platform key:
           deserialize the executables instead of re-tracing;
        3. **cold**  — trace + compile everything, then persist the bundle
           so the NEXT process IR-boots.

        Any mismatch (absent/stale/corrupt artifact, version or tier drift)
        falls through to the next rung with the reason recorded in
        ``manifest["boot"]["fallthrough"]`` — mirroring how probe-tier
        rejections are recorded per API. Programs the IR rung installed are
        never re-traced: the warmup sweep below dispatches to them by shape
        fingerprint and compiles only what is missing."""
        t0 = time.perf_counter()
        reg = self._aot_registry()
        boot: dict[str, Any] = {"path": "cold",
                                "bundle_key": self._bundle_key,
                                "fallthrough": []}
        if reg.compiled_count() > 0:
            boot["path"] = "warm"
        else:
            boot["fallthrough"].append(
                "warm: program bundle empty (first boot in this process)")
            if self.artifact_store is None:
                boot["fallthrough"].append("ir: no artifact store attached")
            elif not aot.AOT_AVAILABLE:
                boot["fallthrough"].append(
                    "ir: jax AOT serialization unavailable")
            else:
                got = self.artifact_store.get(self._bundle_key)
                if got is None:
                    reasons = [self.artifact_store.last_error
                               or "artifact missing"]
                    reasons += aot.explain_mismatch(self.artifact_store,
                                                    self._aot_fields)
                    boot["fallthrough"].extend(f"ir: {r}" for r in reasons)
                else:
                    blobs, _meta = got
                    installed, errors = reg.install(blobs)
                    boot["fallthrough"].extend(f"ir: {e}" for e in errors)
                    if installed > 0:
                        boot["path"] = "ir"
                    else:
                        boot["fallthrough"].append(
                            "ir: artifact held no installable programs")
        compiles_before = reg.compile_count()
        with self._bound():
            self._warmup_programs()
        boot["warmup_compiles"] = reg.compile_count() - compiles_before
        if (self.artifact_store is not None and aot.AOT_AVAILABLE
                and boot["path"] != "warm" and boot["warmup_compiles"] > 0):
            # cold rung persists; an IR boot that still had to compile some
            # programs tops the artifact up for the next process
            boot["persisted"] = self.persist_programs().get("persisted", 0)
        boot["programs"] = reg.counts()
        boot["boot_s"] = round(time.perf_counter() - t0, 6)
        manifest = dict(self.manifest) if self.manifest else {}
        manifest["boot"] = boot
        self.manifest = manifest
        tiers = {a: c["provider"]
                 for a, c in manifest.get("apis", {}).items()}
        logger.info("serving warm [%s @ %s] boot=%s (%.2fs): %s",
                    manifest.get("container", "?"),
                    manifest.get("profile", "?"),
                    boot["path"], boot["boot_s"], tiers)
        return self.manifest

    def persist_programs(self) -> dict:
        """Serialize every compiled executable of this bundle into the
        artifact store under the bundle key. Called automatically at the
        end of a cold (or partially-cold) warmup; call it again after
        serving traffic to also capture shapes warmup's sweep missed."""
        if self.artifact_store is None:
            return {"persisted": 0, "reason": "no artifact store attached"}
        if not aot.AOT_AVAILABLE:
            return {"persisted": 0,
                    "reason": "jax AOT serialization unavailable"}
        reg = self._aot_registry()
        blobs = reg.export()
        if not blobs:
            return {"persisted": 0, "reason": "no serializable executables"}
        meta = {
            "fields": aot.canonical_fields(self._aot_fields),
            "programs": sorted({k.rpartition("@")[0] for k in blobs}),
        }
        self.artifact_store.put(self._bundle_key, blobs, meta=meta)
        return {"persisted": len(blobs)}

    def _warmup_programs(self) -> None:
        if self.paged:
            self._warmup_paged()
            return
        if self.fused:
            self._fused_step(self.params, self.rng, self.states, self.ctrl)
            if self.spec is not None:
                # verify program (outputs discarded, engine state untouched)
                # + the proposer's own programs (draft prefill/decode loop)
                self._spec_step(
                    self.params, self.rng, self.states, self.ctrl,
                    jnp.zeros((self.slots, self.spec.k), jnp.int32),
                    jnp.zeros((self.slots,), jnp.int32))
                self.proposer.warmup()
        else:
            self._decode(self.params, self.ctrl["last"], self.states,
                         self.ctrl["lengths"])
        npads, n = [], 1
        top = _pow2(self.slots) if self.fused else 1
        while n <= top:
            npads.append(n)
            n <<= 1
        key = jax.random.key(0)
        zero_tok = self._zero_tokens(1)[0]
        for npad in npads:
            states = self._init_batch(npad)
            start = jnp.zeros((npad,), jnp.int32)
            lens = jnp.ones((npad,), jnp.int32)
            for sb in self.prompt_buckets:
                if self.cfg.frontend == "audio":
                    toks = jnp.zeros((npad, self.cfg.num_codebooks, sb), jnp.int32)
                else:
                    toks = jnp.zeros((npad, sb), jnp.int32)
                logits, bstates, _ = self._prefill_chunk(
                    self.params, toks, states, start, lens)
            self._sample_first(
                key, logits, SamplingParams.from_configs([SamplingConfig()] * npad))
            self._assign(self.states, bstates, self.ctrl, 0, 0, 0, zero_tok,
                         0.0, 0, _NO_LIMIT, -1)
            if self.prefix_cache is not None:
                # prefix-cache device ops: one extract/restore program per
                # pow2 block length per batch geometry
                ops = self.prefix_cache.ops
                p, zero = 1, jnp.int32(0)
                while p <= self.max_len:
                    blk = ops.extract_pos(p, bstates, zero, zero)
                    ops.restore_pos(p, states, blk, zero, zero, zero)
                    p <<= 1
                ops.restore_snap(states, ops.extract_snap(bstates, zero), zero)
        jax.block_until_ready(self.states)

    def _warmup_paged(self) -> None:
        """Pre-compile the paged data plane: the fused step, the spec
        verify step, each (pow2 batch, chunk width) prefill program, the
        ctrl arm/release ops, and the CoW page copy. All outputs are
        discarded; writes land on the null page (zero block tables)."""
        bt = jnp.zeros((self.slots, self.max_blocks), jnp.int32)
        self._fused_step_paged(self.params, self.rng, self.states, self.ctrl,
                               bt)
        if self.spec is not None:
            self._spec_step(self.params, self.rng, self.states, self.ctrl,
                            jnp.zeros((self.slots, self.spec.k), jnp.int32),
                            jnp.zeros((self.slots,), jnp.int32), bt)
            self.proposer.warmup()
        key = jax.random.key(0)
        n = 1
        while n <= _pow2(self.slots):
            start = jnp.zeros((n,), jnp.int32)
            lens = jnp.ones((n,), jnp.int32)
            sbt = jnp.zeros((n, self.max_blocks), jnp.int32)
            for cw in self._chunk_widths:
                toks = jnp.zeros((n, cw), jnp.int32)
                logits, _, _ = self._prefill_chunk_paged(
                    self.params, toks, self.states, start, lens, sbt)
            self._sample_first(
                key, logits,
                SamplingParams.from_configs([SamplingConfig()] * n))
            n <<= 1
        self._arm(self.ctrl, jnp.int32(0), jnp.int32(0), jnp.int32(0),
                  jnp.float32(0.0), jnp.int32(0), jnp.int32(_NO_LIMIT),
                  jnp.int32(-1))
        self._release_ctrl(self.ctrl, jnp.int32(0))
        self._copy_page(self.states, jnp.int32(0), jnp.int32(0))
        if self.role == "prefill":
            # handoff staging: the prefill pool's only extra program.
            # Monolithic engines skip both handoff programs — a colocation
            # fallback never runs them either (it prefills locally), so
            # their bundles stay exactly as before this feature existed.
            self._gather_pages(self.states,
                               jnp.zeros((self.max_blocks,), jnp.int32))
        elif self.role == "decode":
            # install scatter only: a decode replica must never compile (or
            # persist) the prefill pool's staging program
            self._scatter_pages(self.states, self._payload_zeros(),
                                jnp.zeros((self.max_blocks,), jnp.int32))
        jax.block_until_ready(self.states)

    # ------------------------------------------------------------------
    def _zero_tokens(self, n: int):
        if self.cfg.frontend == "audio":
            return jnp.zeros((n, self.cfg.num_codebooks), jnp.int32)
        return jnp.zeros((n,), jnp.int32)

    def submit(self, req: Request) -> None:
        s = np.asarray(req.prompt).shape[-1]
        if s > self.max_len:
            raise ValueError(f"prompt {s} > engine max_len {self.max_len}")
        if req.request_id in self._seen_ids:
            # a duplicate would silently overwrite its results entry and
            # corrupt downstream token metering deltas
            raise ValueError(f"duplicate request_id {req.request_id}")
        self._seen_ids.add(req.request_id)
        self._submit_s[req.request_id] = time.perf_counter()
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.active) if r is None]

    # ---- proposer protocol: host mirrors of the device control block ----
    def history(self, slot: int) -> np.ndarray:
        """Prompt + every emitted token of the request in ``slot`` (the last
        entry is the pending token the next verify step will process)."""
        return self._hist[slot]

    def last_tokens(self) -> np.ndarray:
        """(B,) pending last token per slot (garbage for free slots)."""
        return self._last_host

    def cache_lengths(self) -> np.ndarray:
        """(B,) committed cache lengths per slot (mirrors ctrl['lengths'])."""
        return self._len_host

    # ------------------------------------------------------------------
    # Admission: longest-cached-prefix lookup -> restore -> suffix-only
    # batched prefill, one program call per suffix bucket
    # ------------------------------------------------------------------
    def _admit(self) -> None:
        """Prefill queued requests into free slots, one batched prefill call
        per suffix bucket (legacy mode admits one request per call, matching
        the seed engine's behavior for before/after comparison).

        Requests that retire *at* admission (max_new_tokens <= 1, or no
        decode room) never occupy a slot, so the loop keeps refilling from
        the queue until the slots are saturated or the queue drains — a
        retired-at-admission request must not cost a slot a full engine
        step of idleness.
        """
        if self.paged:
            self._admit_paged()
            return
        while True:
            free = self._free_slots()
            take = min(len(free), len(self.queue))
            if not take:
                return
            entries = []
            for _ in range(take):
                req = self.queue.popleft()
                entries.append((req,) + self._lookup_prefix(req))
            groups: dict[int, list[tuple]] = {}
            for e in entries:
                req, _, start = e
                suffix = np.asarray(req.prompt).shape[-1] - start
                groups.setdefault(
                    _bucket(suffix, self.prompt_buckets), []).append(e)
            for sc, es in groups.items():
                if self.fused:
                    self._admit_group(sc, es, free)
                else:
                    for e in es:
                        self._admit_group(sc, [e], free)

    def _lookup_prefix(self, req: Request):
        """-> (match, start): the longest usable cached prefix and the pin
        protecting it through admission (start == 0: miss / disabled)."""
        if self.prefix_cache is None:
            return None, 0
        prompt = np.asarray(req.prompt, np.int32)
        # always prefill at least the last prompt token: its logits seed the
        # first sampled token
        match = self.prefix_cache.match(prompt, limit=prompt.shape[-1] - 1)
        if match.usable <= 0:
            self.stats["prefix_misses"] += 1
            return None, 0
        self.prefix_cache.acquire(match.path[-1][0])  # pin through admission
        self.stats["prefix_hits"] += 1
        self.stats["prefix_hit_tokens"] += match.usable
        return match, match.usable

    def _admit_group(self, sc: int, entries: list[tuple], free: list[int]) -> None:
        n = len(entries)
        npad = _pow2(n)  # bound compiled-program count per bucket
        if self.cfg.frontend == "audio":
            batch = np.zeros((npad, self.cfg.num_codebooks, sc), np.int32)
        else:
            batch = np.zeros((npad, sc), np.int32)
        starts = np.zeros((npad,), np.int32)
        lens = np.ones((npad,), np.int32)  # pad rows: 1 valid pos at start 0
        bstates = self._init_batch(npad)
        for i, (req, match, start) in enumerate(entries):
            prompt = np.asarray(req.prompt, np.int32)
            # right-pad: real suffix at the front, absolute positions
            # [start, L) — see the class docstring for why
            batch[i, ..., : prompt.shape[-1] - start] = prompt[..., start:]
            starts[i] = start
            lens[i] = prompt.shape[-1]
            if start > 0:
                # restore re-walks the radix tree itself: `match` may be
                # stale if an earlier group's insert split a node on its path
                bstates = self.prefix_cache.restore(prompt, bstates, i, start)
        logits, bstates, _ = self._prefill_chunk(
            self.params, jnp.asarray(batch), bstates,
            jnp.asarray(starts), jnp.asarray(lens))
        self.stats["prefill_calls"] += 1
        self.stats["prefills"] += n
        self.stats["prefill_tokens"] += npad * sc

        pad_cfg = [e[0].sampling for e in entries] \
            + [SamplingConfig()] * (npad - n)
        self.rng, sub = jax.random.split(self.rng)
        first = self._sample_first(sub, logits, SamplingParams.from_configs(pad_cfg))
        first_host = np.asarray(jax.device_get(first))
        self.stats["host_syncs_admit"] += 1
        now = time.perf_counter()

        for i, (req, match, start) in enumerate(entries):
            ttft = now - self._submit_s.pop(req.request_id, now)
            self.stats["ttft_sum_s"] += ttft
            plen = int(np.asarray(req.prompt).shape[-1])
            pin = None
            if self.prefix_cache is not None:
                # donate the full-prompt state back to the radix tree and
                # swap the admission pin for one on the (deeper) donated node
                pin = self.prefix_cache.acquire(
                    self.prefix_cache.insert(req.prompt, bstates, i, match))
                if match is not None:
                    self.prefix_cache.release(match.path[-1][0])
            # prefill token + decode steps until the cache fills at max_len
            room = self.max_len - plen + 1
            if room < req.max_new_tokens:
                logger.warning(
                    "request %s: prompt length %d leaves room for %d of the "
                    "%d requested tokens (engine max_len=%d) — output will "
                    "be truncated", req.request_id, plen, room,
                    req.max_new_tokens, self.max_len)
            if req.max_new_tokens <= 1 or room <= 1:
                # the prefill logits already yielded the only (or only
                # representable) token; retire without occupying a decode slot
                self.results[req.request_id] = RequestResult(
                    request_id=req.request_id,
                    tokens=[self._row_out(first_host[i])],
                    decode_steps=0, ttft_s=ttft)
                self.stats["retired"] += 1
                if pin is not None:
                    self.prefix_cache.release(pin)
                continue
            slot = free.pop(0)
            self.states, self.ctrl = self._assign(
                self.states, bstates, self.ctrl, i, slot, plen, first[i],
                float(req.sampling.temperature), int(req.sampling.top_k),
                int(req.max_new_tokens),
                -1 if req.eos_id is None else int(req.eos_id))
            self.active[slot] = req
            self.generated[slot] = [self._row_out(first_host[i])]
            self._slot_pins[slot] = pin
            self._slot_ttft[slot] = ttft
            self._admit_s[slot] = now
            if self.spec is not None:
                prompt = np.asarray(req.prompt, np.int32).reshape(-1)
                self._hist[slot] = np.concatenate(
                    [prompt, [np.int32(first_host[i])]])
                self._len_host[slot] = plen
                self._last_host[slot] = int(first_host[i])
                self.proposer.admit(slot, prompt)

    def _row_out(self, row: np.ndarray):
        return tuple(int(x) for x in row) if row.ndim else int(row)

    # ------------------------------------------------------------------
    # Paged admission + chunked prefill + page growth/CoW/preemption
    # ------------------------------------------------------------------
    def _admit_paged(self) -> None:
        """Paged admission: reference the longest cached prefix's pages
        into a fresh block table (aliasing, not copying), allocate fresh
        pages for the rest of the prompt, and hand the slot to the chunked
        prefiller. Admission is OUT OF ORDER under the page watermark: a
        large request that cannot allocate yet is skipped (not blocking),
        so smaller requests behind it keep the replica busy — the
        head-of-line starvation fix (see stats['admit_skips'])."""
        free = self._free_slots()
        if not free or not self.queue:
            return
        bm = self.block_manager
        ps = self.page_size
        kept: deque[Request] = deque()
        for _ in range(len(self.queue)):
            if not free:
                break
            req = self.queue.popleft()
            prompt = np.asarray(req.prompt, np.int32).reshape(-1)
            plen = int(prompt.shape[-1])
            match = (self.prefix_cache.match(prompt, limit=plen - 1)
                     if self.prefix_cache is not None else None)
            start = match.usable if match is not None else 0
            # budget: fresh pages past the shared FULL pages — a shared
            # PARTIAL tail page is copied (not aliased) right here, so the
            # check below reserves its replacement too
            need = pages_for(plen, ps) - start // ps
            if not bm.can_alloc(need, respect_watermark=True):
                # pages held ONLY by the prefix cache are best-effort memory:
                # evict them on demand rather than stall admission. An IDLE
                # engine additionally ignores the watermark — it only
                # arbitrates between concurrent tenants, and nothing running
                # means nothing will ever free pages for us.
                idle = not kept and all(r is None for r in self.active)
                if self.prefix_cache is not None:
                    self.prefix_cache.reclaim(need + bm.watermark_pages)
                    # eviction may have dropped the matched branch: re-match
                    # before touching its page refs
                    match = self.prefix_cache.match(prompt, limit=plen - 1)
                    start = match.usable
                    need = pages_for(plen, ps) - start // ps
                if not bm.can_alloc(need, respect_watermark=not idle):
                    self.stats["admit_skips"] += 1
                    kept.append(req)
                    continue
            slot = free.pop(0)
            shared = list(match.pages) if start > 0 else []
            bm.incref(shared)
            if start % ps:
                # copy-on-write the shared partial tail page NOW: the
                # remaining prompt prefills into an owned page, and no
                # mid-prefill CoW can run out of pool later
                tail = shared[-1]
                new = bm.cow(tail)  # consumes OUR ref on `tail`
                self.states = self._copy_page(
                    self.states, jnp.int32(tail), jnp.int32(new))
                shared[-1] = new
            fresh = bm.alloc(pages_for(plen, ps) - len(shared))
            self._pages[slot] = shared + fresh
            self.active[slot] = req
            self.generated[slot] = []
            self._seq += 1
            self._admit_seq[slot] = self._seq
            self._slot_submit[slot] = self._submit_s.get(
                req.request_id, time.perf_counter())
            self._admitting[slot] = {"prompt": prompt, "plen": plen,
                                     "pos": start}
            if self.prefix_cache is not None:
                if start > 0:
                    self.stats["prefix_hits"] += 1
                    self.stats["prefix_hit_tokens"] += start
                else:
                    self.stats["prefix_misses"] += 1
        self.queue.extendleft(reversed(kept))

    def _prefill_step_paged(self) -> None:
        """Advance every mid-prefill slot by one batched chunk: ONE
        compiled program per engine step regardless of how many rows are
        admitting, interleaved with the decode step that follows — chunked
        prefill never stalls in-flight decodes for a whole prompt. Rows
        whose prompt completes sample their first token from the chunk's
        logits (the chunk program returns logits at each row's last real
        position) and arm the device control block."""
        rows = sorted(self._admitting)
        if not rows:
            return
        ps = self.page_size
        remaining = max(self._admitting[s]["plen"] - self._admitting[s]["pos"]
                        for s in rows)
        cw = _bucket(min(remaining, self._chunk_cap), self._chunk_widths)
        n = len(rows)
        npad = _pow2(n)
        toks = np.zeros((npad, cw), np.int32)
        starts = np.zeros((npad,), np.int32)
        lens = np.ones((npad,), np.int32)  # pad rows: 1 pos on the null page
        bt = np.zeros((npad, self.max_blocks), np.int32)
        for i, s in enumerate(rows):
            st = self._admitting[s]
            w = min(cw, st["plen"] - st["pos"])
            # CoW the shared partial tail page of a restored prefix before
            # this chunk writes into it (admitting slots are never preempted,
            # so the slot survives)
            self._prepare_write(s, st["pos"], st["pos"] + w)
            toks[i, :w] = st["prompt"][st["pos"]: st["pos"] + w]
            starts[i] = st["pos"]
            lens[i] = st["pos"] + w
            bt[i, : len(self._pages[s])] = self._pages[s]
        logits, self.states, _ = self._prefill_chunk_paged(
            self.params, jnp.asarray(toks), self.states,
            jnp.asarray(starts), jnp.asarray(lens), jnp.asarray(bt))
        self.stats["prefill_calls"] += 1
        self.stats["chunk_prefill_calls"] += 1
        self.stats["prefill_tokens"] += npad * cw

        fin = [i for i, s in enumerate(rows)
               if int(lens[i]) >= self._admitting[s]["plen"]]
        if not fin:
            for i, s in enumerate(rows):
                self._admitting[s]["pos"] = int(lens[i])
            return
        pad_cfg = [self.active[s].sampling for s in rows] \
            + [SamplingConfig()] * (npad - n)
        self.rng, sub = jax.random.split(self.rng)
        first = self._sample_first(sub, logits,
                                   SamplingParams.from_configs(pad_cfg))
        first_host = np.asarray(jax.device_get(first))
        self.stats["host_syncs_admit"] += 1
        now = time.perf_counter()
        for i, s in enumerate(rows):
            st = self._admitting[s]
            if int(lens[i]) < st["plen"]:
                st["pos"] = int(lens[i])
                continue
            # ---- prompt complete: donate pages to the prefix cache, arm ----
            del self._admitting[s]
            req = self.active[s]
            plen = st["plen"]
            tok = int(first_host[i])
            self.stats["prefills"] += 1
            ttft = now - self._submit_s.pop(req.request_id, now)
            self.stats["ttft_sum_s"] += ttft
            if self.prefix_cache is not None:
                self.prefix_cache.insert(
                    st["prompt"], self._pages[s][: pages_for(plen, ps)])
            room = self.max_len - plen + 1
            if room < req.max_new_tokens:
                logger.warning(
                    "request %s: prompt length %d leaves room for %d of the "
                    "%d requested tokens (engine max_len=%d) — output will "
                    "be truncated", req.request_id, plen, room,
                    req.max_new_tokens, self.max_len)
            if req.max_new_tokens <= 1 or room <= 1:
                # prefill logits already yielded the only token; retire
                # without ever occupying a decode step
                self.results[req.request_id] = RequestResult(
                    request_id=req.request_id, tokens=[tok],
                    decode_steps=0, ttft_s=ttft)
                self.stats["retired"] += 1
                self.block_manager.decref(self._pages[s])
                self._pages[s] = []
                self.active[s] = None
                continue
            if self.role == "prefill":
                # phase boundary: this engine's job ends at the first
                # token. The request leaves as a handoff packet (pages
                # ticket-referenced, slot freed) instead of arming a
                # decode slot it does not have.
                self.handoff_out.append(
                    self._export_handoff(s, st, tok, ttft))
                continue
            self.ctrl = self._arm(
                self.ctrl, jnp.int32(s), jnp.int32(plen), jnp.int32(tok),
                jnp.float32(req.sampling.temperature),
                jnp.int32(req.sampling.top_k),
                jnp.int32(req.max_new_tokens),
                jnp.int32(-1 if req.eos_id is None else req.eos_id))
            self.generated[s] = [tok]
            self._slot_ttft[s] = ttft
            self._admit_s[s] = now
            self._len_host[s] = plen
            self._last_host[s] = tok
            self._bt_host[s, :] = 0
            self._bt_host[s, : len(self._pages[s])] = self._pages[s]
            self._bt_dirty = True
            if self.spec is not None:
                self._hist[s] = np.concatenate([st["prompt"], [np.int32(tok)]])
                self.proposer.admit(s, st["prompt"])

    # ------------------------------------------------------------------
    # KV page handoff (disaggregated fleets): prefill engines stage
    # finished prompts out; decode engines admit by installing the pages
    # ------------------------------------------------------------------
    def _payload_zeros(self):
        """A zero handoff payload pytree ((max_blocks, ...) per state leaf)
        — the scatter program's warmup argument."""
        def z(ax, leaf):
            shape = list(leaf.shape)
            shape.pop(ax)
            return jnp.zeros((self.max_blocks, *shape), leaf.dtype)
        return jax.tree.map(z, self._paged_progs.page_axes, self.states)

    def _export_handoff(self, slot: int, st: dict, tok: int,
                        ttft: float) -> HandoffPacket:
        """Stage slot ``slot``'s finished prefill for transfer: take a
        ticket reference per page (the pages survive the slot being freed),
        gather them device->host, hash each page, and free the slot. The
        returned packet owns the request from here — the handoff plane
        decrefs the ticket references after the destination installs (or
        the packet is dropped)."""
        req = self.active[slot]
        plen = st["plen"]
        pages = self._pages[slot][: pages_for(plen, self.page_size)]
        self.block_manager.export_pages(pages)  # the ticket's own refs
        idx = np.zeros((self.max_blocks,), np.int32)
        idx[: len(pages)] = pages
        gathered = self._gather_pages(self.states, jnp.asarray(idx))
        payload = [np.asarray(jax.device_get(l))
                   for l in jax.tree.leaves(gathered)]
        self.stats["host_syncs_admit"] += 1
        packet = HandoffPacket(
            request=req, prompt=st["prompt"], length=plen,
            first_token=tok, ttft_s=ttft, pages=list(pages),
            payload=payload, shas=_page_shas(payload, len(pages)),
            nbytes=len(pages) * self.page_bytes)
        # the slot's own references drop now; only the ticket's remain
        self.block_manager.decref(self._pages[slot])
        self._pages[slot] = []
        self.active[slot] = None
        self.generated[slot] = []
        self.stats["handoffs_out"] += 1
        return packet

    def release_handoff(self, packet: HandoffPacket) -> None:
        """Drop the ticket references a packet holds on THIS engine's pages
        — called by the handoff plane once the destination confirmed the
        install, or when the packet is abandoned. This is the cross-replica
        half of the refcount invariant: every export_pages incref is undone
        by exactly one release."""
        self.block_manager.decref(packet.pages)

    def can_install(self, packet: HandoffPacket) -> bool:
        """Whether a handoff could install right now: a free slot that is
        not mid-prefill, plus pool room for the packet's pages under the
        same watermark discipline as fresh admission (prefix-cache pages
        are reclaimable; an idle engine ignores the watermark)."""
        if self.role == "prefill" or not self.paged:
            return False
        if not self._free_slots():
            return False
        need = len(packet.pages)
        bm = self.block_manager
        if bm.can_alloc(need, respect_watermark=True):
            return True
        if self.prefix_cache is not None:
            self.prefix_cache.reclaim(need + bm.watermark_pages)
        idle = not self.queue and all(r is None for r in self.active)
        return bm.can_alloc(need, respect_watermark=not idle)

    def install_handoff(self, packet: HandoffPacket) -> bool:
        """Admit a request by INSTALLING its already-computed KV pages: the
        decode-pool admission path. Verifies the per-page shas against the
        staged payload, allocates fresh physical pages
        (``BlockManager.install_pages``), scatters the payload into them,
        and arms the slot exactly as a local prefill completion would —
        same first token, same absolute positions, so the greedy stream is
        byte-identical to the monolithic engine's. Returns False (with no
        state touched beyond best-effort cache reclaim) when verification
        fails or there is no room; the caller re-queues or falls back."""
        if _page_shas(packet.payload, len(packet.pages)) != packet.shas:
            self.stats["handoff_sha_rejects"] += 1
            return False
        if not self.can_install(packet):
            return False
        req = packet.request
        plen = packet.length
        tok = int(packet.first_token)
        npg = len(packet.pages)
        slot = self._free_slots()[0]
        ids = self.block_manager.install_pages(npg)
        idx = np.zeros((self.max_blocks,), np.int32)
        idx[:npg] = ids
        payload = jax.tree.unflatten(
            jax.tree.structure(self._paged_progs.page_axes),
            [jnp.asarray(a) for a in packet.payload])
        self.states = self._scatter_pages(self.states, payload,
                                          jnp.asarray(idx))
        self._pages[slot] = list(ids)
        self.active[slot] = req
        self._seen_ids.add(req.request_id)
        self._seq += 1
        self._admit_seq[slot] = self._seq
        now = time.perf_counter()
        self._slot_submit[slot] = now
        self.ctrl = self._arm(
            self.ctrl, jnp.int32(slot), jnp.int32(plen), jnp.int32(tok),
            jnp.float32(req.sampling.temperature),
            jnp.int32(req.sampling.top_k),
            jnp.int32(req.max_new_tokens),
            jnp.int32(-1 if req.eos_id is None else req.eos_id))
        self.generated[slot] = [tok]
        self._slot_ttft[slot] = packet.ttft_s
        self._admit_s[slot] = now
        self._len_host[slot] = plen
        self._last_host[slot] = tok
        self._bt_host[slot, :] = 0
        self._bt_host[slot, :npg] = ids
        self._bt_dirty = True
        if self.prefix_cache is not None:
            # the handed-off prompt seeds THIS replica's radix tree, so
            # session followers and shared-prefix siblings routed here by
            # affinity hit locally instead of re-prefilling
            self.prefix_cache.insert(packet.prompt, ids)
        if self.spec is not None:
            self._hist[slot] = np.concatenate(
                [packet.prompt, [np.int32(tok)]])
            self.proposer.admit(slot, packet.prompt)
        self.stats["handoffs_in"] += 1
        return True

    # ------------------------------------------------------------------
    def _bt_device(self) -> jax.Array:
        if self._bt_dirty or self._bt_dev is None:
            self._bt_dev = jnp.asarray(self._bt_host)
            self._bt_dirty = False
        return self._bt_dev

    def _youngest_decoding(self) -> int | None:
        cands = [s for s, r in enumerate(self.active)
                 if r is not None and s not in self._admitting]
        return max(cands, key=lambda s: self._admit_seq[s]) if cands else None

    def _reclaim_or_preempt(self, n: int) -> int | None:
        """Make ``n`` pages allocatable: evict prefix-cache pages first
        (cold reuse state is the cheapest thing to give back), then preempt
        the YOUNGEST decoding slot (its recompute loses the least work).
        Returns the preempted slot, or None when cache eviction sufficed;
        raises when nothing is left to take."""
        if self.prefix_cache is not None and self.prefix_cache.reclaim(n):
            return None
        victim = self._youngest_decoding()
        if victim is None:
            raise RuntimeError(
                "KV page pool exhausted with nothing left to preempt")
        self._preempt(victim)
        return victim

    def _prepare_write(self, slot: int, lo: int, hi: int) -> bool:
        """Make cache positions [lo, hi) of ``slot`` writable: grow its
        block table to cover ``hi`` entries and copy-on-write any shared
        page in the write range. May preempt other slots under pool
        pressure — or, at the last resort, ``slot`` itself, in which case
        this returns False and the caller skips the slot's step."""
        bm, ps = self.block_manager, self.page_size
        pages = self._pages[slot]
        while True:
            need = pages_for(hi, ps) - len(pages)
            if need <= 0:
                break
            if bm.can_alloc(need):
                fresh = bm.alloc(need)
                base = len(pages)
                pages.extend(fresh)
                if slot not in self._admitting:
                    self._bt_host[slot, base: base + need] = fresh
                    self._bt_dirty = True
                break
            if self._reclaim_or_preempt(need) == slot:
                return False
        if hi > lo:
            for j in range(lo // ps, (hi - 1) // ps + 1):
                # re-check the ref each round: a cache eviction can DE-SHARE
                # this very page (making the copy unnecessary) without
                # freeing anything
                while bm.ref[pages[j]] > 1:
                    if bm.can_alloc(1):
                        pid = pages[j]
                        new = bm.cow(pid)
                        self.states = self._copy_page(
                            self.states, jnp.int32(pid), jnp.int32(new))
                        pages[j] = new
                        if slot not in self._admitting:
                            self._bt_host[slot, j] = new
                            self._bt_dirty = True
                        break
                    if (self.prefix_cache is not None
                            and self.prefix_cache.reclaim(1)):
                        continue
                    if bm.ref[pages[j]] <= 1:
                        break
                    victim = self._youngest_decoding()
                    if victim is None:
                        raise RuntimeError(
                            "KV page pool exhausted with nothing left to "
                            "preempt")
                    self._preempt(victim)
                    if victim == slot:
                        return False
        return True

    def _preempt(self, slot: int) -> None:
        """Preemption by recompute (the vLLM policy): release the victim's
        pages and push its request back to the FRONT of the queue; it
        re-admits (reusing whatever prefix is still cached) once pages free
        up. Generated tokens are discarded — recomputation replays the same
        stream for greedy sampling. Buffered step results are flushed first
        so a later sync cannot credit old tokens to the slot's next
        tenant."""
        self._flush()
        req = self.active[slot]
        if req is None:
            return  # the flush retired it — its pages are already free
        self.block_manager.decref(self._pages[slot])
        self._pages[slot] = []
        self.active[slot] = None
        self.generated[slot] = []
        self._admitting.pop(slot, None)
        self._bt_host[slot, :] = 0
        self._bt_dirty = True
        self.ctrl = self._release_ctrl(self.ctrl, jnp.int32(slot))
        if self.spec is not None:
            self._hist[slot] = None
            self.proposer.retire(slot)
        # restore the original submit time so TTFT honestly includes the wait
        self._submit_s[req.request_id] = self._slot_submit[slot]
        self.queue.appendleft(req)
        self.stats["preemptions"] += 1

    def _step_fused_paged(self) -> None:
        """The paged decode step: grow/CoW every armed slot's write
        position, then run ONE fused program over all B rows through the
        device block tables. Mid-prefill rows ride along on the null page
        (ctrl-inactive, zero block-table rows)."""
        for s in range(self.slots):
            if self.active[s] is None or s in self._admitting:
                continue
            length = int(self._len_host[s])
            if length >= self.max_len:
                continue
            self._prepare_write(s, length, length + 1)
        armed = [s for s, r in enumerate(self.active)
                 if r is not None and s not in self._admitting]
        if not armed:
            return
        self.rng, self.states, self.ctrl, packed = self._fused_step_paged(
            self.params, self.rng, self.states, self.ctrl, self._bt_device())
        self.stats["decode_steps"] += 1
        for s in armed:
            # pessimistic host mirror: rows that hit done mid-window stop
            # advancing on device; the flush reconciles (the extra page a
            # stale +1 can allocate is freed at retire)
            self._len_host[s] = min(int(self._len_host[s]) + 1, self.max_len)
        self._pending.append(packed)
        if len(self._pending) >= self.sync_every or all(
            len(self.generated[i]) + len(self._pending) >= r.max_new_tokens
            for i, r in enumerate(self.active)
            if r is not None and i not in self._admitting
        ):
            self._flush()

    def paged_summary(self) -> dict | None:
        """Page-pool occupancy, fragmentation, CoW sharing, and per-request
        block-count telemetry (None for the slot engine) — the paged
        analogue of :meth:`spec_summary`, surfaced by fleet reports."""
        if not self.paged:
            return None
        bm = self.block_manager
        tokens = 0
        blocks = []
        for s, r in enumerate(self.active):
            if r is None:
                continue
            st = self._admitting.get(s)
            tokens += st["pos"] if st is not None else int(self._len_host[s])
            blocks.append(len(self._pages[s]))
        out = {
            "page_size": self.page_size,
            **bm.utilization(tokens),
            **bm.stats,
            "preemptions": self.stats["preemptions"],
            "admit_skips": self.stats["admit_skips"],
            "active_requests": len(blocks),
            "blocks_per_request_max": max(blocks, default=0),
            "blocks_per_request_mean": (
                round(sum(blocks) / len(blocks), 3) if blocks else 0.0),
        }
        if self.prefix_cache is not None:
            out["prefix"] = self.prefix_cache.report()
        return out

    def _tok_out(self, tok: jax.Array):
        t = jax.device_get(tok)
        self.stats["host_syncs_decode"] += 1
        return tuple(int(x) for x in t) if t.ndim else int(t)

    def _retire(self, slot: int, *, reset_device: bool = False) -> None:
        req = self.active[slot]
        assert req is not None
        decode_s = time.perf_counter() - self._admit_s[slot]
        self.stats["decode_sum_s"] += decode_s
        self.results[req.request_id] = RequestResult(
            request_id=req.request_id,
            tokens=self.generated[slot],
            decode_steps=len(self.generated[slot]),
            ttft_s=self._slot_ttft[slot],
            decode_s=decode_s,
        )
        self.active[slot] = None
        self.generated[slot] = []
        if self.spec is not None:
            self._hist[slot] = None
            self.proposer.retire(slot)
        if reset_device:  # fused path already zeroed these on device
            self.ctrl = dict(
                self.ctrl,
                lengths=self.ctrl["lengths"].at[slot].set(0),
                active=self.ctrl["active"].at[slot].set(False),
            )
        if self._slot_pins[slot] is not None:
            self.prefix_cache.release(self._slot_pins[slot])
            self._slot_pins[slot] = None
        if self.paged:
            self.block_manager.decref(self._pages[slot])
            self._pages[slot] = []
            self._bt_host[slot, :] = 0
            self._bt_dirty = True
        self.stats["retired"] += 1

    # ------------------------------------------------------------------
    # Stepping
    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine iteration: admit, run one fused decode program for all
        B slots, sync the packed result (every ``sync_every`` steps), retire
        finished. Returns number of host-visible active slots."""
        with self._bound():
            return self._step_bound()

    def _step_bound(self) -> int:
        self._admit()
        if not any(r is not None for r in self.active):
            self._flush()
            return 0
        if self.paged:
            # one chunk of every mid-prefill prompt, INTERLEAVED with the
            # decode step below — chunked prefill never stalls decodes
            self._prefill_step_paged()
            if self.role == "prefill":
                # prefill-only engines never decode: finished prompts left
                # as handoff packets above, mid-prefill rows continue next
                # step
                return sum(r is not None for r in self.active)
        if self.spec is not None:
            self._step_spec()
        elif self.paged:
            self._step_fused_paged()
        elif self.fused:
            self.rng, self.states, self.ctrl, packed = self._fused_step(
                self.params, self.rng, self.states, self.ctrl)
            self.stats["decode_steps"] += 1
            self._pending.append(packed)
            # flush at the window boundary — or early, when every in-flight
            # request has provably hit its token budget (each active slot
            # emits one token per buffered step unless it finished even
            # sooner), so the engine never burns whole-batch decode steps on
            # a drained batch just to reach the window edge
            if len(self._pending) >= self.sync_every or all(
                len(self.generated[i]) + len(self._pending) >= r.max_new_tokens
                for i, r in enumerate(self.active) if r is not None
            ):
                self._flush()
        else:
            self._step_host()
        return sum(r is not None for r in self.active)

    def _step_spec(self) -> None:
        """One speculative engine iteration: the proposer drafts up to K
        tokens per active slot on the control plane, ONE fused program
        verifies all K+1 positions per slot (lossless rejection sampling
        inside the jit), and the host syncs a single packed matrix carrying
        up to K+1 emitted tokens per slot. Every step syncs — the proposer
        needs the emitted history — so the speedup is tokens-per-step, not
        syncs-per-step."""
        k = self.spec.k
        c = k + 1
        drafts = np.zeros((self.slots, k), np.int32)
        ndraft = np.zeros((self.slots,), np.int32)
        self.proposer.propose(self, drafts, ndraft)
        for i, r in enumerate(self.active):
            if r is None or i in self._admitting:
                # mid-prefill paged rows are ctrl-inactive: nothing to draft
                ndraft[i] = 0
                continue
            # never draft past the cache: position L+1+ndraft must stay
            # writable or the verify chunk's in-flight attention would read
            # dropped entries; never draft past the token budget either —
            # the step emits at most `remaining` tokens, so later drafts
            # could only be verified and thrown away
            room = self.max_len - int(self._len_host[i]) - 1
            remaining = r.max_new_tokens - len(self.generated[i])
            ndraft[i] = max(0, min(int(ndraft[i]), room, remaining - 1))
        if self.paged:
            for i, r in enumerate(self.active):
                if r is None or i in self._admitting:
                    continue
                length = int(self._len_host[i])
                hi = min(length + 1 + int(ndraft[i]), self.max_len)
                if not self._prepare_write(i, length, hi):
                    ndraft[i] = 0  # slot self-preempted under pool pressure
            self.rng, self.states, self.ctrl, packed = self._spec_step(
                self.params, self.rng, self.states, self.ctrl,
                jnp.asarray(drafts), jnp.asarray(ndraft), self._bt_device())
        else:
            self.rng, self.states, self.ctrl, packed = self._spec_step(
                self.params, self.rng, self.states, self.ctrl,
                jnp.asarray(drafts), jnp.asarray(ndraft))
        self.stats["decode_steps"] += 1
        self.stats["spec_steps"] += 1
        arr = np.asarray(jax.device_get(packed))
        self.stats["host_syncs_decode"] += 1
        for i in range(self.slots):
            if not arr[i, c + 1]:  # slot inactive at this step
                continue
            req = self.active[i]
            if req is None:
                continue
            m = int(arr[i, c])
            toks = [int(t) for t in arr[i, :m]]
            self.generated[i].extend(toks)
            self._hist[i] = np.concatenate(
                [self._hist[i], np.asarray(toks, np.int32)])
            self._len_host[i] += m
            self._last_host[i] = toks[-1]
            self.stats["spec_slot_steps"] += 1
            self.stats["spec_positions"] += c
            self.stats["spec_drafted"] += int(ndraft[i])
            self.stats["spec_accepted"] += max(m - 1, 0)
            self.stats["spec_emitted"] += m
            if arr[i, c + 2]:
                self._retire(i)

    def spec_summary(self) -> dict | None:
        """Acceptance-rate telemetry for operators / fleet reports."""
        if self.spec is None:
            return None
        d, a = self.stats["spec_drafted"], self.stats["spec_accepted"]
        return {
            "proposer": self.proposer.kind,
            "k": self.spec.k,
            "steps": self.stats["spec_steps"],
            "drafted": d,
            "accepted": a,
            "acceptance_rate": round(a / max(d, 1), 4),
            "tokens_per_slot_step": round(
                self.stats["spec_emitted"]
                / max(self.stats["spec_slot_steps"], 1), 4),
        }

    def latency_summary(self) -> dict:
        """p50/p95 TTFT and per-output-token decode latency (TPOT) over the
        completed requests, in real wall-clock seconds."""
        ttfts = [r.ttft_s for r in self.results.values()]
        tpots = [r.tpot_s for r in self.results.values() if len(r.tokens) > 1]
        pct = lambda xs, q: float(np.percentile(xs, q)) if xs else 0.0
        return {
            "requests": len(self.results),
            "ttft_p50_s": pct(ttfts, 50),
            "ttft_p95_s": pct(ttfts, 95),
            "tpot_p50_s": pct(tpots, 50),
            "tpot_p95_s": pct(tpots, 95),
        }

    def _flush(self) -> None:
        """Fetch all buffered packed step results in ONE blocking transfer
        and replay them through the host-side slot table."""
        if not self._pending:
            return
        rows = jax.device_get(self._pending)
        self._pending = []
        self.stats["host_syncs_decode"] += 1
        audio = self.cfg.frontend == "audio"
        for arr in rows:  # (B, T+2): tokens..., active, done
            arr = np.asarray(arr)
            for i in range(self.slots):
                if not arr[i, -2]:  # slot inactive at that step
                    continue
                req = self.active[i]
                if req is None:
                    continue
                tok = arr[i, :-2]
                self.generated[i].append(
                    tuple(int(x) for x in tok) if audio else int(tok[0]))
                if arr[i, -1]:
                    self._retire(i)

    def _step_host(self) -> None:
        """Legacy per-slot host loop (the seed data plane): B scalar sample
        programs + one device_get per token + one length sync per slot."""
        self.ctrl["lengths"] = self.ctrl["lengths"] + jnp.asarray(
            [1 if r is not None else 0 for r in self.active], jnp.int32)
        logits, self.states = self._decode(
            self.params, self.ctrl["last"], self.states, self.ctrl["lengths"])
        self.stats["decode_steps"] += 1
        new_tokens = []
        for i in range(self.slots):
            req = self.active[i]
            if req is None:
                new_tokens.append(self._zero_tokens(1)[0])
                continue
            self.rng, k = jax.random.split(self.rng)
            tok = sample(k, logits[i], req.sampling)
            new_tokens.append(tok)
            self.generated[i].append(self._tok_out(tok))
            done = len(self.generated[i]) >= req.max_new_tokens
            if req.eos_id is not None and not done:
                t = self.generated[i][-1]
                done = (t == req.eos_id) if isinstance(t, int) else (t[0] == req.eos_id)
            length = int(self.ctrl["lengths"][i])
            self.stats["host_syncs_decode"] += 1
            if length >= self.max_len:
                done = True
            if done:
                self._retire(i, reset_device=True)
        self.ctrl["last"] = jnp.stack(new_tokens)

    def run_to_completion(self, max_steps: int = 10_000) -> dict[int, RequestResult]:
        """Drive the engine until every request completes or ``max_steps``
        engine iterations elapse. On truncation, ``stats['unserved']`` holds
        the count of requests left queued/in-flight (and a warning is
        logged) so callers can tell completion from truncation."""
        steps = 0
        while (self.queue or any(r is not None for r in self.active)) and steps < max_steps:
            self.step()
            steps += 1
        self._flush()
        unserved = len(self.queue) + sum(r is not None for r in self.active)
        self.stats["unserved"] = unserved
        if unserved:
            logger.warning(
                "run_to_completion hit max_steps=%d with %d request(s) unserved",
                max_steps, unserved)
        return self.results
