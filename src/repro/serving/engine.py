"""Continuous-batching serving engine over the functional model zoo.

The XaaS serving story: a SERVICE-class lease holds a fixed chip allocation;
inside it, this engine multiplexes many short FaaS-style requests onto one
compiled decode program (the paper's "fine-grained transactional computations"
running on a long-lived high-performance allocation).

Design (vLLM-shape, JAX-native):
  * fixed slot count B (the compiled decode batch) with per-slot state inside
    the *stacked* KV/recurrent caches; slots are recycled across requests
    (continuous batching).
  * a FUSED per-step program: decode, per-slot sampling (temperature/top-k
    carried as (B,) device arrays), length update, and EOS/max-token
    done-flag computation all happen inside one ``jax.jit`` — the host syncs
    a single packed "tokens | active | done" row batch per step (or one
    stacked fetch every ``sync_every`` steps). Nothing slow on the data
    path, per the paper's Invocation principle.
  * batched admission: all admissible queued requests sharing a *suffix*
    bucket prefill in ONE batched program call (batch padded to a power of
    two so the compiled-program count stays bounded at
    #buckets x log2(slots)+1). Prompts are right-padded (absolute positions
    [0, L)), so with the optional radix prefix cache
    (``prefix_cache_bytes``) admission restores the longest cached prefix
    with a jitted scatter and prefills ONLY the suffix tokens — the largest
    prefill-compute lever under shared system prompts / multi-turn traffic.
  * slot admission writes the prefilled per-slot state into the batched
    state tree with a jitted scatter (`_assign`), so admission is O(state of
    one slot), not O(whole cache).
  * all host-side logic (queueing, retirement bookkeeping) is control plane;
    every data-plane array op is jit'd. REST never touches the data path.

``fused=False`` keeps the legacy host-loop step (B scalar ``sample`` calls +
per-token ``device_get`` + per-slot length sync) as the "before" reference for
``benchmarks/serving_throughput.py``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import logging
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hooks
from repro.models import transformer
from repro.serving.prefix_cache import PrefixCache, StateOps
from repro.serving.sampling import (SamplingConfig, SamplingParams, sample,
                                    sample_batched)

__all__ = ["Request", "RequestResult", "ServingEngine"]

logger = logging.getLogger(__name__)

_NO_LIMIT = 1 << 30


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: Any  # (S,) int32 (or (K, S) audio)
    max_new_tokens: int
    sampling: SamplingConfig = dataclasses.field(default_factory=SamplingConfig)
    eos_id: int | None = None


@dataclasses.dataclass
class RequestResult:
    request_id: int
    tokens: list[int] | list[tuple]  # generated tokens (tuples for audio)
    prefill_steps: int = 1
    decode_steps: int = 0


def _bucket(n: int, buckets: tuple[int, ...]) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


def _pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


class _Programs:
    """The compiled data-plane program bundle for one (arch config, slot
    geometry, kernel-tier set).

    Every program the engine executes is pure in (params, state, ctrl), so
    nothing engine-instance-specific is baked into a trace — which means the
    bundle can be SHARED across engine instances. That is what makes a fleet
    replica boot *warm*: the first engine for a geometry pays trace+compile,
    every later replica (and every re-boot after a scale-to-zero release)
    reuses the same jitted programs, the serving analogue of the
    warm-deployment cache in ``InvocationService``.

    The cache key includes the hook binding's chosen providers: programs
    traced under one kernel tier must never serve an engine bound to another.
    """

    def __init__(self, cfg, slots: int, max_len: int):
        dt = jnp.dtype(cfg.activ_dtype)
        # per-leaf slot/batch axis, found structurally: the axis whose extent
        # tracks the state batch size (probe batch=1 vs batch=2 shapes)
        p1 = jax.eval_shape(lambda: transformer.init_states(cfg, 1, max_len, dt))
        p2 = jax.eval_shape(lambda: transformer.init_states(cfg, 2, max_len, dt))

        def _axis(a, b):
            for i, (x, y) in enumerate(zip(a.shape, b.shape)):
                if x != y:
                    return i
            raise AssertionError(f"state leaf has no batch axis: {a.shape}")

        state_axes = jax.tree.map(_axis, p1, p2)

        @jax.jit
        def fused_step(params, key, states, ctrl):
            """decode + sample + length update + done flags, one program."""
            active = ctrl["active"]
            lengths = ctrl["lengths"] + active.astype(jnp.int32)
            key, sub = jax.random.split(key)
            sp = SamplingParams(ctrl["temp"], ctrl["topk"])
            toks, new_states, _ = transformer.decode_and_sample(
                params, cfg, ctrl["last"], states, lengths, sub,
                lambda k, lg: sample_batched(k, lg, sp))
            gen = ctrl["gen"] + active.astype(jnp.int32)
            first = toks if toks.ndim == 1 else toks[:, 0]
            done = active & (
                (gen >= ctrl["max_new"])
                | ((ctrl["eos"] >= 0) & (first == ctrl["eos"]))
                | (lengths >= max_len))
            amask = active if toks.ndim == 1 else active[:, None]
            toks = jnp.where(amask, toks, 0)
            packed = jnp.concatenate([
                toks.reshape(slots, -1),
                active.astype(jnp.int32)[:, None],
                done.astype(jnp.int32)[:, None],
            ], axis=1)
            new_ctrl = dict(
                ctrl,
                lengths=jnp.where(done, 0, lengths),
                active=active & ~done,
                gen=gen,
                last=toks,
            )
            return key, new_states, new_ctrl, packed

        self.fused_step = fused_step

        @jax.jit
        def prefill_chunk(params, tokens, states, start, lengths):
            # tokens: (N, Sc) right-padded suffix chunk ((N, K, Sc) audio);
            # states: batch state tree with any cached prefix already
            # restored at [0, start) per row; full prefill is start == 0
            return transformer.prefill_chunk(params, cfg, tokens, states,
                                             start, lengths)

        self.prefill_chunk = prefill_chunk

        dt_ = dt

        @functools.partial(jax.jit, static_argnums=(0,))
        def init_batch(n):
            return transformer.init_states(cfg, n, max_len, dt_)

        self.init_batch = init_batch

        # structure-aware extract/restore programs for the prefix cache
        # (shared across engine instances like every other program here)
        self.state_ops = StateOps(cfg, max_len, dt)

        self.sample_first = jax.jit(sample_batched)

        @jax.jit
        def assign(states, batch_states, ctrl, src, slot, length, first_tok,
                   temp, topk, max_new, eos):
            """Scatter prefilled request `src` of a batched prefill into
            engine slot `slot`, and arm its control-block entries."""
            def put(ax, dst, s):
                row = jax.lax.dynamic_index_in_dim(s, src, ax, keepdims=False)
                return jax.lax.dynamic_update_index_in_dim(
                    dst, row.astype(dst.dtype), slot, ax)
            new_states = jax.tree.map(put, state_axes, states, batch_states)
            new_ctrl = dict(
                ctrl,
                lengths=ctrl["lengths"].at[slot].set(length),
                active=ctrl["active"].at[slot].set(True),
                gen=ctrl["gen"].at[slot].set(1),
                temp=ctrl["temp"].at[slot].set(temp),
                topk=ctrl["topk"].at[slot].set(topk),
                max_new=ctrl["max_new"].at[slot].set(max_new),
                eos=ctrl["eos"].at[slot].set(eos),
                last=ctrl["last"].at[slot].set(first_tok),
            )
            return new_states, new_ctrl

        self.assign = assign

        @jax.jit
        def decode(params, tokens, states, lengths):
            return transformer.decode_step(params, cfg, tokens, states, lengths)

        self.decode = decode  # legacy (unfused) step


_PROGRAMS: dict[tuple, _Programs] = {}


def _programs_for(cfg, slots: int, max_len: int,
                  binding: hooks.Binding | None) -> _Programs:
    tiers = (None if binding is None
             else tuple(sorted(binding.providers().items())))
    key = (cfg, slots, max_len, tiers)
    prog = _PROGRAMS.get(key)
    if prog is None:
        prog = _PROGRAMS[key] = _Programs(cfg, slots, max_len)
    return prog


class ServingEngine:
    """Continuous-batching engine for one deployed model.

    fused: run the whole per-step loop as one compiled program (default);
        False keeps the legacy host-side loop for before/after benchmarks.
    sync_every: fetch the packed per-step result every k fused steps (k > 1
        trades per-token latency for k-fold fewer host<->device syncs; slots
        that finish mid-window idle until the next sync).
    prefix_cache_bytes: byte budget for the radix prefix cache (None/0
        disables reuse). With a cache, admission looks up the longest cached
        prefix of each prompt, scatters its per-layer state into the batch
        with a jitted restore, prefills only the suffix, and donates the
        full-prompt state back to the tree (ref-counted while the slot
        serves, LRU-evicted under the budget).

    Prompts are RIGHT-padded into their bucket (real tokens at positions
    [0, L), pads at the tail, dropped from the caches): absolute positions
    are what make a shared token prefix produce identical state regardless
    of total prompt length — and, as a bonus, pad tokens no longer pollute
    attention the way the old left-pad layout let them.
    """

    def __init__(
        self,
        cfg,
        params,
        *,
        slots: int = 8,
        max_len: int = 512,
        prompt_buckets: tuple[int, ...] = (32, 128, 512),
        rng: jax.Array | None = None,
        fused: bool = True,
        sync_every: int = 1,
        binding: hooks.Binding | None = None,
        manifest: dict | None = None,
        prefix_cache_bytes: int | None = None,
    ):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        # the deployment's hook binding: data-plane programs trace under it,
        # so the engine serves through the tiers the deployment probed+bound
        # (None = portable floor). `manifest` is the deployment's
        # specialization record, reported by warmup().
        self.binding = binding
        self.manifest = manifest
        # max_len is ALWAYS the final bucket: a prompt longer than the largest
        # configured bucket but <= max_len must land in a bucket that can hold
        # it (otherwise the pad count goes negative and jnp.pad crashes).
        self.prompt_buckets = tuple(
            sorted({b for b in prompt_buckets if b < max_len} | {max_len}))
        self.rng = rng if rng is not None else jax.random.key(0)
        self.fused = fused
        self.sync_every = max(int(sync_every), 1)

        dt = jnp.dtype(cfg.activ_dtype)
        self.states = transformer.init_states(cfg, slots, max_len, dt)
        # device-side control block: everything the fused step needs to run
        # without consulting the host. (B,) arrays + the last sampled tokens.
        self.ctrl = {
            "lengths": jnp.zeros((slots,), jnp.int32),
            "active": jnp.zeros((slots,), bool),
            "gen": jnp.zeros((slots,), jnp.int32),
            "temp": jnp.zeros((slots,), jnp.float32),
            "topk": jnp.zeros((slots,), jnp.int32),
            "max_new": jnp.full((slots,), _NO_LIMIT, jnp.int32),
            "eos": jnp.full((slots,), -1, jnp.int32),
            "last": self._zero_tokens(slots),
        }
        # host-side slot table (control plane only)
        self.active: list[Request | None] = [None] * slots
        self.generated: list[list] = [[] for _ in range(slots)]
        self.queue: deque[Request] = deque()
        self.results: dict[int, RequestResult] = {}
        self._seen_ids: set[int] = set()
        self._pending: list[jax.Array] = []  # un-synced packed step results
        self.stats = {
            "prefills": 0,          # requests prefilled
            "prefill_calls": 0,     # batched prefill program executions
            "prefill_tokens": 0,    # padded token-positions run through prefill
            "decode_steps": 0,
            "retired": 0,
            "host_syncs_decode": 0,  # blocking device->host syncs on the decode path
            "host_syncs_admit": 0,   # blocking syncs during admission
            "unserved": 0,
            "prefix_hits": 0,        # admissions that reused a cached prefix
            "prefix_misses": 0,      # cache enabled but no usable prefix
            "prefix_hit_tokens": 0,  # prompt tokens restored instead of prefilled
        }

        # ---- compiled programs: shared per (cfg, geometry, tier-set) so
        # replica boots after the first are warm (see _Programs) ----
        progs = _programs_for(cfg, slots, max_len, binding)
        self._fused_step = progs.fused_step
        self._prefill_chunk = progs.prefill_chunk
        self._init_batch = progs.init_batch
        self._sample_first = progs.sample_first
        self._assign = progs.assign
        self._decode = progs.decode  # legacy (unfused) step

        self.prefix_cache = (
            PrefixCache(progs.state_ops, capacity_bytes=prefix_cache_bytes)
            if prefix_cache_bytes else None)
        self._slot_pins: list = [None] * slots

    # ------------------------------------------------------------------
    def _bound(self):
        """Hook-binding scope for data-plane tracing: jit programs trace on
        first call, and the trace must happen under the deployment's binding
        for the probed tiers to actually serve traffic."""
        if self.binding is None:
            return contextlib.nullcontext()
        return hooks.use(self.binding)

    def warmup(self) -> dict | None:
        """Pre-compile every data-plane program so steady-state serving never
        compiles: the fused step, each (batch, bucket) prefill shape, the
        first-token sampler, and the slot-assign scatter. Outputs are
        discarded — engine state is untouched. Returns (and logs) the
        deployment's specialization manifest, so the operator sees exactly
        which kernel tier serves each accelerated API before traffic lands."""
        with self._bound():
            self._warmup_programs()
        if self.manifest is not None:
            tiers = {a: c["provider"]
                     for a, c in self.manifest.get("apis", {}).items()}
            logger.info("serving warm [%s @ %s]: %s",
                        self.manifest.get("container", "?"),
                        self.manifest.get("profile", "?"), tiers)
        return self.manifest

    def _warmup_programs(self) -> None:
        if self.fused:
            self._fused_step(self.params, self.rng, self.states, self.ctrl)
        else:
            self._decode(self.params, self.ctrl["last"], self.states,
                         self.ctrl["lengths"])
        npads, n = [], 1
        top = _pow2(self.slots) if self.fused else 1
        while n <= top:
            npads.append(n)
            n <<= 1
        key = jax.random.key(0)
        zero_tok = self._zero_tokens(1)[0]
        for npad in npads:
            states = self._init_batch(npad)
            start = jnp.zeros((npad,), jnp.int32)
            lens = jnp.ones((npad,), jnp.int32)
            for sb in self.prompt_buckets:
                if self.cfg.frontend == "audio":
                    toks = jnp.zeros((npad, self.cfg.num_codebooks, sb), jnp.int32)
                else:
                    toks = jnp.zeros((npad, sb), jnp.int32)
                logits, bstates, _ = self._prefill_chunk(
                    self.params, toks, states, start, lens)
            self._sample_first(
                key, logits, SamplingParams.from_configs([SamplingConfig()] * npad))
            self._assign(self.states, bstates, self.ctrl, 0, 0, 0, zero_tok,
                         0.0, 0, _NO_LIMIT, -1)
            if self.prefix_cache is not None:
                # prefix-cache device ops: one extract/restore program per
                # pow2 block length per batch geometry
                ops = self.prefix_cache.ops
                p, zero = 1, jnp.int32(0)
                while p <= self.max_len:
                    blk = ops.extract_pos(p, bstates, zero, zero)
                    ops.restore_pos(p, states, blk, zero, zero, zero)
                    p <<= 1
                ops.restore_snap(states, ops.extract_snap(bstates, zero), zero)
        jax.block_until_ready(self.states)

    # ------------------------------------------------------------------
    def _zero_tokens(self, n: int):
        if self.cfg.frontend == "audio":
            return jnp.zeros((n, self.cfg.num_codebooks), jnp.int32)
        return jnp.zeros((n,), jnp.int32)

    def submit(self, req: Request) -> None:
        s = np.asarray(req.prompt).shape[-1]
        if s > self.max_len:
            raise ValueError(f"prompt {s} > engine max_len {self.max_len}")
        if req.request_id in self._seen_ids:
            # a duplicate would silently overwrite its results entry and
            # corrupt downstream token metering deltas
            raise ValueError(f"duplicate request_id {req.request_id}")
        self._seen_ids.add(req.request_id)
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.active) if r is None]

    # ------------------------------------------------------------------
    # Admission: longest-cached-prefix lookup -> restore -> suffix-only
    # batched prefill, one program call per suffix bucket
    # ------------------------------------------------------------------
    def _admit(self) -> None:
        """Prefill queued requests into free slots, one batched prefill call
        per suffix bucket (legacy mode admits one request per call, matching
        the seed engine's behavior for before/after comparison).

        Requests that retire *at* admission (max_new_tokens <= 1, or no
        decode room) never occupy a slot, so the loop keeps refilling from
        the queue until the slots are saturated or the queue drains — a
        retired-at-admission request must not cost a slot a full engine
        step of idleness.
        """
        while True:
            free = self._free_slots()
            take = min(len(free), len(self.queue))
            if not take:
                return
            entries = []
            for _ in range(take):
                req = self.queue.popleft()
                entries.append((req,) + self._lookup_prefix(req))
            groups: dict[int, list[tuple]] = {}
            for e in entries:
                req, _, start = e
                suffix = np.asarray(req.prompt).shape[-1] - start
                groups.setdefault(
                    _bucket(suffix, self.prompt_buckets), []).append(e)
            for sc, es in groups.items():
                if self.fused:
                    self._admit_group(sc, es, free)
                else:
                    for e in es:
                        self._admit_group(sc, [e], free)

    def _lookup_prefix(self, req: Request):
        """-> (match, start): the longest usable cached prefix and the pin
        protecting it through admission (start == 0: miss / disabled)."""
        if self.prefix_cache is None:
            return None, 0
        prompt = np.asarray(req.prompt, np.int32)
        # always prefill at least the last prompt token: its logits seed the
        # first sampled token
        match = self.prefix_cache.match(prompt, limit=prompt.shape[-1] - 1)
        if match.usable <= 0:
            self.stats["prefix_misses"] += 1
            return None, 0
        self.prefix_cache.acquire(match.path[-1][0])  # pin through admission
        self.stats["prefix_hits"] += 1
        self.stats["prefix_hit_tokens"] += match.usable
        return match, match.usable

    def _admit_group(self, sc: int, entries: list[tuple], free: list[int]) -> None:
        n = len(entries)
        npad = _pow2(n)  # bound compiled-program count per bucket
        if self.cfg.frontend == "audio":
            batch = np.zeros((npad, self.cfg.num_codebooks, sc), np.int32)
        else:
            batch = np.zeros((npad, sc), np.int32)
        starts = np.zeros((npad,), np.int32)
        lens = np.ones((npad,), np.int32)  # pad rows: 1 valid pos at start 0
        bstates = self._init_batch(npad)
        for i, (req, match, start) in enumerate(entries):
            prompt = np.asarray(req.prompt, np.int32)
            # right-pad: real suffix at the front, absolute positions
            # [start, L) — see the class docstring for why
            batch[i, ..., : prompt.shape[-1] - start] = prompt[..., start:]
            starts[i] = start
            lens[i] = prompt.shape[-1]
            if start > 0:
                # restore re-walks the radix tree itself: `match` may be
                # stale if an earlier group's insert split a node on its path
                bstates = self.prefix_cache.restore(prompt, bstates, i, start)
        logits, bstates, _ = self._prefill_chunk(
            self.params, jnp.asarray(batch), bstates,
            jnp.asarray(starts), jnp.asarray(lens))
        self.stats["prefill_calls"] += 1
        self.stats["prefills"] += n
        self.stats["prefill_tokens"] += npad * sc

        pad_cfg = [e[0].sampling for e in entries] \
            + [SamplingConfig()] * (npad - n)
        self.rng, sub = jax.random.split(self.rng)
        first = self._sample_first(sub, logits, SamplingParams.from_configs(pad_cfg))
        first_host = np.asarray(jax.device_get(first))
        self.stats["host_syncs_admit"] += 1

        for i, (req, match, start) in enumerate(entries):
            plen = int(np.asarray(req.prompt).shape[-1])
            pin = None
            if self.prefix_cache is not None:
                # donate the full-prompt state back to the radix tree and
                # swap the admission pin for one on the (deeper) donated node
                pin = self.prefix_cache.acquire(
                    self.prefix_cache.insert(req.prompt, bstates, i, match))
                if match is not None:
                    self.prefix_cache.release(match.path[-1][0])
            # prefill token + decode steps until the cache fills at max_len
            room = self.max_len - plen + 1
            if room < req.max_new_tokens:
                logger.warning(
                    "request %s: prompt length %d leaves room for %d of the "
                    "%d requested tokens (engine max_len=%d) — output will "
                    "be truncated", req.request_id, plen, room,
                    req.max_new_tokens, self.max_len)
            if req.max_new_tokens <= 1 or room <= 1:
                # the prefill logits already yielded the only (or only
                # representable) token; retire without occupying a decode slot
                self.results[req.request_id] = RequestResult(
                    request_id=req.request_id,
                    tokens=[self._row_out(first_host[i])],
                    decode_steps=0)
                self.stats["retired"] += 1
                if pin is not None:
                    self.prefix_cache.release(pin)
                continue
            slot = free.pop(0)
            self.states, self.ctrl = self._assign(
                self.states, bstates, self.ctrl, i, slot, plen, first[i],
                float(req.sampling.temperature), int(req.sampling.top_k),
                int(req.max_new_tokens),
                -1 if req.eos_id is None else int(req.eos_id))
            self.active[slot] = req
            self.generated[slot] = [self._row_out(first_host[i])]
            self._slot_pins[slot] = pin

    def _row_out(self, row: np.ndarray):
        return tuple(int(x) for x in row) if row.ndim else int(row)

    def _tok_out(self, tok: jax.Array):
        t = jax.device_get(tok)
        self.stats["host_syncs_decode"] += 1
        return tuple(int(x) for x in t) if t.ndim else int(t)

    def _retire(self, slot: int, *, reset_device: bool = False) -> None:
        req = self.active[slot]
        assert req is not None
        self.results[req.request_id] = RequestResult(
            request_id=req.request_id,
            tokens=self.generated[slot],
            decode_steps=len(self.generated[slot]),
        )
        self.active[slot] = None
        self.generated[slot] = []
        if reset_device:  # fused path already zeroed these on device
            self.ctrl = dict(
                self.ctrl,
                lengths=self.ctrl["lengths"].at[slot].set(0),
                active=self.ctrl["active"].at[slot].set(False),
            )
        if self._slot_pins[slot] is not None:
            self.prefix_cache.release(self._slot_pins[slot])
            self._slot_pins[slot] = None
        self.stats["retired"] += 1

    # ------------------------------------------------------------------
    # Stepping
    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine iteration: admit, run one fused decode program for all
        B slots, sync the packed result (every ``sync_every`` steps), retire
        finished. Returns number of host-visible active slots."""
        with self._bound():
            return self._step_bound()

    def _step_bound(self) -> int:
        self._admit()
        if not any(r is not None for r in self.active):
            self._flush()
            return 0
        if self.fused:
            self.rng, self.states, self.ctrl, packed = self._fused_step(
                self.params, self.rng, self.states, self.ctrl)
            self.stats["decode_steps"] += 1
            self._pending.append(packed)
            # flush at the window boundary — or early, when every in-flight
            # request has provably hit its token budget (each active slot
            # emits one token per buffered step unless it finished even
            # sooner), so the engine never burns whole-batch decode steps on
            # a drained batch just to reach the window edge
            if len(self._pending) >= self.sync_every or all(
                len(self.generated[i]) + len(self._pending) >= r.max_new_tokens
                for i, r in enumerate(self.active) if r is not None
            ):
                self._flush()
        else:
            self._step_host()
        return sum(r is not None for r in self.active)

    def _flush(self) -> None:
        """Fetch all buffered packed step results in ONE blocking transfer
        and replay them through the host-side slot table."""
        if not self._pending:
            return
        rows = jax.device_get(self._pending)
        self._pending = []
        self.stats["host_syncs_decode"] += 1
        audio = self.cfg.frontend == "audio"
        for arr in rows:  # (B, T+2): tokens..., active, done
            arr = np.asarray(arr)
            for i in range(self.slots):
                if not arr[i, -2]:  # slot inactive at that step
                    continue
                req = self.active[i]
                if req is None:
                    continue
                tok = arr[i, :-2]
                self.generated[i].append(
                    tuple(int(x) for x in tok) if audio else int(tok[0]))
                if arr[i, -1]:
                    self._retire(i)

    def _step_host(self) -> None:
        """Legacy per-slot host loop (the seed data plane): B scalar sample
        programs + one device_get per token + one length sync per slot."""
        self.ctrl["lengths"] = self.ctrl["lengths"] + jnp.asarray(
            [1 if r is not None else 0 for r in self.active], jnp.int32)
        logits, self.states = self._decode(
            self.params, self.ctrl["last"], self.states, self.ctrl["lengths"])
        self.stats["decode_steps"] += 1
        new_tokens = []
        for i in range(self.slots):
            req = self.active[i]
            if req is None:
                new_tokens.append(self._zero_tokens(1)[0])
                continue
            self.rng, k = jax.random.split(self.rng)
            tok = sample(k, logits[i], req.sampling)
            new_tokens.append(tok)
            self.generated[i].append(self._tok_out(tok))
            done = len(self.generated[i]) >= req.max_new_tokens
            if req.eos_id is not None and not done:
                t = self.generated[i][-1]
                done = (t == req.eos_id) if isinstance(t, int) else (t[0] == req.eos_id)
            length = int(self.ctrl["lengths"][i])
            self.stats["host_syncs_decode"] += 1
            if length >= self.max_len:
                done = True
            if done:
                self._retire(i, reset_device=True)
        self.ctrl["last"] = jnp.stack(new_tokens)

    def run_to_completion(self, max_steps: int = 10_000) -> dict[int, RequestResult]:
        """Drive the engine until every request completes or ``max_steps``
        engine iterations elapse. On truncation, ``stats['unserved']`` holds
        the count of requests left queued/in-flight (and a warning is
        logged) so callers can tell completion from truncation."""
        steps = 0
        while (self.queue or any(r is not None for r in self.active)) and steps < max_steps:
            self.step()
            steps += 1
        self._flush()
        unserved = len(self.queue) + sum(r is not None for r in self.active)
        self.stats["unserved"] = unserved
        if unserved:
            logger.warning(
                "run_to_completion hit max_steps=%d with %d request(s) unserved",
                max_steps, unserved)
        return self.results
