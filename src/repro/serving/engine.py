"""Continuous-batching serving engine over the functional model zoo.

The XaaS serving story: a SERVICE-class lease holds a fixed chip allocation;
inside it, this engine multiplexes many short FaaS-style requests onto one
compiled decode program (the paper's "fine-grained transactional computations"
running on a long-lived high-performance allocation).

Design (vLLM-shape, JAX-native):
  * fixed slot count B (the compiled decode batch) with per-slot state inside
    the *stacked* KV/recurrent caches; slots are recycled across requests
    (continuous batching).
  * two compiled programs only — `prefill_one` (padded prompt buckets) and
    `decode_all` (one token for all B slots) — so serving never recompiles
    after warmup. Prompt padding buckets bound the prefill-program count.
  * slot admission writes the prefilled per-slot state into the batched
    state tree with a donated scatter (`slot_assign`), so admission is O(state
    of one slot), not O(whole cache).
  * all host-side logic (queueing, retirement) is control plane; every
    data-plane array op is jit'd. REST never touches the data path, per the
    paper.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.serving.sampling import SamplingConfig, sample

__all__ = ["Request", "RequestResult", "ServingEngine"]


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: Any  # (S,) int32 (or (K, S) audio)
    max_new_tokens: int
    sampling: SamplingConfig = dataclasses.field(default_factory=SamplingConfig)
    eos_id: int | None = None


@dataclasses.dataclass
class RequestResult:
    request_id: int
    tokens: list[int] | list[tuple]  # generated tokens (tuples for audio)
    prefill_steps: int = 1
    decode_steps: int = 0


def _bucket(n: int, buckets: tuple[int, ...]) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


class ServingEngine:
    """Continuous-batching engine for one deployed model."""

    def __init__(
        self,
        cfg,
        params,
        *,
        slots: int = 8,
        max_len: int = 512,
        prompt_buckets: tuple[int, ...] = (32, 128, 512),
        rng: jax.Array | None = None,
    ):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.prompt_buckets = tuple(b for b in prompt_buckets if b <= max_len) or (max_len,)
        self.rng = rng if rng is not None else jax.random.key(0)

        dt = jnp.dtype(cfg.activ_dtype)
        self.states = transformer.init_states(cfg, slots, max_len, dt)
        self.lengths = jnp.zeros((slots,), jnp.int32)
        self.last_tokens = self._zero_tokens(slots)
        # host-side slot table
        self.active: list[Request | None] = [None] * slots
        self.generated: list[list] = [[] for _ in range(slots)]
        self.queue: deque[Request] = deque()
        self.results: dict[int, RequestResult] = {}
        self.stats = {"prefills": 0, "decode_steps": 0, "retired": 0}

        # ---- compiled programs ----
        @jax.jit
        def _decode_all(params, tokens, states, lengths, key):
            logits, new_states = transformer.decode_step(
                params, cfg, tokens, states, lengths)
            return logits, new_states

        self._decode_all = _decode_all

        @functools.partial(jax.jit, static_argnums=(2,))
        def _prefill_one(params, tokens, max_len):
            # tokens: (1, Sb) padded bucket
            return transformer.prefill(params, cfg, tokens, max_len)

        self._prefill_one = _prefill_one

        def _batch_axis(dst, src):
            # first axis where dst and src disagree and src == 1 (the
            # prefilled single-request state) is the slot/batch axis
            for i, (a, b) in enumerate(zip(dst.shape, src.shape)):
                if a != b and b == 1:
                    return i
            for i, a in enumerate(dst.shape):  # same-shape fallback
                if a == self.slots and src.shape[i] == 1:
                    return i
            raise AssertionError(f"no batch axis: {dst.shape} vs {src.shape}")

        @jax.jit
        def _slot_assign(states, slot_states, lengths, slot, length):
            def put(dst, src):
                ax = _batch_axis(dst, src)
                return jax.lax.dynamic_update_index_in_dim(
                    dst, jax.lax.squeeze(src, (ax,)).astype(dst.dtype), slot, ax)
            new = jax.tree.map(put, states, slot_states)
            return new, lengths.at[slot].set(length)

        self._slot_assign = _slot_assign

    # ------------------------------------------------------------------
    def _zero_tokens(self, n: int):
        if self.cfg.frontend == "audio":
            return jnp.zeros((n, self.cfg.num_codebooks), jnp.int32)
        return jnp.zeros((n,), jnp.int32)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.active) if r is None]

    # ------------------------------------------------------------------
    def _admit(self) -> None:
        """Prefill queued requests into free slots."""
        for slot in self._free_slots():
            if not self.queue:
                return
            req = self.queue.popleft()
            prompt = jnp.asarray(req.prompt)
            s = prompt.shape[-1]
            if s > self.max_len:
                raise ValueError(f"prompt {s} > engine max_len {self.max_len}")
            sb = _bucket(s, self.prompt_buckets)
            pad = sb - s
            if self.cfg.frontend == "audio":
                padded = jnp.pad(prompt, ((0, 0), (pad, 0)))[None]
            else:
                padded = jnp.pad(prompt, (pad, 0))[None]
            # NOTE: left-pad keeps the *suffix* alignment the decode path
            # expects (cache slots [0, sb) filled, real prompt at the tail).
            logits, slot_states, _ = self._prefill_one(self.params, padded, self.max_len)
            self.stats["prefills"] += 1
            self.states, self.lengths = self._slot_assign(
                self.states, slot_states, self.lengths, slot, sb)
            self.rng, k = jax.random.split(self.rng)
            first = sample(k, logits[0], req.sampling)
            self.active[slot] = req
            self.generated[slot] = [self._tok_out(first)]
            self.last_tokens = self.last_tokens.at[slot].set(first)

    def _tok_out(self, tok: jax.Array):
        t = jax.device_get(tok)
        return tuple(int(x) for x in t) if t.ndim else int(t)

    def _retire(self, slot: int) -> None:
        req = self.active[slot]
        assert req is not None
        self.results[req.request_id] = RequestResult(
            request_id=req.request_id,
            tokens=self.generated[slot],
            decode_steps=len(self.generated[slot]),
        )
        self.active[slot] = None
        self.generated[slot] = []
        self.lengths = self.lengths.at[slot].set(0)
        self.stats["retired"] += 1

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine iteration: admit, decode once for all active slots,
        sample, retire finished. Returns number of active slots."""
        self._admit()
        active_idx = [i for i, r in enumerate(self.active) if r is not None]
        if not active_idx:
            return 0
        # one decode for all B slots (inactive slots compute but are ignored
        # — the fixed-batch tradeoff that keeps a single compiled program)
        self.lengths = self.lengths + jnp.asarray(
            [1 if r is not None else 0 for r in self.active], jnp.int32)
        self.rng, k = jax.random.split(self.rng)
        logits, self.states = self._decode_all(
            self.params, self.last_tokens, self.states, self.lengths, k)
        self.stats["decode_steps"] += 1
        # sample per slot (host loop over B is control-plane only)
        new_tokens = []
        for i in range(self.slots):
            req = self.active[i]
            if req is None:
                new_tokens.append(self._zero_tokens(1)[0])
                continue
            self.rng, k = jax.random.split(self.rng)
            tok = sample(k, logits[i], req.sampling)
            new_tokens.append(tok)
            self.generated[i].append(self._tok_out(tok))
            done = len(self.generated[i]) >= req.max_new_tokens
            if req.eos_id is not None and not done:
                t = self.generated[i][-1]
                done = (t == req.eos_id) if isinstance(t, int) else (t[0] == req.eos_id)
            if int(self.lengths[i]) >= self.max_len:
                done = True
            if done:
                self._retire(i)
        self.last_tokens = jnp.stack(new_tokens)
        return len([r for r in self.active if r is not None])

    def run_to_completion(self, max_steps: int = 10_000) -> dict[int, RequestResult]:
        steps = 0
        while (self.queue or any(r is not None for r in self.active)) and steps < max_steps:
            self.step()
            steps += 1
        return self.results
