"""Ref-counted radix prefix cache for serving KV / recurrent state.

Thousands of fine-grained serving requests share prompt prefixes (system
prompts, multi-turn conversations). Re-prefilling those shared tokens is
exactly the redundant work the paper's Invocation principle says must not sit
on a lean transactional data path — so the engine caches the per-layer state
a prefix produced and restores it with one scatter instead of recomputing it.

Layout:

  * a **radix tree** over prompt token sequences (columns of a (K, S) int32
    array — K=1 for text, K=num_codebooks for audio). Each edge owns the
    state its token span produced:
      - *positional* state leaves (KV caches, MLA latents — any leaf with a
        ``max_len``-extent axis, found structurally) are stored as per-edge
        slices along that axis, padded to a power of two so the restore /
        extract scatter programs stay bounded at log2(max_len) shapes;
      - *non-positional* leaves (RG-LRU ``h``/conv tails, xLSTM (C, n, m))
        are **boundary snapshots**, valid only at the edge's end. Archs with
        such leaves can only reuse prefixes at snapshot boundaries; pure-KV
        archs reuse at arbitrary token granularity (edges split on demand).
  * **ref-counting**: a slot serving a request pins the deepest node of the
    prefix it used (hit or insert) until the request retires; eviction never
    touches a pinned leaf, and interior nodes are protected by their
    children (leaf-only eviction).
  * **LRU eviction under a byte budget**: every hit/insert touches its path;
    when the byte budget is exceeded the least-recently-used unpinned leaf
    is dropped (repeatedly — freeing a leaf may expose its parent).

All device work (extract on insert, scatter on restore) goes through
:class:`StateOps`, whose jitted programs are shared per (cfg, max_len) via
the engine's ``_Programs`` bundle — fleet replicas share them the same way
they share the decode program. Tree bookkeeping is pure host-side control
plane.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer

__all__ = ["PrefixCache", "PrefixMatch", "StateOps", "state_batch_axes",
           "state_pos_axes"]


def _pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def state_batch_axes(cfg, max_len: int, dtype):
    """Per-leaf batch axis of the serving-state tree, found STRUCTURALLY:
    the axis whose extent tracks the state batch size (probe batch=1 vs
    batch=2 shapes). Shared by StateOps, the engine's program bundle, and
    the draft-model proposer — one probe, one rule."""
    s1 = jax.eval_shape(lambda: transformer.init_states(cfg, 1, max_len, dtype))
    s2 = jax.eval_shape(lambda: transformer.init_states(cfg, 2, max_len, dtype))

    def axis(a, b):
        for i, (x, y) in enumerate(zip(a.shape, b.shape)):
            if x != y:
                return i
        raise AssertionError(f"state leaf has no batch axis: {a.shape}")

    return jax.tree.map(axis, s1, s2)


def state_pos_axes(cfg, max_len: int, dtype):
    """Per-leaf positional axis (extent tracks ``max_len``); -1 for leaves
    with none (recurrent / boundary-snapshot state)."""
    s2 = jax.eval_shape(lambda: transformer.init_states(cfg, 2, max_len, dtype))
    sl = jax.eval_shape(
        lambda: transformer.init_states(cfg, 2, max_len + 1, dtype))

    def axis(a, b):
        for i, (x, y) in enumerate(zip(a.shape, b.shape)):
            if x != y:
                return i
        return -1

    return jax.tree.map(axis, s2, sl)


def _tree_bytes(tree) -> int:
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree.leaves(tree))


class StateOps:
    """Structure-aware device ops over a serving-state pytree.

    Finds, per state leaf, the batch axis (extent tracks the state batch
    size) and the positional axis (extent tracks ``max_len``; -1 when the
    leaf has none, e.g. recurrent state). Provides jitted extract/restore
    programs whose shape space is bounded: one program per power-of-two
    block length per batch geometry.
    """

    def __init__(self, cfg, max_len: int, dtype, *, aot=None):
        self.batch_axes = state_batch_axes(cfg, max_len, dtype)
        self.pos_axes = state_pos_axes(cfg, max_len, dtype)
        self.has_snap = any(p == -1 for p in jax.tree.leaves(self.pos_axes))
        self.max_len = max_len

        @functools.partial(jax.jit, static_argnums=(0,))
        def extract_pos(p, batch_states, row, start):
            """Positional-leaf slices [start, start+p) of one batch row,
            positional axis leading. Rows past the valid span hold garbage
            the matching restore drops via ``true_len``."""
            def f(ba, pa, leaf):
                if pa == -1:
                    return jnp.zeros((0,), leaf.dtype)
                lf = jnp.moveaxis(leaf, (ba, pa), (0, 1))[row]
                idx = jnp.clip(start + jnp.arange(p), 0, lf.shape[0] - 1)
                return jnp.take(lf, idx, axis=0)
            return jax.tree.map(f, self.batch_axes, self.pos_axes, batch_states)

        @functools.partial(jax.jit, static_argnums=(0,))
        def restore_pos(p, states, block, row, start, true_len):
            """Scatter a stored block into row ``row`` at positions
            [start, start+true_len); the block's pow2 padding is dropped."""
            def f(ba, pa, leaf, blk):
                if pa == -1:
                    return leaf
                lf = jnp.moveaxis(leaf, (ba, pa), (0, 1))
                ar = jnp.arange(p)
                idx = jnp.where(ar < true_len, start + ar, lf.shape[1])
                lf = lf.at[row, idx].set(blk.astype(lf.dtype), mode="drop")
                return jnp.moveaxis(lf, (0, 1), (ba, pa))
            return jax.tree.map(f, self.batch_axes, self.pos_axes, states, block)

        @jax.jit
        def extract_snap(batch_states, row):
            def f(ba, pa, leaf):
                if pa != -1:
                    return jnp.zeros((0,), leaf.dtype)
                return jnp.moveaxis(leaf, ba, 0)[row]
            return jax.tree.map(f, self.batch_axes, self.pos_axes, batch_states)

        @jax.jit
        def restore_snap(states, snap, row):
            def f(ba, pa, leaf, sn):
                if pa != -1:
                    return leaf
                lf = jnp.moveaxis(leaf, ba, 0)
                lf = lf.at[row].set(sn.astype(lf.dtype))
                return jnp.moveaxis(lf, 0, ba)
            return jax.tree.map(f, self.batch_axes, self.pos_axes, states, snap)

        if aot is not None:
            # register the cache ops in the bundle's AOT registry so they
            # persist to (and IR-boot from) the artifact store with every
            # other data-plane program
            self.extract_pos = aot.wrap("cache_extract_pos", extract_pos,
                                        static_argnums=(0,))
            self.restore_pos = aot.wrap("cache_restore_pos", restore_pos,
                                        static_argnums=(0,))
            self.extract_snap = aot.wrap("cache_extract_snap", extract_snap)
            self.restore_snap = aot.wrap("cache_restore_snap", restore_snap)
        else:
            self.extract_pos = extract_pos
            self.restore_pos = restore_pos
            self.extract_snap = extract_snap
            self.restore_snap = restore_snap

    def split_block(self, block, true_len: int, m: int):
        """Split a stored positional block at offset m -> (head, tail),
        each re-padded to its own pow2 length. Eager (splits are rare,
        control-plane-only)."""
        ph, pt = _pow2(m), _pow2(true_len - m)

        def head(pa, blk):
            return blk if pa == -1 else blk[:ph]

        def tail(pa, blk):
            if pa == -1:
                return blk
            cut = blk[m:min(m + pt, blk.shape[0])]
            if cut.shape[0] < pt:
                cut = jnp.pad(cut, [(0, pt - cut.shape[0])]
                              + [(0, 0)] * (cut.ndim - 1))
            return cut

        return (jax.tree.map(head, self.pos_axes, block),
                jax.tree.map(tail, self.pos_axes, block))


class _Node:
    __slots__ = ("tokens", "children", "parent", "block", "true_len",
                 "snap", "ref", "last_use", "nbytes", "depth_end")

    def __init__(self, tokens: np.ndarray, parent: "_Node | None"):
        self.tokens = tokens          # (K, seg) edge label
        self.children: dict[tuple, _Node] = {}
        self.parent = parent
        self.block: Any = None        # positional slices, pow2-padded
        self.true_len = int(tokens.shape[-1])
        self.snap: Any = None         # boundary snapshot (or None)
        self.ref = 0
        self.last_use = 0
        self.nbytes = 0
        self.depth_end = 0            # absolute token depth at edge end

    @property
    def depth_start(self) -> int:
        return self.depth_end - self.true_len


@dataclasses.dataclass
class PrefixMatch:
    """Result of a radix lookup: the raw matched path plus the usable
    (restorable) depth — constrained to snapshot boundaries for archs with
    non-positional state and to ``limit`` (the engine always prefills at
    least the prompt's last token to obtain logits)."""

    path: list  # [(node, cols_used)]
    raw_len: int
    usable: int
    snap_node: "_Node | None"


class PrefixCache:
    """Radix prefix cache over prompt tokens; see module docstring."""

    def __init__(self, ops: StateOps, *, capacity_bytes: int):
        self.ops = ops
        self.capacity_bytes = int(capacity_bytes)
        self.root = _Node(np.zeros((1, 0), np.int32), None)
        self.bytes = 0
        self.nodes = 0
        self._tick = 0
        self.stats = {"inserts": 0, "splits": 0, "evictions": 0,
                      "evicted_bytes": 0, "snapshot_upgrades": 0}

    # ------------------------------------------------------------------
    @staticmethod
    def _norm(prompt) -> np.ndarray:
        t = np.asarray(prompt, np.int32)
        return t[None, :] if t.ndim == 1 else t

    def _touch(self, path) -> None:
        self._tick += 1
        for node, _ in path:
            node.last_use = self._tick

    # ------------------------------------------------------------------
    def match(self, prompt, *, limit: int | None = None) -> PrefixMatch:
        """Longest cached prefix of ``prompt``. ``limit`` caps the usable
        depth (engine passes len(prompt)-1 so the suffix is never empty)."""
        toks = self._norm(prompt)
        length = toks.shape[-1]
        if limit is None:
            limit = length
        path: list = []
        node, depth = self.root, 0
        while depth < length:
            child = node.children.get(tuple(int(v) for v in toks[:, depth]))
            if child is None:
                break
            seg = child.true_len
            span = toks[:, depth:depth + seg]
            w = span.shape[-1]
            eq = np.all(child.tokens[:, :w] == span, axis=0)  # (w,) per column
            m = w if eq.all() else int(np.argmax(~eq))
            if m == 0:
                break
            path.append((child, m))
            depth += m
            if m < seg:
                break
            node = child
        usable, snap_node, d = 0, None, 0
        for n, cols in path:
            end = d + cols
            if self.ops.has_snap:
                if cols == n.true_len and n.snap is not None and end <= limit:
                    usable, snap_node = end, n
            else:
                usable = min(end, limit)
            d = end
        return PrefixMatch(path=path, raw_len=depth, usable=usable,
                           snap_node=snap_node)

    # ------------------------------------------------------------------
    def restore(self, prompt, states, row: int, start: int):
        """Scatter the cached prefix [0, start) of ``prompt`` into batch row
        ``row`` of ``states``. Re-walks the tree rather than trusting a
        caller-held :class:`PrefixMatch`: between the lookup that chose
        ``start`` and this restore, an earlier admission group's insert may
        have SPLIT a node on the path (re-slicing its blocks), and a stale
        path would silently restore only part of the prefix. Splits preserve
        content and the lookup's pin protects the path from eviction, so the
        fresh walk always re-finds at least ``start`` usable tokens."""
        match = self.match(prompt, limit=start)
        assert match.usable >= start, (
            f"cached prefix vanished between lookup and restore "
            f"({match.usable} < {start})")
        self._touch(match.path)
        remaining = start
        for node, cols in match.path:
            if remaining <= 0:
                break
            take = min(cols, remaining)
            states = self.ops.restore_pos(
                _pow2(node.true_len), states, node.block,
                jnp.int32(row), jnp.int32(node.depth_start), jnp.int32(take))
            remaining -= take
        if self.ops.has_snap and start > 0:
            assert match.snap_node is not None
            assert match.snap_node.depth_end == start
            states = self.ops.restore_snap(states, match.snap_node.snap,
                                           jnp.int32(row))
        return states

    # ------------------------------------------------------------------
    def _split(self, node: _Node, m: int) -> _Node:
        """Split ``node``'s edge at offset m; returns the new parent
        covering [depth_start, depth_start+m). The new interior node has no
        snapshot (its boundary state was never captured)."""
        parent = node.parent
        head_tok = node.tokens[:, :m]
        head = _Node(head_tok, parent)
        head.depth_end = node.depth_start + m
        head.last_use = node.last_use
        hb, tb = self.ops.split_block(node.block, node.true_len, m)
        old_bytes = node.nbytes
        head.block, head.nbytes = hb, _tree_bytes(hb)
        node.tokens = node.tokens[:, m:]
        node.true_len -= m
        node.block = tb
        node.nbytes = _tree_bytes(tb) + (
            _tree_bytes(node.snap) if node.snap is not None else 0)
        node.parent = head
        parent.children[tuple(int(v) for v in head_tok[:, 0])] = head
        head.children[tuple(int(v) for v in node.tokens[:, 0])] = node
        self.bytes += head.nbytes + node.nbytes - old_bytes
        self.nodes += 1
        self.stats["splits"] += 1
        return head

    def insert(self, prompt, batch_states, row: int,
               match: PrefixMatch | None = None) -> "_Node":
        """Donate the full-prompt state held in ``batch_states`` row ``row``
        to the tree, and return the deepest node covering the prompt (the
        caller pins it with :meth:`acquire` for the request's lifetime)."""
        toks = self._norm(prompt)
        length = toks.shape[-1]
        # re-walk even when the engine hands us its lookup's match: eviction
        # or a sibling's insert in the same admission batch may have changed
        # the tree since
        del match
        match = self.match(prompt)
        depth = match.raw_len
        node = match.path[-1][0] if match.path else self.root
        if match.path and match.path[-1][1] < node.true_len:
            node = self._split(node, match.path[-1][1])
        if depth >= length:
            # prompt fully covered; attach a snapshot at this boundary if the
            # arch needs one and it is missing (split nodes start without)
            if self.ops.has_snap and node.snap is None and node.parent is not None:
                node.snap = self.ops.extract_snap(batch_states, jnp.int32(row))
                add = _tree_bytes(node.snap)
                node.nbytes += add
                self.bytes += add
                self.stats["snapshot_upgrades"] += 1
            self._touch(match.path)
            node.last_use = self._tick
            self.evict_to_budget()
            return node
        seg = length - depth
        leaf = _Node(toks[:, depth:], node)
        leaf.depth_end = length
        leaf.block = self.ops.extract_pos(
            _pow2(seg), batch_states, jnp.int32(row), jnp.int32(depth))
        leaf.nbytes = _tree_bytes(leaf.block)
        if self.ops.has_snap:
            leaf.snap = self.ops.extract_snap(batch_states, jnp.int32(row))
            leaf.nbytes += _tree_bytes(leaf.snap)
        node.children[tuple(int(v) for v in leaf.tokens[:, 0])] = leaf
        self.bytes += leaf.nbytes
        self.nodes += 1
        self.stats["inserts"] += 1
        self._touch(match.path + [(leaf, seg)])
        self.evict_to_budget()
        return leaf

    # ------------------------------------------------------------------
    def acquire(self, node: "_Node") -> "_Node":
        node.ref += 1
        return node

    def release(self, node: "_Node") -> None:
        assert node.ref > 0, "prefix-cache release without acquire"
        node.ref -= 1
        self.evict_to_budget()

    # ------------------------------------------------------------------
    def evict_to_budget(self) -> None:
        """Drop least-recently-used unpinned leaves until under budget.
        Interior nodes become evictable once their children go. One tree
        walk + sort evicts a whole batch of leaves (not one walk per
        eviction); a further pass runs only when evicting a subtree's
        leaves exposed its interior nodes, so the cost is O(nodes log nodes)
        per depth level actually drained — the common under-budget call is
        a single comparison."""
        while self.bytes > self.capacity_bytes:
            leaves = sorted(
                (n for n in self._iter_nodes()
                 if not n.children and n.ref == 0 and n.parent is not None),
                key=lambda n: n.last_use)
            evicted = False
            for victim in leaves:
                if self.bytes <= self.capacity_bytes:
                    break
                if victim.children:
                    continue  # gained a child? impossible mid-pass, but safe
                del victim.parent.children[
                    tuple(int(v) for v in victim.tokens[:, 0])]
                self.bytes -= victim.nbytes
                self.nodes -= 1
                self.stats["evictions"] += 1
                self.stats["evicted_bytes"] += victim.nbytes
                evicted = True
            if not evicted:
                return  # everything pinned (or interior): over budget, stuck

    def _iter_nodes(self):
        stack = [self.root]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n.parent is not None:
                yield n

    # ------------------------------------------------------------------
    def report(self) -> dict:
        return {**self.stats, "nodes": self.nodes, "bytes": self.bytes,
                "capacity_bytes": self.capacity_bytes}
