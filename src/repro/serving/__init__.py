"""Serving substrate: continuous-batching engine + sampling."""
from repro.serving import engine, sampling  # noqa: F401
