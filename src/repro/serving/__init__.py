"""Serving substrate: continuous-batching engine + sampling + speculative
decoding + service glue."""
from repro.serving import engine, sampling, service, speculative  # noqa: F401
