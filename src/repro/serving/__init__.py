"""Serving substrate: continuous-batching engine + sampling + service glue."""
from repro.serving import engine, sampling, service  # noqa: F401
