"""Checkpoint substrate: async sharded store with elastic restore."""
from repro.checkpoint.store import CheckpointStore  # noqa: F401
