"""Sharded, async checkpoint store with elastic (re-shard) restore.

The paper: on HPC "checkpoint/restart is a viable mode of operation as long
as the storage system is reliable", and XaaS needs it doubly — it is both the
fault-tolerance substrate (node loss at 1000+ nodes is routine) and the
elasticity substrate (restore onto a different mesh when the allocation
grows/shrinks).

Format: one directory per step
    step_000042/
      MANIFEST.json       — pytree structure, leaf paths, shapes, dtypes,
                            logical axes, save-time mesh, data-step
      arrays/<leaf>.npy   — one file per leaf (real multi-host would write
                            per-shard files; single-process writes the
                            gathered array, keeping the same manifest schema)
      COMMIT              — written last; a checkpoint without COMMIT is
                            ignored (atomicity under mid-write failure)

Async: `save()` snapshots to host RAM (device_get) synchronously — the
train loop's only stall — then a background thread serializes to disk. This
is the standard two-phase async checkpoint (MaxText/Orbax-style) and is what
makes frequent checkpoints affordable at scale.

Elastic restore: arrays are saved *unsharded by logical content*; restore
takes the target mesh + sharding rules and lays each leaf out for the new
topology (`restore(..., mesh=new_mesh)`), so a job that lost a pod restarts
on the survivors without format conversion.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from typing import Any, Mapping

import jax
import numpy as np

__all__ = ["ArtifactStore", "CheckpointStore"]


def _flatten(tree: Any, prefix: str = "") -> list[tuple[str, Any]]:
    out = []
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        keys = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                keys.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        out.append(("/".join(keys), leaf))
    return out


class _Corrupt(Exception):
    """An artifact dir exists but fails integrity checks (never escapes
    ``ArtifactStore.get`` — it becomes a recorded miss)."""


class ArtifactStore:
    """Durable store of serialized compiled-program artifacts — the IR half
    of an XaaS container.

    Same durability idiom as :class:`CheckpointStore`: one directory per
    key, blobs + MANIFEST.json written into a temp dir, COMMIT written
    last, then an atomic rename over any previous version. A directory
    without COMMIT (or whose manifest/blob hashes disagree) is treated as
    absent: ``get`` NEVER raises — a corrupted or truncated artifact is a
    recorded miss that the boot ladder turns into a cold boot, never a
    serving failure.

    Format: <root>/<key>/
        MANIFEST.json  — {key, meta, blobs: [{name, file, bytes, sha256}]}
        blobs/<name>.bin
        COMMIT         — written last (atomicity under mid-write failure)
    """

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._lock = threading.Lock()
        self.stats = {"puts": 0, "hits": 0, "misses": 0, "corrupt": 0}
        self.last_error: str | None = None

    # ------------------------------------------------------------------
    @staticmethod
    def _safe(name: str) -> str:
        return "".join(c if c.isalnum() or c in "._-@" else "%"
                       for c in name)

    def _dir(self, key: str) -> str:
        return os.path.join(self.root, self._safe(key))

    def contains(self, key: str) -> bool:
        return os.path.exists(os.path.join(self._dir(key), "COMMIT"))

    def keys(self) -> list[str]:
        try:
            names = sorted(os.listdir(self.root))
        except OSError:
            return []
        return [d for d in names
                if os.path.exists(os.path.join(self.root, d, "COMMIT"))]

    # ------------------------------------------------------------------
    def put(self, key: str, blobs: Mapping[str, bytes],
            meta: dict | None = None) -> None:
        """Atomically (over)write the artifact for ``key``."""
        final = self._dir(key)
        with self._lock:
            tmp = tempfile.mkdtemp(dir=self.root, prefix=".tmp_")
            try:
                bdir = os.path.join(tmp, "blobs")
                os.makedirs(bdir)
                entries = []
                for name in sorted(blobs):
                    data = blobs[name]
                    # sanitized names can collide ("a/b" and "a?b" both
                    # land on "a%b"); a short hash of the ORIGINAL name
                    # keeps one file per blob
                    tag = hashlib.sha256(name.encode()).hexdigest()[:8]
                    fn = f"{self._safe(name)}-{tag}.bin"
                    with open(os.path.join(bdir, fn), "wb") as f:
                        f.write(data)
                    entries.append({
                        "name": name, "file": fn, "bytes": len(data),
                        "sha256": hashlib.sha256(data).hexdigest(),
                    })
                manifest = {"key": key, "meta": meta or {}, "blobs": entries}
                with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                    json.dump(manifest, f, indent=1)
                with open(os.path.join(tmp, "COMMIT"), "w") as f:
                    f.write("ok")
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self.stats["puts"] += 1
            finally:
                if os.path.exists(tmp):
                    shutil.rmtree(tmp, ignore_errors=True)

    def get(self, key: str) -> tuple[dict[str, bytes], dict] | None:
        """(blobs, meta) for a committed, integrity-checked artifact —
        else None, with the reason in ``last_error`` (the boot ladder
        surfaces it in the specialization manifest)."""
        d = self._dir(key)
        if not os.path.exists(os.path.join(d, "COMMIT")):
            self.last_error = f"no committed artifact for key {key}"
            self.stats["misses"] += 1
            return None
        try:
            with open(os.path.join(d, "MANIFEST.json")) as f:
                manifest = json.load(f)
            blobs: dict[str, bytes] = {}
            for e in manifest["blobs"]:
                path = os.path.join(d, "blobs", e["file"])
                with open(path, "rb") as f:
                    data = f.read()
                if len(data) != e["bytes"]:
                    raise _Corrupt(
                        f"blob {e['name']}: {len(data)} bytes on disk, "
                        f"manifest says {e['bytes']} (truncated)")
                if hashlib.sha256(data).hexdigest() != e["sha256"]:
                    raise _Corrupt(f"blob {e['name']}: sha256 mismatch")
                blobs[e["name"]] = data
        except Exception as err:
            self.last_error = f"artifact {key} rejected: {err}"
            self.stats["corrupt"] += 1
            return None
        self.last_error = None
        self.stats["hits"] += 1
        return blobs, manifest.get("meta", {})

    def meta(self, key: str) -> dict | None:
        """Manifest meta without reading blobs (family diffing on a key
        miss); None when absent or unreadable."""
        try:
            with open(os.path.join(self._dir(key), "MANIFEST.json")) as f:
                return json.load(f).get("meta", {})
        except (OSError, ValueError):
            return None

    def delete(self, key: str) -> None:
        with self._lock:
            shutil.rmtree(self._dir(key), ignore_errors=True)

    # ------------------------------------------------------------------
    def sync_from(self, other: "ArtifactStore | str",
                  *, overwrite: bool = False) -> dict:
        """Cross-host distribution: copy committed artifacts from another
        store (or a bare directory) into this one.

        This is the "store is a plain directory" rsync story the IR-boot
        containers doc promised: a prefill-pool host can compile once and
        every decode-pool host syncs the corpus before booting. Semantics:

          * manifest-diff — keys already committed here are skipped unless
            ``overwrite`` (a local artifact is never clobbered by default);
          * sha-verified — every source blob is re-hashed against the
            SOURCE manifest during the read; an artifact with a corrupt or
            truncated blob is **skipped as a recorded miss** on the source
            store (never copied, never fatal), matching ``get``'s
            never-raise contract;
          * atomic per key — copied artifacts land through :meth:`put`
            (temp dir + COMMIT + rename), so a crash mid-sync leaves no
            uncommitted debris visible to readers.

        Returns ``{"copied", "skipped", "corrupt", "keys"}``.
        """
        src = other if isinstance(other, ArtifactStore) else ArtifactStore(other)
        out = {"copied": 0, "skipped": 0, "corrupt": 0, "keys": []}
        for key in src.keys():
            if not overwrite and self.contains(key):
                out["skipped"] += 1
                continue
            got = src.get(key)  # integrity-checked read; miss on corruption
            if got is None:
                out["corrupt"] += 1
                continue
            blobs, meta = got
            self.put(key, blobs, meta=meta)
            out["copied"] += 1
            out["keys"].append(key)
        return out


class CheckpointStore:
    def __init__(self, root: str, *, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._pending: threading.Thread | None = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:09d}")

    def save(self, step: int, tree: Any, *, meta: dict | None = None,
             blocking: bool = False) -> None:
        """Two-phase async save of `tree` at `step`."""
        # phase 1 (synchronous): snapshot device -> host
        flat = _flatten(tree)
        host = [(k, np.asarray(jax.device_get(v))) for k, v in flat]
        structure = jax.tree.structure(tree)
        manifest = {
            "step": step,
            "meta": meta or {},
            "treedef": str(structure),
            "leaves": [
                {"key": k, "shape": list(a.shape), "dtype": str(a.dtype)}
                for k, a in host
            ],
        }
        self.wait()  # one in-flight save at a time

        def _write():
            final = self._step_dir(step)
            tmp = tempfile.mkdtemp(dir=self.root, prefix=".tmp_")
            try:
                os.makedirs(os.path.join(tmp, "arrays"), exist_ok=True)
                for k, a in host:
                    fn = os.path.join(tmp, "arrays", k.replace("/", "%") + ".npy")
                    np.save(fn, a)
                with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                    json.dump(manifest, f)
                with open(os.path.join(tmp, "COMMIT"), "w") as f:
                    f.write("ok")
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
            finally:
                if os.path.exists(tmp):
                    shutil.rmtree(tmp, ignore_errors=True)
            self._gc()

        t = threading.Thread(target=_write, daemon=True)
        with self._lock:
            self._pending = t
        t.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        with self._lock:
            t = self._pending
        if t is not None:
            t.join()
            with self._lock:
                if self._pending is t:
                    self._pending = None

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and os.path.exists(
                os.path.join(self.root, d, "COMMIT")):
                out.append(int(d[len("step_"):]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, like: Any, *, step: int | None = None,
                mesh: jax.sharding.Mesh | None = None,
                pspecs: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of `like` (a pytree of arrays or
        ShapeDtypeStructs). With `mesh` + `pspecs`, each leaf is placed
        sharded for the *target* topology — elastic restore. Returns
        (tree, meta)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.root}")
        d = self._step_dir(step)
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        by_key = {e["key"]: e for e in manifest["leaves"]}
        flat_like = _flatten(like)
        leaves = []
        flat_specs = None
        if pspecs is not None:
            is_spec = lambda x: isinstance(x, jax.sharding.PartitionSpec)
            flat_specs = jax.tree.flatten(pspecs, is_leaf=is_spec)[0]
        for i, (k, proto) in enumerate(flat_like):
            if k not in by_key:
                raise KeyError(f"checkpoint {step} missing leaf {k!r}")
            fn = os.path.join(d, "arrays", k.replace("/", "%") + ".npy")
            a = np.load(fn)
            want_shape = tuple(proto.shape)
            if tuple(a.shape) != want_shape:
                raise ValueError(
                    f"leaf {k}: checkpoint shape {a.shape} != target {want_shape}")
            if mesh is not None and flat_specs is not None:
                sh = jax.sharding.NamedSharding(mesh, flat_specs[i])
                leaves.append(jax.device_put(a.astype(proto.dtype), sh))
            else:
                leaves.append(jax.numpy.asarray(a.astype(proto.dtype)))
        tree = jax.tree.unflatten(jax.tree.structure(like), leaves)
        return tree, manifest["meta"]
