"""Sharded, async checkpoint store with elastic (re-shard) restore.

The paper: on HPC "checkpoint/restart is a viable mode of operation as long
as the storage system is reliable", and XaaS needs it doubly — it is both the
fault-tolerance substrate (node loss at 1000+ nodes is routine) and the
elasticity substrate (restore onto a different mesh when the allocation
grows/shrinks).

Format: one directory per step
    step_000042/
      MANIFEST.json       — pytree structure, leaf paths, shapes, dtypes,
                            logical axes, save-time mesh, data-step
      arrays/<leaf>.npy   — one file per leaf (real multi-host would write
                            per-shard files; single-process writes the
                            gathered array, keeping the same manifest schema)
      COMMIT              — written last; a checkpoint without COMMIT is
                            ignored (atomicity under mid-write failure)

Async: `save()` snapshots to host RAM (device_get) synchronously — the
train loop's only stall — then a background thread serializes to disk. This
is the standard two-phase async checkpoint (MaxText/Orbax-style) and is what
makes frequent checkpoints affordable at scale.

Elastic restore: arrays are saved *unsharded by logical content*; restore
takes the target mesh + sharding rules and lays each leaf out for the new
topology (`restore(..., mesh=new_mesh)`), so a job that lost a pod restarts
on the survivors without format conversion.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["CheckpointStore"]


def _flatten(tree: Any, prefix: str = "") -> list[tuple[str, Any]]:
    out = []
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        keys = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                keys.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        out.append(("/".join(keys), leaf))
    return out


class CheckpointStore:
    def __init__(self, root: str, *, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._pending: threading.Thread | None = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:09d}")

    def save(self, step: int, tree: Any, *, meta: dict | None = None,
             blocking: bool = False) -> None:
        """Two-phase async save of `tree` at `step`."""
        # phase 1 (synchronous): snapshot device -> host
        flat = _flatten(tree)
        host = [(k, np.asarray(jax.device_get(v))) for k, v in flat]
        structure = jax.tree.structure(tree)
        manifest = {
            "step": step,
            "meta": meta or {},
            "treedef": str(structure),
            "leaves": [
                {"key": k, "shape": list(a.shape), "dtype": str(a.dtype)}
                for k, a in host
            ],
        }
        self.wait()  # one in-flight save at a time

        def _write():
            final = self._step_dir(step)
            tmp = tempfile.mkdtemp(dir=self.root, prefix=".tmp_")
            try:
                os.makedirs(os.path.join(tmp, "arrays"), exist_ok=True)
                for k, a in host:
                    fn = os.path.join(tmp, "arrays", k.replace("/", "%") + ".npy")
                    np.save(fn, a)
                with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                    json.dump(manifest, f)
                with open(os.path.join(tmp, "COMMIT"), "w") as f:
                    f.write("ok")
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
            finally:
                if os.path.exists(tmp):
                    shutil.rmtree(tmp, ignore_errors=True)
            self._gc()

        t = threading.Thread(target=_write, daemon=True)
        with self._lock:
            self._pending = t
        t.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        with self._lock:
            t = self._pending
        if t is not None:
            t.join()
            with self._lock:
                if self._pending is t:
                    self._pending = None

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and os.path.exists(
                os.path.join(self.root, d, "COMMIT")):
                out.append(int(d[len("step_"):]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, like: Any, *, step: int | None = None,
                mesh: jax.sharding.Mesh | None = None,
                pspecs: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of `like` (a pytree of arrays or
        ShapeDtypeStructs). With `mesh` + `pspecs`, each leaf is placed
        sharded for the *target* topology — elastic restore. Returns
        (tree, meta)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.root}")
        d = self._step_dir(step)
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        by_key = {e["key"]: e for e in manifest["leaves"]}
        flat_like = _flatten(like)
        leaves = []
        flat_specs = None
        if pspecs is not None:
            is_spec = lambda x: isinstance(x, jax.sharding.PartitionSpec)
            flat_specs = jax.tree.flatten(pspecs, is_leaf=is_spec)[0]
        for i, (k, proto) in enumerate(flat_like):
            if k not in by_key:
                raise KeyError(f"checkpoint {step} missing leaf {k!r}")
            fn = os.path.join(d, "arrays", k.replace("/", "%") + ".npy")
            a = np.load(fn)
            want_shape = tuple(proto.shape)
            if tuple(a.shape) != want_shape:
                raise ValueError(
                    f"leaf {k}: checkpoint shape {a.shape} != target {want_shape}")
            if mesh is not None and flat_specs is not None:
                sh = jax.sharding.NamedSharding(mesh, flat_specs[i])
                leaves.append(jax.device_put(a.astype(proto.dtype), sh))
            else:
                leaves.append(jax.numpy.asarray(a.astype(proto.dtype)))
        tree = jax.tree.unflatten(jax.tree.structure(like), leaves)
        return tree, manifest["meta"]
