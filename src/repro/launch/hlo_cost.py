"""Loop-aware HLO cost model — the roofline instrument.

``compiled.cost_analysis()`` counts every while-loop body ONCE (verified
empirically: a scan of 10 matmuls reports one matmul's FLOPs). Our programs
are scan-over-layers x scan-over-microbatches x scan-over-kv-blocks, so the
official numbers under-count by orders of magnitude. This module walks the
post-optimization HLO text instead and rolls costs up *with loop
multiplicity*, which XLA conveniently records on each while op as
``backend_config={"known_trip_count":{"n":...}}``.

Per computation we accumulate:
  * flops      — dot ops: 2 * |result| * |contracting dims| (from operand
                 shapes); elementwise arithmetic: 1 flop/element (matmuls
                 dominate; transcendental weighting is noise at model scale)
  * hbm_bytes  — operand + result bytes at fusion boundaries (fusion
                 internals live in registers/VMEM, the standard convention);
                 gathers/scatters count data moved, not the full table
  * collectives — result bytes per op kind, split ICI vs DCN by replica
                 group analysis (pod axis = device-id stride `pod_size`)

Validated in tests/test_hlo_cost.py against cost_analysis() on loop-free
programs and against hand-computed scan costs.
"""
from __future__ import annotations

import dataclasses
import json
import re

import numpy as np

from repro.kernels import compat

__all__ = ["CostReport", "analyze", "parse_computations", "xla_cost_analysis"]


def xla_cost_analysis(compiled) -> dict:
    """XLA's own per-module cost dict, normalized across jax versions.

    ``Compiled.cost_analysis()`` has returned a dict, a one-element list of
    dicts, or nothing depending on version/backend; every consumer (tests,
    dryrun, accounting) reads it through this one helper so a format change
    is one fix, not N.
    """
    return compat.xla_cost_analysis(compiled)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "e4m3": 1,
    "e5m2": 1, "token": 0, "opaque": 0,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# opcodes that move no HBM bytes themselves
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "broadcast",
    "reshape", "custom-call", "rng-bit-generator", "get-dimension-size",
    "opt-barrier", "domain",
}
# elementwise-ish ops: 1 flop per output element
_ARITH_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "compare", "select", "and", "or", "xor", "not", "clamp", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "sign", "atan2",
    "remainder", "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "exponential-minus-one", "log-plus-one", "cosine", "sine", "logistic",
    "cbrt", "erf", "convert", "reduce", "reduce-window", "map",
}


@dataclasses.dataclass
class CostReport:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    # fusion-optimistic lower bound: only ops that MUST move HBM bytes on a
    # well-fused TPU program count (dots, gathers/scatters, collectives);
    # elementwise/layout ops are assumed fused into their consumers. The
    # true traffic lies in [hbm_min, hbm_bytes] — CPU-lowered HLO leaves
    # many converts/broadcasts unfused that TPU fuses, so hbm_bytes alone
    # over-states the memory roofline term by ~10-50x.
    hbm_min: float = 0.0
    collectives: dict = dataclasses.field(
        default_factory=lambda: {
            fab: dict.fromkeys(COLLECTIVE_KINDS, 0.0) for fab in ("ici", "dcn")})
    unknown_trip_counts: int = 0

    def add(self, other: "CostReport", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.hbm_min += other.hbm_min * mult
        for fab in self.collectives:
            for k in COLLECTIVE_KINDS:
                self.collectives[fab][k] += other.collectives[fab][k] * mult
        self.unknown_trip_counts += other.unknown_trip_counts

    def collective_bytes(self, fabric: str | None = None) -> float:
        if fabric is None:
            return self.collective_bytes("ici") + self.collective_bytes("dcn")
        return sum(self.collectives[fabric].values())

    def to_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "hbm_min": self.hbm_min,
            "collectives": self.collectives,
            "collective_bytes_ici": self.collective_bytes("ici"),
            "collective_bytes_dcn": self.collective_bytes("dcn"),
            "unknown_trip_counts": self.unknown_trip_counts,
        }


# ---------------------------------------------------------------------------
# Shape parsing
# ---------------------------------------------------------------------------
_SHAPE_RE = re.compile(r"(\w+)\[([\d,<=\s]*)\]")


def _shape_dims(shape: str) -> tuple[str, list[int]]:
    m = _SHAPE_RE.match(shape.strip())
    if not m:
        return "opaque", []
    dims = [int(d.strip().lstrip("<=")) for d in m.group(2).split(",")
            if d.strip()]
    return m.group(1), dims


def _shape_bytes(shape: str) -> int:
    shape = shape.strip()
    if shape.startswith("("):  # tuple: sum elements
        return sum(_shape_bytes(p) for p in _split_tuple(shape[1:-1]))
    dt, dims = _shape_dims(shape)
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES.get(dt, 4)


def _shape_elems(shape: str) -> int:
    if shape.strip().startswith("("):
        return sum(_shape_elems(p) for p in _split_tuple(shape.strip()[1:-1]))
    _, dims = _shape_dims(shape)
    n = 1
    for d in dims:
        n *= d
    return n


def _split_tuple(s: str) -> list[str]:
    parts, depth, cur = [], 0, ""
    for ch in s:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append(cur)
            cur = ""
        else:
            cur += ch
    if cur.strip():
        parts.append(cur)
    return parts


def _strip_layout(shape: str) -> str:
    # f32[512,128]{1,0:T(8,128)} -> f32[512,128]
    return re.sub(r"\{[^}]*\}", "", shape)


# ---------------------------------------------------------------------------
# HLO text parsing
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Instruction:
    name: str
    result_type: str
    opcode: str
    operands: list[str]
    attrs: str
    line: str


def parse_computations(hlo_text: str) -> dict[str, list[Instruction]]:
    comps: dict[str, list[Instruction]] = {}
    cur: list[Instruction] | None = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.endswith("{") and ("(" in line) and (
                line.startswith("%") or line.startswith("ENTRY")):
            name = line.split("(", 1)[0].replace("ENTRY", "").strip()
            name = name.lstrip("%").split()[0]
            cur = comps.setdefault(name, [])
            if line.startswith("ENTRY"):
                comps["__entry__"] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None or "=" not in line:
            continue
        instr = _parse_instruction(line)
        if instr is not None:
            cur.append(instr)
    return comps


def _parse_instruction(line: str) -> Instruction | None:
    line = line.lstrip()
    if line.startswith("ROOT "):
        line = line[5:]
    if not line.startswith("%"):
        return None
    try:
        name, rest = line.split("=", 1)
    except ValueError:
        return None
    name = name.strip().lstrip("%")
    rest = rest.strip()
    # result type: tuple (...) or single shape token
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        result_type = rest[: i + 1]
        rest = rest[i + 1:].strip()
    else:
        sp = rest.index(" ")
        result_type = rest[:sp]
        rest = rest[sp + 1:].strip()
    if "(" not in rest:
        return None
    opcode = rest[: rest.index("(")].strip()
    # operand list = first balanced paren group
    depth = 0
    start = rest.index("(")
    for i in range(start, len(rest)):
        depth += rest[i] == "("
        depth -= rest[i] == ")"
        if depth == 0:
            break
    opnd_str = rest[start + 1: i]
    attrs = rest[i + 1:]
    operands = re.findall(r"%([\w.\-]+)", opnd_str)
    return Instruction(name, _strip_layout(result_type), opcode, operands,
                       attrs, line)


# ---------------------------------------------------------------------------
# Cost walk
# ---------------------------------------------------------------------------
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
# op_name fragments marking regions the pallas-tpu tier fuses into one
# VMEM-resident kernel (jax.named_scope markers in kernels/ops.py)
_KERNEL_REGION_RE = re.compile(
    r"op_name=\"[^\"]*fused_(attention|mlstm)_kernel[^\"]*\"")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_RE = re.compile(
    r"replica_groups=(\{\{[^=]*?\}\}|\[[^]]*\](?:<=\[[^]]*\])?(?:T\([^)]*\))?)")
_PAIRS_RE = re.compile(r"source_target_pairs=\{\{(.*?)\}\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _parse_groups(attr: str):
    if attr.startswith("{{"):
        return [[int(x) for x in g.split(",") if x.strip()]
                for g in attr[2:-2].split("},{")]
    m = re.match(r"\[([\d,]+)\](?:<=\[([\d,]+)\])?(?:T\(([\d,]+)\))?", attr)
    if not m:
        return None
    gshape = [int(x) for x in m.group(1).split(",")]
    if m.group(2) is None:
        return [gshape]
    dims = [int(x) for x in m.group(2).split(",")]
    ids = np.arange(int(np.prod(dims))).reshape(dims)
    if m.group(3):
        ids = ids.transpose([int(x) for x in m.group(3).split(",")])
    return ids.reshape(gshape).tolist()


def _fabric(instr: Instruction, pod_size: int) -> str:
    gm = _GROUPS_RE.search(instr.attrs)
    if gm:
        groups = _parse_groups(gm.group(1))
        if groups:
            for g in groups:
                if g and (max(g) // pod_size) != (min(g) // pod_size):
                    return "dcn"
    pm = _PAIRS_RE.search(instr.attrs)
    if pm:
        ids = [int(x) for x in re.findall(r"\d+", pm.group(1))]
        for a, b in zip(ids[::2], ids[1::2]):
            if a // pod_size != b // pod_size:
                return "dcn"
    return "ici"


def analyze(hlo_text: str, *, pod_size: int = 256) -> CostReport:
    comps = parse_computations(hlo_text)
    types: dict[str, dict[str, str]] = {
        cname: {i.name: i.result_type for i in instrs}
        for cname, instrs in comps.items()
    }
    memo: dict[str, CostReport] = {}

    def op_bytes(instr: Instruction, table: dict[str, str]) -> float:
        return sum(_shape_bytes(table.get(o, "opaque[]")) for o in instr.operands)

    def walk(cname: str) -> CostReport:
        if cname in memo:
            return memo[cname]
        memo[cname] = CostReport()  # cycle guard
        rep = CostReport()
        table = types.get(cname, {})
        for instr in comps.get(cname, ()):
            oc = instr.opcode
            if oc == "while":
                body = _BODY_RE.search(instr.attrs)
                cond = _COND_RE.search(instr.attrs)
                tm = _TRIP_RE.search(instr.attrs)
                trips = int(tm.group(1)) if tm else 1
                if not tm:
                    rep.unknown_trip_counts += 1
                if body:
                    rep.add(walk(body.group(1)), trips)
                if cond:
                    rep.add(walk(cond.group(1)), trips + 1)
                continue
            if oc == "conditional":
                bm = _BRANCHES_RE.search(instr.attrs)
                if bm:
                    subs = [walk(b.strip().lstrip("%"))
                            for b in bm.group(1).split(",")]
                    if subs:  # upper bound: the costliest branch
                        rep.add(max(subs, key=lambda r: r.flops))
                continue
            if oc in ("fusion", "call", "async-start"):
                cm = _CALLS_RE.search(instr.attrs)
                to = re.search(r"to_apply=%([\w.\-]+)", instr.attrs)
                target = cm or to
                result_b = _shape_bytes(instr.result_type)
                boundary_b = op_bytes(instr, table) + result_b
                if target:
                    sub = walk(target.group(1))
                    # flops/collectives roll up; HBM bytes are the smaller
                    # of the boundary view (operands+result — right for
                    # fused elementwise chains) and the body view (right
                    # for gather fusions, which touch O(result), not the
                    # whole table operand)
                    rep.add(CostReport(flops=sub.flops, hbm_bytes=0.0,
                                       hbm_min=sub.hbm_min,
                                       collectives=sub.collectives))
                    rep.unknown_trip_counts += sub.unknown_trip_counts
                    rep.hbm_bytes += min(boundary_b,
                                         sub.hbm_bytes + result_b)
                else:
                    rep.hbm_bytes += boundary_b
                continue
            if oc in COLLECTIVE_KINDS or any(
                    oc == f"{k}-start" for k in COLLECTIVE_KINDS):
                kind = oc.removesuffix("-start")
                nbytes = _shape_bytes(instr.result_type)
                rep.collectives[_fabric(instr, pod_size)][kind] += nbytes
                rep.hbm_bytes += nbytes + op_bytes(instr, table)
                rep.hbm_min += nbytes
                continue
            if oc.endswith("-done"):
                continue
            if oc == "dot":
                out_elems = _shape_elems(instr.result_type)
                lhs_type = table.get(instr.operands[0], "f32[]")
                _, lhs_dims = _shape_dims(lhs_type)
                cm = _LHS_CONTRACT_RE.search(instr.attrs)
                contract = 1
                if cm and cm.group(1):
                    for d in cm.group(1).split(","):
                        contract *= lhs_dims[int(d)] if int(d) < len(lhs_dims) else 1
                rep.flops += 2.0 * out_elems * contract
                dot_b = op_bytes(instr, table) + _shape_bytes(instr.result_type)
                rep.hbm_bytes += dot_b
                # dots inside attention/mLSTM regions (identified by op_name
                # metadata) are VMEM-resident in the deployed pallas-tpu
                # tier (flash attention / chunked mLSTM): their score-matrix
                # traffic never reaches HBM, so hbm_min credits the fusion
                # and charges only the kernel's q/k/v/o boundary (counted
                # once per region via the first dot's operands).
                if _KERNEL_REGION_RE.search(instr.line):
                    rep.hbm_min += op_bytes(instr, table) * 0.5
                else:
                    rep.hbm_min += dot_b
                continue
            if oc in ("gather", "dynamic-slice"):
                rep.hbm_bytes += 2 * _shape_bytes(instr.result_type)
                rep.hbm_min += 2 * _shape_bytes(instr.result_type)
                continue
            if oc in ("scatter", "dynamic-update-slice"):
                upd = instr.operands[-1] if oc == "dynamic-update-slice" else (
                    instr.operands[len(instr.operands) // 2])
                rep.hbm_bytes += 2 * _shape_bytes(table.get(upd, "opaque[]"))
                rep.hbm_min += 2 * _shape_bytes(table.get(upd, "opaque[]"))
                continue
            if oc in _FREE_OPS:
                continue
            if oc == "copy" or oc == "transpose" or oc == "sort" or oc in (
                    "pad", "slice", "concatenate", "reverse",
                    "dynamic-reshape", "select-and-scatter"):
                rep.hbm_bytes += op_bytes(instr, table) + _shape_bytes(
                    instr.result_type)
                continue
            if oc in _ARITH_OPS:
                rep.flops += _shape_elems(instr.result_type)
                rep.hbm_bytes += op_bytes(instr, table) + _shape_bytes(
                    instr.result_type)
                continue
            # unknown op: count bytes conservatively
            rep.hbm_bytes += op_bytes(instr, table) + _shape_bytes(
                instr.result_type)
        memo[cname] = rep
        return rep

    if "__entry__" not in comps:
        raise ValueError("no ENTRY computation found in HLO text")
    # resolve entry's real name (entry aliased under __entry__)
    entry_rep = CostReport()
    entry_name = next(
        (n for n, il in comps.items()
         if n != "__entry__" and il is comps["__entry__"]), "__entry__")
    entry_rep.add(walk(entry_name))
    return entry_rep
