"""Dry-run cell construction: (arch x shape x mesh) -> lowerable program.

A *cell* packages everything `jax.jit(...).lower()` needs for one assigned
(architecture x input-shape) pair on one production mesh:

  * the step function (train_step / prefill serve_step / decode serve_step)
    with the deployment's hook binding + sharding rules baked in,
  * ShapeDtypeStruct stand-ins for every input (``input_specs`` — no device
    allocation; weights/caches never materialize),
  * in/out shardings from the recipe's rule set,
  * donation so caches/state update in place.

This module performs NO device-count tricks itself — callers (dryrun.py)
own XLA_FLAGS; cells are also reused at toy scale by tests/benchmarks.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs import base as cfgbase
from repro.core import hooks
from repro.distributed import sharding as shd
from repro.launch import mesh as meshlib
from repro.launch import recipes as rec
from repro.models import frontends, transformer
from repro.training import train_step as ts

__all__ = ["Cell", "build_cell", "input_specs", "DRYRUN_HOOKS", "cell_ids"]

# The dry-run hook binding: memory-bounded XLA implementations. Pallas
# kernels cannot lower for CPU stand-in devices; on TPU metal the deploy
# profile binds pallas-tpu instead (see kernels/ops.py priorities).
DRYRUN_HOOKS = {"attention": "xla-blocked", "mlstm": "xla-blocked"}


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str  # train | prefill | decode
    fn: Callable
    args: tuple  # ShapeDtypeStructs
    in_shardings: Any
    out_shardings: Any
    donate_argnums: tuple[int, ...]
    rules: shd.Rules
    meta: dict

    def jitted(self):
        return jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )

    def lower(self):
        return self.jitted().lower(*self.args)


def cell_ids() -> list[tuple[str, str]]:
    """All 40 assigned (arch, shape) pairs, applicable or not."""
    return [(a, s) for a in configs.ARCH_IDS for s in cfgbase.SHAPES]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _named(mesh, tree):
    isp = lambda x: isinstance(x, P)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=isp)


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------
def input_specs(arch_id: str, shape_id: str) -> dict[str, jax.ShapeDtypeStruct]:
    """The assignment-mandated entrypoint: weak-type-correct, shardable,
    allocation-free stand-ins for one (arch, shape) cell's *data* inputs.
    (Params/optimizer/cache trees are derived separately via eval_shape.)"""
    cfg = configs.get_config(arch_id)
    shape = cfgbase.SHAPES[shape_id]
    b, s = shape.global_batch, shape.seq_len
    out: dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        tok_shape = (b, cfg.num_codebooks, s) if cfg.frontend == "audio" else (b, s)
        out["tokens"] = _sds(tok_shape, jnp.int32)
        out["labels"] = _sds(tok_shape, jnp.int32)
        if cfg.frontend == "vlm":
            out["patch_embeds"] = _sds(
                (b, cfg.num_image_tokens, frontends.VIS_DIM), jnp.bfloat16)
    elif shape.kind == "prefill":
        tok_shape = (b, cfg.num_codebooks, s) if cfg.frontend == "audio" else (b, s)
        out["tokens"] = _sds(tok_shape, jnp.int32)
        if cfg.frontend == "vlm":
            out["patch_embeds"] = _sds(
                (b, cfg.num_image_tokens, frontends.VIS_DIM), jnp.bfloat16)
    else:  # decode: one new token against a seq_len cache
        tok_shape = (b, cfg.num_codebooks) if cfg.frontend == "audio" else (b,)
        out["tokens"] = _sds(tok_shape, jnp.int32)
        out["lengths"] = _sds((b,), jnp.int32)
    return out


# ---------------------------------------------------------------------------
# Cell builders
# ---------------------------------------------------------------------------
def build_cell(arch_id: str, shape_id: str, mesh: jax.sharding.Mesh,
               *, hook_overrides: dict | None = None) -> Cell:
    cfg = configs.get_config(arch_id)
    shape = cfgbase.SHAPES[shape_id]
    ok, why = cfgbase.shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{arch_id} x {shape_id} skipped: {why}")
    multi_pod = "pod" in mesh.axis_names
    recipe = rec.recipe_for(arch_id, shape_id)
    rules = rec.rules_for(recipe, multi_pod=multi_pod,
                          serving=shape.is_serving)
    binding = hooks.bind(None, overrides=dict(
        DRYRUN_HOOKS, **(hook_overrides or {})))
    if shape.kind == "train":
        return _train_cell(arch_id, cfg, shape, mesh, recipe, rules, binding,
                           multi_pod)
    if shape.kind == "prefill":
        return _prefill_cell(arch_id, cfg, shape, mesh, recipe, rules, binding)
    return _decode_cell(arch_id, cfg, shape, mesh, recipe, rules, binding)


def _batch_specs(cfg, shape, mesh, rules):
    """(arg dict of SDS, sharding dict) for the data inputs."""
    specs = input_specs(cfg.name, shape.name)
    shardings = {}
    with shd.use_rules(rules, mesh):
        for k, v in specs.items():
            spec = shd.guarded_spec(v.shape, ("batch",) + (None,) * (v.ndim - 1))
            shardings[k] = NamedSharding(mesh, spec)
    return specs, shardings


def _train_cell(arch_id, cfg, shape, mesh, recipe, rules, binding, multi_pod):
    tcfg = rec.train_config_for(cfg, recipe, mesh=mesh, multi_pod=multi_pod)
    step = ts.make_train_step(cfg, tcfg, multi_pod=multi_pod)

    def fn(state, batch):
        with shd.use_rules(rules, mesh), hooks.use(binding):
            return step(state, batch)

    state_shapes = jax.eval_shape(
        lambda: ts.init_train_state(jax.random.key(0), cfg, tcfg))
    with shd.use_rules(rules, mesh):
        state_specs = ts.train_state_pspecs(state_shapes, mesh, tcfg)
    state_shardings = _named(mesh, state_specs)
    batch_sds, batch_shardings = _batch_specs(cfg, shape, mesh, rules)
    repl = NamedSharding(mesh, P())
    return Cell(
        arch=arch_id, shape=shape.name, kind="train",
        fn=fn,
        args=(state_shapes, batch_sds),
        in_shardings=(state_shardings, batch_shardings),
        out_shardings=(state_shardings, repl),
        donate_argnums=(0,),
        rules=rules,
        meta={"tcfg": tcfg, "recipe": recipe,
              "microbatches": tcfg.microbatches},
    )


def _params_specs(cfg, mesh, rules):
    param_shapes = jax.eval_shape(
        lambda: transformer.init_model(jax.random.key(0), cfg))
    with shd.use_rules(rules, mesh):
        pspecs = shd.param_pspecs(param_shapes)
    return param_shapes, _named(mesh, pspecs)


def _total_seq(cfg, shape):
    s = shape.seq_len
    if cfg.frontend == "vlm":
        s += cfg.num_image_tokens
    return s


def _prefill_cell(arch_id, cfg, shape, mesh, recipe, rules, binding):
    max_len = _total_seq(cfg, shape)

    def fn(params, batch):
        with shd.use_rules(rules, mesh), hooks.use(binding):
            logits, states, lengths = transformer.prefill(
                params, cfg, batch["tokens"], max_len,
                patch_embeds=batch.get("patch_embeds"))
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt, states, lengths

    param_shapes, param_shardings = _params_specs(cfg, mesh, rules)
    batch_sds, batch_shardings = _batch_specs(cfg, shape, mesh, rules)
    state_shapes = jax.eval_shape(
        lambda: transformer.init_states(
            cfg, shape.global_batch, max_len, jnp.dtype(cfg.activ_dtype)))
    with shd.use_rules(rules, mesh):
        state_specs = shd.state_pspecs(state_shapes)
    baxes = meshlib.batch_axes(mesh)
    tok_sh = NamedSharding(mesh, P(baxes))
    nxt_sh = tok_sh if cfg.frontend != "audio" else NamedSharding(
        mesh, P(baxes, None))
    return Cell(
        arch=arch_id, shape=shape.name, kind="prefill",
        fn=fn,
        args=(param_shapes, batch_sds),
        in_shardings=(param_shardings, batch_shardings),
        out_shardings=(nxt_sh, _named(mesh, state_specs), tok_sh),
        donate_argnums=(),
        rules=rules,
        meta={"recipe": recipe, "max_len": max_len},
    )


def _decode_cell(arch_id, cfg, shape, mesh, recipe, rules, binding):
    max_len = _total_seq(cfg, shape)
    b = shape.global_batch

    def fn(params, tokens, states, lengths):
        with shd.use_rules(rules, mesh), hooks.use(binding):
            logits, new_states = transformer.decode_step(
                params, cfg, tokens, states, lengths)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt, new_states

    param_shapes, param_shardings = _params_specs(cfg, mesh, rules)
    state_shapes = jax.eval_shape(
        lambda: transformer.init_states(
            cfg, b, max_len, jnp.dtype(cfg.activ_dtype)))
    with shd.use_rules(rules, mesh):
        state_specs = shd.state_pspecs(state_shapes)
    state_shardings = _named(mesh, state_specs)
    data_sds = input_specs(arch_id, shape.name)
    baxes = meshlib.batch_axes(mesh)
    # long_500k has batch=1: not shardable over data — replicate (honest
    # waste, recorded in the roofline; see DESIGN.md §3)
    bentry = baxes if b % _axis_prod(mesh, baxes) == 0 else None
    tok_sh = NamedSharding(mesh, P(bentry))
    tok_in_sh = tok_sh if cfg.frontend != "audio" else NamedSharding(
        mesh, P(bentry, None))
    return Cell(
        arch=arch_id, shape=shape.name, kind="decode",
        fn=fn,
        args=(param_shapes, data_sds["tokens"], state_shapes,
              data_sds["lengths"]),
        in_shardings=(param_shardings, tok_in_sh, state_shardings, tok_sh),
        out_shardings=(tok_in_sh, state_shardings),
        donate_argnums=(2,),
        rules=rules,
        meta={"recipe": recipe, "max_len": max_len},
    )


def _axis_prod(mesh, axes) -> int:
    names = axes if isinstance(axes, tuple) else (axes,)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in names:
        n *= sizes[a]
    return n
