"""Print the backend specialization manifest — which kernel tier serves each
accelerated API on each system profile, after deploy-time probing.

CI runs this after the test suite so a tier regression (a probe that starts
failing and silently demotes an API to a lower tier) is visible in the
workflow log at a glance, not buried behind green tests that exercise the
fallback. See docs/kernel-portability.md for the tier x backend matrix.

Usage:
    python -m repro.launch.manifest [--json] [--profile NAME ...]
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core import hooks, recompile
from repro.distributed import sharding as shd
from repro.kernels import ops  # noqa: F401 — registers the tiers

PROFILES = {
    p.name: p
    for p in (
        recompile.PORTABLE_CPU,
        recompile.CPU_INTERPRET,
        recompile.host_mesh_profile((1, 2)),
        recompile.TPU_V5E,
        recompile.TPU_V5E_POD,
    )
}


def collect(names: list[str] | None = None) -> dict:
    out = {}
    for name in names or list(PROFILES):
        profile = PROFILES[name]
        binding = hooks.bind(profile, probe=True)
        man = binding.manifest()
        # resolved mesh geometry + the logical-axis rule set a container
        # would install on this profile (XContainer.rules_for): the
        # specialization record pairs "which tier serves each API" with
        # "how logical axes land on the chip grid"
        rules = (shd.RULES_3D if "pod" in profile.mesh_axes
                 else shd.RULES_2D)
        man["mesh"] = {"shape": list(profile.mesh_shape),
                       "axes": list(profile.mesh_axes),
                       "chips": profile.chips}
        man["sharding_rules"] = shd.rule_summary(rules)
        out[name] = man
    return out


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", action="store_true", help="machine-readable")
    ap.add_argument(
        "--profile", action="append", choices=sorted(PROFILES),
        help="limit to one or more profiles (default: all)")
    args = ap.parse_args(argv)

    manifests = collect(args.profile)
    if args.json:
        print(json.dumps(manifests, indent=2))
        return 0

    for pname, man in manifests.items():
        chip = PROFILES[pname].chip
        print(f"\n== {pname} ({chip}) ==")
        mesh = man["mesh"]
        geom = "x".join(str(d) for d in mesh["shape"])
        axes = ",".join(mesh["axes"])
        print(f"  mesh {geom} ({axes}) — {mesh['chips']} chip(s)")
        srules = {k: v for k, v in man["sharding_rules"].items() if v}
        print("  rules " + (" ".join(f"{k}->{v}"
                                     for k, v in sorted(srules.items()))
                            if srules else "(none)"))
        width = max(len(a) for a in man["apis"]) + 2
        for api, choice in sorted(man["apis"].items()):
            line = f"  {api:<{width}} {choice['provider']}"
            if choice["probed"]:
                line += "  [probed]"
            for provider, err in choice["rejected"]:
                line += f"\n  {'':<{width}} rejected {provider}: {err}"
            print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
