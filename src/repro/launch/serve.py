"""Serving driver — continuous-batching engine over a deployed model.

Runs REAL decode steps (not the dry-run): builds a model, boots the
``ServingEngine`` (vLLM-shape: slot recycling, two compiled programs), feeds
it a synthetic request stream, and reports throughput + per-request stats.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
      --requests 16 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampling import SamplingConfig

__all__ = ["run", "main"]


def run(arch_id: str, *, requests: int = 8, max_new: int = 16,
        slots: int = 4, max_len: int = 256, prompt_len: int = 24,
        smoke: bool = True, temperature: float = 0.0, seed: int = 0) -> dict:
    arch = arch_id + ("-smoke" if smoke and not arch_id.endswith("-smoke") else "")
    cfg = configs.get_config(arch)
    rng = np.random.default_rng(seed)
    params = transformer.init_model(jax.random.key(seed), cfg)
    engine = ServingEngine(cfg, params, slots=slots, max_len=max_len,
                           prompt_buckets=(32, 64, 128))
    sampling = SamplingConfig(temperature=temperature)
    for i in range(requests):
        plen = int(rng.integers(prompt_len // 2, prompt_len + 1))
        if cfg.frontend == "audio":
            prompt = rng.integers(0, cfg.vocab_size,
                                  (cfg.num_codebooks, plen), dtype=np.int32)
        else:
            prompt = rng.integers(0, cfg.vocab_size, (plen,), dtype=np.int32)
        engine.submit(Request(request_id=i, prompt=prompt,
                              max_new_tokens=max_new, sampling=sampling))
    t0 = time.perf_counter()
    results = engine.run_to_completion()
    wall = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in results.values())
    print(f"served {len(results)}/{requests} requests, {toks} tokens in "
          f"{wall:.1f}s ({toks / max(wall, 1e-9):.1f} tok/s) | "
          f"prefills {engine.stats['prefills']} "
          f"decode steps {engine.stats['decode_steps']}")
    return {"results": results, "stats": dict(engine.stats), "wall_s": wall,
            "tokens": toks}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    out = run(args.arch, requests=args.requests, max_new=args.max_new,
              slots=args.slots, max_len=args.max_len,
              prompt_len=args.prompt_len, smoke=args.smoke,
              temperature=args.temperature)
    assert len(out["results"]) == args.requests


if __name__ == "__main__":
    main()
