"""Serving driver — leased continuous-batching engine over a deployed model.

Serving is a first-class XaaS workload here: the driver acquires a
SERVICE-class lease from the ``InvocationService`` control plane, the lease's
deployment boots the ``ServingEngine`` (fused data plane: one compiled
program per decode step, one host sync per step), traffic flows through the
lease, and every served token lands in the tenant's accounting ledger.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
      --requests 16 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.core import recompile, scheduler
from repro.core.invocation import InvocationService
from repro.models import transformer
from repro.serving.engine import Request
from repro.serving.sampling import SamplingConfig
from repro.serving.service import serving_container

__all__ = ["run", "main"]


def run(arch_id: str, *, requests: int = 8, max_new: int = 16,
        slots: int = 4, max_len: int = 256, prompt_len: int = 24,
        smoke: bool = True, temperature: float = 0.0, seed: int = 0,
        tenant: str = "serve-demo", fused: bool = True,
        sync_every: int = 1) -> dict:
    arch = arch_id + ("-smoke" if smoke and not arch_id.endswith("-smoke") else "")
    cfg = configs.get_config(arch)
    rng = np.random.default_rng(seed)
    params = transformer.init_model(jax.random.key(seed), cfg)

    # control plane: schedule chips, deploy the container, boot the engine
    profile = recompile.PORTABLE_CPU
    cont = serving_container(cfg, params, slots=slots, max_len=max_len,
                             prompt_buckets=(32, 64, 128), fused=fused,
                             sync_every=sync_every)
    service = InvocationService(scheduler.Cluster(chips=profile.chips))
    executor = service.acquire_serving(tenant, cont, profile)
    t0 = time.perf_counter()
    executor.warmup()
    print(f"warmup (all data-plane programs compiled): "
          f"{time.perf_counter() - t0:.1f}s")

    for i in range(requests):
        plen = int(rng.integers(prompt_len // 2, prompt_len + 1))
        if cfg.frontend == "audio":
            prompt = rng.integers(0, cfg.vocab_size,
                                  (cfg.num_codebooks, plen), dtype=np.int32)
        else:
            prompt = rng.integers(0, cfg.vocab_size, (plen,), dtype=np.int32)
        executor.submit(Request(request_id=i, prompt=prompt,
                                max_new_tokens=max_new,
                                sampling=SamplingConfig(temperature=temperature)))

    t0 = time.perf_counter()
    results = executor.run()
    wall = time.perf_counter() - t0
    stats = dict(executor.engine.stats)
    toks = sum(len(r.tokens) for r in results.values())
    ledger_tokens = service.meter.served_tokens(tenant)
    billed = service.meter.total_usd(tenant)
    executor.release()

    print(f"lease {executor.lease.lease_id} ({tenant}): served "
          f"{len(results)}/{requests} requests, {toks} tokens in "
          f"{wall:.1f}s ({toks / max(wall, 1e-9):.1f} tok/s) | "
          f"prefills {stats['prefills']} ({stats['prefill_calls']} calls) "
          f"decode steps {stats['decode_steps']} "
          f"syncs/step {stats['host_syncs_decode'] / max(stats['decode_steps'], 1):.2f}")
    print(f"ledger[{tenant}]: {ledger_tokens} tokens metered, "
          f"${billed:.6f} billed across "
          f"{len([b for b in service.meter.bills if b.tenant == tenant])} line items")
    return {"results": results, "stats": stats, "wall_s": wall,
            "tokens": toks, "ledger_tokens": ledger_tokens,
            "billed_usd": billed, "service": service}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--tenant", default="serve-demo")
    ap.add_argument("--sync-every", type=int, default=1)
    ap.add_argument("--unfused", action="store_true",
                    help="legacy host-loop data plane (before/after reference)")
    args = ap.parse_args()
    out = run(args.arch, requests=args.requests, max_new=args.max_new,
              slots=args.slots, max_len=args.max_len,
              prompt_len=args.prompt_len, smoke=args.smoke,
              temperature=args.temperature, tenant=args.tenant,
              fused=not args.unfused, sync_every=args.sync_every)
    assert len(out["results"]) == args.requests
    assert out["ledger_tokens"] == out["tokens"]


if __name__ == "__main__":
    main()
