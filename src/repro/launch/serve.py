"""Serving driver — leased continuous-batching engine over a deployed model.

Serving is a first-class XaaS workload here: the driver acquires a
SERVICE-class lease from the ``InvocationService`` control plane, the lease's
deployment boots the ``ServingEngine`` (fused data plane: one compiled
program per decode step, one host sync per step), traffic flows through the
lease, and every served token lands in the tenant's accounting ledger.

``--fleet`` switches to the elastic multi-replica control plane
(``repro.fleet``): N leased replicas behind the affinity router, SLO-driven
autoscaling with BATCH preemption, and per-tenant metering aggregated across
replicas — the same objects the fleet benchmark simulates, driven live.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
      --requests 16 --max-new 32
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
      --fleet [--trace bursty|diurnal|steady] [--max-replicas 4]
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
      --fleet --disagg [--prefill-pool 1 2] [--decode-pool 1 2]
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve --arch deepseek-v3-671b --smoke \
      --mesh 1x2   # tensor/expert-parallel sharded replica
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.core import recompile, scheduler
from repro.core.invocation import InvocationService
from repro.models import transformer
from repro.serving.engine import Request
from repro.serving.sampling import SamplingConfig
from repro.serving.service import serving_container

__all__ = ["run", "run_fleet", "main"]


def run(arch_id: str, *, requests: int = 8, max_new: int = 16,
        slots: int = 4, max_len: int = 256, prompt_len: int = 24,
        smoke: bool = True, temperature: float = 0.0, seed: int = 0,
        tenant: str = "serve-demo", fused: bool = True,
        sync_every: int = 1, prefix_cache_mb: float = 0.0,
        shared_prefix_len: int = 0, spec_k: int = 0,
        spec_proposer: str = "ngram", draft_arch: str | None = None,
        page_size: int | None = None, kv_pages: int | None = None,
        kv_watermark: float = 0.05,
        prefill_chunk_tokens: int | None = None,
        artifact_store_dir: str | None = None,
        mesh: tuple[int, ...] | None = None) -> dict:
    arch = arch_id + ("-smoke" if smoke and not arch_id.endswith("-smoke") else "")
    cfg = configs.get_config(arch)
    rng = np.random.default_rng(seed)
    params = transformer.init_model(jax.random.key(seed), cfg)

    store = None
    if artifact_store_dir:
        from repro.checkpoint.store import ArtifactStore
        store = ArtifactStore(artifact_store_dir)

    spec = None
    if spec_k > 0:
        from repro.serving.speculative import SpecConfig
        spec = SpecConfig(k=spec_k, proposer=spec_proposer,
                          draft_arch=draft_arch)

    # control plane: schedule chips, deploy the container, boot the engine
    profile = recompile.PORTABLE_CPU
    if mesh is not None and int(np.prod(mesh)) > 1:
        need = int(np.prod(mesh))
        if jax.device_count() < need:
            raise SystemExit(
                f"--mesh {'x'.join(map(str, mesh))} needs {need} devices but "
                f"only {jax.device_count()} visible; on CPU set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
        profile = recompile.host_mesh_profile(tuple(mesh))
    cont = serving_container(cfg, params, slots=slots, max_len=max_len,
                             prompt_buckets=(32, 64, 128), fused=fused,
                             sync_every=sync_every,
                             prefix_cache_bytes=int(prefix_cache_mb * (1 << 20))
                             or None, spec=spec, page_size=page_size,
                             kv_pages=kv_pages, kv_watermark=kv_watermark,
                             prefill_chunk_tokens=prefill_chunk_tokens,
                             artifact_store=store,
                             mesh_shape=(tuple(mesh) if mesh is not None
                                         and int(np.prod(mesh)) > 1 else None))
    cluster = scheduler.Cluster(chips=profile.chips)
    service = InvocationService(cluster)
    # the executor is a context manager: the SERVICE lease is released on
    # every exit path (shutdown OR error), so the chips always return to the
    # cluster free pool — a leaked lease would pin them forever
    with service.acquire_serving(tenant, cont, profile) as executor:
        t0 = time.perf_counter()
        man = executor.warmup()
        boot = (man or {}).get("boot", {})
        print(f"warmup ({boot.get('path', 'cold')}-boot, "
              f"{boot.get('warmup_compiles', '?')} compiles, key "
              f"{boot.get('bundle_key', '-')}): "
              f"{time.perf_counter() - t0:.1f}s")
        if boot.get("fallthrough"):
            for why in boot["fallthrough"]:
                print(f"  boot fallthrough: {why}")
        mman = (man or {}).get("mesh")
        if mman and int(np.prod(mman["shape"])) > 1:
            print(f"mesh {'x'.join(str(d) for d in mman['shape'])} "
                  f"({','.join(mman['axes'])}) — sharded replica on "
                  f"{executor.lease.chips} leased chips")

        lead = (cfg.num_codebooks,) if cfg.frontend == "audio" else ()
        sys_prompt = rng.integers(0, cfg.vocab_size,
                                  lead + (shared_prefix_len,), dtype=np.int32)
        for i in range(requests):
            plen = int(rng.integers(prompt_len // 2, prompt_len + 1))
            prompt = rng.integers(0, cfg.vocab_size, lead + (plen,),
                                  dtype=np.int32)
            if shared_prefix_len:
                prompt = np.concatenate([sys_prompt, prompt], axis=-1)
            executor.submit(Request(request_id=i, prompt=prompt,
                                    max_new_tokens=max_new,
                                    sampling=SamplingConfig(temperature=temperature)))

        t0 = time.perf_counter()
        results = executor.run()
        wall = time.perf_counter() - t0
        stats = dict(executor.engine.stats)
        toks = sum(len(r.tokens) for r in results.values())
        ledger_tokens = service.meter.served_tokens(tenant)
        billed = service.meter.total_usd(tenant)

    assert not executor.lease.active
    assert cluster.free_chips == cluster.total_chips, (
        f"lease released but {cluster.total_chips - cluster.free_chips} "
        f"chip(s) missing from the free pool")

    print(f"lease {executor.lease.lease_id} ({tenant}): served "
          f"{len(results)}/{requests} requests, {toks} tokens in "
          f"{wall:.1f}s ({toks / max(wall, 1e-9):.1f} tok/s) | "
          f"prefills {stats['prefills']} ({stats['prefill_calls']} calls) "
          f"decode steps {stats['decode_steps']} "
          f"syncs/step {stats['host_syncs_decode'] / max(stats['decode_steps'], 1):.2f}")
    if prefix_cache_mb:
        hits, misses = stats["prefix_hits"], stats["prefix_misses"]
        print(f"prefix cache: {hits}/{hits + misses} hits "
              f"({stats['prefix_hit_tokens']} prompt tokens restored, "
              f"{stats['prefill_tokens']} padded positions prefilled)")
    if spec is not None:
        sm = executor.engine.spec_summary()
        print(f"speculative[{sm['proposer']} k={sm['k']}]: "
              f"{sm['accepted']}/{sm['drafted']} drafts accepted "
              f"({sm['acceptance_rate']:.0%}), "
              f"{sm['tokens_per_slot_step']:.2f} tokens/slot-step")
    pg = executor.engine.paged_summary()
    if pg is not None:
        print(f"paged kv[page={pg['page_size']}]: peak "
              f"{pg['peak_in_use']}/{pg['pages_total']} pages "
              f"({pg['cow_copies']} CoW copies, "
              f"{pg['cow_shared_pages']} pages shared now) | "
              f"{pg['preemptions']} preemptions, "
              f"{pg['admit_skips']} watermark skips, "
              f"{stats['chunk_prefill_calls']} chunked prefill calls")
    lat = executor.engine.latency_summary()
    print(f"latency: ttft p50 {lat['ttft_p50_s'] * 1e3:.1f}ms "
          f"p95 {lat['ttft_p95_s'] * 1e3:.1f}ms | tpot p50 "
          f"{lat['tpot_p50_s'] * 1e3:.1f}ms p95 {lat['tpot_p95_s'] * 1e3:.1f}ms")
    print(f"ledger[{tenant}]: {ledger_tokens} tokens metered, "
          f"${billed:.6f} billed across "
          f"{len([b for b in service.meter.bills if b.tenant == tenant])} line items")
    return {"results": results, "stats": stats, "wall_s": wall,
            "tokens": toks, "ledger_tokens": ledger_tokens,
            "billed_usd": billed, "service": service}


def run_fleet(arch_id: str, *, trace_kind: str = "bursty", smoke: bool = True,
              seed: int = 0, chips: int = 4, min_replicas: int = 1,
              max_replicas: int = 4, slots: int = 2, max_len: int = 64,
              duration_s: float = 24.0, batch_jobs: int = 2,
              batch_steps: int = 30, prefix_cache_mb: float = 16.0,
              shared_prefix_len: int = 0, multi_turn: bool = False,
              spec_k: int = 0, spec_proposer: str = "ngram",
              draft_arch: str | None = None, page_size: int | None = None,
              kv_pages: int | None = None,
              artifact_store_dir: str | None = None,
              mesh: tuple[int, ...] | None = None,
              mesh_options: tuple[tuple[int, ...], ...] | None = None,
              disagg: bool = False, prefill_min: int = 1,
              prefill_max: int = 2, decode_min: int = 1,
              decode_max: int = 2) -> dict:
    """Drive the elastic fleet live: same control plane the benchmark
    simulates (repro.fleet), printed as an operator would see it."""
    from repro import fleet as fl

    store = None
    if artifact_store_dir:
        from repro.checkpoint.store import ArtifactStore
        store = ArtifactStore(artifact_store_dir)

    arch = arch_id + ("-smoke" if smoke and not arch_id.endswith("-smoke") else "")
    cfg = configs.get_config(arch)
    params = transformer.init_model(jax.random.key(seed), cfg)
    makers = {"bursty": fl.bursty_trace, "diurnal": fl.diurnal_trace,
              "steady": fl.steady_trace}
    trace = makers[trace_kind](seed=seed, duration_s=duration_s,
                               prompt_median=8, prompt_lo=4, prompt_hi=16,
                               max_new_lo=4, max_new_hi=8)
    reqs = fl.materialize(trace, vocab_size=cfg.vocab_size, seed=seed + 1,
                          num_codebooks=(cfg.num_codebooks
                                         if cfg.frontend == "audio" else 0),
                          shared_prefix_len=shared_prefix_len,
                          multi_turn=multi_turn, max_prompt_len=max_len // 2)
    if disagg and page_size is None:
        page_size = 8  # disaggregation rides the paged-KV handoff plane
    fleet_cfg = fl.FleetConfig(min_replicas=min_replicas,
                               max_replicas=max_replicas, slots=slots,
                               max_len=max_len, prompt_buckets=(8, 16, 32),
                               tick_s=0.1, warm_boot_s=0.5, cold_boot_s=1.5,
                               prefix_cache_mb=prefix_cache_mb,
                               spec_k=spec_k, spec_proposer=spec_proposer,
                               spec_draft_arch=draft_arch,
                               page_size=page_size, kv_pages=kv_pages,
                               artifact_store=store,
                               mesh_shape=(tuple(mesh) if mesh else None),
                               mesh_options=mesh_options)
    if disagg:
        fm = fl.DisaggFleetManager.build(
            cfg, params, chips=chips, fleet=fleet_cfg,
            disagg=fl.DisaggConfig(prefill_min=prefill_min,
                                   prefill_max=prefill_max,
                                   decode_min=decode_min,
                                   decode_max=decode_max),
            batch_jobs=[(1, batch_steps)] * batch_jobs)
    else:
        fm = fl.FleetManager.build(
            cfg, params, chips=chips, fleet=fleet_cfg,
            batch_jobs=[(1, batch_steps)] * batch_jobs)
    t0 = time.perf_counter()
    report = fm.run_trace(reqs)
    wall = time.perf_counter() - t0

    print(f"fleet[{arch} x{trace_kind}]: {report.served}/{report.requests} "
          f"requests, {report.tokens} tokens over {report.duration_s:.1f} "
          f"virtual s ({wall:.1f}s real) | p50 {report.latency_p50_s:.2f}s "
          f"p99 {report.latency_p99_s:.2f}s | {report.serving_chip_s:.1f} "
          f"serving chip-s, utilization {report.utilization:.0%}")
    print(f"elasticity: {report.scale_ups} scale-ups, {report.scale_downs} "
          f"scale-downs, {report.lease_releases} lease releases, "
          f"{report.preemptions} batch preemptions "
          f"({report.batch.get('resumes', 0)} checkpoint-resumes)")
    if report.width_decision:
        print(f"replica width: {report.width_decision['reason']}")
    pc = report.prefix_cache
    if pc.get("enabled"):
        print(f"prefix cache: {pc['hits']}/{pc['hits'] + pc['misses']} hits "
              f"({pc['hit_tokens']} tokens restored) | router: "
              f"{pc['prefix_affinity_routes']} prefix-affinity routes, "
              f"{pc['session_affinity_routes']} session routes")
    sp = report.speculative
    if sp.get("enabled"):
        print(f"speculative: {sp['accepted']}/{sp['drafted']} drafts "
              f"accepted ({sp['acceptance_rate']:.0%}) across "
              f"{sp['steps']} verify steps")
    pk = report.paged_kv
    if pk.get("enabled"):
        print(f"paged kv: peak {pk['peak_in_use']}/{pk['pages_total']} pages "
              f"fleet-wide | {pk['cow_copies']} CoW copies, "
              f"{pk['preemptions']} preemptions, "
              f"{pk['admit_skips']} watermark skips")
    bt = report.boot
    if bt.get("paths"):
        by_path = " ".join(f"{k}x{v}" for k, v in sorted(bt["paths"].items()))
        print(f"boot ladder: {by_path} | real warmup "
              + " ".join(f"{k}={v:.2f}s"
                         for k, v in sorted(bt["wall_s_by_path"].items()))
              + f" | next boot est {bt['expected_next_boot_s']:.2f} virtual s")
    dg = report.disagg
    if dg.get("enabled"):
        ho = dg["handoff"]
        pools = dg["pools"]
        print(f"disagg: {ho['installed']}/{ho['submitted']} KV handoffs "
              f"installed ({ho['bytes'] / 1e6:.2f} MB, "
              f"{ho['sha_rejected']} sha-rejects, "
              f"{dg['fallback_submits']} fallback colocations) | pools "
              + " ".join(f"{p}={v['live']}/{v['peak']}peak"
                         f"(+{v['scale_ups']}up)"
                         for p, v in sorted(pools.items())))
        print(f"virtual ttft: p50 {report.ttft_virtual_p50_s:.2f}s "
              f"p99 {report.ttft_virtual_p99_s:.2f}s (arrival -> first token)")
    print(f"engine latency: ttft p95 {report.ttft_p95_s * 1e3:.1f}ms | "
          f"tpot p95 {report.tpot_p95_s * 1e3:.1f}ms (real wall clock)")
    for t, what in fm.timeline:
        print(f"  [{t:7.2f}s] {what}")
    for tenant in sorted(report.tokens_by_tenant):
        print(f"ledger[{tenant}]: {report.metered_by_tenant[tenant]} tokens "
              f"metered (${fm.service.meter.total_usd(tenant):.6f})")
    assert report.served == report.requests
    assert report.reconciled, "per-tenant ledger does not reconcile"
    return {"report": report, "manager": fm}


def _parse_mesh(text: str) -> tuple[int, ...]:
    try:
        shape = tuple(int(d) for d in text.lower().split("x"))
        assert shape and all(d >= 1 for d in shape)
        return shape
    except (ValueError, AssertionError):
        raise argparse.ArgumentTypeError(
            f"mesh {text!r} is not DxM (e.g. 1x2)") from None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--tenant", default="serve-demo")
    ap.add_argument("--sync-every", type=int, default=1)
    ap.add_argument("--unfused", action="store_true",
                    help="legacy host-loop data plane (before/after reference)")
    ap.add_argument("--fleet", action="store_true",
                    help="elastic multi-replica fleet mode")
    ap.add_argument("--trace", default="bursty",
                    choices=["bursty", "diurnal", "steady"])
    ap.add_argument("--duration", type=float, default=24.0)
    ap.add_argument("--chips", type=int, default=4)
    ap.add_argument("--min-replicas", type=int, default=1)
    ap.add_argument("--max-replicas", type=int, default=4)
    ap.add_argument("--batch-jobs", type=int, default=2)
    ap.add_argument("--prefix-cache-mb", type=float, default=16.0,
                    help="radix prefix-cache byte budget per engine/replica "
                         "(0 disables KV reuse)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="tokens of shared system prompt prepended to every "
                         "request (per tenant in fleet mode)")
    ap.add_argument("--multi-turn", action="store_true",
                    help="fleet sessions extend their previous prompt")
    ap.add_argument("--page-size", type=int, default=None,
                    help="paged KV: page granularity in tokens (unset keeps "
                         "contiguous per-slot KV strips)")
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="paged KV pool size in pages incl. the null page "
                         "(unset = full provisioning, slots*max_len tokens)")
    ap.add_argument("--kv-watermark", type=float, default=0.05,
                    help="free-page fraction admission keeps in reserve")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="max tokens per chunked-prefill step (paged mode)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: drafts per step (0 disables)")
    ap.add_argument("--spec-proposer", default="ngram",
                    choices=["ngram", "draft"])
    ap.add_argument("--draft-arch", default=None,
                    help="draft model config id (with --spec-proposer draft)")
    ap.add_argument("--mesh", type=_parse_mesh, default=None, metavar="DxM",
                    help="per-replica mesh geometry, e.g. 1x2: shards the "
                         "data plane tensor/expert-parallel across that many "
                         "chips (on CPU set XLA_FLAGS=--xla_force_host_"
                         "platform_device_count=N first). In --fleet mode "
                         "fixes every replica's width; unset keeps the "
                         "single-device portability floor")
    ap.add_argument("--mesh-options", default=None, metavar="DxM,DxM,...",
                    help="with --fleet: candidate replica widths; the "
                         "manager picks the narrowest whose per-chip "
                         "footprint fits HBM and logs the width-vs-count "
                         "decision in the timeline")
    ap.add_argument("--artifact-store", default=None, metavar="DIR",
                    help="persistent AOT artifact store directory: first run "
                         "cold-boots and persists serialized executables, "
                         "later runs IR-boot from them (docs/ir-containers.md)")
    ap.add_argument("--disagg", action="store_true",
                    help="with --fleet: split into prefill/decode pools with "
                         "KV page handoff (docs/disaggregation.md)")
    ap.add_argument("--prefill-pool", type=int, nargs=2, default=(1, 2),
                    metavar=("MIN", "MAX"),
                    help="disagg prefill pool size bounds")
    ap.add_argument("--decode-pool", type=int, nargs=2, default=(1, 2),
                    metavar=("MIN", "MAX"),
                    help="disagg decode pool size bounds")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.fleet:
        run_fleet(args.arch, trace_kind=args.trace, smoke=args.smoke,
                  seed=args.seed, chips=args.chips,
                  min_replicas=args.min_replicas,
                  max_replicas=args.max_replicas,
                  duration_s=args.duration, batch_jobs=args.batch_jobs,
                  prefix_cache_mb=args.prefix_cache_mb,
                  shared_prefix_len=args.shared_prefix,
                  multi_turn=args.multi_turn, spec_k=args.spec_k,
                  spec_proposer=args.spec_proposer,
                  draft_arch=args.draft_arch, page_size=args.page_size,
                  kv_pages=args.kv_pages,
                  artifact_store_dir=args.artifact_store,
                  mesh=args.mesh,
                  mesh_options=(tuple(_parse_mesh(m) for m in
                                      args.mesh_options.split(","))
                                if args.mesh_options else None),
                  disagg=args.disagg,
                  prefill_min=args.prefill_pool[0],
                  prefill_max=args.prefill_pool[1],
                  decode_min=args.decode_pool[0],
                  decode_max=args.decode_pool[1])
        return
    out = run(args.arch, requests=args.requests, max_new=args.max_new,
              slots=args.slots, max_len=args.max_len,
              prompt_len=args.prompt_len, smoke=args.smoke,
              temperature=args.temperature, tenant=args.tenant,
              fused=not args.unfused, sync_every=args.sync_every,
              prefix_cache_mb=args.prefix_cache_mb,
              shared_prefix_len=args.shared_prefix, spec_k=args.spec_k,
              spec_proposer=args.spec_proposer, draft_arch=args.draft_arch,
              page_size=args.page_size, kv_pages=args.kv_pages,
              kv_watermark=args.kv_watermark,
              prefill_chunk_tokens=args.prefill_chunk,
              artifact_store_dir=args.artifact_store,
              mesh=args.mesh)
    assert len(out["results"]) == args.requests
    assert out["ledger_tokens"] == out["tokens"]


if __name__ == "__main__":
    main()
