"""Production mesh construction (assignment-fixed shapes).

``make_production_mesh`` is a FUNCTION so importing this module never touches
jax device state — the dry-run sets XLA_FLAGS for 512 stand-in host devices
before any jax import, and only then calls this.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "mesh_axis_sizes", "batch_axes"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: (data=16, model=16) = 256 chips of TPU v5e.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; `pod` crosses DCN."""
    import math

    import numpy as np

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run via launch/dryrun.py (it forces 512 stand-in host devices)")
    # more devices than the mesh needs (e.g. single-pod mesh in a 512-device
    # dry-run process): take a prefix slice
    return jax.sharding.Mesh(np.array(devices[:n]).reshape(shape), axes)


def mesh_axis_sizes(mesh: jax.sharding.Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(mesh: jax.sharding.Mesh):
    """The mesh axes the global batch shards over (pure DP across pods)."""
    return ("pod", "data") if "pod" in mesh.axis_names else "data"
