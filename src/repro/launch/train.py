"""End-to-end training driver — the XaaS train entrypoint.

Runs REAL steps (this is not the dry-run): builds the data pipeline, deploys
the train-step container, and executes the fault-tolerant training loop with
checkpointing. On this CPU container it is exercised with ``--smoke`` (reduced
configs); the same code path launches the production mesh on TPU metal.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import pathlib
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint import store as ckpt
from repro.core import hooks
from repro.data import pipeline as datalib
from repro.distributed import sharding as shd
from repro.ft import manager as ftlib
from repro.models import frontends
from repro.training import train_step as ts

__all__ = ["run", "main"]


def run(arch_id: str, *, steps: int = 20, batch: int = 8, seq: int = 64,
        smoke: bool = True, microbatches: int = 1, optimizer: str = "adamw",
        ckpt_dir: str | None = None, ckpt_every: int = 0,
        resume: bool = False, seed: int = 0, log_every: int = 10,
        hook_overrides: dict | None = None) -> dict:
    arch = arch_id + ("-smoke" if smoke and not arch_id.endswith("-smoke") else "")
    cfg = configs.get_config(arch)
    tcfg = ts.TrainConfig(microbatches=microbatches, optimizer=optimizer)

    data = datalib.SyntheticLM(datalib.DataConfig(
        global_batch=batch, seq_len=seq, vocab_size=cfg.vocab_size, seed=seed,
        num_codebooks=cfg.num_codebooks if cfg.frontend == "audio" else 0,
        num_image_tokens=cfg.num_image_tokens if cfg.frontend == "vlm" else 0))
    binding = hooks.bind(None, overrides=hook_overrides or {})

    state = ts.init_train_state(jax.random.key(seed), cfg, tcfg)
    start_step = 0
    store = ckpt.CheckpointStore(str(ckpt_dir)) if ckpt_dir else None
    if store and resume and store.latest_step() is not None:
        state, meta = store.restore(state)
        start_step = int(meta.get("data_step", store.latest_step()))

    raw_step = ts.make_train_step(cfg, tcfg)

    @jax.jit
    def step_fn(state, batch_):
        with hooks.use(binding):
            return raw_step(state, batch_)

    metrics_hist = []
    t0 = time.perf_counter()
    for i in range(start_step, steps):
        batch_ = data.batch(i)
        state, metrics = step_fn(state, batch_)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()
                 if jnp.ndim(v) == 0}
            metrics_hist.append({"step": i, **m})
            print(f"step {i:5d} loss {m['loss']:.4f} "
                  f"lr {m.get('lr', 0):.2e} gnorm {m.get('grad_norm', 0):.3f}")
        if store and ckpt_every and (i + 1) % ckpt_every == 0:
            store.save(i + 1, state, meta={"data_step": i + 1})
    if store:
        store.wait()
    wall = time.perf_counter() - t0
    print(f"{steps - start_step} steps in {wall:.1f}s "
          f"({(steps - start_step) / max(wall, 1e-9):.2f} steps/s)")
    return {"final_loss": metrics_hist[-1]["loss"] if metrics_hist else None,
            "history": metrics_hist, "wall_s": wall, "state": state}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = run(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
              smoke=args.smoke, microbatches=args.microbatches,
              optimizer=args.optimizer, ckpt_dir=args.ckpt_dir,
              ckpt_every=args.ckpt_every, resume=args.resume, seed=args.seed)
    print(f"final loss: {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
